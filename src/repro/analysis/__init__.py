"""repro.analysis: static schema + fabric-communication analyzer.

Proves configs safe before anything runs — the schema is data (the
paper's thesis), so wire bounds, ROM/stack fits, tag soundness,
field-width budgets, per-link fabric load, and credit/QoS liveness are
all computable at construction time.  ``python -m repro.analysis`` runs
every pass over every shipped target; ``Fabric(analyze=True)`` /
``serve_requests_*(analyze=True)`` run them inline and raise on ERROR
findings with the rule's fix hint.

Import discipline: ``findings`` and ``rules`` load eagerly (the fabric
package imports them at module top); everything touching the fabric
package itself (``fabric_passes``, ``comm``, ``targets``) loads lazily
via PEP 562 so ``repro.fabric -> repro.analysis.rules`` never re-enters a
half-initialized fabric.
"""
from __future__ import annotations

from .findings import (
    Finding,
    Report,
    Rule,
    RULES,
    Severity,
    assert_clean,
    finding,
)
from .rules import (
    MAX_LIST_LEVEL,
    fabric_config_findings,
    list_level_error,
    max_ranks_error,
)
from .schema_passes import (
    WireBounds,
    analyze_plan_caps,
    analyze_schema,
    analyze_stream_schema,
    message_wire_len,
    wire_bounds,
)

__all__ = [
    "Finding", "Report", "Rule", "RULES", "Severity", "assert_clean",
    "finding",
    "MAX_LIST_LEVEL", "fabric_config_findings", "list_level_error",
    "max_ranks_error",
    "WireBounds", "analyze_plan_caps", "analyze_schema",
    "analyze_stream_schema", "message_wire_len", "wire_bounds",
    # lazy (fabric-touching):
    "analyze_fabric", "analyze_fabric_values", "analyze_demand",
    "analyze_sends", "demand_link_loads", "bounds_from_loads",
    "busiest_links", "total_frames", "LinkLoad",
    "analyze_model_config", "run_all",
]

_LAZY = {
    "analyze_fabric": "fabric_passes",
    "analyze_fabric_values": "fabric_passes",
    "analyze_demand": "fabric_passes",
    "analyze_sends": "fabric_passes",
    "demand_link_loads": "comm",
    "bounds_from_loads": "comm",
    "busiest_links": "comm",
    "total_frames": "comm",
    "LinkLoad": "comm",
    "analyze_model_config": "config_passes",
    "run_all": "__main__",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f".{mod}", __name__), name)
