"""Static per-(link, direction) communication analysis (the ``plan_steps``
machinery, factored out of ``fabric/router.py``).

Frames route dimension-ordered, so while a frame crosses axis ``ai`` its
other coordinates are pinned (axes before ``ai`` already at the
destination, axes after still at the source); that tuple names the
physical ring the frame rides.  Frames on different rings — or moving in
opposite directions on one ring — never compete for a link, so per-axis
``{(ring, direction): LinkLoad}`` is the complete static load matrix of a
demand: :func:`demand_link_loads` builds it, :func:`bounds_from_loads`
turns it into the per-axis (scan steps, direction mask) bounds.
``Router.plan_steps`` composes exactly these two functions, so the load
matrix the analyzer reports and the scan bounds the router jits from can
never disagree (ROADMAP item 4 keys the self-tuning fabric on this
signature).

Pure host integer math — importable and runnable with no devices.
"""
from __future__ import annotations

# NOTE: these constants are defined BEFORE any intra-repo import:
# fabric/router.py re-exports them at its module top, which may execute
# while THIS module is still initializing (analysis -> fabric -> router
# import chain), and a partially-initialized module only exposes what ran
# before the cycle re-entered.
#: direction masks for the per-axis scan bounds
DIR_FWD, DIR_BWD = 1, 2

import math  # noqa: E402
from dataclasses import dataclass  # noqa: E402
from typing import Dict, List, Optional, Sequence, Tuple  # noqa: E402

from ..fabric.frames import frame_capacity  # noqa: E402

#: one axis of the load matrix: {(ring, direction): LinkLoad}
AxisLoads = Dict[Tuple[Tuple[int, int], int], "LinkLoad"]


@dataclass(frozen=True)
class LinkLoad:
    """Static demand on one (ring, direction) contention set."""

    frames: int  # frames riding this directed ring this tick
    max_hops: int  # farthest distance any of them travels on it


def demand_link_loads(
    sizes: Sequence[int],
    srcs: Sequence[int],
    dsts: Sequence[int],
    counts: Sequence[int],
    adaptive: bool,
) -> Tuple[AxisLoads, ...]:
    """The static load matrix of a demand: per axis, frames and max hops
    per (ring, direction) contention set.

    ``counts`` is in FRAMES (use :func:`demand_from_sends` /
    ``frame_capacity`` to derive it from message wires).  The ring id is
    ``(dst // (stride * n), src % stride)`` — axes before the current one
    already at the destination coordinates, axes after still at the
    source's — and with ``adaptive`` routing a frame whose +1 distance
    exceeds half the ring rides the -1 direction instead.
    """
    out: List[AxisLoads] = []
    for ai, n in enumerate(sizes):
        group: AxisLoads = {}
        if n == 1:
            out.append(group)
            continue
        stride = math.prod(sizes[ai + 1:])
        for s, d, cnt in zip(srcs, dsts, counts):
            sc = (s // stride) % n
            dc = (d // stride) % n
            fwd = (dc - sc) % n
            if fwd == 0 or cnt == 0:
                continue
            ring = (d // (stride * n), s % stride)
            if adaptive and fwd > n // 2:
                key, hops = (ring, DIR_BWD), n - fwd
            else:
                key, hops = (ring, DIR_FWD), fwd
            prev = group.get(key)
            group[key] = LinkLoad(
                cnt + (prev.frames if prev else 0),
                max(hops, prev.max_hops if prev else 0),
            )
        out.append(group)
    return tuple(out)


def bounds_from_loads(
    loads: Tuple[AxisLoads, ...],
    sizes: Sequence[int],
    credits: int,
    defect: int,
    defaults: Sequence[Tuple[int, int]],
) -> Tuple[Tuple[int, int], ...]:
    """Per-axis (scan steps, direction mask) from a load matrix.

    The busiest-contention-set bound per (ring, direction) is
    ``ceil(frames / credits) + max_hops + 1``; with defection enabled
    (``defect > 0``) a ring whose total load exceeds the per-step credit
    budget can starve frames into the opposite direction, so its two
    direction groups merge under the bound ``ceil(ring_frames / credits)
    + (n - 1) + defect + 1`` and both directions stay live.  Results are
    rounded up to an even step count (jit-cache bucketing) and never
    exceed ``defaults`` (the demand-blind worst case).
    """
    out: List[Tuple[int, int]] = []
    for ai, n in enumerate(sizes):
        group = loads[ai]
        if n == 1 or not group:
            out.append((0, 0))
            continue
        bounds: List[int] = []
        dirs = 0
        if defect:
            ring_frames: Dict[Tuple[int, int], int] = {}
            for (ring, _), ll in group.items():
                ring_frames[ring] = ring_frames.get(ring, 0) + ll.frames
            for ring, load in ring_frames.items():
                if load > credits:  # starvation (so defection) possible
                    bounds.append(-(-load // credits) + (n - 1) + defect + 1)
                    dirs |= DIR_FWD | DIR_BWD
                else:
                    for dmask in (DIR_FWD, DIR_BWD):
                        ll = group.get((ring, dmask))
                        if ll is not None:
                            bounds.append(
                                -(-ll.frames // credits) + ll.max_hops + 1
                            )
                            dirs |= dmask
        else:
            for (_, dmask), ll in group.items():
                bounds.append(-(-ll.frames // credits) + ll.max_hops + 1)
                dirs |= dmask
        steps = max(bounds)
        steps = min(steps + (steps % 2), defaults[ai][0])  # even bucket
        out.append((steps, dirs))
    return tuple(out)


def demand_from_sends(
    sends: Sequence[Tuple], frame_phits: int,
) -> Tuple[List[int], List[int], List[int]]:
    """(srcs, dsts, frame counts) of pending ``(src, dst, wire, ...)``
    sends — frames per message via ``frame_capacity`` (terminator
    included), matching what the mailbox will actually inject."""
    srcs = [s[0] for s in sends]
    dsts = [s[1] for s in sends]
    counts = [frame_capacity(len(s[2]), frame_phits) for s in sends]
    return srcs, dsts, counts


def busiest_links(
    loads: Tuple[AxisLoads, ...], top: int = 3,
) -> List[Tuple[int, Tuple[int, int], int, int, int]]:
    """The ``top`` most-loaded (axis, ring, direction) entries as
    ``(axis, ring, direction, frames, max_hops)`` — the human-report view
    of the load matrix."""
    rows = [
        (ai, ring, dmask, ll.frames, ll.max_hops)
        for ai, group in enumerate(loads)
        for (ring, dmask), ll in group.items()
    ]
    rows.sort(key=lambda r: (-r[3], r[0], r[1], r[2]))
    return rows[:top]


def total_frames(loads: Tuple[AxisLoads, ...],
                 axis: Optional[int] = None) -> int:
    """Frames crossing one axis (or the busiest axis when None)."""
    sums = [sum(ll.frames for ll in g.values()) for g in loads] or [0]
    return sums[axis] if axis is not None else max(sums)
