"""Findings model + rule catalog of the static analyzer.

A :class:`Finding` is one violated (or advisory) property: a rule id from
the :data:`RULES` catalog, a severity, the location it anchors to (a
schema/target name, a config field, a demand entry), the human message,
and the rule's fix hint.  Pass functions (``schema_passes``,
``fabric_passes``, ``config_passes``) return lists of findings; a
:class:`Report` aggregates them for the CLI / the ``analyze=True`` hooks.

The catalog is the single place a rule's severity and fix hint are
defined, so the CLI report, the README rule table, and the exceptions the
runtime hooks raise can never disagree about what a rule means.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional


class Severity(enum.IntEnum):
    """ERROR = the config/schema WILL fail at runtime; WARN = it can fail
    or silently misbehave under some demand; INFO = advisory only."""

    INFO = 0
    WARN = 1
    ERROR = 2


@dataclass(frozen=True)
class Rule:
    """One catalog entry: what the rule proves when it does NOT fire."""

    id: str
    severity: Severity
    proves: str  # the property that holds when the rule is silent
    hint: str  # how to fix a firing


#: every rule the analyzer can emit, keyed by id (see README "Static
#: analysis" for the rendered table)
RULES: Dict[str, Rule] = {
    r.id: r
    for r in [
        # -- schema passes (core/idl.py + schema_tree.py) -------------------
        Rule("schema-undefined-struct", Severity.ERROR,
             "every StructRef resolves to a defined struct",
             "define the struct or fix the reference name"),
        Rule("schema-recursive", Severity.ERROR,
             "the schema tree is finite (no recursive struct cycles)",
             "break the cycle — HGum messages are finite trees"),
        Rule("schema-empty-struct", Severity.ERROR,
             "struct inlining never produces an empty node group",
             "give the struct at least one field or drop the reference"),
        Rule("schema-unreachable-struct", Severity.WARN,
             "every defined struct is reachable from the top message",
             "delete the dead struct or reference it from the message"),
        Rule("schema-rom-capacity", Severity.ERROR,
             "the flattened schema tree fits the schema-ROM capacity",
             "split the message into smaller schemas (or raise "
             "ROM_CAPACITY together with the hardware BRAM budget)"),
        Rule("schema-stack-depth", Severity.ERROR,
             "container nesting fits the DES/SER context-stack capacity",
             "flatten the nesting (or raise STACK_CAPACITY together with "
             "the hardware stack)"),
        Rule("schema-list-level-overflow", Severity.ERROR,
             "List nesting depth fits the u8 ListLevel header lane",
             "keep List nesting depth <= 255"),
        Rule("client-tag-collision", Severity.ERROR,
             "each client-schema tag names a unique token path",
             "assign every tagged path a distinct tag — the DES emits "
             "(tag, value) pairs, so shared tags are indistinguishable"),
        Rule("client-unknown-path", Severity.ERROR,
             "every client-schema path names a real token of the schema",
             "fix the path (fields dotted from the top struct; container "
             "suffixes are .start/.end/.elem)"),
        Rule("plan-cap-count-width", Severity.ERROR,
             "decode-plan caps fit the u32 count field",
             "keep per-path caps below 2**32"),
        Rule("plan-cap-overflow", Severity.WARN,
             "nested caps hold at least one element per enclosing "
             "container instance",
             "raise the inner path's cap to >= the enclosing container's "
             "cap (plan_from_wire raises the moment real instances "
             "exceed a cap)"),
        # -- fabric / communication passes ---------------------------------
        Rule("fabric-config-positive", Severity.ERROR,
             "frame_phits and credits are positive",
             "set frame_phits >= 1 and credits >= 1"),
        Rule("fabric-routing-mode", Severity.ERROR,
             "routing names a known discipline",
             "use routing='shortest' or routing='dimension'"),
        Rule("fabric-defect-config", Severity.ERROR,
             "defection is only enabled where it can act",
             "set defect_after >= 0 and pair defect_after > 0 with "
             "routing='shortest' (only adaptive frames may defect)"),
        Rule("fabric-defect-bound", Severity.WARN,
             "a starved frame defects before it could have ridden the "
             "whole ring",
             "set defect_after below the ring size — a longer wait "
             "inflates the scan bound past the dimension-order worst case "
             "with no path left to escape to"),
        Rule("fabric-qos-weights", Severity.ERROR,
             "QoS weights are positive",
             "use weights >= 1 (drop qos_weights for single-class FIFO)"),
        Rule("fabric-credit-deadlock", Severity.ERROR,
             "every QoS class holds at least one link credit",
             "raise credits to >= len(qos_weights) or merge classes — a "
             "zero-credit class can never inject, its frames wait "
             "forever, and the tick never drains"),
        Rule("fabric-qos-quota-floor", Severity.WARN,
             "no class's largest-remainder credit share floors to zero",
             "rebalance qos_weights or raise credits so every class "
             "earns >= 1 credit by weight instead of surviving on the "
             "floor bump (a floored class runs at 1 credit/step however "
             "congested its traffic)"),
        Rule("fabric-max-ranks", Severity.ERROR,
             "the fabric's rank count fits the route word's u7 src lane",
             "keep n_ranks <= MAX_RANKS (128) or widen the route word"),
        Rule("fabric-list-level", Severity.ERROR,
             "send ListLevels fit the u8 header lane",
             "keep list_level in [0, 255] — larger values wrap and alias "
             "another tenant's QoS class"),
        Rule("fabric-rank-range", Severity.ERROR,
             "every demand entry's src/dst is a real rank",
             "fix the demand matrix — an out-of-range dst is "
             "undeliverable and fails the whole tick"),
        Rule("fabric-rx-overflow", Severity.ERROR,
             "per-rank deliveries fit the configured rx_frames capacity",
             "raise FabricConfig.rx_frames (or leave it None to size "
             "from the tick) — overflow drops frames and fails the tick"),
        Rule("fabric-seq-window", Severity.ERROR,
             "one tick's frames per (src, dst) stream fit the u16 seq "
             "window",
             "split the burst across ticks — seq aliasing breaks the "
             "receiver's reorder-by-seq reassembly"),
        Rule("fabric-arq-config", Severity.ERROR,
             "the ARQ knobs are in range (timeout >= 1, retries >= 0, "
             "buffer >= 1, control level fits the u8 lane)",
             "fix the out-of-range ARQ field (or set arq=False)"),
        Rule("fabric-arq-window", Severity.ERROR,
             "the retransmit buffer stays inside half the u16 seq window "
             "so cumulative ACKs are unambiguous",
             "keep arq_buffer < SEQ_MOD // 2 — past that a retransmit "
             "may alias a message half a window away"),
        Rule("fabric-arq-control-class", Severity.ERROR,
             "the ACK/NACK control class earns a nonzero "
             "weight-proportional credit share",
             "raise the control class's qos weight (or move arq_level to "
             "a heavier class) — recovery liveness depends on control "
             "frames draining every step, not on the floor bump"),
        Rule("fabric-arq-timeout", Severity.ERROR,
             "skip and blackout-detection horizons sit above the "
             "retransmit timeout",
             "set arq_skip_after and suspect_after > retransmit_timeout "
             "so a healthy peer's first retransmit can arrive before it "
             "is skipped or suspected"),
        # -- stream plane ---------------------------------------------------
        Rule("stream-chunk-tokens", Severity.ERROR,
             "a chunk's token count fits the count-word sanity bound",
             "split the step's tokens across chunks below "
             "MAX_CHUNK_TOKENS"),
        Rule("stream-id-width", Severity.ERROR,
             "stream ids fit the (request:u16 | prompt:u16) packing",
             "serve fewer than 2**16 requests (and prompts per request) "
             "per streaming call"),
        Rule("stream-meta-budget", Severity.ERROR,
             "fragment-meta bit budgets fit their u32 wire words",
             "keep id_bits and step_bits in [1, 32] — stream_id, step, "
             "and flags each ride exactly one u32 fragment-meta word"),
        Rule("stream-elem-size", Severity.ERROR,
             "stream elements are fixed-size and the largest fragment "
             "stays u32 word-addressable",
             "give the stream element a static wire size (no nested "
             "containers) small enough that MAX_CHUNK_TOKENS elements "
             "stay below 2**32 words"),
        # -- model configs --------------------------------------------------
        Rule("config-moe-topk", Severity.ERROR,
             "the MoE router's top-k never exceeds the expert count",
             "set moe_topk <= moe_experts"),
        Rule("config-layer-pattern", Severity.ERROR,
             "layer_pattern names a known layer plan",
             "use one of the ModelConfig.layer_kinds patterns"),
        Rule("config-head-grouping", Severity.ERROR,
             "KV head grouping divides evenly (GQA repeats n_heads/n_kv)",
             "pick n_kv dividing n_heads and, when head_dim is unset, "
             "n_heads dividing d_model"),
    ]
}


@dataclass(frozen=True)
class Finding:
    """One rule firing at one location."""

    rule: str
    severity: Severity
    location: str
    message: str
    hint: str

    def render(self) -> str:
        return (f"[{self.severity.name}] {self.rule} @ {self.location}: "
                f"{self.message} (fix: {self.hint})")


def finding(rule_id: str, location: str, message: str,
            hint: Optional[str] = None) -> Finding:
    """Build a Finding with severity + hint pulled from the catalog."""
    rule = RULES[rule_id]
    return Finding(rule_id, rule.severity, location, message,
                   hint if hint is not None else rule.hint)


@dataclass
class Report:
    """Aggregated findings across every analyzed target."""

    findings: List[Finding] = field(default_factory=list)
    targets: int = 0  # targets analyzed (for the summary line)

    def extend(self, fs: List[Finding]) -> List[Finding]:
        self.findings.extend(fs)
        return fs

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity is Severity.ERROR]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity is Severity.WARN]

    @property
    def clean(self) -> bool:
        """No ERROR and no WARN findings."""
        return not self.errors and not self.warnings

    def render(self) -> str:
        lines = [f.render() for f in sorted(
            self.findings, key=lambda f: (-int(f.severity), f.location)
        )]
        lines.append(
            f"{self.targets} targets analyzed: {len(self.errors)} errors, "
            f"{len(self.warnings)} warnings"
        )
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {
            "targets": self.targets,
            "errors": len(self.errors),
            "warnings": len(self.warnings),
            "findings": [
                {
                    "rule": f.rule,
                    "severity": f.severity.name,
                    "location": f.location,
                    "message": f.message,
                    "hint": f.hint,
                }
                for f in self.findings
            ],
            "rules": {
                r.id: {
                    "severity": r.severity.name,
                    "proves": r.proves,
                    "hint": r.hint,
                }
                for r in RULES.values()
            },
        }


def assert_clean(fs: List[Finding], context: str) -> List[Finding]:
    """Raise ValueError on any ERROR finding (the ``analyze=True`` hook
    contract: fail with the rule's fix hint before any device work)."""
    errors = [f for f in fs if f.severity is Severity.ERROR]
    if errors:
        raise ValueError(
            f"{context}: static analysis found "
            f"{len(errors)} error(s):\n" +
            "\n".join("  " + f.render() for f in errors)
        )
    return fs
