"""Model-config passes: static invariants of shipped ModelConfigs.

The model layer assumes these silently (``layer_kinds`` raises only when
called, GQA repeats ``n_heads // n_kv`` heads, the MoE router top-ks over
``moe_experts`` logits); the analyzer states them once and checks every
shipped config before a forward pass exists to crash.
"""
from __future__ import annotations

from typing import List, Optional

from .findings import Finding, finding


def analyze_model_config(cfg, location: Optional[str] = None) -> List[Finding]:
    """Analyze one :class:`~repro.configs.base.ModelConfig`."""
    loc = location or cfg.name
    fs: List[Finding] = []
    try:
        cfg.layer_kinds()
        cfg.ffn_kinds()
    except ValueError as e:
        fs.append(finding("config-layer-pattern", loc, str(e)))
    if cfg.moe_experts > 0 and cfg.moe_topk > cfg.moe_experts:
        fs.append(finding(
            "config-moe-topk", loc,
            f"moe_topk={cfg.moe_topk} exceeds moe_experts="
            f"{cfg.moe_experts}: the router cannot pick more experts "
            f"than exist",
        ))
    if cfg.n_kv < 1 or cfg.n_heads % cfg.n_kv != 0:
        fs.append(finding(
            "config-head-grouping", loc,
            f"n_kv={cfg.n_kv} does not divide n_heads={cfg.n_heads}: GQA "
            f"repeats each KV head n_heads/n_kv times",
        ))
    if cfg.head_dim is None and cfg.d_model % cfg.n_heads != 0:
        fs.append(finding(
            "config-head-grouping", loc,
            f"head_dim is unset and n_heads={cfg.n_heads} does not "
            f"divide d_model={cfg.d_model}",
        ))
    return fs
