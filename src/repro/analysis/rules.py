"""Shared primitive validation rules: runtime checks == analyzer findings.

These functions are the SINGLE source of truth for checks that used to be
duplicated across ``Fabric.__init__`` / ``Router.__init__`` (the
MAX_RANKS route-word budget), ``Fabric.send`` (the u8 ``list_level``
lane), and ``FabricConfig.__post_init__`` (the config invariants).  The
runtime call sites raise exactly the message a function here returns and
the analyzer wraps the same message in a :class:`~.findings.Finding`, so
the error a user hits at runtime and the finding ``python -m
repro.analysis`` reports are literally the same words — and each check is
tested once.

Import discipline: ``fabric/router.py`` and ``fabric/mailbox.py`` import
this module at module top, so it must be importable BEFORE
``repro.fabric`` finishes initializing — anything from the fabric package
is therefore imported lazily inside the functions (by call time the
packages are fully loaded).
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .findings import Finding, finding

#: u8 budget of the frame header's ListLevel lane (``frames.HDR_LEVEL``)
MAX_LIST_LEVEL = 255


def max_ranks_error(n_ranks: int) -> Optional[str]:
    """Unified MAX_RANKS message (rule ``fabric-max-ranks``), raised
    verbatim by both ``Fabric.__init__`` and ``Router.__init__``."""
    from ..fabric.frames import MAX_RANKS

    if n_ranks <= MAX_RANKS:
        return None
    return (
        f"fabric of {n_ranks} ranks exceeds MAX_RANKS={MAX_RANKS}: the "
        f"route word's src field is a u7 lane (frames.py packs "
        f"adaptive:u1|src:u7|dst:u8|seq:u16), so ranks beyond {MAX_RANKS} "
        f"would silently alias rank (r % {MAX_RANKS}) and misdeliver "
        f"frames"
    )


def list_level_error(list_level) -> Optional[str]:
    """Unified ``list_level`` range message (rule ``fabric-list-level``),
    raised verbatim by ``Fabric.send``: the ListLevel header lane is
    u8-budgeted, and an out-of-range level would wrap silently and alias
    another tenant's QoS class (the router keys credit classes on
    ``level % n_classes``)."""
    if isinstance(list_level, (int, np.integer)) and not isinstance(
        list_level, bool
    ) and 0 <= int(list_level) <= MAX_LIST_LEVEL:
        return None
    return (
        f"list_level must be an int in [0, {MAX_LIST_LEVEL}], got "
        f"{list_level!r}"
    )


def fabric_config_findings(
    frame_phits: int,
    credits: int,
    routing: str,
    defect_after: int,
    qos_weights: Optional[Tuple[int, ...]],
    sizes: Optional[Sequence[int]] = None,
    location: str = "FabricConfig",
) -> List[Finding]:
    """Every static finding derivable from FabricConfig fields alone.

    ``FabricConfig.__post_init__`` raises the first ERROR's message, so
    runtime construction and the analyzer agree word for word; WARN-level
    findings (quota floors, defection bounds — the latter only when the
    mesh ``sizes`` are known) surface exclusively through the analyzer.
    """
    fs: List[Finding] = []
    if frame_phits < 1 or credits < 1:
        fs.append(finding(
            "fabric-config-positive", location,
            f"frame_phits/credits must be >= 1, got "
            f"{frame_phits}/{credits}",
        ))
    if routing not in ("shortest", "dimension"):
        fs.append(finding(
            "fabric-routing-mode", location,
            f"routing must be 'shortest' or 'dimension', got {routing!r}",
        ))
    if defect_after < 0:
        fs.append(finding(
            "fabric-defect-config", location,
            f"defect_after must be >= 0, got {defect_after}",
        ))
    if defect_after > 0 and routing == "dimension":
        fs.append(finding(
            "fabric-defect-config", location,
            "defect_after needs routing='shortest': only frames whose "
            "route word carries the adaptive bit may defect, and "
            "dimension-order frames never do",
        ))
    if qos_weights is not None:
        if len(qos_weights) < 1 or any(w < 1 for w in qos_weights):
            fs.append(finding(
                "fabric-qos-weights", location,
                f"qos_weights must be positive, got {qos_weights}",
            ))
        elif credits >= 1:
            if credits < len(qos_weights):
                fs.append(finding(
                    "fabric-credit-deadlock", location,
                    f"need credits >= qos classes so every class holds at "
                    f"least one credit, got credits={credits} for "
                    f"{len(qos_weights)} classes",
                ))
            else:
                # largest-remainder zero-quota classes: a class whose raw
                # share floors to 0 survives only by the >= 1 bump
                total = sum(qos_weights)
                floored = [
                    c for c, w in enumerate(qos_weights)
                    if math.floor(credits * w / total) == 0
                ]
                if floored:
                    fs.append(finding(
                        "fabric-qos-quota-floor", location,
                        f"classes {floored} earn a zero largest-remainder "
                        f"share of {credits} credits under weights "
                        f"{tuple(qos_weights)} and run on the 1-credit "
                        f"floor",
                    ))
    if (
        defect_after > 0 and routing == "shortest" and sizes
        and any(n > 1 and defect_after >= n for n in sizes)
    ):
        fs.append(finding(
            "fabric-defect-bound", location,
            f"defect_after={defect_after} is >= a ring size in "
            f"{tuple(sizes)}: a starved frame waits longer than riding "
            f"the whole ring the long way, and the scan bound inflates "
            f"past the dimension-order worst case",
        ))
    return fs
