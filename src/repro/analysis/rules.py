"""Shared primitive validation rules: runtime checks == analyzer findings.

These functions are the SINGLE source of truth for checks that used to be
duplicated across ``Fabric.__init__`` / ``Router.__init__`` (the
MAX_RANKS route-word budget), ``Fabric.send`` (the u8 ``list_level``
lane), and ``FabricConfig.__post_init__`` (the config invariants).  The
runtime call sites raise exactly the message a function here returns and
the analyzer wraps the same message in a :class:`~.findings.Finding`, so
the error a user hits at runtime and the finding ``python -m
repro.analysis`` reports are literally the same words — and each check is
tested once.

Import discipline: ``fabric/router.py`` and ``fabric/mailbox.py`` import
this module at module top, so it must be importable BEFORE
``repro.fabric`` finishes initializing — anything from the fabric package
is therefore imported lazily inside the functions (by call time the
packages are fully loaded).
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .findings import Finding, finding

#: u8 budget of the frame header's ListLevel lane (``frames.HDR_LEVEL``)
MAX_LIST_LEVEL = 255


def max_ranks_error(n_ranks: int) -> Optional[str]:
    """Unified MAX_RANKS message (rule ``fabric-max-ranks``), raised
    verbatim by both ``Fabric.__init__`` and ``Router.__init__``."""
    from ..fabric.frames import MAX_RANKS

    if n_ranks <= MAX_RANKS:
        return None
    return (
        f"fabric of {n_ranks} ranks exceeds MAX_RANKS={MAX_RANKS}: the "
        f"route word's src field is a u7 lane (frames.py packs "
        f"adaptive:u1|src:u7|dst:u8|seq:u16), so ranks beyond {MAX_RANKS} "
        f"would silently alias rank (r % {MAX_RANKS}) and misdeliver "
        f"frames"
    )


def list_level_error(list_level) -> Optional[str]:
    """Unified ``list_level`` range message (rule ``fabric-list-level``),
    raised verbatim by ``Fabric.send``: the ListLevel header lane is
    u8-budgeted, and an out-of-range level would wrap silently and alias
    another tenant's QoS class (the router keys credit classes on
    ``level % n_classes``)."""
    if isinstance(list_level, (int, np.integer)) and not isinstance(
        list_level, bool
    ) and 0 <= int(list_level) <= MAX_LIST_LEVEL:
        return None
    return (
        f"list_level must be an int in [0, {MAX_LIST_LEVEL}], got "
        f"{list_level!r}"
    )


def fabric_config_findings(
    frame_phits: int,
    credits: int,
    routing: str,
    defect_after: int,
    qos_weights: Optional[Tuple[int, ...]],
    sizes: Optional[Sequence[int]] = None,
    location: str = "FabricConfig",
    *,
    arq: bool = False,
    retransmit_timeout: int = 8,
    max_retries: int = 4,
    arq_buffer: int = 1024,
    arq_level: int = 255,
    arq_skip_after: int = 0,
    suspect_after: Optional[int] = None,
) -> List[Finding]:
    """Every static finding derivable from FabricConfig fields alone.

    ``FabricConfig.__post_init__`` raises the first ERROR's message, so
    runtime construction and the analyzer agree word for word; WARN-level
    findings (quota floors, defection bounds — the latter only when the
    mesh ``sizes`` are known) surface exclusively through the analyzer.
    ``suspect_after`` is the serve-plane blackout-detection knob: it never
    lives on FabricConfig, but its consistency with the ARQ timeouts is a
    fabric property, so the rule lives here with the rest.
    """
    fs: List[Finding] = []
    if frame_phits < 1 or credits < 1:
        fs.append(finding(
            "fabric-config-positive", location,
            f"frame_phits/credits must be >= 1, got "
            f"{frame_phits}/{credits}",
        ))
    if routing not in ("shortest", "dimension"):
        fs.append(finding(
            "fabric-routing-mode", location,
            f"routing must be 'shortest' or 'dimension', got {routing!r}",
        ))
    if defect_after < 0:
        fs.append(finding(
            "fabric-defect-config", location,
            f"defect_after must be >= 0, got {defect_after}",
        ))
    if defect_after > 0 and routing == "dimension":
        fs.append(finding(
            "fabric-defect-config", location,
            "defect_after needs routing='shortest': only frames whose "
            "route word carries the adaptive bit may defect, and "
            "dimension-order frames never do",
        ))
    if qos_weights is not None:
        if len(qos_weights) < 1 or any(w < 1 for w in qos_weights):
            fs.append(finding(
                "fabric-qos-weights", location,
                f"qos_weights must be positive, got {qos_weights}",
            ))
        elif credits >= 1:
            if credits < len(qos_weights):
                fs.append(finding(
                    "fabric-credit-deadlock", location,
                    f"need credits >= qos classes so every class holds at "
                    f"least one credit, got credits={credits} for "
                    f"{len(qos_weights)} classes",
                ))
            else:
                # largest-remainder zero-quota classes: a class whose raw
                # share floors to 0 survives only by the >= 1 bump
                total = sum(qos_weights)
                floored = [
                    c for c, w in enumerate(qos_weights)
                    if math.floor(credits * w / total) == 0
                ]
                if floored:
                    fs.append(finding(
                        "fabric-qos-quota-floor", location,
                        f"classes {floored} earn a zero largest-remainder "
                        f"share of {credits} credits under weights "
                        f"{tuple(qos_weights)} and run on the 1-credit "
                        f"floor",
                    ))
    if (
        defect_after > 0 and routing == "shortest" and sizes
        and any(n > 1 and defect_after >= n for n in sizes)
    ):
        fs.append(finding(
            "fabric-defect-bound", location,
            f"defect_after={defect_after} is >= a ring size in "
            f"{tuple(sizes)}: a starved frame waits longer than riding "
            f"the whole ring the long way, and the scan bound inflates "
            f"past the dimension-order worst case",
        ))
    if arq:
        fs.extend(arq_config_findings(
            credits=credits,
            qos_weights=qos_weights,
            retransmit_timeout=retransmit_timeout,
            max_retries=max_retries,
            arq_buffer=arq_buffer,
            arq_level=arq_level,
            arq_skip_after=arq_skip_after,
            suspect_after=suspect_after,
            location=location,
        ))
    return fs


def arq_config_findings(
    *,
    credits: int = 4,
    qos_weights: Optional[Tuple[int, ...]] = None,
    retransmit_timeout: int = 8,
    max_retries: int = 4,
    arq_buffer: int = 1024,
    arq_level: int = 255,
    arq_skip_after: int = 0,
    suspect_after: Optional[int] = None,
    location: str = "FabricConfig",
) -> List[Finding]:
    """Static findings for the ARQ reliability layer (``arq=True``).

    Three properties, shared verbatim by ``FabricConfig.__post_init__``
    and the analyzer:

    * **seq-window ambiguity**: the per-(src, dst) retransmit buffer must
      stay strictly inside half the u16 seq window — with ``arq_buffer >=
      SEQ_MOD // 2`` a cumulative ACK can no longer tell "already
      delivered" from "half a window behind" and a retransmit may resolve
      to the wrong message bytes.
    * **control-class credit floor**: ACK/NACK control frames ride QoS
      class ``arq_level % len(qos_weights)``; if that class's
      weight-proportional share of the link credits floors to zero, bulk
      data can starve the very frames that un-starve it (recovery
      liveness depends on control traffic draining every step).
    * **timeout consistency**: the give-up/skip horizon and the serve
      plane's blackout detector must both sit ABOVE the retransmit
      timeout, or a healthy peer gets skipped/suspected before its first
      retransmit could possibly arrive.
    """
    from ..fabric.frames import SEQ_MOD

    fs: List[Finding] = []
    if retransmit_timeout < 1 or max_retries < 0 or arq_buffer < 1 \
            or arq_skip_after < 0:
        fs.append(finding(
            "fabric-arq-config", location,
            f"need retransmit_timeout >= 1, max_retries >= 0, "
            f"arq_buffer >= 1, arq_skip_after >= 0; got "
            f"retransmit_timeout={retransmit_timeout}, "
            f"max_retries={max_retries}, arq_buffer={arq_buffer}, "
            f"arq_skip_after={arq_skip_after}",
        ))
    lvl_err = list_level_error(arq_level)
    if lvl_err is not None:
        fs.append(finding(
            "fabric-arq-config", location, f"arq_level: {lvl_err}",
        ))
    if arq_buffer >= SEQ_MOD // 2:
        fs.append(finding(
            "fabric-arq-window", location,
            f"arq_buffer={arq_buffer} reaches half the u16 seq window "
            f"(SEQ_MOD//2={SEQ_MOD // 2}): cumulative ACKs become "
            f"ambiguous and a retransmit may alias a message half a "
            f"window away",
        ))
    if (
        qos_weights is not None and len(qos_weights) >= 1
        and all(w >= 1 for w in qos_weights) and credits >= len(qos_weights)
    ):
        cls = int(arq_level) % len(qos_weights)
        total = sum(qos_weights)
        if math.floor(credits * qos_weights[cls] / total) == 0:
            fs.append(finding(
                "fabric-arq-control-class", location,
                f"ARQ control class {cls} (arq_level={arq_level} % "
                f"{len(qos_weights)} classes) earns a zero "
                f"weight-proportional share of {credits} credits under "
                f"weights {tuple(qos_weights)}: ACK/NACK frames survive "
                f"only on the 1-credit floor bump while recovery "
                f"liveness depends on them",
            ))
    if arq_skip_after > 0 and arq_skip_after <= retransmit_timeout:
        fs.append(finding(
            "fabric-arq-timeout", location,
            f"arq_skip_after={arq_skip_after} must exceed "
            f"retransmit_timeout={retransmit_timeout}: the receiver "
            f"would skip past a gap before the sender's first "
            f"retransmit could arrive",
        ))
    if suspect_after is not None and suspect_after <= retransmit_timeout:
        fs.append(finding(
            "fabric-arq-timeout", location,
            f"suspect_after={suspect_after} must exceed "
            f"retransmit_timeout={retransmit_timeout}: a healthy shard "
            f"mid-retransmit would be declared suspect and its requests "
            f"re-placed for no fault",
        ))
    return fs
