"""CLI: run every static pass over every shipped target.

    PYTHONPATH=src python -m repro.analysis [--strict] [--json PATH]

Human report on stdout (per-target findings + busiest-link summary of the
bench demand matrices), JSON findings + rule catalog to ``--json`` (
``analysis_findings.json`` by default).  ``--strict`` exits 1 on any
ERROR finding — the CI lint gate.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from .comm import busiest_links, total_frames
from .config_passes import analyze_model_config
from .fabric_passes import analyze_demand, analyze_fabric_values
from .findings import Report
from .schema_passes import analyze_schema, analyze_stream_schema, wire_bounds
from .targets import (
    demand_targets,
    fabric_targets,
    model_config_targets,
    schema_targets,
    stream_targets,
)


def run_all(verbose: bool = False) -> Report:
    """Analyze every shipped target; returns the aggregated Report."""
    report = Report()
    lines: List[str] = []

    for loc, schema, client, caps in schema_targets():
        fs = report.extend(analyze_schema(
            schema, client=client, caps=caps, location=loc,
        ))
        report.targets += 1
        wb = wire_bounds(schema)
        hi = wb.max_bytes if wb.max_bytes is not None else "unbounded"
        lines.append(
            f"  schema {loc}: wire [{wb.min_bytes}, {hi}] B, "
            f"min {wb.min_frames(16)} frames @ 16 phits, "
            f"{len(fs)} finding(s)"
        )

    for loc, schema in stream_targets():
        fs = report.extend(analyze_stream_schema(schema, location=loc))
        report.targets += 1
        try:
            from ..core.stream_plans import stream_plans

            shapes = ", ".join(
                f"{p}: {plan.n_leaves} leaves x {plan.elem_words} word(s)"
                for p, plan in sorted(stream_plans(schema).items())
            )
        except Exception:
            shapes = "no plan (see findings)"
        lines.append(f"  stream {loc}: {shapes}; {len(fs)} finding(s)")

    for loc, kw in fabric_targets():
        fs = report.extend(analyze_fabric_values(location=loc, **kw))
        report.targets += 1
        lines.append(f"  fabric {loc}: {len(fs)} finding(s)")

    for loc, sizes, cfg_kw, srcs, dsts, counts, levels in demand_targets():
        from ..fabric.router import FabricConfig

        cfg = FabricConfig(**cfg_kw)
        loads, fs = analyze_demand(
            sizes, cfg, srcs, dsts, counts, levels=levels, location=loc,
        )
        report.extend(fs)
        report.targets += 1
        busy = busiest_links(loads, top=1)
        peak = (f"peak link axis {busy[0][0]} ring {busy[0][1]} "
                f"dir {busy[0][2]}: {busy[0][3]} frames over "
                f"{busy[0][4]} hops") if busy else "no traffic"
        lines.append(
            f"  demand {loc}: {total_frames(loads)} frames on the "
            f"busiest axis; {peak}; {len(fs)} finding(s)"
        )

    for loc, cfg in model_config_targets():
        fs = report.extend(analyze_model_config(cfg, location=loc))
        report.targets += 1
        lines.append(f"  config {loc}: {len(fs)} finding(s)")

    if verbose:
        print("\n".join(lines))
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any ERROR finding (CI gate)")
    ap.add_argument("--json", default="analysis_findings.json",
                    metavar="PATH",
                    help="write the JSON findings file here ('-' skips)")
    ap.add_argument("--quiet", action="store_true",
                    help="summary line only (no per-target bounds)")
    args = ap.parse_args(argv)

    report = run_all(verbose=not args.quiet)
    print(report.render())
    if args.json != "-":
        with open(args.json, "w") as f:
            json.dump(report.to_json(), f, indent=2)
        print(f"findings written to {args.json}")
    if args.strict and report.errors:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
