"""Shipped analysis targets: everything ``python -m repro.analysis``
proves safe.

Four registries — schemas (the framework's own messages + the paper's
Fig. 6/7 example), fabric configs (the serve default + every bench
configuration), demand matrices (the deterministic ``bench_fabric``
workloads), and the shipped model configs.  Each entry carries the
location string findings anchor to, so a CI failure names the exact
artifact that regressed.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from ..core.idl import ClientSchema, Schema

#: benchmarks/bench_fabric.py geometry (the oracle workloads)
BENCH_RANKS = 8
BENCH_FRAME_PHITS = 16
BENCH_PAYLOAD_BYTES = 4096
BENCH_N_MSGS = 8

# The paper's Fig. 6 schema + Fig. 7 client schema (examples/quickstart.py)
QUICKSTART_SCHEMA_JSON = {
    "Msg": [
        ["a", ["List", ["Array", ["Struct", "Tuple"]]]],
        ["b", ["Bytes", 1]],
    ],
    "Tuple": [
        ["x", ["Bytes", 4]],
        ["y", ["Bytes", 8]],
    ],
}
QUICKSTART_CLIENT_JSON = {
    "a.start": 1,
    "a.elem.start": 2,
    "a.elem.elem.x": 3,
    "a.elem.elem.y": 4,
    "a.elem.end": 5,
}


def schema_targets() -> List[Tuple[
    str, Schema, Optional[ClientSchema], Optional[Dict[str, int]]
]]:
    """(location, schema, client, caps) for every shipped schema."""
    from ..data.schemas import (
        batch_client_schema,
        batch_schema,
        request_schema,
        response_schema,
    )

    return [
        ("data.batch_schema", batch_schema(128), batch_client_schema(),
         {"rows": 64, "rows.elem.tokens": 128, "rows.elem.segids": 128}),
        ("data.request_schema", request_schema(), None,
         {"prompts": 64, "prompts.elem.tokens": 4096}),
        ("data.response_schema", response_schema(), None, None),
        ("examples.quickstart",
         Schema.from_json(QUICKSTART_SCHEMA_JSON),
         ClientSchema.from_json(QUICKSTART_CLIENT_JSON), None),
    ]


def stream_targets() -> List[Tuple[str, Schema]]:
    """(location, schema) for every shipped ``Stream<T>`` declaration —
    the generated token codec and the logprob side stream (both live in
    ``stream/chunks.py`` as pure schema JSON)."""
    from ..stream.chunks import (
        LOGPROB_STREAM_SCHEMA_JSON,
        TOKEN_STREAM_SCHEMA_JSON,
    )

    return [
        ("stream.token_stream",
         Schema.from_json(TOKEN_STREAM_SCHEMA_JSON)),
        ("stream.logprob_stream",
         Schema.from_json(LOGPROB_STREAM_SCHEMA_JSON)),
    ]


def fabric_targets() -> List[Tuple[str, dict]]:
    """(location, analyze_fabric_values kwargs) for every shipped fabric
    configuration: the serve default, the bench_fabric sweeps, and the
    bench_stream QoS classes."""
    sizes = (BENCH_RANKS,)
    targets: List[Tuple[str, dict]] = [
        ("launch.default_serve_fabric", dict(
            frame_phits=16, credits=4, routing="shortest", sizes=sizes,
            arq=True, suspect_after=24,
        )),
        ("bench_fabric.faulty_link.arq", dict(
            frame_phits=BENCH_FRAME_PHITS, credits=8, routing="shortest",
            sizes=sizes, arq=True,
        )),
        ("bench_fabric.dimension", dict(
            frame_phits=BENCH_FRAME_PHITS, credits=8, routing="dimension",
            sizes=sizes,
        )),
        ("bench_fabric.starved_link.defect", dict(
            frame_phits=BENCH_FRAME_PHITS, credits=2, routing="shortest",
            defect_after=2, sizes=sizes,
        )),
    ]
    for credits in (1, 2, 4, 8, 16):
        targets.append((f"bench_fabric.credits[{credits}]", dict(
            frame_phits=BENCH_FRAME_PHITS, credits=credits,
            routing="shortest", sizes=sizes,
        )))
    for weights in ((1, 1), (3, 1), (1, 3)):
        targets.append((f"bench_stream.qos{weights}", dict(
            frame_phits=2, credits=4, qos_weights=weights, sizes=sizes,
        )))
    return targets


def _bench_counts(n_msgs: int, payload: int) -> int:
    from ..fabric.frames import frame_capacity

    return n_msgs * frame_capacity(payload, BENCH_FRAME_PHITS)


def demand_targets() -> List[Tuple[
    str, Tuple[int, ...], dict,
    Sequence[int], Sequence[int], Sequence[int], Optional[Sequence[int]]
]]:
    """(location, sizes, config kwargs, srcs, dsts, counts, levels) —
    the deterministic ``bench_fabric`` workloads, with counts in frames
    exactly as the mailbox will inject them (terminator included)."""
    sizes = (BENCH_RANKS,)
    per_msg = _bench_counts(1, BENCH_PAYLOAD_BYTES)
    base = dict(frame_phits=BENCH_FRAME_PHITS, credits=8,
                routing="shortest")
    out = []
    # bit-exactness workload: every rank sends one payload to +1
    out.append((
        "bench_fabric.neighbor", sizes, base,
        list(range(BENCH_RANKS)),
        [(r + 1) % BENCH_RANKS for r in range(BENCH_RANKS)],
        [per_msg] * BENCH_RANKS, None,
    ))
    # hop sweep: N_MSGS payloads 0 -> dst for every non-zero dst
    for dst in range(1, BENCH_RANKS):
        out.append((
            f"bench_fabric.hops[dst={dst}]", sizes, base,
            [0] * BENCH_N_MSGS, [dst] * BENCH_N_MSGS,
            [per_msg] * BENCH_N_MSGS, None,
        ))
    # credit sweep: N_MSGS payloads 0 -> 4 under each budget
    for credits in (1, 2, 4, 8, 16):
        out.append((
            f"bench_fabric.credits[{credits}]", sizes,
            dict(base, credits=credits),
            [0] * BENCH_N_MSGS, [4] * BENCH_N_MSGS,
            [per_msg] * BENCH_N_MSGS, None,
        ))
    # starved +1 link: heavy 0 -> 1 and light 0 -> 4, defection off/on
    starved = _bench_counts(1, 1536)
    for defect in (0, 2):
        out.append((
            f"bench_fabric.starved[defect={defect}]", sizes,
            dict(frame_phits=BENCH_FRAME_PHITS, credits=2,
                 routing="shortest", defect_after=defect),
            [0] * 12, [1] * 6 + [4] * 6, [starved] * 12,
            [2] * 6 + [1] * 6,
        ))
    return out


def model_config_targets() -> List[Tuple[str, object]]:
    """(location, ModelConfig) for every registered architecture."""
    from ..configs import all_archs, get_config

    return [(f"configs.{name}", get_config(name)) for name in all_archs()]


def total_targets() -> int:
    return (len(schema_targets()) + len(stream_targets())
            + len(fabric_targets()) + len(demand_targets())
            + len(model_config_targets()))
