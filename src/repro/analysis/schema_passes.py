"""Schema passes: static safety properties of HGum schemas.

The schema is data (the paper's core thesis), so its safety properties
are statically computable: wire-size and frame-count bounds
(:func:`wire_bounds`), ROM/stack capacity fits, ListLevel budgets, client
tag soundness (:func:`analyze_schema`), and decode-plan cap consistency
(:func:`analyze_plan_caps` — ``plan_from_wire``'s runtime cap error
becomes a compile-time finding).  Everything here is host-only math over
``core/idl.py`` / ``core/schema_tree.py``; no devices, no jax.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.idl import (
    Array,
    Bytes,
    ClientSchema,
    ELEM,
    ListT,
    Schema,
    SchemaError,
    StreamT,
    StructRef,
    TypeNode,
    all_token_paths,
)
from ..core.schema_tree import (
    COUNT_BYTES,
    ROM_CAPACITY,
    STACK_CAPACITY,
    build_rom,
)
from ..core.stream_plans import (
    STREAM_ID_BITS,
    elem_size_error,
    meta_budget_error,
    stream_plans,
)
from .findings import Finding, Severity, finding
from .rules import MAX_LIST_LEVEL

_CONTAINER = (Array, ListT, StreamT)


# ---------------------------------------------------------------------------
# wire-size / frame-count bounds
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WireBounds:
    """Static wire-size bounds of one message type (SW->HW layout: every
    container contributes its COUNT_BYTES count word; the minimum assumes
    every container is empty, the maximum is None when any container makes
    the wire unbounded)."""

    min_bytes: int
    max_bytes: Optional[int]

    def min_frames(self, frame_phits: int) -> int:
        """Fewest HW->HW frames a message can occupy (terminator incl.)."""
        from ..fabric.frames import frame_capacity

        return frame_capacity(self.min_bytes, frame_phits)

    def max_frames(self, frame_phits: int) -> Optional[int]:
        from ..fabric.frames import frame_capacity

        if self.max_bytes is None:
            return None
        return frame_capacity(self.max_bytes, frame_phits)


def wire_bounds(schema: Schema) -> WireBounds:
    """Static min/max wire bytes of ``schema``'s top message."""

    def bounds(t: TypeNode) -> Tuple[int, Optional[int]]:
        if isinstance(t, Bytes):
            return t.n, t.n
        if isinstance(t, StructRef):
            lo = hi = 0
            for _, ft in schema.structs[t.name]:
                flo, fhi = bounds(ft)
                lo += flo
                hi = None if hi is None or fhi is None else hi + fhi
            return lo, hi
        if isinstance(t, _CONTAINER):
            return COUNT_BYTES, None  # empty is legal; non-empty unbounded
        raise SchemaError(f"bad type {t!r}")

    return WireBounds(*bounds(StructRef(schema.top)))


# ---------------------------------------------------------------------------
# the schema pass
# ---------------------------------------------------------------------------


def _reachable(schema: Schema) -> set:
    seen = set()
    stack = [schema.top]
    while stack:
        s = stack.pop()
        if s in seen or s not in schema.structs:
            continue
        seen.add(s)
        for _, ftype in schema.structs[s]:
            t = ftype
            while isinstance(t, _CONTAINER):
                t = t.elem
            if isinstance(t, StructRef):
                stack.append(t.name)
    return seen


def analyze_schema(
    schema: Schema,
    client: Optional[ClientSchema] = None,
    caps: Optional[Dict[str, int]] = None,
    location: Optional[str] = None,
) -> List[Finding]:
    """Run every schema rule; returns the findings (empty = provably
    safe to build a ROM for and run through the FSM engines)."""
    loc = location or schema.top
    fs: List[Finding] = []
    try:
        schema.validate()
    except SchemaError as e:
        rule = ("schema-recursive" if "recursive" in str(e)
                else "schema-undefined-struct")
        return [finding(rule, loc, str(e))]

    reach = _reachable(schema)
    for sname in sorted(set(schema.structs) - reach):
        fs.append(finding(
            "schema-unreachable-struct", loc,
            f"struct {sname!r} is never reached from top "
            f"{schema.top!r}",
        ))
    try:
        rom = build_rom(schema)
    except SchemaError as e:
        # build_tree refuses empty inlined structs ("... has no fields")
        fs.append(finding("schema-empty-struct", loc, str(e)))
        return fs

    b = rom.static_bounds()
    if b["n_nodes"] > ROM_CAPACITY:
        fs.append(finding(
            "schema-rom-capacity", loc,
            f"schema tree flattens to {b['n_nodes']} ROM entries, over "
            f"the {ROM_CAPACITY}-entry schema-ROM capacity",
        ))
    if b["stack_depth"] > STACK_CAPACITY:
        fs.append(finding(
            "schema-stack-depth", loc,
            f"container nesting needs a {b['stack_depth']}-deep context "
            f"stack, over the {STACK_CAPACITY}-deep capacity",
        ))
    if b["max_list_level"] > MAX_LIST_LEVEL:
        fs.append(finding(
            "schema-list-level-overflow", loc,
            f"List nesting reaches level {b['max_list_level']}, over the "
            f"u8 ListLevel header budget of {MAX_LIST_LEVEL}",
        ))

    if client is not None:
        valid = set(all_token_paths(schema))
        for path in sorted(client.tags):
            if path not in valid:
                fs.append(finding(
                    "client-unknown-path", loc,
                    f"client-schema path {path!r} does not name a token "
                    f"of {schema.top!r}",
                ))
        by_tag: Dict[int, List[str]] = {}
        for path, tag in client.tags.items():
            by_tag.setdefault(tag, []).append(path)
        for tag, paths in sorted(by_tag.items()):
            if len(paths) > 1:
                fs.append(finding(
                    "client-tag-collision", loc,
                    f"tag {tag} is shared by paths "
                    f"{sorted(paths)} — DES output would be ambiguous",
                ))

    if caps is not None:
        fs.extend(analyze_plan_caps(schema, caps, location=loc))
    return fs


# ---------------------------------------------------------------------------
# typed-stream pass (core/stream_plans.py's runtime errors, statically)
# ---------------------------------------------------------------------------


def analyze_stream_schema(
    schema: Schema,
    location: Optional[str] = None,
    *,
    id_bits: int = 2 * STREAM_ID_BITS,
    step_bits: int = STREAM_ID_BITS,
) -> List[Finding]:
    """Run the schema rules plus the ``stream-*`` rules over a schema
    that declares ``Stream<T>`` nodes.

    The stream checks wrap the exact functions the runtime raises with
    (:func:`~repro.core.stream_plans.meta_budget_error`,
    :func:`~repro.core.stream_plans.elem_size_error`), so a finding here
    is word-for-word the ``SchemaError`` ``stream_plans`` /
    ``StreamPlan`` would raise.  Also proves the serve plane's
    ``(request:u16 | prompt:u16)`` id packing fits the plan's id budget
    (rule ``stream-id-width``)."""
    loc = location or schema.top
    fs = analyze_schema(schema, location=loc)
    if any(f.severity is Severity.ERROR for f in fs):
        return fs  # the ROM below these checks would not even build

    budget_err = meta_budget_error(id_bits, step_bits)
    if budget_err is not None:
        fs.append(finding("stream-meta-budget", loc, budget_err))
        # fall back to the shipped budgets so the element checks still run
        id_bits, step_bits = 2 * STREAM_ID_BITS, STREAM_ID_BITS
    try:
        plans = stream_plans(schema, id_bits=id_bits, step_bits=step_bits)
    except SchemaError as e:
        # non-fixed-size element, or element too wide for the plan ctor
        fs.append(finding("stream-elem-size", loc, str(e)))
        return fs

    for path, plan in sorted(plans.items()):
        size_err = elem_size_error(plan.elem_words)
        if size_err is not None:  # unreachable today: the ctor re-checks
            fs.append(finding("stream-elem-size", loc, f"{path}: {size_err}"))
        if plan.id_bits < 2 * STREAM_ID_BITS:
            fs.append(finding(
                "stream-id-width", loc,
                f"{path}: id budget of {plan.id_bits} bits cannot hold "
                f"the serve plane's (request:u{STREAM_ID_BITS} | "
                f"prompt:u{STREAM_ID_BITS}) stream-id packing",
            ))
    return fs


# ---------------------------------------------------------------------------
# decode-plan caps (vectorized.plan_from_wire's error, statically)
# ---------------------------------------------------------------------------


def _paths_with_parents(schema: Schema) -> List[Tuple[str, Optional[str]]]:
    """Every plan path with its nearest enclosing container path."""
    out: List[Tuple[str, Optional[str]]] = []

    def walk(t: TypeNode, path: str, parent: Optional[str]) -> None:
        if isinstance(t, Bytes):
            out.append((path, parent))
        elif isinstance(t, StructRef):
            for f, ft in schema.structs[t.name]:
                walk(ft, f"{path}.{f}" if path else f, parent)
        elif isinstance(t, _CONTAINER):
            out.append((path, parent))
            walk(t.elem, f"{path}.{ELEM}", path)

    for f, ft in schema.structs[schema.top]:
        walk(ft, f, None)
    return out


def analyze_plan_caps(
    schema: Schema, caps: Dict[str, int], location: Optional[str] = None,
) -> List[Finding]:
    """Static consistency of a ``build_plan``/``plan_from_wire`` caps
    dict: each cap must fit the u32 count field, and an inner path's
    cap below its enclosing container's cap overflows the moment every
    container instance holds one element (``plan_from_wire`` raises
    '{path}: N instances exceed cap' at runtime)."""
    loc = location or schema.top
    fs: List[Finding] = []
    count_mod = 1 << (8 * COUNT_BYTES)
    for path, cap in sorted(caps.items()):
        if cap >= count_mod:
            fs.append(finding(
                "plan-cap-count-width", loc,
                f"cap {cap} for {path!r} exceeds the "
                f"{COUNT_BYTES}-byte count field (max {count_mod - 1})",
            ))
    for path, parent in _paths_with_parents(schema):
        if parent is None or path not in caps or parent not in caps:
            continue
        if caps[path] < caps[parent]:
            fs.append(finding(
                "plan-cap-overflow", loc,
                f"cap {caps[path]} for {path!r} is below enclosing "
                f"{parent!r}'s cap {caps[parent]}: one element per "
                f"instance already overflows (plan_from_wire would "
                f"raise '{path}: N instances exceed cap "
                f"{caps[path]}')",
            ))
    return fs


def message_wire_len(schema: Schema, msg: dict) -> int:
    """Exact SW->HW wire bytes of one concrete message (bounds check
    helper for tests: min_bytes <= this <= max_bytes always holds)."""

    def size(t: TypeNode, v) -> int:
        if isinstance(t, Bytes):
            return t.n
        if isinstance(t, StructRef):
            return sum(size(ft, v[f]) for f, ft in schema.structs[t.name])
        if isinstance(t, _CONTAINER):
            return COUNT_BYTES + sum(size(t.elem, e) for e in v)
        raise SchemaError(f"bad type {t!r}")

    return int(np.sum([
        size(ft, msg[f]) for f, ft in schema.structs[schema.top]
    ], dtype=np.int64))
