"""Fabric/communication passes: prove a fabric config + demand safe
before any device allocation.

:func:`analyze_fabric_values` checks raw config values (so invalid
combinations that ``FabricConfig.__post_init__`` would refuse to even
construct still get findings), :func:`analyze_fabric` checks a live
:class:`~repro.fabric.mailbox.Fabric`, and :func:`analyze_demand` /
:func:`analyze_sends` check a concrete demand matrix against a topology:
per-(link, direction) static load via the ``plan_steps`` machinery
(:mod:`.comm`), rank ranges, rx-capacity overflow, and u16 seq-window
aliasing.  All host-only integer math.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from .comm import AxisLoads, demand_from_sends, demand_link_loads
from .findings import Finding, finding
from .rules import (
    fabric_config_findings,
    list_level_error,
    max_ranks_error,
)


def analyze_fabric_values(
    *,
    frame_phits: int = 16,
    credits: int = 4,
    routing: str = "shortest",
    defect_after: int = 0,
    qos_weights: Optional[Tuple[int, ...]] = None,
    rx_frames: Optional[int] = None,
    n_ranks: Optional[int] = None,
    sizes: Optional[Sequence[int]] = None,
    arq: bool = False,
    retransmit_timeout: int = 8,
    max_retries: int = 4,
    arq_buffer: int = 1024,
    arq_level: int = 255,
    arq_skip_after: int = 0,
    suspect_after: Optional[int] = None,
    location: str = "FabricConfig",
) -> List[Finding]:
    """Analyze raw fabric-config values (no FabricConfig construction, so
    combinations its ``__post_init__`` raises on still produce findings
    instead of exceptions)."""
    fs = fabric_config_findings(
        frame_phits, credits, routing, defect_after, qos_weights,
        sizes=sizes, location=location,
        arq=arq, retransmit_timeout=retransmit_timeout,
        max_retries=max_retries, arq_buffer=arq_buffer,
        arq_level=arq_level, arq_skip_after=arq_skip_after,
        suspect_after=suspect_after,
    )
    if rx_frames is not None and rx_frames < 1:
        fs.append(finding(
            "fabric-config-positive", location,
            f"rx_frames must be >= 1 when set, got {rx_frames}",
        ))
    total = n_ranks
    if total is None and sizes:
        total = math.prod(sizes)
    if total is not None:
        err = max_ranks_error(total)
        if err is not None:
            fs.append(finding("fabric-max-ranks", location, err))
    return fs


def analyze_fabric(fabric, location: Optional[str] = None) -> List[Finding]:
    """Analyze a live Fabric: its config against its topology sizes."""
    cfg = fabric.config
    sizes = tuple(fabric.router.sizes)
    return analyze_fabric_values(
        frame_phits=cfg.frame_phits,
        credits=cfg.credits,
        routing=cfg.routing,
        defect_after=cfg.defect_after,
        qos_weights=cfg.qos_weights,
        rx_frames=cfg.rx_frames,
        n_ranks=fabric.n_ranks,
        sizes=sizes,
        arq=cfg.arq,
        retransmit_timeout=cfg.retransmit_timeout,
        max_retries=cfg.max_retries,
        arq_buffer=cfg.arq_buffer,
        arq_level=cfg.arq_level,
        arq_skip_after=cfg.arq_skip_after,
        location=location or f"Fabric(n_ranks={fabric.n_ranks})",
    )


def analyze_demand(
    sizes: Sequence[int],
    config,
    srcs: Sequence[int],
    dsts: Sequence[int],
    counts: Sequence[int],
    levels: Optional[Sequence[int]] = None,
    location: str = "demand",
) -> Tuple[Tuple[AxisLoads, ...], List[Finding]]:
    """Analyze one tick's demand matrix (``counts`` in frames) against a
    topology + config.  Returns ``(loads, findings)`` — the per-axis
    per-(ring, direction) static load matrix plus any findings.

    Checks: src/dst rank ranges, send ListLevel budgets, per-(src, dst)
    u16 seq-window aliasing, and — when ``config.rx_frames`` is set — the
    per-destination rx-buffer capacity (with ``rx_frames=None`` the
    mailbox sizes rx from the tick itself and cannot overflow).
    """
    from ..fabric.frames import SEQ_MOD

    n_ranks = math.prod(sizes)
    fs: List[Finding] = []
    for i, (s, d) in enumerate(zip(srcs, dsts)):
        if not (0 <= s < n_ranks and 0 <= d < n_ranks):
            fs.append(finding(
                "fabric-rank-range", location,
                f"demand entry {i} routes {s} -> {d}, outside the "
                f"{n_ranks}-rank fabric [0, {n_ranks - 1}]",
            ))
    if levels is not None:
        for i, lvl in enumerate(levels):
            err = list_level_error(lvl)
            if err is not None:
                fs.append(finding(
                    "fabric-list-level", location,
                    f"demand entry {i}: {err}",
                ))
    if fs:  # loads of an unroutable demand are meaningless
        return (tuple({} for _ in sizes), fs)

    stream_frames: Dict[Tuple[int, int], int] = {}
    rx_total: Dict[int, int] = {}
    for s, d, cnt in zip(srcs, dsts, counts):
        key = (s, d)
        stream_frames[key] = stream_frames.get(key, 0) + int(cnt)
        if s != d:
            rx_total[d] = rx_total.get(d, 0) + int(cnt)
    for (s, d), frames in sorted(stream_frames.items()):
        if frames >= SEQ_MOD:
            fs.append(finding(
                "fabric-seq-window", location,
                f"{frames} frames from {s} to {d} in one tick alias the "
                f"u16 seq window (SEQ_MOD={SEQ_MOD})",
            ))
    if config.rx_frames is not None:
        for d, frames in sorted(rx_total.items()):
            if frames > config.rx_frames:
                fs.append(finding(
                    "fabric-rx-overflow", location,
                    f"rank {d} receives {frames} frames this tick, over "
                    f"the configured rx_frames={config.rx_frames} buffer",
                ))

    loads = demand_link_loads(sizes, srcs, dsts, counts, config.adaptive)
    return loads, fs


def analyze_sends(
    sizes: Sequence[int], config, sends: Sequence[Tuple],
    location: str = "pending sends",
) -> Tuple[Tuple[AxisLoads, ...], List[Finding]]:
    """Analyze pending mailbox sends ``(src, dst, wire, level, ...)`` —
    the ``Fabric(analyze=True)`` per-tick hook path."""
    srcs, dsts, counts = demand_from_sends(sends, config.frame_phits)
    levels = [s[3] for s in sends if len(s) > 3] or None
    return analyze_demand(
        sizes, config, srcs, dsts, counts, levels=levels,
        location=location,
    )
