"""Production mesh factory (DESIGN.md §6).

A function (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets the 512-device XLA flag before
calling it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod outer axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh(shape, axes)
