import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the right step (train_step / prefill / serve_step) against
     ShapeDtypeStruct inputs with the runtime's shardings,
  3. compiles (the pass/fail gate: sharding mismatches, OOM-at-compile and
     unsupported collectives all fail here),
  4. records memory_analysis / cost_analysis / the while-aware text
     analysis (launch.hloanalysis) and the three roofline terms,
  5. writes one JSON per cell into experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax

from ..configs import SHAPES, all_archs, get_config, supports_shape
from ..configs.base import ModelConfig, ShapeConfig
from ..optim import AdamWConfig
from ..runtime import (
    ShardRules,
    batch_shardings,
    cache_shardings,
    param_shardings,
)
from ..runtime.actshard import mesh_constrainer, use_constrainer
from .hloanalysis import HBM_BW, ICI_BW, PEAK_FLOPS, analyze
from .mesh import make_production_mesh
from .steps import (
    cache_specs,
    input_specs,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

HBM_PER_CHIP = 16 * 1024**3  # v5e: 16 GiB


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N*D (train) / 2*N*D (fwd-only), N = active params (MoE-aware)."""
    n = cfg.param_counts()["active"]
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch  # decode: one token per row


def lower_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    rules: Optional[ShardRules] = None,
    donate: bool = True,
):
    """Returns (lowered, jitted, specs) for one cell."""
    rules = rules or ShardRules()
    with use_constrainer(mesh_constrainer(mesh, rules, shape.global_batch)):
        return _lower_cell_inner(cfg, shape, mesh, rules, donate)


def _lower_cell_inner(cfg, shape, mesh, rules, donate):
    specs = input_specs(cfg, shape)
    psh = param_shardings(specs["params"], cfg, mesh, rules)
    if shape.kind == "train":
        # ZeRO over the pod axis: optimizer state and gradients shard over
        # ("pod", fsdp) on the multi-pod mesh — grads reduce-scatter across
        # pods instead of all-reduce, opt state is never replicated.
        opt_rules = rules
        if "pod" in mesh.axis_names and isinstance(rules.fsdp, str):
            opt_rules = dataclasses.replace(rules, fsdp=("pod", rules.fsdp))
        osh = param_shardings(specs["opt_state"], cfg, mesh, opt_rules)
        gsh = param_shardings(specs["params"], cfg, mesh, opt_rules)
        bsh = batch_shardings(
            specs["batch"], mesh, rules, global_batch=shape.global_batch
        )
        from jax.sharding import NamedSharding, PartitionSpec as P

        from ..runtime.sharding import batch_pspec

        bspec = batch_pspec(mesh, rules, shape.global_batch // max(cfg.microbatch, 1))

        def micro_sharding_fn(tree):
            def c(x):
                spec = P(None, *(list(bspec) + [None] * (x.ndim - 2)))
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, spec)
                )
            return jax.tree.map(c, tree)

        step = make_train_step(
            cfg, AdamWConfig(moments=cfg.opt_moments), grad_shardings=gsh,
            micro_sharding_fn=micro_sharding_fn if cfg.microbatch > 1 else None,
        )
        jitted = jax.jit(
            step,
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, None),
            donate_argnums=(0, 1) if donate else (),
        )
        lowered = jitted.lower(specs["params"], specs["opt_state"], specs["batch"])
    elif shape.kind == "prefill":
        bsh = batch_shardings(
            specs["batch"], mesh, rules, global_batch=shape.global_batch
        )
        csh_out = cache_shardings(
            cache_specs(cfg, shape.global_batch, shape.seq_len), cfg, mesh, rules
        )
        step = make_prefill_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(psh, bsh),
            out_shardings=(None, csh_out),
        )
        lowered = jitted.lower(specs["params"], specs["batch"])
    else:  # decode
        csh = cache_shardings(specs["cache"], cfg, mesh, rules)
        tsh = batch_shardings(
            specs["tokens"], mesh, rules, global_batch=shape.global_batch
        )
        step = make_serve_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(psh, csh, tsh),
            out_shardings=(tsh, csh),
            donate_argnums=(1,) if donate else (),
        )
        lowered = jitted.lower(specs["params"], specs["cache"], specs["tokens"])
    return lowered, jitted, specs


def _parse_overrides(pairs):
    """["k=v", ...] -> dict with literal-ish coercion."""
    out = {}
    for kv in pairs or []:
        k, v = kv.split("=", 1)
        if v in ("true", "True"):
            v = True
        elif v in ("false", "False"):
            v = False
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        out[k] = v
    return out


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    rules: Optional[ShardRules] = None,
    scan: Optional[bool] = None,
    out_dir: str = "experiments/dryrun",
    tag: str = "",
    cfg_overrides: Optional[Dict] = None,
    mesh_shape: Optional[tuple] = None,
) -> Dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    ok, reason = supports_shape(cfg, shape)
    result: Dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
        "status": "skipped", "reason": reason,
    }
    if not ok:
        _write(result, out_dir)
        return result

    # scan-over-layers: small HLO, while-aware analyzer keeps costs exact
    if scan is None:
        scan = cfg.family == "lm" and shape.kind == "train"
    cfg = dataclasses.replace(cfg, scan_layers=scan)

    if mesh_shape is not None:  # hillclimb: re-factor the 256 chips
        axes = ("pod", "data", "model")[-len(mesh_shape):]
        mesh = jax.make_mesh(mesh_shape, axes)
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        lowered, jitted, specs = lower_cell(cfg, shape, mesh, rules)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
        result.update(status="FAILED", error=f"{type(e).__name__}: {e}",
                      trace=traceback.format_exc()[-2000:])
        _write(result, out_dir)
        return result

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    rep = analyze(compiled.as_text())

    per_dev_bytes = (
        ma.argument_size_in_bytes + ma.temp_size_in_bytes + ma.output_size_in_bytes
        - ma.alias_size_in_bytes
    )
    terms = {
        "t_compute": rep.flops / PEAK_FLOPS,
        "t_memory": rep.hbm_bytes / HBM_BW,
        "t_collective": rep.collective_bytes / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_flops_global = rep.flops * n_chips
    result.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        scan_layers=scan,
        memory={
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "per_device_bytes": per_dev_bytes,
            "hbm_frac": per_dev_bytes / HBM_PER_CHIP,
            "fits": bool(per_dev_bytes <= HBM_PER_CHIP),
        },
        xla_cost_analysis={
            "flops": ca.get("flops"),
            "bytes_accessed": ca.get("bytes accessed"),
        },
        hlo={**rep.as_dict()},
        roofline={
            **terms,
            "dominant": dominant,
            "model_flops_global": mf,
            "hlo_flops_global": hlo_flops_global,
            "useful_ratio": mf / hlo_flops_global if hlo_flops_global else None,
            "step_time_bound_s": max(terms.values()),
            "mfu_bound": mf / (max(terms.values()) * n_chips * PEAK_FLOPS)
            if max(terms.values()) > 0 else None,
        },
    )
    _write(result, out_dir)
    return result


def _write(result: Dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"_{result['tag']}" if result.get("tag") else ""
    fn = f"{result['arch']}_{result['shape']}_{result['mesh']}{tag}.json"
    with open(os.path.join(out_dir, fn), "w") as f:
        json.dump(result, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--scan", default=None, choices=[None, "on", "off"])
    ap.add_argument("--seq-sharded", action="store_true")
    ap.add_argument("--no-ep", action="store_true")
    ap.add_argument("--no-kv-heads", action="store_true")
    ap.add_argument("--set", nargs="*", default=None, metavar="K=V",
                    help="ModelConfig overrides, e.g. remat_policy=dots")
    ap.add_argument("--rules", nargs="*", default=None, metavar="K=V",
                    help="ShardRules overrides, e.g. batch=pod,data,model")
    ap.add_argument("--mesh-shape", default=None,
                    help="re-factor chips, e.g. 32,8 (hillclimb)")
    args = ap.parse_args()

    rules = ShardRules(
        expert_parallel=not args.no_ep,
        kv_head_sharded=not args.no_kv_heads,
        seq_sharded_acts=args.seq_sharded,
    )
    rule_over = _parse_overrides(args.rules)
    if "batch" in rule_over:
        rule_over["batch"] = tuple(rule_over["batch"].split(","))
    if rule_over:
        rules = dataclasses.replace(rules, **rule_over)
    cfg_over = _parse_overrides(args.set)
    mesh_shape = tuple(int(x) for x in args.mesh_shape.split(",")) if args.mesh_shape else None
    scan = None if args.scan is None else (args.scan == "on")
    archs = all_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                r = run_cell(arch, shape, mk, rules, scan, args.out, args.tag,
                             cfg_overrides=cfg_over, mesh_shape=mesh_shape)
                line = f"{arch:28s} {shape:12s} {mk:6s} {r['status']:8s}"
                if r["status"] == "ok":
                    rf = r["roofline"]
                    line += (
                        f" compile={r['compile_s']:7.1f}s"
                        f" mem/dev={r['memory']['per_device_bytes']/2**30:6.2f}GiB"
                        f" dom={rf['dominant'][2:]:10s}"
                        f" t=({rf['t_compute']*1e3:8.3f},{rf['t_memory']*1e3:8.3f},"
                        f"{rf['t_collective']*1e3:8.3f})ms"
                    )
                elif r["status"] == "FAILED":
                    line += " " + r.get("error", "")[:90]
                else:
                    line += " " + r.get("reason", "")[:70]
                print(line, flush=True)


if __name__ == "__main__":
    main()
