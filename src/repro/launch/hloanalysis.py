"""While-aware cost accounting over compiled HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE (verified
empirically — scan of 10 matmuls reports 1/10 of the unrolled FLOPs), which
would wreck the roofline for scanned layer stacks and blocked attention.
This module re-derives the three roofline inputs from ``compiled.as_text()``
with trip-count multiplication:

* FLOPs        — dot ops: 2 * |out| * contracted_size (operand shapes from a
  per-computation symbol table); elementwise/reduce ops: 1 flop/element
  (counted inside fusion computations too).
* HBM bytes    — per *materializing* top-level op (fusion, dot, copy,
  collectives, dynamic-slice/update, sort, scatter/gather, custom-call):
  sum of operand bytes + output bytes.  Parameters / bitcasts / tuples /
  get-tuple-element are free.
* Collective bytes — per collective kind, operand bytes and output bytes
  summed separately (the brief's roofline term uses operand bytes).

Multipliers: ENTRY = 1; a while op with ``known_trip_count n`` inside a
computation with multiplier m gives its body/condition multiplier m*n;
fusion/call computations inherit the call site's multiplier (summed over
call sites).  ``to_apply`` reducers are ignored (O(1) work per element,
already counted by the reduce op itself).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s+(?:ROOT )?%([\w.\-]+) = (.*?) ([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\((.*?)\) -> ")
_PARAM_RE = re.compile(r"([\w.\-]+): ([^,)]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs", "sign",
    "compare", "select", "and", "or", "xor", "not", "clamp", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "cosine", "sine", "logistic",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "remainder",
    "atan2", "expm1", "log1p", "cbrt", "erf",
}
_REDUCE = {"reduce", "reduce-window", "cumsum"}
_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}
_FREE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "broadcast",
    "reshape", "convert", "transpose", "slice", "pad", "concatenate", "copy",
    "rng-bit-generator", "rng-get-and-update-state",
}  # shape ops usually fuse / alias; charged when appearing as fusions
_MATERIALIZING = {
    "fusion", "dot", "convolution", "custom-call", "dynamic-slice",
    "dynamic-update-slice", "sort", "scatter", "gather", "while", "select-and-scatter",
    "cholesky", "triangular-solve",
} | _COLLECTIVES


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _first_shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Op:
    name: str
    kind: str
    out_type: str
    rest: str  # args + attributes text
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    is_entry: bool
    params: Dict[str, str] = field(default_factory=dict)  # name -> type
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # %name -> type


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if line.startswith("HloModule"):
            continue
        if not line.startswith(" ") and ("->" in line) and "(" in line:
            m = _COMP_RE.match(line.strip())
            if m:
                name = m.group(1)
                cur = Computation(name, is_entry=line.startswith("ENTRY"))
                for pname, ptype in _PARAM_RE.findall(m.group(2)):
                    cur.params[pname] = ptype
                    cur.symbols[pname] = ptype
                comps[cur.name] = cur
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, out_type, kind, rest = m.groups()
        # operands: %refs inside the parenthesised arg list (up to matching ')')
        depth, arg_end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    arg_end = i
                    break
        operands = _OPERAND_RE.findall(rest[:arg_end])
        op = Op(name, kind, out_type, rest, operands)
        cur.ops.append(op)
        cur.symbols[name] = out_type
    return comps


def _call_edges(comps: Dict[str, Computation]) -> Dict[str, List[Tuple[str, float]]]:
    """caller -> [(callee, factor)]; while bodies get their trip count."""
    edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "while":
                trip = 1.0
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    trip = float(tm.group(1))
                for rx in (_BODY_RE, _COND_RE):
                    m = rx.search(op.rest)
                    if m and m.group(1) in comps:
                        edges[comp.name].append((m.group(1), trip))
            elif op.kind in ("fusion", "call", "custom-call", "conditional", "map"):
                for t in _CALLS_RE.findall(op.rest):
                    if t in comps:
                        edges[comp.name].append((t, 1.0))
    return edges


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Topological propagation (the call graph is a DAG): a computation's
    multiplier must be final before its callees accumulate it."""
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {}
    edges = _call_edges(comps)
    indeg: Dict[str, int] = defaultdict(int)
    for caller, outs in edges.items():
        for callee, _ in outs:
            indeg[callee] += 1
    mult: Dict[str, float] = defaultdict(float)
    mult[entry.name] = 1.0
    # Kahn order over computations reachable from anywhere
    ready = [c for c in comps if indeg[c] == 0]
    topo: List[str] = []
    indeg = dict(indeg)
    while ready:
        c = ready.pop()
        topo.append(c)
        for callee, _ in edges.get(c, ()):  # noqa: B905
            indeg[callee] -= 1
            if indeg[callee] == 0:
                ready.append(callee)
    for c in topo:
        m = mult.get(c, 0.0)
        if m == 0.0:
            continue
        for callee, factor in edges.get(c, ()):  # noqa: B905
            mult[callee] += m * factor
    return dict(mult)


_FUSION_COMP_HINT = re.compile(r"fused|region|wide|computation")


@dataclass
class CostReport:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_op_bytes: Dict[str, float] = field(default_factory=dict)
    collective_out_bytes: Dict[str, float] = field(default_factory=dict)
    collective_count: Dict[str, int] = field(default_factory=dict)
    dot_flops: float = 0.0
    notes: List[str] = field(default_factory=list)

    @property
    def collective_bytes(self) -> float:
        return sum(self.collective_op_bytes.values())

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "dot_flops": self.dot_flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_op_bytes": dict(self.collective_op_bytes),
            "collective_out_bytes": dict(self.collective_out_bytes),
            "collective_count": dict(self.collective_count),
            "notes": list(self.notes),
        }


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = _shape_elems(op.out_type)
    lhs_dims_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contracted = 1
    if lhs_dims_m and op.operands:
        lhs_type = comp.symbols.get(op.operands[0])
        if lhs_type:
            dims = _first_shape_dims(lhs_type)
            if dims is not None and lhs_dims_m.group(1):
                for idx in lhs_dims_m.group(1).split(","):
                    i = int(idx)
                    if i < len(dims):
                        contracted *= dims[i]
    return 2.0 * out_elems * contracted


_FULL_OPERAND_KINDS = {
    "dot", "convolution", "sort", "scatter", "custom-call",
    "select-and-scatter", "cholesky", "triangular-solve",
} | _COLLECTIVES
_REDUCE_HINT = re.compile(r"reduce")
_DUS_HINT = re.compile(r"dynamic-update-slice|dynamic_update_slice")


def _op_bytes(op: Op, comp: Computation) -> float:
    """HBM traffic model per materializing op.

    * dot / reduce-like / collectives: full operands + output (they really
      stream every operand byte).
    * dynamic-update-slice (op or fusion): 2x the update slice — XLA updates
      the buffer in place; charging the whole buffer per scan iteration
      overstates traffic by the trip count.
    * other fusions / gathers / dynamic-slice: output + min(operand, output)
      per operand — a slice-heavy fusion only touches what it produces.
    """
    out_b = _shape_bytes(op.out_type)
    operand_bytes = []
    for o in op.operands:
        t = comp.symbols.get(o)
        if t:
            operand_bytes.append(_shape_bytes(t))
    if op.kind in _FULL_OPERAND_KINDS or (
        op.kind == "fusion" and _REDUCE_HINT.search(op.name)
    ):
        return out_b + float(sum(operand_bytes))
    if _DUS_HINT.search(op.name) or op.kind == "dynamic-update-slice":
        upd = min(operand_bytes) if operand_bytes else out_b
        return 2.0 * upd
    return out_b + float(sum(min(b, out_b) for b in operand_bytes))


def analyze(text: str) -> CostReport:
    comps = parse_module(text)
    mult = _multipliers(comps)
    rep = CostReport()
    fusion_comps = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.kind in ("fusion", "call", "map"):
                for t in _CALLS_RE.findall(op.rest):
                    fusion_comps.add(t)

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        inside_fusion = comp.name in fusion_comps
        for op in comp.ops:
            # ---- flops -------------------------------------------------
            if op.kind == "dot":
                f = _dot_flops(op, comp) * m
                rep.flops += f
                rep.dot_flops += f
            elif op.kind == "convolution":
                # rare here; approximate with 2 * |out| * window (unknown) -> |out|
                rep.flops += 2.0 * _shape_elems(op.out_type) * m
            elif op.kind in _ELEMENTWISE or op.kind in _REDUCE:
                rep.flops += float(_shape_elems(op.out_type)) * m
            elif op.kind == "exponential-minus-one":
                rep.flops += float(_shape_elems(op.out_type)) * m
            # ---- bytes ---------------------------------------------------
            if not inside_fusion and op.kind in _MATERIALIZING and op.kind != "while":
                rep.hbm_bytes += _op_bytes(op, comp) * m
            # ---- collectives -------------------------------------------
            if op.kind in _COLLECTIVES:
                kind = op.kind.replace("-start", "")
                ob = 0
                for o in op.operands:
                    t = comp.symbols.get(o)
                    if t:
                        ob += _shape_bytes(t)
                rep.collective_op_bytes[kind] = (
                    rep.collective_op_bytes.get(kind, 0.0) + ob * m
                )
                rep.collective_out_bytes[kind] = (
                    rep.collective_out_bytes.get(kind, 0.0)
                    + _shape_bytes(op.out_type) * m
                )
                rep.collective_count[kind] = rep.collective_count.get(kind, 0) + int(m)
    return rep


# ---------------------------------------------------------------------------
# Roofline terms (hardware constants from the brief)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link (per chip, one link)


def roofline_terms(
    rep: CostReport, n_chips: int, per_device: bool = True
) -> Dict[str, float]:
    """Seconds per term.  The analyzer sees the SPMD module of ONE device
    (post-partitioning shapes), so costs are already per-device."""
    flops = rep.flops
    bts = rep.hbm_bytes
    coll = rep.collective_bytes
    return {
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": bts / HBM_BW,
        "t_collective": coll / ICI_BW,
    }
