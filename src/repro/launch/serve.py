"""Serving driver: HGum request/response wire + batched prefill/decode.

Requests arrive as HGum-serialized wires (``request_schema`` — a List of
prompts with unknown lengths, the paper's List case).  The host DES
reconstructs prompts, pads them into a batch, runs prefill then greedy
decode, and serializes the response in the HW->SW direction (counts after
elements; the host parses from the end — paper §IV-B).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
      --n-prompts 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke_config
from ..core import (
    DesFSM,
    SerFSM,
    build_rom,
    des_hw_to_sw,
    msg_to_des_tokens,
    ser_sw_to_hw,
    strip_for_ser,
    tokens_to_msg,
)
from ..data.schemas import request_schema, response_schema
from ..models import init_cache, init_params
from .steps import make_prefill_step, make_serve_step


def encode_request(req_id: int, prompts: List[List[int]]) -> bytes:
    schema = request_schema()
    msg = {"req_id": req_id, "prompts": [{"tokens": p} for p in prompts]}
    return ser_sw_to_hw(schema, msg)


def decode_request(wire: bytes) -> Tuple[int, List[List[int]]]:
    """Hardware-side DES of the request (streaming FSM engine)."""
    schema = request_schema()
    rom = build_rom(schema)
    res = DesFSM(rom, "sw2hw").run(wire)
    msg = tokens_to_msg(schema, res.tokens)
    return msg["req_id"], [p["tokens"] for p in msg["prompts"]]


def encode_response(req_id: int, outputs: List[List[int]]) -> bytes:
    """Hardware-side SER (HW->SW: counts after elements)."""
    schema = response_schema()
    rom = build_rom(schema)
    msg = {"req_id": req_id, "outputs": [{"tokens": o} for o in outputs]}
    toks = strip_for_ser(msg_to_des_tokens(schema, msg))
    return SerFSM(rom, "hw2sw").run(toks).wire


def decode_response(wire: bytes) -> Tuple[int, List[List[int]]]:
    schema = response_schema()
    msg = des_hw_to_sw(schema, wire)
    return msg["req_id"], [o["tokens"] for o in msg["outputs"]]


def serve_request(
    params, cfg, wire: bytes, max_new: int = 16, pad_to: int = 64
) -> bytes:
    req_id, prompts = decode_request(wire)
    B = len(prompts)
    max_len = max(len(p) for p in prompts)
    S = min(pad_to, max(8, max_len))
    toks = np.zeros((B, S), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : min(len(p), S)] = p[:S]
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.family == "vlm":
        batch["vision"] = jnp.zeros((B, cfg.vision_tokens, cfg.vision_dim), jnp.float32)
    if cfg.family == "encdec":
        batch["audio"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model), jnp.float32)

    prefill_step = jax.jit(make_prefill_step(cfg, cache_len=S + max_new))
    serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    next_tok, cache = prefill_step(params, batch)
    out_tokens = [next_tok]
    tok = next_tok
    for _ in range(max_new - 1):
        tok, cache = serve_step(params, cache, tok)
        out_tokens.append(tok)
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)  # (B, max_new)
    outputs = [list(map(int, gen[i])) for i in range(B)]
    return encode_response(req_id, outputs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--n-prompts", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    prompts = [
        list(map(int, rng.integers(2, cfg.vocab, rng.integers(4, 24))))
        for _ in range(args.n_prompts)
    ]
    wire = encode_request(7, prompts)
    print(f"[serve] request wire: {len(wire)} bytes, {len(prompts)} prompts")
    t0 = time.time()
    resp_wire = serve_request(params, cfg, wire, max_new=args.max_new)
    dt = time.time() - t0
    rid, outs = decode_response(resp_wire)
    print(f"[serve] req {rid}: generated {sum(len(o) for o in outs)} tokens "
          f"in {dt:.2f}s; response wire {len(resp_wire)} bytes")
    for i, o in enumerate(outs[:2]):
        print(f"  out[{i}][:8] = {o[:8]}")


if __name__ == "__main__":
    main()
