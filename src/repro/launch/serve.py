"""Serving driver: the batched HGum message plane + continuous batching.

Requests arrive as HGum-serialized wires (``request_schema`` — a List of
prompts with unknown lengths, the paper's List case).  Request paths:
``serve_requests`` (local batched plane), ``serve_requests_sharded``
(whole-response wires over the routed fabric), ``serve_requests_streaming``
(token chunks stream back every decode tick, async fabric/compute overlap,
per-tenant QoS levels), and the seed ``serve_request`` baseline.  The first
two are documented below:

* **Batched plane (default)** — ``serve_requests`` takes MANY request wires
  at once.  One *batched structure pass* (``core.vectorized.batch_plans``)
  walks the schema a single time with per-message cursor columns and yields
  a ``BatchedDecodePlan`` with a leading message axis; one gather per leaf
  path (``decode_batch``) then decodes every payload of every message.  The
  reconstructed prompts feed ``runtime.scheduler.ContinuousBatcher`` — a
  fixed-slot KV cache with per-step admit/evict and *cached* jitted
  prefill/decode steps — and all responses are serialized back through the
  HW->SW SerFSM in bulk (one schema ROM shared across the batch, counts
  after elements so the host parses from the end — paper §IV-B).
* **Sequential path (seed baseline)** — ``serve_request`` answers one wire
  at a time with a fresh ROM walk, a streaming-FSM DES, and per-request
  ``jax.jit``.  Kept verbatim so ``benchmarks/bench_serve.py`` measures the
  batched plane against it.

Scheduler knobs (see ``runtime.scheduler.SchedulerConfig``):

* ``slots``      — concurrent sequences / KV-cache rows (decode batch width)
* ``prompt_cap`` — static prompt pad length (``--pad-to``)
* ``max_new``    — greedy tokens per sequence
* ``admit_cap``  — prefill width per scheduler tick (default: ``slots``)

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
      --n-requests 8 --n-prompts 4 --max-new 16 --slots 8
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke_config
from ..core import (
    DesFSM,
    SerFSM,
    batch_plans,
    build_rom,
    decode_batch,
    des_hw_to_sw,
    lanes_to_int,
    msg_to_des_tokens,
    ser_sw_to_hw,
    stack_wires,
    strip_for_ser,
    tokens_to_msg,
)
from ..data.schemas import request_schema, response_schema
from ..models import init_params
from ..runtime.scheduler import ContinuousBatcher, SchedulerConfig
from .steps import make_prefill_step, make_serve_step


def encode_request(req_id: int, prompts: List[List[int]]) -> bytes:
    schema = request_schema()
    msg = {"req_id": req_id, "prompts": [{"tokens": p} for p in prompts]}
    return ser_sw_to_hw(schema, msg)


def decode_request(wire: bytes) -> Tuple[int, List[List[int]]]:
    """Hardware-side DES of ONE request (streaming FSM engine — seed path)."""
    schema = request_schema()
    rom = build_rom(schema)
    res = DesFSM(rom, "sw2hw").run(wire)
    msg = tokens_to_msg(schema, res.tokens)
    return msg["req_id"], [p["tokens"] for p in msg["prompts"]]


def decode_request_batch(wires: List[bytes]) -> List[Tuple[int, List[List[int]]]]:
    """Batched DES of N request wires: one schema walk + one gather per leaf.

    The per-prompt lengths are read from the decoded *count fields* of the
    inner token lists (container paths decode like u32 leaves), so splitting
    the flat token column back into prompts needs no second walk.
    """
    schema = request_schema()
    # only these three leaves are consumed; skipping the outer 'prompts'
    # count leaf drops one gather from the request hot path
    paths = ["req_id", "prompts.elem.tokens", "prompts.elem.tokens.elem"]
    bplan = batch_plans(schema, wires, record_paths=paths)
    vals = decode_batch(jnp.asarray(stack_wires(wires)), bplan)
    rid_lanes = np.asarray(vals["req_id"])  # (N, 1, 2)
    len_lanes = np.asarray(vals["prompts.elem.tokens"])  # (N, capP, 1)
    tok_lanes = np.asarray(vals["prompts.elem.tokens.elem"])  # (N, capT, 1)
    out = []
    for m in range(len(wires)):
        rid = int(lanes_to_int(rid_lanes[m], 8)[0])
        n_prompts = int(bplan.counts["prompts.elem.tokens"][m])
        n_toks = int(bplan.counts["prompts.elem.tokens.elem"][m])
        lens = len_lanes[m, :n_prompts, 0].astype(np.int64)
        toks = tok_lanes[m, :n_toks, 0]
        splits = np.split(toks, np.cumsum(lens)[:-1]) if n_prompts else []
        out.append((rid, [list(map(int, p)) for p in splits]))
    return out


def encode_response(req_id: int, outputs: List[List[int]]) -> bytes:
    """Hardware-side SER (HW->SW: counts after elements)."""
    return encode_response_batch([(req_id, outputs)])[0]


def encode_response_batch(
    responses: List[Tuple[int, List[List[int]]]]
) -> List[bytes]:
    """Bulk HW->SW SER: one schema ROM shared by every response wire."""
    schema = response_schema()
    rom = build_rom(schema)
    wires = []
    for req_id, outputs in responses:
        msg = {"req_id": req_id, "outputs": [{"tokens": o} for o in outputs]}
        toks = strip_for_ser(msg_to_des_tokens(schema, msg))
        wires.append(SerFSM(rom, "hw2sw").run(toks).wire)
    return wires


def decode_response(wire: bytes) -> Tuple[int, List[List[int]]]:
    schema = response_schema()
    msg = des_hw_to_sw(schema, wire)
    return msg["req_id"], [o["tokens"] for o in msg["outputs"]]


# ---------------------------------------------------------------------------
# Sequential path — the seed's one-wire-at-a-time loop (benchmark baseline)
# ---------------------------------------------------------------------------


def serve_request(
    params, cfg, wire: bytes, max_new: int = 16, pad_to: int = 64
) -> bytes:
    """Answer ONE request wire (seed baseline: per-request ROM walk + jit)."""
    req_id, prompts = decode_request(wire)
    if not prompts:  # zero-prompt request: nothing to generate
        return encode_response(req_id, [])
    B = len(prompts)
    max_len = max(len(p) for p in prompts)
    S = min(pad_to, max(8, max_len))
    toks = np.zeros((B, S), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : min(len(p), S)] = p[:S]
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.family == "vlm":
        batch["vision"] = jnp.zeros((B, cfg.vision_tokens, cfg.vision_dim), jnp.float32)
    if cfg.family == "encdec":
        batch["audio"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model), jnp.float32)

    prefill_step = jax.jit(make_prefill_step(cfg, cache_len=S + max_new))
    serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    next_tok, cache = prefill_step(params, batch)
    out_tokens = [next_tok]
    tok = next_tok
    for _ in range(max_new - 1):
        tok, cache = serve_step(params, cache, tok)
        out_tokens.append(tok)
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)  # (B, max_new)
    outputs = [list(map(int, gen[i])) for i in range(B)]
    return encode_response(req_id, outputs)


# ---------------------------------------------------------------------------
# Batched plane — many wires in, many wires out
# ---------------------------------------------------------------------------


def serve_requests(
    params,
    cfg,
    wires: List[bytes],
    max_new: int = 16,
    pad_to: int = 64,
    slots: int = 8,
    admit_cap: Optional[int] = None,
) -> List[bytes]:
    """Answer N request wires through the batched message plane.

    Batched structure pass -> one gather per leaf -> continuous-batching
    generate -> bulk SER.  Responses come back in request order; a request
    with zero prompts yields an empty-outputs response wire.

    Padding semantics: every prompt is padded/truncated to the static
    ``pad_to`` (fixed KV slots need one shape), whereas the seed's
    ``serve_request`` picks ``min(pad_to, max(8, longest prompt))`` per
    request — so the two paths emit identical tokens exactly when prompts
    are >= ``pad_to`` long (both truncate to ``pad_to``).
    """
    reqs = decode_request_batch(wires)
    sched = SchedulerConfig(
        slots=slots, prompt_cap=pad_to, max_new=max_new, admit_cap=admit_cap
    )
    batcher = ContinuousBatcher(params, cfg, sched)
    for m, (_, prompts) in enumerate(reqs):
        for i, p in enumerate(prompts):
            batcher.submit((m, i), p)
    outs = batcher.run()
    responses = [
        (rid, [outs[(m, i)] for i in range(len(prompts))])
        for m, (rid, prompts) in enumerate(reqs)
    ]
    return encode_response_batch(responses)


# ---------------------------------------------------------------------------
# Sharded plane — requests routed over the message fabric to per-shard
# batchers (ISSUE 2); composes the batched plane with repro.fabric
# ---------------------------------------------------------------------------


def place_requests(
    router,
    n_requests: int,
    shards: List[int],
    capacity: int,
    weights: Optional[List[int]] = None,
    exclude=(),
) -> List[int]:
    """Topology-aware ingress placement (ROADMAP item): requests go to the
    nearest shard with free capacity instead of round-robin.

    Shards are ordered by round-trip fabric distance from the ingress
    (``Router.route_hops(0, s) + route_hops(s, 0)`` — request path plus
    response/stream return path, measured under the router's configured
    routing mode so placement stays consistent with the paths frames
    actually take: ``min_hops`` under shortest-path routing, +1-ring
    ``hops`` under dimension-order); each request takes the nearest shard
    whose load is still under ``capacity``, spilling to the next nearest
    when full.  When every shard is full, the least-loaded (nearest first)
    takes the overflow.  ``weights`` measures each request's load — pass
    per-request sequence counts with ``capacity`` = KV slots so "free"
    means free *decode slots* (the streaming ingress does; default: one
    unit per request).  Under +1-ring routing a 1D round trip is the same
    length from every shard; under shortest-path routing the round trip is
    ``2 * min_hops``, so near ranks genuinely cost less and placement
    prefers them.  Placement cannot change tokens — rows decode
    independently — only how far each request's wires travel.

    ``exclude`` removes shards from consideration entirely — the serve
    plane passes its *suspect* set (ranks that stopped ACKing) so
    neither fresh placement nor a retry ever lands on a rank believed
    dead.  Excluding every shard raises rather than silently placing on
    a suspect.
    """
    live = [s for s in shards if s not in exclude]
    if not live:
        raise ValueError(
            f"no healthy shard to place on: all of {sorted(shards)} are "
            f"excluded (suspect)"
        )
    order = sorted(
        live,
        key=lambda s: (router.route_hops(0, s) + router.route_hops(s, 0), s),
    )
    w = weights if weights is not None else [1] * n_requests
    load = {s: 0 for s in order}
    placement = []
    for i in range(n_requests):
        free = [s for s in order if load[s] < capacity]
        s = free[0] if free else min(order, key=lambda t: load[t])
        placement.append(s)
        load[s] += max(1, w[i])
    return placement


def _analyze_serve(fabric, n_requests: int, context: str) -> None:
    """The ``analyze=True`` serve hook: statically prove the serving
    schemas, the fabric config + topology, and the stream-id budget safe
    before any request crosses a link — raising on ERROR findings with the
    rule's fix hint.  Also arms the fabric's per-tick demand analysis."""
    from ..analysis import analyze_schema, assert_clean, finding
    from ..analysis.fabric_passes import analyze_fabric
    from ..data.schemas import request_schema, response_schema
    from ..stream.chunks import STREAM_ID_BITS

    fs = analyze_schema(request_schema(), location=f"{context}.request")
    fs += analyze_schema(response_schema(), location=f"{context}.response")
    fs += analyze_fabric(fabric, location=f"{context}.fabric")
    if n_requests >= (1 << STREAM_ID_BITS):
        fs.append(finding(
            "stream-id-width", context,
            f"{n_requests} requests overflow the u{STREAM_ID_BITS} "
            f"request lane of the (request | prompt) stream-id packing",
        ))
    assert_clean(fs, context)
    fabric.analyze = True  # per-tick demand checks from here on


def default_serve_fabric(
    n_shards: Optional[int] = None, routing: str = "shortest",
    defect_after: int = 0, analyze: bool = False, arq: bool = True,
    faults=None,
):
    """The fabric ``serve_requests_sharded`` builds when none is passed:
    rank 0 ingress plus up to 7 serving shards on the available devices,
    shortest-path routed with the fused single-jit tick (pass
    ``routing="dimension"`` for the legacy +1-ring discipline).
    ``defect_after=k`` enables congestion-aware direction defection: a
    frame whose preferred ring direction has been credit-starved for k
    consecutive router steps escapes to the other direction.

    ``arq=True`` (the serving default) turns on reliable delivery: every
    request/response/chunk message is retransmit-buffered and recovered
    on NACK or timeout, so seeded chaos (``faults`` — a
    ``fabric.faults.FaultPlan``, e.g. from ``parse_chaos``) degrades
    latency instead of correctness.  ``arq=False`` is the escape hatch
    back to flag-only delivery.
    Returns None when fewer than 2 ranks fit (no shard to route to)."""
    from ..fabric import Fabric, FabricConfig

    n_devices = len(jax.devices())
    n_ranks = (n_shards + 1) if n_shards else min(n_devices, 8)
    if n_ranks > n_devices:
        raise ValueError(
            f"n_shards={n_shards} needs {n_ranks} devices (shards + ingress) "
            f"but only {n_devices} are visible — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count or lower n_shards"
        )
    if n_ranks < 2:
        return None
    fab = Fabric(
        n_ranks=n_ranks,
        config=FabricConfig(frame_phits=16, routing=routing,
                            defect_after=defect_after, arq=arq),
        analyze=analyze,
    )
    fab.faults = faults
    return fab


def serve_requests_sharded(
    params,
    cfg,
    wires: List[bytes],
    max_new: int = 16,
    pad_to: int = 64,
    slots: int = 8,
    admit_cap: Optional[int] = None,
    n_shards: Optional[int] = None,
    fabric=None,
    placement: Optional[List[int]] = None,
    routing: str = "shortest",
    defect_after: int = 0,
    analyze: bool = False,
    metrics=None,
    trace=None,
    suspect_after: Optional[int] = 24,
    deadline_ticks: Optional[int] = None,
) -> List[bytes]:
    """Answer N request wires across fabric-connected serving shards.

    Rank 0 is the *ingress*: it routes each request wire over the message
    fabric (``repro.fabric``) to one of the serving shards (ranks 1..R-1,
    nearest free shard first — ``place_requests``; pass ``placement`` to
    pin requests to shards), every shard answers its share through the batched plane
    (``serve_requests`` — batched DES, ContinuousBatcher, bulk SER), and the
    response wires ride the fabric back to the ingress, which restores
    request order.  Requests and responses cross the links as routed framed
    Lists with CRC32 per frame; responses from shard ``s`` take the
    multi-hop return path (``R - s`` ring hops).

    Token-identical to ``serve_requests`` on the same wires: both pad every
    prompt to the static ``pad_to``, and rows decode independently, so shard
    placement cannot change the greedy outputs.

    Failure awareness (requires an ARQ fabric, the ``default_serve_fabric``
    default): the loop keeps ticking until every request is answered or
    ``deadline_ticks`` fabric ticks elapse (default 256 with ARQ; exactly
    the legacy 2-exchange schedule without it), and a shard the ingress has
    not heard from — no data, no ACKs — for more than ``suspect_after``
    ticks while it still owes responses is marked *suspect*: its
    outstanding requests are re-placed once onto healthy shards
    (``place_requests(..., exclude=suspects)``).  Rows decode
    independently and greedily, so a retried request re-decodes to the
    same bytes and the answer stays byte-identical; a request whose retry
    also dies raises.  ``suspect_after=None`` disables the detector.

    Falls back to the local batched plane when the fabric would have fewer
    than 2 ranks (no shard to route to).
    """
    if fabric is None:
        fabric = default_serve_fabric(n_shards, routing=routing,
                                      defect_after=defect_after)
    if fabric is None or fabric.n_ranks < 2:
        return serve_requests(
            params, cfg, wires, max_new=max_new, pad_to=pad_to,
            slots=slots, admit_cap=admit_cap,
        )
    if metrics is not None:
        fabric.metrics = metrics
    if trace is not None:
        fabric.trace = trace
    if analyze:
        _analyze_serve(fabric, len(wires), "serve_requests_sharded")
    shards = list(range(1, fabric.n_ranks))
    ingress = fabric.mailbox(0)
    if placement is None:
        placement = place_requests(
            fabric.router, len(wires), shards, capacity=max(1, slots)
        )

    # ingress -> shards: route the raw request wires.  queue[s] is the
    # FIFO of global request indices shard s owes responses for — every
    # (src, dst) stream delivers in order (ARQ enforces it under faults),
    # so the k-th response arriving from s answers queue[s][k]
    queue: Dict[int, List[int]] = {s: [] for s in shards}
    for i, w in enumerate(wires):
        queue[placement[i]].append(i)
        ingress.send(placement[i], w)

    arq = bool(fabric.config.arq)
    watch = arq and suspect_after is not None
    max_ticks = (deadline_ticks or 256) if arq else 3
    t0_tick = fabric.ticks if arq else 0
    answered: Dict[int, bytes] = {}
    cursor = {s: 0 for s in shards}
    suspects: set = set()
    retried: set = set()
    wait_since: Dict[int, int] = {}  # shard -> tick its current debt began
    for _ in range(max_ticks):
        fabric.exchange()
        # each shard answers newly arrived request wires through the
        # batched plane and sends the response wires back
        for s in shards:
            box = fabric.mailbox(s)
            arrived = box.recv()
            if s in suspects or not arrived:
                continue
            bad = [d.src for d in arrived if not d.ok]
            if bad:
                raise RuntimeError(
                    f"shard {s}: corrupt request frames from {bad}")
            resp = serve_requests(
                params, cfg, [d.wire for d in arrived], max_new=max_new,
                pad_to=pad_to, slots=slots, admit_cap=admit_cap,
            )
            for rw in resp:
                box.send(0, rw)
        # ingress: responses arrive per-shard in FIFO order; undo the
        # placement.  setdefault: when a slow shard was wrongly suspected,
        # the FIRST answer (original or retry) wins — both are identical
        for d in ingress.recv():
            if not d.ok:
                raise RuntimeError(
                    f"ingress: corrupt response frames from {d.src}")
            i = queue[d.src][cursor[d.src]]
            cursor[d.src] += 1
            answered.setdefault(i, d.wire)
        if len(answered) == len(wires):
            break
        if not watch:
            continue
        for s in shards:
            if s in suspects:
                continue
            outstanding = [i for i in queue[s][cursor[s]:]
                           if i not in answered]
            if not outstanding:
                wait_since.pop(s, None)
                continue  # a shard that owes nothing goes quiet, fine
            # the horizon starts when the shard last spoke OR when its
            # current debt began, whichever is later — a shard that sat
            # idle before being handed a retry is not late
            since = wait_since.setdefault(s, fabric.ticks)
            heard = fabric.ticks_since_heard(0, s)
            waited = (fabric.ticks - t0_tick) if heard is None else heard
            waited = min(waited, fabric.ticks - since)
            if waited <= suspect_after:
                continue
            # rank s stopped ACKing with responses outstanding: mark it
            # suspect and retry its in-flight requests elsewhere, once
            suspects.add(s)
            # the fabric registry is always on (and IS `metrics` when one
            # was passed), so recovery stays observable either way
            fabric.metrics.counter("serve.suspects").add(1)
            twice = [i for i in outstanding if i in retried]
            if twice:
                raise RuntimeError(
                    f"sharded serve: request(s) {twice} failed on shard "
                    f"{s} after a retry — no healthy shard answered")
            repl = place_requests(
                fabric.router, len(outstanding), shards,
                capacity=max(1, slots), exclude=suspects)
            for i, s2 in zip(outstanding, repl):
                retried.add(i)
                queue[s2].append(i)
                ingress.send(s2, wires[i])
                fabric.metrics.counter("serve.retries").add(1)
    if len(answered) < len(wires):
        missing = sorted(i for i in range(len(wires)) if i not in answered)
        raise RuntimeError(
            f"sharded serve: {len(missing)} request(s) unanswered after "
            f"{max_ticks} fabric ticks (missing {missing[:8]})")
    out = [answered[i] for i in range(len(wires))]
    if metrics is not None:
        metrics.gauge("fabric.load_drift.entries").set(
            len(fabric.load_drift())
        )
    return out


# ---------------------------------------------------------------------------
# Streaming plane — tokens leave each shard the tick they decode (ISSUE 3);
# composes the batched compute plane with repro.stream over repro.fabric
# ---------------------------------------------------------------------------

#: ListLevel reserved for the typed logprob side-stream when
#: ``serve_requests_streaming(logprobs=True)`` — the ingress partitions
#: deliveries between the token reader and the logprob reader by this tag,
#: so tenant QoS levels must stay below it (254 itself stays clear of the
#: fabric's ``FabricConfig.arq_level`` control class, 255)
LOGPROB_STREAM_LEVEL = 254


def serve_requests_streaming(
    params,
    cfg,
    wires: List[bytes],
    max_new: int = 16,
    pad_to: int = 64,
    slots: int = 8,
    admit_cap: Optional[int] = None,
    n_shards: Optional[int] = None,
    fabric=None,
    placement: Optional[List[int]] = None,
    qos_levels: Optional[List[int]] = None,
    overlap: bool = True,
    on_token=None,
    on_event=None,
    routing: str = "shortest",
    defect_after: int = 0,
    backpressure_p95: Optional[float] = None,
    backpressure_chunks: int = 1,
    backpressure_hold: int = 3,
    analyze: bool = False,
    metrics=None,
    trace=None,
    spans=None,
    suspect_after: Optional[int] = 24,
    deadline_ticks: Optional[int] = None,
    logprobs: bool = False,
    on_logprob=None,
) -> List[bytes]:
    """Answer N request wires with token-level streamed responses.

    Same placement and compute as ``serve_requests_sharded`` — rank-0
    ingress, nearest-free-shard placement, one ContinuousBatcher per shard
    — but the response path streams: every decode tick, each shard writes
    the step's tokens into per-sequence ``StreamWriter``s and one
    ``ChunkLane`` burst per (shard, tenant) rides the fabric back, so the
    ingress sees each token one fabric tick after it decodes instead of
    after the whole generation.  ``on_token(req_idx, prompt_idx, step,
    token)`` fires as tokens arrive (time-to-first-token = first admit tick
    + one exchange).

    With ``overlap=True`` (default) the fabric and compute pipelines run
    double-buffered: each tick dispatches the batched decode
    (``ContinuousBatcher.step_begin``), reaps the PREVIOUS tick's routed
    chunks while the decode executes (``Fabric.poll``), syncs the decode
    (``step_finish``), and dispatches the new bursts without waiting
    (``Fabric.exchange_async``) — multi-hop latency hides behind decode
    steps.  ``overlap=False`` runs the same ticks synchronously (chunks
    arrive one tick earlier; tokens identical either way).

    ``qos_levels`` tags each request's stream chunks with a ListLevel (the
    tenant's QoS class when the fabric is built with
    ``FabricConfig.qos_weights``); default: level 1 for everyone.
    ``on_event(StreamEvent)`` fires per arriving chunk with the raw stream
    event (including ``arrive_step``, the router scan step its carrying
    message arrived at — benchmarks use it to measure time-to-token
    jitter); ``routing`` picks the fabric's routing mode when no ``fabric``
    is passed, and ``defect_after=k`` additionally lets a credit-starved
    frame defect to the opposite ring direction after k starved router
    steps (congestion-aware routing).

    ``backpressure_p95`` closes the latency feedback loop: every tick the
    ingress reader's per-QoS-class arrive-step percentiles
    (``StreamReader.class_arrive_stats``, sliding window) feed back into
    each shard's ``ChunkLane``; a lane whose class p95 exceeds the
    threshold clamps its flush rate — it *trickles* ``backpressure_chunks``
    chunks per tick (default 1) and holds the rest — so its WRR credit
    quota spills to the healthy tenants and a stalled tenant stops
    inflating everyone else's queues.  ``backpressure_chunks=0`` holds
    entirely instead of trickling, with ``backpressure_hold`` bounding the
    consecutive fully-held flushes so a stream can never stall forever.
    Held chunks ride later bursts in order; the streamed tokens and the
    final wires are identical with backpressure on or off.

    Returns the final response wires, byte-identical to ``serve_requests``
    on the same inputs (the streamed tokens are re-serialized through the
    same bulk SER).  Falls back to the local batched plane (no streaming
    events) when the fabric would have fewer than 2 ranks.

    ``metrics`` (an ``obs.metrics.MetricsRegistry``) turns on serve-level
    telemetry — per-stream TTFT (``serve.ttft_s``), per-tick token rate
    (``serve.tick.tokens`` + the final ``serve.tokens_per_s`` gauge), and
    the per-class backpressure feedback values (``serve.backpressure.p95``)
    — and is shared with the fabric, the batchers, the lanes, and the
    reader, so one ``snapshot()`` covers the whole stack.  ``trace`` (an
    ``obs.trace.TraceRecorder``) records the tick/chunk/recompile
    timeline.  ``spans`` (an ``obs.spans.SpanTracker``; auto-created when
    a ``trace`` is given) mints one request id per request wire at
    ingress and follows it through mailbox deliveries, batcher
    admit/evict, lane first-flush and first-token — the end-to-end causal
    arc the attribution report breaks down.  All three are
    observation-only: tokens and final wires are byte-identical with or
    without them (property-tested).

    Failure awareness (requires an ARQ fabric, the ``default_serve_fabric``
    default): a shard the ingress has not heard from — no chunks, no ACKs
    — for more than ``suspect_after`` fabric ticks while it still owes
    live streams is marked *suspect*.  Its batcher and lanes are dropped,
    its unfinished streams abandoned, and every request that had not
    fully streamed there is re-sent once to a healthy shard
    (``place_requests(..., exclude=suspects)``), where it re-decodes from
    scratch and re-streams under fresh stream ids; greedy decode makes
    the retried tokens — and therefore the final wires — byte-identical
    to an undisturbed run.  Each retry leg is visible as a
    ``serve.retry`` span event plus ``serve.retries``/``serve.suspects``
    counters.  A request whose retry shard also dies raises.  When no
    compute remains, the loop keeps draining in-flight chunks for up to
    ``deadline_ticks`` fabric ticks (default 256 with ARQ; the legacy 3
    without) before declaring the missing streams lost.
    ``suspect_after=None`` disables the detector.

    ``logprobs=True`` attaches the *second typed stream*: per-token
    logprobs as the schema-declared ``Stream<Struct{tok, logprob}>``
    (``stream.chunks.LOGPROB_STREAM_SCHEMA_JSON``), generated by
    ``core.stream_plans`` with no hand-written codec.  Each shard runs
    one extra ``ChunkLane`` on the reserved :data:`LOGPROB_STREAM_LEVEL`
    ListLevel carrying ``(token, float32-bit-pattern)`` elements, and the
    ingress demultiplexes it through a second plan-parametric
    ``StreamReader``.  ``on_logprob(req_idx, prompt_idx, step, token,
    logprob)`` fires per element.  The greedy pick is computed exactly as
    without logprobs, so tokens — and the returned wires — are
    byte-identical with or without the extra stream attached (CI gates
    on this).
    """
    from ..stream import ChunkLane, StreamReader, logprob_stream_plan

    if fabric is None:
        fabric = default_serve_fabric(n_shards, routing=routing,
                                      defect_after=defect_after)
    if fabric is None or fabric.n_ranks < 2:
        return serve_requests(
            params, cfg, wires, max_new=max_new, pad_to=pad_to,
            slots=slots, admit_cap=admit_cap,
        )
    if metrics is not None:
        fabric.metrics = metrics  # one registry across the whole stack
    if trace is not None:
        fabric.trace = trace
        if spans is None:
            from ..obs import SpanTracker

            spans = SpanTracker(trace)
    if spans is not None:
        fabric.spans = spans  # deliveries correlate back to request ids
        spans.set_tick(0)
    if analyze:
        _analyze_serve(fabric, len(wires), "serve_requests_streaming")
    shards = list(range(1, fabric.n_ranks))
    ingress = fabric.mailbox(0)
    reqs = decode_request_batch(wires)  # ingress keeps rids + prompt counts
    if placement is None:
        # the ingress already decoded the burst, so placement can weigh each
        # request by its sequence count: "free" = free KV slots, not
        # request headroom
        placement = place_requests(
            fabric.router, len(wires), shards, capacity=max(1, slots),
            weights=[len(p) for _, p in reqs],
        )
    levels = list(qos_levels) if qos_levels is not None else [1] * len(wires)
    if logprobs and any(lvl >= LOGPROB_STREAM_LEVEL for lvl in levels):
        raise ValueError(
            f"qos_levels must stay below the reserved logprob stream "
            f"level {LOGPROB_STREAM_LEVEL} when logprobs=True"
        )

    # ingress -> shards: mint one span per request at tick 0 and route the
    # raw request wires, each tagged with its request id so every fabric
    # delivery it causes correlates back to the span
    rid_of: List[Optional[int]] = [None] * len(wires)
    for i, w in enumerate(wires):
        if spans is not None:
            rid_of[i] = spans.start("request", req=i, cls=levels[i],
                                    shard=placement[i])
            spans.event(rid_of[i], "serve.ingress", shard=placement[i])
        ingress.send(placement[i], w, list_level=levels[i],
                     request_id=rid_of[i])
    fabric.exchange()

    # shard setup: per-shard batcher + per-sequence stream writers.  The
    # k-th delivery at shard s is the k-th entry of globals_of[s]
    # (per-source FIFO; ARQ keeps it true under faults), which maps
    # shard-local stream ids back to global requests — retried requests
    # are appended to globals_of at re-send time, preserving the map.
    arq = bool(fabric.config.arq)
    watch = arq and suspect_after is not None
    t0_tick = fabric.ticks if arq else 0
    globals_of = {s: [i for i, p in enumerate(placement) if p == s]
                  for s in shards}
    sched = SchedulerConfig(
        slots=slots, prompt_cap=pad_to, max_new=max_new, admit_cap=admit_cap
    )
    batchers: Dict[int, ContinuousBatcher] = {}
    lanes: Dict[Tuple[int, int], ChunkLane] = {}
    writers: Dict[Tuple[int, int, int], object] = {}
    expected = []  # (src shard, stream_id) keys the reader must close
    # corrupt deliveries on an ARQ fabric mean the link already gave up
    # retransmitting (skip) — drop them and let the suspect machinery
    # re-place the request instead of poisoning the stream
    reader = StreamReader(metrics=metrics, spans=spans,
                          on_corrupt="retry" if arq else "flag")
    # second typed stream: the schema-declared logprob plan gets its own
    # reader (streams are keyed (src, stream_id) per reader; the reserved
    # ListLevel partitions deliveries between the two planes).  Span
    # accounting stays on the token reader — one open-stream count per
    # request, not two.
    lp_reader = (
        StreamReader(metrics=metrics, plan=logprob_stream_plan(),
                     on_corrupt="retry" if arq else "flag")
        if logprobs else None
    )
    lp_writers: Dict[Tuple[int, int, int], object] = {}
    open_streams: Dict[int, int] = {}  # rid -> streams not yet at EOS
    admitted = {s: 0 for s in shards}  # request wires admitted at s
    suspects: set = set()
    retried: set = set()
    abandoned: set = set()  # (src, stream_id) keys of dead streams

    def _admit(s: int) -> None:
        # admit newly arrived request wires at shard s into its (possibly
        # new) batcher — runs at setup and once per tick, so a retried
        # request re-routed to s mid-serve joins its continuous batch
        # exactly like an initial one
        box = fabric.mailbox(s)
        arrived = box.recv()
        if not arrived:
            return
        bad = [d.src for d in arrived if not d.ok]
        if bad:
            raise RuntimeError(f"shard {s}: corrupt request frames from {bad}")
        local_reqs = decode_request_batch([d.wire for d in arrived])
        batcher = batchers.get(s)
        if batcher is None:
            batcher = ContinuousBatcher(params, cfg, sched, metrics=metrics,
                                        spans=spans, logprobs=logprobs)
            batchers[s] = batcher
        for d, (_, prompts) in zip(arrived, local_reqs):
            k = admitted[s]
            admitted[s] += 1
            lvl = levels[globals_of[s][k]]
            lane = lanes.setdefault(
                (s, lvl),
                ChunkLane(box, 0, list_level=lvl,
                          p95_threshold=backpressure_p95,
                          clamp_chunks=backpressure_chunks,
                          max_hold=backpressure_hold,
                          metrics=metrics),
            )
            lane.spans = spans
            if logprobs:
                lp_lane = lanes.setdefault(
                    (s, LOGPROB_STREAM_LEVEL),
                    ChunkLane(box, 0, list_level=LOGPROB_STREAM_LEVEL,
                              plan=logprob_stream_plan(), metrics=metrics),
                )
            rid = d.request_id if spans is not None else None
            for j, p in enumerate(prompts):
                batcher.submit((k, j), p)
                sid = (k << 16) | j
                writers[(s, k, j)] = lane.writer(sid)
                if logprobs:
                    lp_writers[(s, k, j)] = lp_lane.writer(sid)
                expected.append((s, sid))
                if rid is not None:
                    batcher.span_of[(k, j)] = rid
                    lane.span_ids[sid] = rid
                    reader.span_ids[(s, sid)] = rid
                    open_streams[rid] = open_streams.get(rid, 0) + 1

    for s in shards:
        _admit(s)

    def _live_expected():
        return [key for key in expected if key not in abandoned]

    def _stream_done(key) -> bool:
        st = reader.streams.get(key)
        return st is not None and st.eos

    def _mark_suspect(s: int) -> None:
        # rank s stopped ACKing: drop its compute and lanes, abandon its
        # unfinished streams, and re-send every request that had not fully
        # streamed there to a healthy shard — once; a second failure is an
        # outage, not a flaky link.  Requests that already reached EOS on
        # s keep their streams (and tokens) untouched.
        suspects.add(s)
        batchers.pop(s, None)
        for key in [k for k in lanes if k[0] == s]:
            del lanes[key]
        for key in [k for k in writers if k[0] == s]:
            del writers[key]
        for key in [k for k in lp_writers if k[0] == s]:
            del lp_writers[key]
        # the fabric registry is always on (and IS `metrics` when one was
        # passed), so recovery stays observable either way
        fabric.metrics.counter("serve.suspects").add(1)
        inflight = []
        for k, i in enumerate(globals_of[s]):
            keys = [(s, (k << 16) | j) for j in range(len(reqs[i][1]))]
            if k < admitted[s] and all(_stream_done(key) for key in keys):
                continue
            for key in keys:
                abandoned.add(key)
                rid = reader.span_ids.get(key)
                if rid is not None and not _stream_done(key):
                    open_streams[rid] = open_streams.get(rid, 1) - 1
            if i in retried:
                raise RuntimeError(
                    f"streaming serve: request {i} failed on shard {s} "
                    f"after a retry — no healthy shard answered it")
            inflight.append(i)
        if not inflight:
            return
        repl = place_requests(
            fabric.router, len(inflight), shards, capacity=max(1, slots),
            weights=[len(reqs[i][1]) for i in inflight], exclude=suspects)
        for i, s2 in zip(inflight, repl):
            retried.add(i)
            globals_of[s2].append(i)
            if spans is not None and rid_of[i] is not None:
                spans.event(rid_of[i], "serve.retry", from_shard=s,
                            to_shard=s2)
            ingress.send(s2, wires[i], list_level=levels[i],
                         request_id=rid_of[i])
            fabric.metrics.counter("serve.retries").add(1)

    wait_since: Dict[int, int] = {}  # shard -> tick its current debt began

    def _check_suspects() -> None:
        for s in shards:
            if s in suspects:
                continue
            # only a shard that still owes something can be suspect — a
            # shard that finished its share goes legitimately quiet
            waiting = (
                admitted[s] < len(globals_of[s])
                or any(key[0] == s and key not in abandoned
                       and not _stream_done(key) for key in expected))
            if not waiting:
                wait_since.pop(s, None)
                continue
            # the horizon starts when the shard last spoke OR when its
            # current debt began, whichever is later — a shard that sat
            # legitimately idle before being handed a retry is not late
            since = wait_since.setdefault(s, fabric.ticks)
            heard = fabric.ticks_since_heard(0, s)
            waited = (fabric.ticks - t0_tick) if heard is None else heard
            waited = min(waited, fabric.ticks - since)
            if waited > suspect_after:
                _mark_suspect(s)

    # the streamed tick pipeline
    t_serve0 = time.perf_counter()
    seen_first: set = set()  # stream keys that produced their first token
    tok_count = [0, 0]  # [total tokens arrived, tokens this tick]

    def _pump() -> None:
        got = ingress.recv()
        if lp_reader is not None:
            # the reserved ListLevel partitions the two typed streams
            lp_got = [d for d in got if d.list_level == LOGPROB_STREAM_LEVEL]
            got = [d for d in got if d.list_level != LOGPROB_STREAM_LEVEL]
            for ev in lp_reader.feed(lp_got):
                key = (ev.src, ev.stream_id)
                if key in abandoned:
                    continue  # stale side-stream of a retried request
                if not ev.ok:
                    raise RuntimeError(
                        f"ingress: corrupt logprob stream chunks from "
                        f"shard {ev.src}"
                    )
                if on_logprob is not None:
                    k, j = ev.stream_id >> 16, ev.stream_id & 0xFFFF
                    m = globals_of[ev.src][k]
                    for t, (tok, bits) in enumerate(ev.tokens):
                        lpv = float(np.uint32(bits).view(np.float32))
                        on_logprob(m, j, ev.step + t, int(tok), lpv)
        for ev in reader.feed(got):
            key = (ev.src, ev.stream_id)
            if key in abandoned:
                continue  # stale chunks from a suspect shard's old stream
            if not ev.ok:
                raise RuntimeError(
                    f"ingress: corrupt stream chunks from shard {ev.src}"
                )
            tok_count[0] += len(ev.tokens)
            tok_count[1] += len(ev.tokens)
            if ev.tokens and key not in seen_first:
                seen_first.add(key)
                ttft = time.perf_counter() - t_serve0
                if metrics is not None:
                    metrics.histogram("serve.ttft_s", base=0.001).observe(ttft)
                    metrics.series("serve.ttft_s.series").append(ttft)
                if spans is not None and key in reader.span_ids:
                    spans.event(reader.span_ids[key], "serve.first_token",
                                ttft_s=ttft)
            if ev.eos and spans is not None and key in reader.span_ids:
                rid = reader.span_ids[key]
                open_streams[rid] = open_streams.get(rid, 1) - 1
                if open_streams[rid] <= 0:
                    spans.finish(rid)
            if trace is not None:
                trace.instant(
                    "stream.chunk", cat="stream", pid=ev.src,
                    args={"stream": ev.stream_id, "step": ev.step,
                          "tokens": len(ev.tokens),
                          "arrive_step": ev.arrive_step},
                )
            if on_event is not None:
                on_event(ev)
            if on_token is not None:
                k, j = ev.stream_id >> 16, ev.stream_id & 0xFFFF
                m = globals_of[ev.src][k]
                for t, tok in enumerate(ev.tokens):
                    on_token(m, j, ev.step + t, tok)
        per_class = (
            reader.class_arrive_stats(window=64)
            if (backpressure_p95 is not None or metrics is not None)
            else {}
        )
        if metrics is not None:
            # the live backpressure feedback values, recorded whether or
            # not a threshold acts on them — the observability of the loop
            # must not depend on the loop being closed
            for cls, st in per_class.items():
                metrics.series("serve.backpressure.p95",
                               cls=cls).append(st["p95"])
        if backpressure_p95 is not None:
            # close the loop: the reader's per-class p95 arrive latency
            # clamps (or releases) each lane's flush rate for next tick;
            # the sliding window lets a clamped tenant recover once its
            # congested tail has drained
            for lane in lanes.values():
                st = per_class.get(lane.list_level)
                lane.feedback(st["p95"] if st else None)

    tick = 0
    idle = 0
    drain_cap = (deadline_ticks or 256) if arq else 3
    force_flushed = False
    while True:
        active = any(b.pending or b.n_active for b in batchers.values())
        awaiting = any(admitted[s] < len(globals_of[s])
                       for s in shards if s not in suspects)
        if (not active and not awaiting
                and reader.all_eos(_live_expected())
                and (lp_reader is None
                     or lp_reader.all_eos(_live_expected()))):
            break
        tick += 1
        if spans is not None:
            spans.set_tick(tick)  # ingress was tick 0; the loop is 1..N
        if active:
            idle = 0
            force_flushed = False
            t_tick0 = trace.now_us() if trace is not None else 0.0
            tok_count[1] = 0
            for b in batchers.values():
                b.step_begin()  # dispatch compute; device runs in background
            if overlap:
                fabric.poll()  # reap last tick's chunks while decode runs
                _pump()
            for s, b in list(batchers.items()):
                for (k, j), pos, tok in b.step_finish():
                    eos = pos == max_new - 1
                    writers[(s, k, j)].write((tok,), eos=eos)
                    if logprobs:
                        # the logprob element is (tok, float32 bit
                        # pattern) — the schema's Struct{tok, logprob}
                        bits = int(np.float32(
                            b.tick_logprobs[((k, j), pos)]
                        ).view(np.uint32))
                        lp_writers[(s, k, j)].write(((tok, bits),), eos=eos)
            for lane in lanes.values():
                lane.flush()  # ONE burst per (shard, tenant) this tick
            if overlap:
                fabric.exchange_async()  # dispatch routing; overlap next tick
            else:
                fabric.exchange()
                _pump()
            if metrics is not None:
                metrics.series("serve.tick.tokens").append(tok_count[1])
            if trace is not None:
                trace.complete("serve.tick", t_tick0,
                               trace.now_us() - t_tick0, cat="serve",
                               args={"tokens_arrived": tok_count[1]})
        else:
            # nothing left to compute: force out any bursts a clamped
            # lane still holds, then keep the fabric ticking so in-flight
            # chunks, ARQ recovery traffic, and retried request wires land
            if not force_flushed:
                for lane in lanes.values():
                    lane.flush(force=True)
                force_flushed = True
            idle += 1
            if idle > drain_cap:
                raise RuntimeError(
                    "streaming serve: streams did not reach EOS")
            fabric.exchange()
            _pump()
        if watch:
            _check_suspects()
            for s in shards:
                if s not in suspects:
                    _admit(s)
    if metrics is not None:
        dt = max(time.perf_counter() - t_serve0, 1e-9)
        metrics.gauge("serve.tokens_per_s").set(tok_count[0] / dt)
        metrics.counter("serve.tokens").add(tok_count[0])
        metrics.gauge("fabric.load_drift.entries").set(
            len(fabric.load_drift())
        )

    # final wires from the streamed tokens — same bulk SER as the batched
    # plane, so the result is byte-identical to serve_requests
    outs: Dict[Tuple[int, int], List[int]] = {}
    for (src, sid), st in reader.streams.items():
        if (src, sid) in abandoned:
            continue  # a retried request's dead first attempt
        m = globals_of[src][sid >> 16]
        outs[(m, sid & 0xFFFF)] = st.tokens
    responses = [
        (rid, [outs[(m, j)] for j in range(len(prompts))])
        for m, (rid, prompts) in enumerate(reqs)
    ]
    return encode_response_batch(responses)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--n-requests", type=int, default=4)
    ap.add_argument("--n-prompts", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pad-to", type=int, default=64)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--sequential", action="store_true",
                    help="use the seed one-wire-at-a-time path")
    ap.add_argument("--sharded", action="store_true",
                    help="route requests over the message fabric to "
                         "per-shard batchers (ranks 1..N serve, rank 0 ingress)")
    ap.add_argument("--streaming", action="store_true",
                    help="sharded serve with token-level streamed responses "
                         "(chunks ride the fabric back every decode tick)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable the async fabric/compute overlap pipeline "
                         "for --streaming")
    ap.add_argument("--logprobs", action="store_true",
                    help="for --streaming: attach the typed logprob "
                         "side-stream (Stream<Struct{tok, logprob}> from "
                         "schema JSON); tokens are byte-identical either "
                         "way")
    ap.add_argument("--n-shards", type=int, default=None,
                    help="serving shards for --sharded/--streaming "
                         "(default: devices-1)")
    ap.add_argument("--routing", choices=("shortest", "dimension"),
                    default="shortest",
                    help="fabric routing mode for --sharded/--streaming: "
                         "per-frame shortest ring direction (default) or "
                         "the legacy +1-only dimension order")
    ap.add_argument("--defect-after", type=int, default=0,
                    help="congestion-aware routing: let a frame defect to "
                         "the opposite ring direction after its preferred "
                         "link has been credit-starved for this many "
                         "consecutive router steps (0 = static shortest)")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="seeded deterministic fault injection on the serve "
                         "fabric: 'drop=0.02,corrupt=0.01,...' (see "
                         "repro.fabric.faults.parse_chaos); deterministic "
                         "in --seed")
    ap.add_argument("--no-arq", action="store_true",
                    help="disable ARQ reliable delivery on the serve fabric "
                         "(corruption is flagged, never recovered)")
    ap.add_argument("--suspect-after", type=int, default=24,
                    help="mark a shard suspect — and retry its in-flight "
                         "requests on a healthy shard — after this many "
                         "fabric ticks without hearing from it (needs ARQ; "
                         "0 disables)")
    ap.add_argument("--deadline-ticks", type=int, default=None,
                    help="max fabric ticks to wait on in-flight deliveries "
                         "before the serve gives up (default 256 with ARQ, "
                         "3 without)")
    ap.add_argument("--backpressure-p95", type=float, default=None,
                    help="for --streaming: clamp a tenant lane's flush "
                         "rate while its QoS class's p95 arrive latency "
                         "(router steps) exceeds this threshold")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the run's metrics snapshot (repro.obs "
                         "registry + environment meta) as JSON")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON timeline of ticks, "
                         "chunk arrivals and recompiles (load in "
                         "chrome://tracing or ui.perfetto.dev)")
    ap.add_argument("--attribution-json", default=None, metavar="PATH",
                    help="for --streaming: write the per-request span "
                         "export (latency attribution + degradation) as "
                         "JSON; render with `python -m repro.obs "
                         "attribution PATH`")
    ap.add_argument("--slo", default=None, metavar="SPEC",
                    help="evaluate SLO targets against the run's metrics "
                         "('k=v,k=v' inline or a JSON file; see "
                         "repro.obs.slo) and exit 1 on any violation")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    metrics = trace = spans = None
    if args.metrics_json or args.trace_out or args.slo or args.attribution_json:
        from ..obs import MetricsRegistry, SpanTracker, TraceRecorder

        metrics = MetricsRegistry()
        if args.trace_out:
            trace = TraceRecorder()
        if args.attribution_json or args.trace_out:
            spans = SpanTracker(trace)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    serve_fabric = None
    if args.sharded or args.streaming:
        from ..fabric import parse_chaos

        faults = parse_chaos(args.chaos, args.seed) if args.chaos else None
        serve_fabric = default_serve_fabric(
            args.n_shards, routing=args.routing,
            defect_after=args.defect_after, arq=not args.no_arq,
            faults=faults)
        if args.chaos and serve_fabric is None:
            raise SystemExit("--chaos needs a multi-rank fabric "
                             "(>= 2 visible devices)")
    suspect_after = args.suspect_after if args.suspect_after > 0 else None

    rng = np.random.default_rng(args.seed)
    wires = []
    for r in range(args.n_requests):
        prompts = [
            list(map(int, rng.integers(2, cfg.vocab, rng.integers(4, 24))))
            for _ in range(args.n_prompts)
        ]
        wires.append(encode_request(r, prompts))
    total_b = sum(len(w) for w in wires)
    print(f"[serve] {len(wires)} request wires, {total_b} bytes total")
    t0 = time.time()
    first_tok_t = []
    lp_events = []
    if args.sequential:
        resp_wires = [
            serve_request(params, cfg, w, max_new=args.max_new,
                          pad_to=args.pad_to)
            for w in wires
        ]
    elif args.streaming:
        resp_wires = serve_requests_streaming(
            params, cfg, wires, max_new=args.max_new, pad_to=args.pad_to,
            slots=args.slots, n_shards=args.n_shards, fabric=serve_fabric,
            overlap=not args.no_overlap, routing=args.routing,
            defect_after=args.defect_after,
            backpressure_p95=args.backpressure_p95,
            metrics=metrics,
            trace=trace,
            spans=spans,
            suspect_after=suspect_after,
            deadline_ticks=args.deadline_ticks,
            logprobs=args.logprobs,
            on_logprob=(
                (lambda m, j, step, tok, lp: lp_events.append((tok, lp)))
                if args.logprobs else None
            ),
            on_token=lambda m, j, step, tok: first_tok_t.append(time.time())
            if not first_tok_t else None,
        )
    elif args.sharded:
        resp_wires = serve_requests_sharded(
            params, cfg, wires, max_new=args.max_new, pad_to=args.pad_to,
            slots=args.slots, n_shards=args.n_shards, fabric=serve_fabric,
            routing=args.routing, defect_after=args.defect_after,
            metrics=metrics, trace=trace,
            suspect_after=suspect_after,
            deadline_ticks=args.deadline_ticks,
        )
    else:
        resp_wires = serve_requests(
            params, cfg, wires, max_new=args.max_new, pad_to=args.pad_to,
            slots=args.slots,
        )
    dt = time.time() - t0
    n_tok = 0
    for rw in resp_wires:
        rid, outs = decode_response(rw)
        n_tok += sum(len(o) for o in outs)
    mode = ("sequential" if args.sequential
            else f"streaming(slots={args.slots})" if args.streaming
            else f"sharded(slots={args.slots})" if args.sharded
            else f"batched(slots={args.slots})")
    print(f"[serve] {mode}: {len(wires)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({len(wires)/dt:.2f} req/s, {n_tok/dt:.1f} tok/s)")
    if first_tok_t:
        print(f"[serve] time-to-first-token {first_tok_t[0] - t0:.3f}s "
              f"(vs {dt:.2f}s total)")
    if lp_events:
        tok, lp = lp_events[0]
        print(f"[serve] logprob side-stream: {len(lp_events)} events "
              f"(first tok={tok}, lp={lp:.4f})")
    if args.metrics_json and metrics is not None:
        import json as _json

        from ..obs.report import environment_meta

        snap = metrics.snapshot()
        snap["meta"] = environment_meta()
        with open(args.metrics_json, "w") as f:
            _json.dump(snap, f, indent=1)
            f.write("\n")
        print(f"[serve] metrics snapshot -> {args.metrics_json} "
              f"({len(snap['metrics'])} metrics)")
    if args.trace_out and trace is not None:
        trace.save(args.trace_out)
        print(f"[serve] trace timeline -> {args.trace_out} "
              f"({len(trace.events)} events)")
    if args.attribution_json and spans is not None:
        import json as _json

        export = spans.export()
        with open(args.attribution_json, "w") as f:
            _json.dump(export, f, indent=1)
            f.write("\n")
        print(f"[serve] attribution export -> {args.attribution_json} "
              f"({len(export['requests'])} request span(s))")
    rid, outs = decode_response(resp_wires[0])
    for i, o in enumerate(outs[:2]):
        print(f"  req {rid} out[{i}][:8] = {o[:8]}")
    if args.slo and metrics is not None:
        from ..obs import evaluate_slo

        rep = evaluate_slo(args.slo, snapshot=metrics.snapshot())
        print(rep.render_text())
        if not rep.ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
