"""Step functions (train / prefill / serve) + ShapeDtypeStruct input specs.

These are the units the dry-run lowers and the drivers execute.  All three
are pure functions of (params/opt_state/cache, batch) so they jit and shard
cleanly; samplers stay greedy (argmax) to keep serving deterministic.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models import decode_step, init_cache, init_params, loss_fn, prefill
from ..optim import AdamWConfig, OptState, adamw_init, adamw_update, microbatched_grads

PyTree = Any


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, lr_fn=None,
                    grad_shardings: Optional[PyTree] = None,
                    micro_sharding_fn=None):
    lr_fn = lr_fn or (lambda step: opt_cfg.lr)
    if grad_shardings is not None:
        constrain = lambda g: jax.lax.with_sharding_constraint(g, grad_shardings)
    else:
        constrain = lambda g: g
    constrain_micro = micro_sharding_fn or (lambda b: b)

    def train_step(params: PyTree, opt_state: OptState, batch: Dict):
        loss, grads, metrics = microbatched_grads(
            lambda p, b: loss_fn(p, cfg, b), params, batch, cfg.microbatch,
            constrain=constrain, constrain_micro=constrain_micro,
        )
        lr = lr_fn(opt_state.step)
        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, opt_cfg, lr
        )
        metrics.update(opt_metrics)
        metrics["lr"] = lr
        return params, opt_state, metrics

    return train_step


def _greedy_with_logprob(logits: jnp.ndarray):
    """Greedy pick + the chosen token's log-probability.

    The argmax is computed exactly as in the logprob-free path, so
    enabling logprobs can never change which token is served.
    """
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tok_lp = jnp.take_along_axis(logp, next_tok[..., None].astype(jnp.int32),
                                 axis=-1)[..., 0]
    return next_tok, tok_lp


def make_prefill_step(cfg: ModelConfig, cache_len: Optional[int] = None,
                      logprobs: bool = False):
    def prefill_step(params: PyTree, batch: Dict):
        # last_only: serving prefill needs next-token logits, not (B, S, V)
        logits, cache = prefill(params, cfg, batch, cache_len=cache_len,
                                last_only=True)
        if logprobs:
            next_tok, tok_lp = _greedy_with_logprob(logits[:, -1:])
            return next_tok, tok_lp, cache
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, logprobs: bool = False):
    def serve_step(params: PyTree, cache: Dict, tokens: jnp.ndarray):
        logits, cache = decode_step(params, cfg, cache, tokens)
        if logprobs:
            next_tok, tok_lp = _greedy_with_logprob(logits)
            return next_tok, tok_lp, cache
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


# ---------------------------------------------------------------------------
# cached jitted serving steps (the batched message plane re-enters these
# every scheduler tick; re-jitting per request — the seed's serve_request
# behaviour — costs more than the decode itself)
# ---------------------------------------------------------------------------

_SERVE_STEP_CACHE: Dict[Tuple, Tuple] = {}


def cached_serve_steps(cfg: ModelConfig, cache_len: int,
                       logprobs: bool = False):
    """(jitted prefill_step, jitted serve_step) memoized on
    (cfg, cache_len, logprobs).

    ModelConfig is a frozen dataclass, so it keys the cache directly; jit
    then dedupes further by input shapes.  The decode step donates its cache
    argument — the scheduler rebinds the cache every tick, so the input
    buffer is dead after the call and donating it avoids holding two full
    slot caches at once.  With ``logprobs=True`` the steps additionally
    return the chosen token's log-probability (feeding the typed logprob
    stream); the greedy pick itself is unchanged.
    """
    key = (cfg, cache_len, logprobs)
    if key not in _SERVE_STEP_CACHE:
        _SERVE_STEP_CACHE[key] = (
            jax.jit(make_prefill_step(cfg, cache_len=cache_len,
                                      logprobs=logprobs)),
            jax.jit(make_serve_step(cfg, logprobs=logprobs),
                    donate_argnums=(1,)),
        )
    return _SERVE_STEP_CACHE[key]


def clear_serve_step_cache() -> None:
    _SERVE_STEP_CACHE.clear()


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation — dry-run food)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, B: int, S: int, kind: str) -> Dict:
    """Specs for the batch dict of a train/prefill step."""
    f32 = jnp.float32
    specs = {"tokens": _sds((B, S), jnp.int32)}
    if kind == "train":
        specs["labels"] = _sds((B, S), jnp.int32)
        specs["loss_mask"] = _sds((B, S), f32)
        specs["segment_ids"] = _sds((B, S), jnp.int32)
        specs["positions"] = _sds((B, S), jnp.int32)
    if cfg.family == "vlm":
        specs["vision"] = _sds((B, cfg.vision_tokens, cfg.vision_dim), f32)
    if cfg.family == "encdec":
        specs["audio"] = _sds((B, cfg.enc_seq, cfg.d_model), f32)
    return specs


def params_specs(cfg: ModelConfig) -> PyTree:
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


def opt_specs(cfg: ModelConfig) -> PyTree:
    p = params_specs(cfg)
    return jax.eval_shape(lambda q: adamw_init(q, cfg.opt_moments), p)


def cache_specs(cfg: ModelConfig, B: int, cache_len: int) -> PyTree:
    return jax.eval_shape(lambda: init_cache(cfg, B, cache_len))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """All inputs a dry-run cell lowers against, keyed by step argument."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {
            "params": params_specs(cfg),
            "opt_state": opt_specs(cfg),
            "batch": batch_specs(cfg, B, S, "train"),
        }
    if shape.kind == "prefill":
        return {
            "params": params_specs(cfg),
            "batch": batch_specs(cfg, B, S, "prefill"),
        }
    # decode: one new token against a seq_len cache
    return {
        "params": params_specs(cfg),
        "cache": cache_specs(cfg, B, S),
        "tokens": _sds((B, 1), jnp.int32),
    }
