"""Training driver: HGum data pipeline + checkpoint/restart + watchdog.

CPU-runnable end-to-end (reduced configs); the same code path lowers to the
production mesh in the dry-run.  Fault tolerance:

* atomic HGum-framed checkpoints every ``--ckpt-every`` steps (keep-K),
* ``--resume auto`` restores the newest valid checkpoint (bitwise: step,
  params, optimizer moments, data seed),
* straggler watchdog: a step slower than 3x the trailing median forces an
  early checkpoint at the next boundary,
* simulated failures (``--die-at N``) for the restart tests.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --steps 50 --ckpt-dir /tmp/run1 --resume auto
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Dict, Optional

import jax

from ..checkpoint import CheckpointManager
from ..configs import get_config, smoke_config
from ..data import HGumBatchPipeline, Prefetcher
from ..data.prefetch import StragglerWatchdog
from ..models import init_params
from ..optim import AdamWConfig, adamw_init, linear_warmup_cosine
from .steps import make_train_step


def train_loop(
    arch: str,
    steps: int = 50,
    batch: int = 4,
    seq: int = 64,
    smoke: bool = True,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 20,
    resume: str = "no",
    die_at: Optional[int] = None,
    lr: float = 3e-4,
    seed: int = 0,
    log_every: int = 10,
    prefetch: int = 2,
) -> Dict:
    cfg = get_config(arch)
    if smoke:
        cfg = smoke_config(cfg)
    cfg = dataclasses.replace(cfg, microbatch=1)

    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = adamw_init(params)
    opt_cfg = AdamWConfig(lr=lr)
    step_fn = jax.jit(
        make_train_step(cfg, opt_cfg, linear_warmup_cosine(lr, 10, steps)),
        donate_argnums=(0, 1),
    )

    start_step = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr and resume == "auto":
        latest, restored = mgr.restore_latest({"params": params, "opt": opt_state})
        if latest is not None:
            params, opt_state = restored["params"], restored["opt"]
            start_step = latest
            print(f"[train] resumed from step {start_step}")

    pipe = HGumBatchPipeline(vocab=cfg.vocab, batch=batch, seq=seq, seed=seed)
    # deterministic resume: fast-forward the host pipeline
    for _ in range(start_step):
        pipe.host_make_wire()
    from ..data.pipeline import decode_batch

    pf = Prefetcher(pipe.host_make_wire, depth=prefetch)
    dog = StragglerWatchdog()
    losses = []
    force_ckpt = False
    try:
        for step in range(start_step, steps):
            if die_at is not None and step == die_at:
                print(f"[train] simulated failure at step {step}", flush=True)
                pf.close()
                sys.exit(17)
            wire = pf.get()
            b = decode_batch(wire, batch, seq)
            dog.start()
            params, opt_state, metrics = step_fn(params, opt_state, b)
            loss = float(metrics["loss"])
            slow = dog.stop()
            force_ckpt |= slow
            losses.append(loss)
            if step % log_every == 0 or step == steps - 1:
                print(
                    f"[train] step {step:5d} loss {loss:7.4f} "
                    f"gnorm {float(metrics.get('grad_norm', 0)):6.3f}"
                    + (" STRAGGLER" if slow else ""),
                    flush=True,
                )
            at_boundary = (step + 1) % ckpt_every == 0 or step == steps - 1
            if mgr and (at_boundary or force_ckpt):
                mgr.save(step + 1, {"params": params, "opt": opt_state},
                         meta={"arch": arch, "loss": loss})
                force_ckpt = False
    finally:
        pf.close()
    return {
        "final_loss": losses[-1] if losses else None,
        "first_loss": losses[0] if losses else None,
        "steps": len(losses),
        "stragglers": dog.flagged,
        "params": params,
        "opt_state": opt_state,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", default="no", choices=["no", "auto"])
    ap.add_argument("--die-at", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train_loop(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        smoke=args.smoke, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        resume=args.resume, die_at=args.die_at, lr=args.lr, seed=args.seed,
    )
    print(f"[train] done: first_loss={out['first_loss']:.4f} "
          f"final_loss={out['final_loss']:.4f} stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
