"""HGum-framed checkpoint store (fault-tolerant, elastic).

The on-disk format *is* the paper's HW-to-HW framing protocol (§IV-C)
applied at bulk rate, with one documented extension — a CRC32 word in each
frame header for fault tolerance:

    file   := magic "HGCK" | version u32 | frame*
    frame  := header | payload (padded to phit)
    header := size u32 | list_level u32 | crc32 u32 | reserved u32
              (one 16-byte phit, like the paper's §V configuration)

Stream structure (framing rules verbatim from the paper):
  * level-1 frame: the JSON meta message (leaf paths, shapes, dtypes, step).
  * per tensor, in meta order: level-2 data frames (bounded payload,
    default 512 phits * 16 B), then an *empty* level-2 frame = end-of-list.
  * an empty level-1 frame terminates the checkpoint (used to detect
    truncated writes in addition to the CRCs).

Saves are atomic (tmp + rename); ``CheckpointManager`` keeps the newest K
and can restore onto a *different mesh shape* (elastic restart): tensors are
materialized on host and re-placed with the target sharding.
"""
from __future__ import annotations

import json
import os
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

import jax

MAGIC = b"HGCK"
VERSION = 2
PHIT = 16
HEADER = 16
FRAME_PAYLOAD = 512 * PHIT  # paper §IV-C: 512-deep block RAM sizing

PyTree = Any


def _pad(n: int) -> int:
    return (-n) % PHIT


def _header(size: int, level: int, crc: int) -> bytes:
    return (
        np.array([size, level, crc, 0], "<u4").tobytes()
    )


def _write_frames(f, payload: memoryview, level: int) -> None:
    n = len(payload)
    off = 0
    while off < n:
        chunk = payload[off : off + FRAME_PAYLOAD]
        crc = zlib.crc32(chunk)
        f.write(_header(len(chunk), level, crc))
        f.write(chunk)
        f.write(b"\0" * _pad(len(chunk)))
        off += len(chunk)
    # empty frame = end of this list level (paper: "an empty frame always
    # represents the end of a list")
    f.write(_header(0, level, 0))


def _leaf_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def save_checkpoint(path: str, tree: PyTree, meta: Optional[Dict] = None) -> str:
    """Atomically write `tree` (+user meta) to `path`."""
    leaves = _leaf_paths(tree)
    arrays = [np.asarray(jax.device_get(x)) for _, x in leaves]
    meta_obj = {
        "version": VERSION,
        "user": meta or {},
        "tensors": [
            {"path": p, "shape": list(a.shape), "dtype": a.dtype.name}
            for (p, _), a in zip(leaves, arrays)
        ],
    }
    meta_bytes = json.dumps(meta_obj).encode()
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(MAGIC)
        f.write(np.uint32(VERSION).tobytes())
        f.write(b"\0" * _pad(len(MAGIC) + 4))
        _write_frames(f, memoryview(meta_bytes), level=1)
        for a in arrays:
            buf = np.ascontiguousarray(a)
            _write_frames(f, memoryview(buf.view(np.uint8).reshape(-1)), level=2)
        f.write(_header(0, 1, 0))  # end of checkpoint
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


class CorruptCheckpoint(ValueError):
    pass


def _read_frames(buf: bytes, pos: int, level: int) -> Tuple[bytes, int]:
    """Read data frames at `level` until its empty terminator frame."""
    out = bytearray()
    while True:
        if pos + HEADER > len(buf):
            raise CorruptCheckpoint("truncated: missing frame header")
        size, lvl, crc, rsv = np.frombuffer(buf[pos : pos + HEADER], "<u4")
        pos += HEADER
        if int(rsv) != 0:
            raise CorruptCheckpoint("nonzero reserved header word")
        if int(lvl) != level:
            raise CorruptCheckpoint(f"frame level {lvl}, expected {level}")
        if size == 0:
            return bytes(out), pos
        chunk = buf[pos : pos + int(size)]
        if len(chunk) != int(size):
            raise CorruptCheckpoint("truncated frame payload")
        if zlib.crc32(chunk) != int(crc):
            raise CorruptCheckpoint("CRC mismatch")
        out.extend(chunk)
        pos += int(size) + _pad(int(size))


def load_checkpoint(path: str) -> Tuple[Dict, Dict[str, np.ndarray]]:
    """Returns (meta_json, {leaf_path: np.ndarray})."""
    buf = open(path, "rb").read()
    if buf[:4] != MAGIC:
        raise CorruptCheckpoint("bad magic")
    pos = 4 + 4 + _pad(8)
    meta_bytes, pos = _read_frames(buf, pos, level=1)
    meta = json.loads(meta_bytes.decode())
    tensors: Dict[str, np.ndarray] = {}
    ml_dtypes = None
    for t in meta["tensors"]:
        raw, pos = _read_frames(buf, pos, level=2)
        dt = t["dtype"]
        if dt == "bfloat16":
            try:
                import ml_dtypes as _ml

                np_dt = np.dtype(_ml.bfloat16)
            except ImportError:  # decode via uint16 view
                np_dt = np.dtype("<u2")
        else:
            np_dt = np.dtype(dt)
        arr = np.frombuffer(raw, np_dt).reshape(t["shape"])
        tensors[t["path"]] = arr
    # final empty level-1 frame proves the file is complete
    size, lvl, _, _ = np.frombuffer(buf[pos : pos + HEADER], "<u4")
    if int(size) != 0 or int(lvl) != 1:
        raise CorruptCheckpoint("missing end-of-checkpoint frame")
    return meta, tensors


def restore_into(
    template: PyTree,
    tensors: Dict[str, np.ndarray],
    place: Optional[Callable[[str, np.ndarray], Any]] = None,
) -> PyTree:
    """Rebuild a pytree shaped like `template` from loaded tensors.

    `place(path, array)` controls device placement/sharding (elastic
    restore onto a different mesh); defaults to jnp.asarray.
    """
    import jax.numpy as jnp

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, leaf in flat:
        p = jax.tree_util.keystr(kp)
        if p not in tensors:
            raise KeyError(f"checkpoint missing leaf {p}")
        a = tensors[p]
        want = np.dtype("uint16") if str(leaf.dtype) == "bfloat16" and a.dtype == np.dtype("<u2") else None
        if str(leaf.dtype) == "bfloat16" and a.dtype == np.dtype("<u2"):
            arr = jax.lax.bitcast_convert_type(jnp.asarray(a), jnp.bfloat16)
        else:
            arr = place(p, a) if place else jnp.asarray(a, dtype=leaf.dtype)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{p}: shape {arr.shape} != template {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Manager: step-numbered files, keep-K, resume latest
# ---------------------------------------------------------------------------


@dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    prefix: str = "ckpt"

    def path(self, step: int) -> str:
        return os.path.join(self.directory, f"{self.prefix}_{step:08d}.hgck")

    def all_steps(self) -> List[int]:
        if not os.path.isdir(self.directory):
            return []
        out = []
        for fn in os.listdir(self.directory):
            if fn.startswith(self.prefix + "_") and fn.endswith(".hgck"):
                try:
                    out.append(int(fn[len(self.prefix) + 1 : -5]))
                except ValueError:
                    continue
        return sorted(out)

    def save(self, step: int, tree: PyTree, meta: Optional[Dict] = None) -> str:
        meta = dict(meta or {})
        meta["step"] = step
        p = save_checkpoint(self.path(step), tree, meta)
        self._gc()
        return p

    def latest(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore_latest(
        self, template: PyTree, place=None
    ) -> Tuple[Optional[int], PyTree]:
        """Restore newest valid checkpoint; skip corrupt ones (crash during
        write leaves either a .tmp file — invisible here — or a complete
        file, but defense-in-depth costs nothing)."""
        for step in reversed(self.all_steps()):
            try:
                meta, tensors = load_checkpoint(self.path(step))
            except (CorruptCheckpoint, OSError):
                continue
            return step, restore_into(template, tensors, place)
        return None, template

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            try:
                os.remove(self.path(s))
            except OSError:
                pass
