"""HGum-framed fault-tolerant checkpointing."""
from .store import (
    CheckpointManager,
    load_checkpoint,
    restore_into,
    save_checkpoint,
)

__all__ = [
    "CheckpointManager", "load_checkpoint", "restore_into", "save_checkpoint",
]
