"""HGum Pallas TPU kernels (DES/SER payload pass).

``phit_unpack`` / ``frame_pack`` are the tiled production kernels with
explicit BlockSpec VMEM tiling; ``ops`` holds the jitted wrappers;
``ref`` the pure-jnp oracles the tests assert against.
"""
from .ops import (
    batched_runs_from_plan,
    decode_batch_kernel,
    decode_frames_batch,
    decode_gather,
    decode_message_kernel,
    decode_run,
    encode_chunks_batch,
    encode_frames_batch,
    encode_run,
    runs_from_plan,
    wire_to_u32,
    wires_to_u32,
    write_headers,
)

__all__ = [
    "batched_runs_from_plan", "decode_batch_kernel", "decode_frames_batch",
    "decode_gather", "decode_message_kernel", "decode_run",
    "encode_chunks_batch", "encode_frames_batch", "encode_run",
    "runs_from_plan", "wire_to_u32", "wires_to_u32", "write_headers",
]
