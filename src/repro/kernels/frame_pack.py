"""Pallas TPU kernel: HGum SER payload pass (token lanes -> phit stream).

Mirror of ``phit_unpack``: pack a run of fixed-width tokens contiguously
into the wire, and stamp HW-to-HW frame headers (paper §IV-C) onto a framed
stream.  The aligned path is a pure reshape (one VMEM tile per grid step);
the general path writes one token per fori_loop iteration with dynamic
slices (store-side shift-combine would race across rows at word granularity,
so unaligned tokens serialize within the block — documented cost model:
aligned = vector rate, unaligned = token rate, matching the paper's "few
extra cycles" overhead class).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..fabric.frames import HDR_WORDS
from .phit_unpack import BLOCK, _lane_mask


def _pack_kernel_aligned(tok_ref, out_ref, *, stride_w: int):
    # tokens arrive pre-padded to the element pitch; packing is a reshape
    # (one VMEM tile in, one contiguous wire tile out).
    out_ref[...] = tok_ref[...].reshape(BLOCK * stride_w)


def pack_run(
    tokens: jnp.ndarray,  # (N, nlanes) uint32
    stride: int,  # element pitch in bytes (>= nbytes)
    nbytes: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pack N tokens at pitch `stride` from byte 0; returns u32 wire run.

    Aligned fast path only (stride % 4 == 0); ragged/unaligned encoding goes
    through the jnp oracle (`ref.encode_run_ref`) — see module docstring.
    """
    if stride % 4 != 0:
        raise ValueError("pack_run: stride must be 4-byte aligned (use ref path)")
    n, nlanes = tokens.shape
    assert nlanes == (nbytes + 3) // 4
    cap = -(-n // BLOCK) * BLOCK
    stride_w = stride // 4
    toks = jnp.pad(
        tokens & _lane_mask(nbytes, nlanes)[None, :],
        ((0, cap - n), (0, stride_w - nlanes)),
    )
    out = pl.pallas_call(
        functools.partial(_pack_kernel_aligned, stride_w=stride_w),
        grid=(cap // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK, stride_w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK * stride_w,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cap * stride_w,), jnp.uint32),
        interpret=interpret,
    )(toks)
    return out[: n * stride_w]


# ---------------------------------------------------------------------------
# frame header stamping (HW-to-HW framing, §IV-C)
# ---------------------------------------------------------------------------


def _header_kernel(wire_ref, hdr_ref, out_ref, *, n_headers: int):
    out_ref[...] = wire_ref[...]

    def body(i, _):
        word = hdr_ref[i, 0]  # phit-word index of this header
        size = hdr_ref[i, 1].astype(jnp.uint32)
        level = hdr_ref[i, 2].astype(jnp.uint32)
        pl.store(out_ref, (pl.ds(word, 1),), size[None])
        pl.store(out_ref, (pl.ds(word + 1, 1),), level[None])
        return 0

    jax.lax.fori_loop(0, n_headers, body, 0)


def _assemble_kernel(hdr_ref, pay_ref, out_ref):
    # one whole stream (all F frames) per grid step: header phit + payload
    # words concatenate into wire layout lane-parallel across the frames
    out_ref[...] = jnp.concatenate([hdr_ref[...], pay_ref[...]], axis=-1)


def pack_frames_batch(
    headers: jnp.ndarray,  # (B, F, HDR_WORDS) u32 — incl. crc + route words
    payloads: jnp.ndarray,  # (B, F, frame_words) u32 — pre-masked
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Assemble B framed streams (multi-destination send) in one call.

    The structure half (sizes, CRC32, route words, tail masking) comes from
    ``fabric.frames.frame_parts_batch``; this kernel is the payload half —
    one VMEM tile per stream (all of its frames at once, F x width words)
    writes the wire-layout frames, so the grid is B steps rather than the
    old B*F.  Output is (B, F, HDR_WORDS + frame_words), bit-identical to a
    vmapped ``fabric.frames.frame_stream``.
    """
    B, F, frame_words = payloads.shape
    width = HDR_WORDS + frame_words
    return pl.pallas_call(
        _assemble_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, F, HDR_WORDS), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, F, frame_words), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, F, width), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, F, width), jnp.uint32),
        interpret=interpret,
    )(headers.astype(jnp.uint32), payloads.astype(jnp.uint32))


def _chunk_kernel(meta_ref, tok_ref, cnt_ref, out_ref):
    # one row block per grid step: [meta | tokens | count] in wire layout
    out_ref[...] = jnp.concatenate(
        [meta_ref[...], tok_ref[...], cnt_ref[...]], axis=-1
    )


def pack_chunks_batch(
    meta: jnp.ndarray,  # (B, 3) u32 — stream_id, step, flags per chunk
    tokens: jnp.ndarray,  # (B, capW) u32 — pre-masked element words
    counts: jnp.ndarray,  # (B, 1) u32 — true element count per chunk
    *,
    block: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """Assemble B small stream fragments into wire rows in one call.

    The streaming plane emits ONE tiny fragment per live sequence per
    decode tick; batching them through a single Pallas pass amortizes the
    SER launch the same way ``pack_frames_batch`` does for whole messages.
    Output rows are ``[stream_id, step, flags, w0..w_{capW-1}, count]``
    — the HW->SW List layout (count AFTER elements, §IV-B), so rows
    trimmed to their live element words concatenate into a burst the host
    parses back-to-front.  The kernel is width-generic: ``capW`` is
    ``cap * elem_words`` for whatever element width the ``Stream<T>``
    plan generated (see ``core.stream_plans``), and the trailing count
    stays the element count.
    """
    B, cap = tokens.shape
    width = cap + meta.shape[1] + 1
    capB = -(-max(B, 1) // block) * block
    padB = capB - B
    meta = jnp.pad(meta.astype(jnp.uint32), ((0, padB), (0, 0)))
    tokens = jnp.pad(tokens.astype(jnp.uint32), ((0, padB), (0, 0)))
    counts = jnp.pad(counts.astype(jnp.uint32), ((0, padB), (0, 0)))
    out = pl.pallas_call(
        _chunk_kernel,
        grid=(capB // block,),
        in_specs=[
            pl.BlockSpec((block, meta.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((block, cap), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((capB, width), jnp.uint32),
        interpret=interpret,
    )(meta, tokens, counts)
    return out[:B]


def _split_kernel(fr_ref, hdr_ref, pay_ref):
    fr = fr_ref[...]
    hdr_ref[...] = fr[:, :HDR_WORDS]
    pay_ref[...] = fr[:, HDR_WORDS:]


def unpack_frames_batch(
    frames: jnp.ndarray,  # (N, HDR_WORDS + frame_words) u32
    *,
    block: int = 8,
    interpret: bool = True,
) -> tuple:
    """Split a batch of received frames into (headers, payloads).

    The RX-side twin of ``pack_frames_batch``: (N, width) delivered frames
    -> headers (N, HDR_WORDS) and payload words (N, frame_words), one row
    block per grid step.
    """
    N, width = frames.shape
    frame_words = width - HDR_WORDS
    cap = -(-max(N, 1) // block) * block
    fr = jnp.pad(frames.astype(jnp.uint32), ((0, cap - N), (0, 0)))
    hdr, pay = pl.pallas_call(
        _split_kernel,
        grid=(cap // block,),
        in_specs=[pl.BlockSpec((block, width), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((block, HDR_WORDS), lambda i: (i, 0)),
            pl.BlockSpec((block, frame_words), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((cap, HDR_WORDS), jnp.uint32),
            jax.ShapeDtypeStruct((cap, frame_words), jnp.uint32),
        ),
        interpret=interpret,
    )(fr)
    return hdr[:N], pay[:N]


def stamp_headers(
    wire_u32: jnp.ndarray,  # (W,) framed stream with header slots zeroed
    headers: jnp.ndarray,  # (H, 3) int32 [word_index, size, list_level]
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Write (size, ListLevel) frame headers into their phit slots."""
    H = headers.shape[0]
    return pl.pallas_call(
        functools.partial(_header_kernel, n_headers=H),
        grid=(1,),
        in_specs=[
            pl.BlockSpec(wire_u32.shape, lambda i: (0,)),
            pl.BlockSpec((H, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec(wire_u32.shape, lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct(wire_u32.shape, jnp.uint32),
        interpret=interpret,
    )(wire_u32, headers.astype(jnp.int32))
