"""Pallas TPU kernel: HGum SER payload pass (token lanes -> phit stream).

Mirror of ``phit_unpack``: pack a run of fixed-width tokens contiguously
into the wire, and stamp HW-to-HW frame headers (paper §IV-C) onto a framed
stream.  The aligned path is a pure reshape (one VMEM tile per grid step);
the general path writes one token per fori_loop iteration with dynamic
slices (store-side shift-combine would race across rows at word granularity,
so unaligned tokens serialize within the block — documented cost model:
aligned = vector rate, unaligned = token rate, matching the paper's "few
extra cycles" overhead class).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .phit_unpack import BLOCK, _lane_mask


def _pack_kernel_aligned(tok_ref, out_ref, *, stride_w: int):
    # tokens arrive pre-padded to the element pitch; packing is a reshape
    # (one VMEM tile in, one contiguous wire tile out).
    out_ref[...] = tok_ref[...].reshape(BLOCK * stride_w)


def pack_run(
    tokens: jnp.ndarray,  # (N, nlanes) uint32
    stride: int,  # element pitch in bytes (>= nbytes)
    nbytes: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pack N tokens at pitch `stride` from byte 0; returns u32 wire run.

    Aligned fast path only (stride % 4 == 0); ragged/unaligned encoding goes
    through the jnp oracle (`ref.encode_run_ref`) — see module docstring.
    """
    if stride % 4 != 0:
        raise ValueError("pack_run: stride must be 4-byte aligned (use ref path)")
    n, nlanes = tokens.shape
    assert nlanes == (nbytes + 3) // 4
    cap = -(-n // BLOCK) * BLOCK
    stride_w = stride // 4
    toks = jnp.pad(
        tokens & _lane_mask(nbytes, nlanes)[None, :],
        ((0, cap - n), (0, stride_w - nlanes)),
    )
    out = pl.pallas_call(
        functools.partial(_pack_kernel_aligned, stride_w=stride_w),
        grid=(cap // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK, stride_w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK * stride_w,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cap * stride_w,), jnp.uint32),
        interpret=interpret,
    )(toks)
    return out[: n * stride_w]


# ---------------------------------------------------------------------------
# frame header stamping (HW-to-HW framing, §IV-C)
# ---------------------------------------------------------------------------


def _header_kernel(wire_ref, hdr_ref, out_ref, *, n_headers: int):
    out_ref[...] = wire_ref[...]

    def body(i, _):
        word = hdr_ref[i, 0]  # phit-word index of this header
        size = hdr_ref[i, 1].astype(jnp.uint32)
        level = hdr_ref[i, 2].astype(jnp.uint32)
        pl.store(out_ref, (pl.ds(word, 1),), size[None])
        pl.store(out_ref, (pl.ds(word + 1, 1),), level[None])
        return 0

    jax.lax.fori_loop(0, n_headers, body, 0)


def stamp_headers(
    wire_u32: jnp.ndarray,  # (W,) framed stream with header slots zeroed
    headers: jnp.ndarray,  # (H, 3) int32 [word_index, size, list_level]
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Write (size, ListLevel) frame headers into their phit slots."""
    H = headers.shape[0]
    return pl.pallas_call(
        functools.partial(_header_kernel, n_headers=H),
        grid=(1,),
        in_specs=[
            pl.BlockSpec(wire_u32.shape, lambda i: (0,)),
            pl.BlockSpec((H, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec(wire_u32.shape, lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct(wire_u32.shape, jnp.uint32),
        interpret=interpret,
    )(wire_u32, headers.astype(jnp.int32))
