"""Pure-jnp oracles for the HGum kernels (tests assert allclose against these)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.vectorized import decode_leaf


def wire_u32_to_u8(wire_u32: jnp.ndarray) -> jnp.ndarray:
    """uint32 lanes -> little-endian uint8 stream."""
    shifts = jnp.array([0, 8, 16, 24], jnp.uint32)
    b = (wire_u32[:, None] >> shifts[None, :]) & jnp.uint32(0xFF)
    return b.reshape(-1).astype(jnp.uint8)


def unpack_run_ref(
    wire_u32: jnp.ndarray, base: int, stride: int, count: int, nbytes: int
) -> jnp.ndarray:
    """Oracle for phit_unpack.unpack_run (via core.vectorized.decode_leaf)."""
    wire_u8 = wire_u32_to_u8(wire_u32)
    offsets = base + stride * jnp.arange(count, dtype=jnp.int32)
    return decode_leaf(wire_u8, offsets, nbytes)


def unpack_gather_ref(
    wire_u32: jnp.ndarray, offsets: jnp.ndarray, nbytes: int
) -> jnp.ndarray:
    """Oracle for phit_unpack.unpack_gather."""
    return decode_leaf(wire_u32_to_u8(wire_u32), offsets, nbytes)


def pack_run_ref(tokens: jnp.ndarray, stride: int, nbytes: int) -> jnp.ndarray:
    """Oracle for frame_pack.pack_run: scatter lanes at pitch `stride`."""
    n, nlanes = tokens.shape
    masks = []
    for j in range(nlanes):
        rem = nbytes - 4 * j
        masks.append(
            0xFFFFFFFF if rem >= 4 else ((1 << (8 * max(rem, 0))) - 1)
        )
    toks = tokens & jnp.asarray(masks, jnp.uint32)[None, :]
    stride_w = stride // 4
    buf = jnp.zeros((n, stride_w), jnp.uint32)
    buf = buf.at[:, :nlanes].set(toks)
    return buf.reshape(n * stride_w)


def stamp_headers_ref(wire_u32: jnp.ndarray, headers: np.ndarray) -> jnp.ndarray:
    """Oracle for frame_pack.stamp_headers."""
    w = np.asarray(wire_u32).copy()
    for word, size, level in np.asarray(headers):
        w[word] = np.uint32(size)
        w[word + 1] = np.uint32(level)
    return jnp.asarray(w)
