"""Jitted public wrappers around the HGum Pallas kernels.

``decode_runs`` is the production DES payload pass: it takes the wire plus a
*run table* (the structure pass output — one row per uniform run of a leaf
field) and returns the unpacked token lanes for each requested leaf.  The
interpret flag defaults to True because this container executes TPU kernels
on CPU; on real TPU pass interpret=False.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.idl import Schema
from ..core.vectorized import DecodePlan
from .frame_pack import pack_run, stamp_headers
from .phit_unpack import unpack_gather, unpack_run


def wire_to_u32(wire: bytes | np.ndarray) -> jnp.ndarray:
    """bytes -> little-endian uint32 lanes (tail zero-padded)."""
    buf = np.frombuffer(wire, np.uint8) if isinstance(wire, bytes) else np.asarray(wire, np.uint8)
    pad = (-len(buf)) % 4
    if pad:
        buf = np.concatenate([buf, np.zeros(pad, np.uint8)])
    return jnp.asarray(buf.view(np.uint32))


@functools.partial(jax.jit, static_argnames=("base", "stride", "count", "nbytes", "interpret"))
def decode_run(wire_u32, base: int, stride: int, count: int, nbytes: int,
               interpret: bool = True):
    return unpack_run(wire_u32, base, stride, count, nbytes, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("nbytes", "interpret"))
def decode_gather(wire_u32, offsets, nbytes: int, interpret: bool = True):
    return unpack_gather(wire_u32, offsets, nbytes, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("stride", "nbytes", "interpret"))
def encode_run(tokens, stride: int, nbytes: int, interpret: bool = True):
    return pack_run(tokens, stride, nbytes, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def write_headers(wire_u32, headers, interpret: bool = True):
    return stamp_headers(wire_u32, headers, interpret=interpret)


# ---------------------------------------------------------------------------
# Plan-driven decode: choose run-kernel vs gather-kernel per leaf
# ---------------------------------------------------------------------------


def runs_from_plan(plan: DecodePlan, path: str) -> Optional[Tuple[int, int]]:
    """If `path`'s instances form one uniform run, return (base, stride)."""
    n = plan.counts[path]
    if n == 0:
        return None
    offs = np.asarray(plan.offsets[path][:n])
    if n == 1:
        return int(offs[0]), max(plan.nbytes[path], 4)
    strides = np.diff(offs)
    if np.all(strides == strides[0]) and strides[0] > 0:
        return int(offs[0]), int(strides[0])
    return None


def decode_message_kernel(
    wire_u32: jnp.ndarray,
    plan: DecodePlan,
    paths: Optional[List[str]] = None,
    interpret: bool = True,
) -> Dict[str, jnp.ndarray]:
    """DES payload pass using the Pallas kernels (run fast-path per leaf)."""
    out = {}
    for p in paths or plan.offsets.keys():
        nbytes = plan.nbytes[p]
        run = runs_from_plan(plan, p)
        if run is not None:
            base, stride = run
            got = decode_run(
                wire_u32, base, stride, plan.counts[p], nbytes, interpret=interpret
            )
            cap = plan.cap(p)
            if got.shape[0] < cap:
                got = jnp.pad(got, ((0, cap - got.shape[0]), (0, 0)))
            out[p] = got
        else:
            out[p] = decode_gather(
                wire_u32, jnp.asarray(plan.offsets[p]), nbytes, interpret=interpret
            )
    return out
