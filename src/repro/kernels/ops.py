"""Jitted public wrappers around the HGum Pallas kernels.

``decode_runs`` is the production DES payload pass: it takes the wire plus a
*run table* (the structure pass output — one row per uniform run of a leaf
field) and returns the unpacked token lanes for each requested leaf.  The
interpret flag defaults to True because this container executes TPU kernels
on CPU; on real TPU pass interpret=False.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.vectorized import BatchedDecodePlan, DecodePlan, stack_wires
from ..fabric.frames import frame_parts_batch
from .frame_pack import (
    pack_chunks_batch,
    pack_frames_batch,
    pack_run,
    stamp_headers,
    unpack_frames_batch,
)
from .phit_unpack import unpack_gather, unpack_run


def wire_to_u32(wire: bytes | np.ndarray) -> jnp.ndarray:
    """bytes -> little-endian uint32 lanes (tail zero-padded)."""
    buf = np.frombuffer(wire, np.uint8) if isinstance(wire, bytes) else np.asarray(wire, np.uint8)
    pad = (-len(buf)) % 4
    if pad:
        buf = np.concatenate([buf, np.zeros(pad, np.uint8)])
    return jnp.asarray(buf.view(np.uint32))


@functools.partial(jax.jit, static_argnames=("base", "stride", "count", "nbytes", "interpret"))
def decode_run(wire_u32, base: int, stride: int, count: int, nbytes: int,
               interpret: bool = True):
    return unpack_run(wire_u32, base, stride, count, nbytes, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("nbytes", "interpret"))
def decode_gather(wire_u32, offsets, nbytes: int, interpret: bool = True):
    return unpack_gather(wire_u32, offsets, nbytes, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("stride", "nbytes", "interpret"))
def encode_run(tokens, stride: int, nbytes: int, interpret: bool = True):
    return pack_run(tokens, stride, nbytes, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def write_headers(wire_u32, headers, interpret: bool = True):
    return stamp_headers(wire_u32, headers, interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("list_level", "frame_phits", "interpret", "adaptive"),
)
def encode_frames_batch(
    payloads_u32,  # (B, Wcap) u32 — one row per send, zero-padded
    nbytes,  # (B,) int32 true byte lengths
    routes,  # (B, 3) int32 (src, dst, seq0) per stream
    list_level: int = 1,
    frame_phits: int = 16,
    interpret: bool = True,
    adaptive: bool = False,  # stamp the shortest-path route-word bit
):
    """Multi-destination SER: B wires -> B routed framed streams.

    One vectorized structure pass (headers: sizes, CRC32, route words) plus
    one Pallas assembly pass.  Returns (frames (B, F, width), n_frames (B,)).
    """
    hdr, data, n_frames = frame_parts_batch(
        payloads_u32, nbytes, routes, list_level=list_level,
        frame_phits=frame_phits, adaptive=adaptive,
    )
    return pack_frames_batch(hdr, data, interpret=interpret), n_frames


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_frames_batch(frames_u32, interpret: bool = True):
    """RX split of delivered frames: (N, width) -> (headers, payloads)."""
    return unpack_frames_batch(frames_u32, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("elem_words", "interpret"))
def encode_chunks_batch(
    meta,  # (B, 3) int32/u32 — (stream_id, step, flags) per chunk
    tokens,  # (B, cap*elem_words) element words, zero-padded past each count
    counts,  # (B,) int32 true ELEMENT counts
    elem_words: int = 1,
    interpret: bool = True,
):
    """Generated stream-fragment SER: B fragments -> B wire rows
    ``[meta | element words | count]`` (count after elements, §IV-B).

    This is the Pallas pack path driven by ``core.stream_plans``: the
    plan's static ``elem_words`` (u32 words per element — 1 for the
    classic ``Stream<Bytes 4>`` token chunks) scales the tail mask, and
    the trailing count word stays the *element* count so bursts parse
    back-to-front regardless of element width.  Tail words beyond each
    fragment's ``count * elem_words`` are masked to zero here, then the
    Pallas ``pack_chunks_batch`` kernel assembles every row in one pass.
    """
    counts = jnp.asarray(counts, jnp.uint32)
    col = jnp.arange(tokens.shape[1], dtype=jnp.uint32)[None, :]
    nwords = counts[:, None] * jnp.uint32(elem_words)
    toks = jnp.where(col < nwords, tokens.astype(jnp.uint32), 0)
    return pack_chunks_batch(
        jnp.asarray(meta), toks, counts[:, None], interpret=interpret
    )


# ---------------------------------------------------------------------------
# Plan-driven decode: choose run-kernel vs gather-kernel per leaf
# ---------------------------------------------------------------------------


def runs_from_plan(plan: DecodePlan, path: str) -> Optional[Tuple[int, int]]:
    """If `path`'s instances form one uniform run, return (base, stride)."""
    n = plan.counts[path]
    if n == 0:
        return None
    offs = np.asarray(plan.offsets[path][:n])
    if n == 1:
        return int(offs[0]), max(plan.nbytes[path], 4)
    strides = np.diff(offs)
    if np.all(strides == strides[0]) and strides[0] > 0:
        return int(offs[0]), int(strides[0])
    return None


def wires_to_u32(wires: List[bytes]) -> Tuple[jnp.ndarray, int]:
    """Stack N wires into one flat u32 lane buffer.

    Rows are padded to a common 4-byte-aligned length L so per-message byte
    offsets become flat offsets by adding ``m * L``.  Returns (lanes, L).
    """
    L = -(-max([len(w) for w in wires] + [1]) // 4) * 4
    mat = stack_wires(wires, pad_to=L)
    return jnp.asarray(mat.reshape(-1).view(np.uint32)), L


def batched_runs_from_plan(
    bplan: BatchedDecodePlan, path: str, row_bytes: int
) -> Optional[Tuple[int, int]]:
    """If `path` is one uniform run in EVERY message at the same (base,
    stride) relative to its row, the flat batch is itself a uniform run of
    ``N * cap`` instances (stride between rows = row_bytes).  This is the
    fixed-layout fast path (e.g. batch_schema rows): one ``unpack_run``
    covers the whole serving batch."""
    n = bplan.counts[path]
    cap = bplan.cap(path)
    if not np.all(n == cap) or cap == 0:
        return None  # ragged: padding rows would break the run
    offs = np.asarray(bplan.offsets[path])
    if cap == 1:
        # one instance per row: consecutive flat instances sit exactly one
        # row apart, so the row itself is the stride
        stride = row_bytes
    else:
        strides = np.diff(offs, axis=1)
        if not (np.all(strides == strides[0, 0]) and strides[0, 0] > 0):
            return None
        stride = int(strides[0, 0])
    if not np.all(offs[:, 0] == offs[0, 0]):
        return None
    # flat offset of (msg m, inst k) is base + m*row_bytes + k*stride; this
    # equals base + (m*cap + k)*stride — one big run — iff cap*stride tiles
    # the row exactly.
    if cap * stride != row_bytes:
        return None
    return int(offs[0, 0]), stride


def decode_batch_kernel(
    wires_u32: jnp.ndarray,  # flat lanes from wires_to_u32
    row_bytes: int,
    bplan: BatchedDecodePlan,
    paths: Optional[List[str]] = None,
    interpret: bool = True,
) -> Dict[str, jnp.ndarray]:
    """Batched DES payload pass on the Pallas kernels.

    ONE ``unpack_run``/``unpack_gather`` call per leaf path decodes that leaf
    for every message in the batch (this is the kernel twin of
    ``repro.core.vectorized.decode_batch``).  Returns
    path -> uint32[N, cap, nlanes].
    """
    N = bplan.n_messages
    base = (np.arange(N, dtype=np.int64) * row_bytes)[:, None]
    out = {}
    for p in paths or bplan.offsets.keys():
        nbytes = bplan.nbytes[p]
        cap = bplan.cap(p)
        run = batched_runs_from_plan(bplan, p, row_bytes)
        if run is not None:
            b, stride = run
            lanes = decode_run(
                wires_u32, b, stride, N * cap, nbytes, interpret=interpret
            )
        else:
            offs = jnp.asarray(
                (bplan.offsets[p] + base).reshape(-1).astype(np.int32)
            )
            lanes = decode_gather(wires_u32, offs, nbytes, interpret=interpret)
        out[p] = lanes.reshape(N, cap, lanes.shape[-1])
    return out


def decode_message_kernel(
    wire_u32: jnp.ndarray,
    plan: DecodePlan,
    paths: Optional[List[str]] = None,
    interpret: bool = True,
) -> Dict[str, jnp.ndarray]:
    """DES payload pass using the Pallas kernels (run fast-path per leaf)."""
    out = {}
    for p in paths or plan.offsets.keys():
        nbytes = plan.nbytes[p]
        run = runs_from_plan(plan, p)
        if run is not None:
            base, stride = run
            got = decode_run(
                wire_u32, base, stride, plan.counts[p], nbytes, interpret=interpret
            )
            cap = plan.cap(p)
            if got.shape[0] < cap:
                got = jnp.pad(got, ((0, cap - got.shape[0]), (0, 0)))
            out[p] = got
        else:
            out[p] = decode_gather(
                wire_u32, jnp.asarray(plan.offsets[p]), nbytes, interpret=interpret
            )
    return out
