"""Pallas TPU kernel: HGum DES payload pass (phit stream -> token lanes).

The FPGA DES emits one <=16B token per cycle from the phit stream; the TPU
analogue emits a *tile* of tokens per grid step (DESIGN.md §3).  Two kernels:

* ``unpack_run``     — uniform-width run: instance i sits at byte
  ``base + i*stride``.  This is the bulk path (the paper's Fig. 14 schema —
  long Array/List of fixed-size elements — is exactly one run).  The aligned
  case (base, stride multiples of 4) is a pure VMEM reshape; the general
  case shift-combines adjacent 32-bit words, vectorized over the 4 possible
  byte phases.
* ``unpack_gather``  — arbitrary per-instance byte offsets (ragged
  containers); one dynamic-sliced vector load per row inside the block.

Wire layout: uint32 little-endian lanes (``ops.wire_to_u32`` pads the tail).
Outputs are (N, nlanes) uint32 lanes, identical to ``ref.decode_leaf_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256  # instances per grid step


def _lane_mask(nbytes: int, nlanes: int) -> jnp.ndarray:
    """Per-lane masks zeroing bytes beyond `nbytes`.

    Computed from an iota (not a literal array) so it can be materialized
    inside a Pallas kernel body without becoming a captured constant.
    """
    j = jax.lax.broadcasted_iota(jnp.int32, (nlanes,), 0)
    rem = nbytes - 4 * j
    partial = (jnp.uint32(1) << (8 * jnp.clip(rem, 0, 3)).astype(jnp.uint32)) - 1
    return jnp.where(
        rem >= 4, jnp.uint32(0xFFFFFFFF), jnp.where(rem <= 0, jnp.uint32(0), partial)
    )


# ---------------------------------------------------------------------------
# uniform-run unpack
# ---------------------------------------------------------------------------


def _run_kernel_aligned(wire_ref, out_ref, *, stride_w: int, nlanes: int, nbytes: int):
    """base%4 == 0 and stride%4 == 0: tokens are word-aligned slices."""
    # wire block for this grid step: (BLOCK*stride_w,) u32 starting at the
    # block's first token (BlockSpec maps grid index -> word offset).
    w = wire_ref[...]
    toks = w.reshape(BLOCK, stride_w)[:, :nlanes]
    out_ref[...] = toks & _lane_mask(nbytes, nlanes)[None, :]


def _run_kernel_general(
    wire_ref, base_ref, out_ref, *, stride: int, nlanes: int, nbytes: int
):
    """Arbitrary base/stride: per-row dynamic vector load + word combine.

    Row i bytes start at  base + (i0+i)*stride  (absolute); wire_ref holds
    the whole wire, loads use dynamic slices.
    """
    i0 = pl.program_id(0) * BLOCK
    mask = _lane_mask(nbytes, nlanes)

    def body(i, _):
        off = base_ref[0] + (i0 + i) * stride
        w = off // 4
        r = (off % 4).astype(jnp.uint32)
        words = pl.load(wire_ref, (pl.ds(w, nlanes + 1),))
        lo = words[:-1] >> (8 * r)
        hi = jnp.where(r == 0, jnp.uint32(0), words[1:] << ((32 - 8 * r) % 32))
        pl.store(out_ref, (pl.ds(i, 1), slice(None)), ((lo | hi) & mask)[None, :])
        return 0

    jax.lax.fori_loop(0, BLOCK, body, 0)


def unpack_run(
    wire_u32: jnp.ndarray,  # (W,) uint32 (padded; see ops.wire_to_u32)
    base: int | jnp.ndarray,
    stride: int,
    count: int,  # static capacity (rows); mask invalid rows downstream
    nbytes: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Unpack `count` fixed-width fields at base + i*stride.  Static shapes."""
    nlanes = (nbytes + 3) // 4
    cap = -(-count // BLOCK) * BLOCK
    grid = cap // BLOCK

    if not isinstance(base, int):
        raise TypeError("unpack_run: base must be a static python int")

    aligned = base % 4 == 0 and stride % 4 == 0 and nbytes >= 1
    if aligned:
        stride_w = stride // 4
        base_w = base // 4
        need = base_w + cap * stride_w
        if wire_u32.shape[0] < need:
            wire_u32 = jnp.pad(wire_u32, (0, need - wire_u32.shape[0]))
        run = jax.lax.dynamic_slice(wire_u32, (base_w,), (cap * stride_w,))
        out = pl.pallas_call(
            functools.partial(
                _run_kernel_aligned, stride_w=stride_w, nlanes=nlanes, nbytes=nbytes
            ),
            grid=(grid,),
            in_specs=[pl.BlockSpec((BLOCK * stride_w,), lambda i: (i,))],
            out_specs=pl.BlockSpec((BLOCK, nlanes), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((cap, nlanes), jnp.uint32),
            interpret=interpret,
        )(run)
        return out[:count]

    base_arr = jnp.asarray([base], jnp.int32)
    need = (base + cap * stride + 4 * nlanes) // 4 + 8
    if wire_u32.shape[0] < need:
        wire_u32 = jnp.pad(wire_u32, (0, need - wire_u32.shape[0]))
    out = pl.pallas_call(
        functools.partial(
            _run_kernel_general, stride=stride, nlanes=nlanes, nbytes=nbytes
        ),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(wire_u32.shape, lambda i: (0,)),  # whole wire resident
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK, nlanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cap, nlanes), jnp.uint32),
        interpret=interpret,
    )(wire_u32, base_arr)
    return out[:count]


# ---------------------------------------------------------------------------
# gather unpack (ragged offsets)
# ---------------------------------------------------------------------------


def _gather_kernel(wire_ref, off_ref, out_ref, *, nlanes: int, nbytes: int):
    mask = _lane_mask(nbytes, nlanes)

    def body(i, _):
        off = off_ref[i]
        w = off // 4
        r = (off % 4).astype(jnp.uint32)
        words = pl.load(wire_ref, (pl.ds(w, nlanes + 1),))
        lo = words[:-1] >> (8 * r)
        hi = jnp.where(r == 0, jnp.uint32(0), words[1:] << ((32 - 8 * r) % 32))
        pl.store(out_ref, (pl.ds(i, 1), slice(None)), ((lo | hi) & mask)[None, :])
        return 0

    jax.lax.fori_loop(0, BLOCK, body, 0)


def unpack_gather(
    wire_u32: jnp.ndarray,
    offsets: jnp.ndarray,  # (cap,) int32 byte offsets
    nbytes: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    nlanes = (nbytes + 3) // 4
    n = offsets.shape[0]
    cap = -(-n // BLOCK) * BLOCK
    offsets = jnp.pad(offsets, (0, cap - n)).astype(jnp.int32)
    wire_u32 = jnp.pad(wire_u32, (0, nlanes + 8))  # safe overread tail
    out = pl.pallas_call(
        functools.partial(_gather_kernel, nlanes=nlanes, nbytes=nbytes),
        grid=(cap // BLOCK,),
        in_specs=[
            pl.BlockSpec(wire_u32.shape, lambda i: (0,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK, nlanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cap, nlanes), jnp.uint32),
        interpret=interpret,
    )(wire_u32, offsets)
    return out[:n]
