"""HW-to-HW framing protocol (paper §IV-C).

Neither side of a HW-to-HW link can buffer a whole List, so serialized list
data is cut into *frames*: a bounded buffer's worth of payload prefixed by a
header carrying ``(size, ListLevel)``.  Protocol rules (verbatim from the
paper):

* an **empty frame** (header only) always represents the **end of a list** —
  the SER logic sends at least one frame per list (the terminator);
* all payload bytes of one frame sit at **one** list-nesting level
  (``ListLevel``), so the DES logic can unambiguously resync its schema-tree
  traversal from the header alone;
* data outside any List flows unframed (raw phits).

Wire format choices (implementation-defined, documented here):
  header = ``size:u32le | list_level:u32le`` padded to a whole number of
  phits; payload padded to a whole number of phits; raw->frame transitions
  are phit-aligned.  ``size`` is the true payload byte count (pre-padding).
"""
from __future__ import annotations

from dataclasses import dataclass

HEADER_BYTES = 8

#: paper §V: "the maximum size of a frame in the HW-to-HW SER logic is set to
#: 500-phit large"; block RAMs on Altera parts are 512 deep (§IV-C).
DEFAULT_FRAME_PHITS = 500
DEFAULT_PHIT_BYTES = 16  # paper §V: 128-bit phits


@dataclass(frozen=True)
class FrameHeader:
    size: int  # payload bytes (0 == end-of-list terminator)
    list_level: int

    def pack(self, phit_bytes: int) -> bytes:
        raw = self.size.to_bytes(4, "little") + self.list_level.to_bytes(4, "little")
        return _pad_to_phit(raw, phit_bytes)

    @staticmethod
    def unpack(buf: bytes, pos: int, phit_bytes: int) -> tuple["FrameHeader", int]:
        size = int.from_bytes(buf[pos : pos + 4], "little")
        level = int.from_bytes(buf[pos + 4 : pos + 8], "little")
        pos += header_wire_bytes(phit_bytes)
        return FrameHeader(size, level), pos

    @property
    def is_end_of_list(self) -> bool:
        return self.size == 0


def header_wire_bytes(phit_bytes: int) -> int:
    return _round_up(HEADER_BYTES, phit_bytes)


def payload_wire_bytes(size: int, phit_bytes: int) -> int:
    return _round_up(size, phit_bytes)


def _round_up(n: int, k: int) -> int:
    return ((n + k - 1) // k) * k


def _pad_to_phit(raw: bytes, phit_bytes: int) -> bytes:
    return raw + b"\0" * (_round_up(len(raw), phit_bytes) - len(raw))


class FrameWriter:
    """SER-side bounded frame buffer: 'a FIFO with an additional write port to
    set the frame header' (§IV-C).  Collects payload at one list level, emits
    wire bytes on flush.  Tracks the cycle overhead per frame."""

    def __init__(self, out: bytearray, frame_phits: int, phit_bytes: int,
                 cycles_per_frame: int = 2):
        self.out = out
        self.max_payload = frame_phits * phit_bytes
        self.phit_bytes = phit_bytes
        self.cycles_per_frame = cycles_per_frame
        self.buf = bytearray()
        self.level = 0
        self.frames_emitted = 0
        self.overhead_cycles = 0

    def _align_out(self) -> None:
        pad = (-len(self.out)) % self.phit_bytes
        self.out.extend(b"\0" * pad)

    def write(self, data: bytes, level: int) -> None:
        assert level >= 1, "frames only carry in-list data"
        if self.buf and self.level != level:
            self.flush()
        self.level = level
        off = 0
        while off < len(data):
            room = self.max_payload - len(self.buf)
            take = min(room, len(data) - off)
            self.buf.extend(data[off : off + take])
            off += take
            if len(self.buf) == self.max_payload:
                self.flush()
                self.level = level
        # re-arm level for a lazily started next frame
        self.level = level

    def flush(self) -> None:
        """Emit the current (non-empty) frame."""
        if not self.buf:
            return
        self._align_out()
        self.out.extend(FrameHeader(len(self.buf), self.level).pack(self.phit_bytes))
        self.out.extend(_pad_to_phit(bytes(self.buf), self.phit_bytes))
        self.buf.clear()
        self.frames_emitted += 1
        self.overhead_cycles += self.cycles_per_frame

    def end_list(self, level: int) -> None:
        """Flush pending payload, then emit the empty end-of-list frame."""
        self.flush()
        self._align_out()
        self.out.extend(FrameHeader(0, level).pack(self.phit_bytes))
        self.frames_emitted += 1
        self.overhead_cycles += self.cycles_per_frame
