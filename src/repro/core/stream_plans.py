"""Generated chunk codecs for ``Stream<T>`` schema nodes.

HGum's thesis is that SER/DES logic is *generated from the message
schema*, never hand-written.  This module extends that to incremental
streams: a ``["Stream", t]`` node in the IDL compiles — via the same
schema ROM as every other type — into a :class:`StreamPlan`, and the
plan drives both the host reference codec here and the Pallas pack path
(``kernels.ops.encode_chunks_batch``).

Wire format of one fragment (all little-endian u32 words)::

    [ stream_id | step | flags | elem words ... | n ]

``n`` is the *element* count and trails the elements (§IV-B
count-after-elements), so a burst of concatenated fragments parses
back-to-front.  Each element occupies ``plan.elem_words`` words: the
fixed-size leaves of the element type, each padded to whole words,
little-endian within a leaf.

The plan also carries the fragment-meta bit budgets (``id_bits`` /
``step_bits``).  The check functions below are shared verbatim between
the runtime (encode raises, decode sets a per-fragment ``corrupt``
flag) and the ``repro.analysis`` ``stream-*`` rules.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .idl import Schema, SchemaError
from .schema_tree import (
    KIND_BYTES,
    KIND_NAMES,
    KIND_STREAM,
    STREAM_META_WORDS,
    build_rom,
)

#: u32 words of fragment metadata: ``(stream_id, step, flags)``
CHUNK_META_WORDS = STREAM_META_WORDS
#: smallest parseable fragment: meta + trailing count word
CHUNK_MIN_WORDS = CHUNK_META_WORDS + 1
#: ``flags`` bit 0 — this fragment ends its stream
FLAG_EOS = 0x1
#: all flag bits the wire format defines; anything else marks corruption
FLAG_KNOWN_MASK = FLAG_EOS
#: an element count this large in a trailing word means a corrupt burst
MAX_CHUNK_TOKENS = 1 << 16
#: id-packing convention of the serve plane: a stream id is
#: ``(hi << STREAM_ID_BITS) | lo`` with each half below ``1 << STREAM_ID_BITS``
STREAM_ID_BITS = 16

_WORD = 4  # bytes per wire word


# ---------------------------------------------------------------------------
# Shared check functions (PR-6 pattern: runtime raises / analyzer wraps)
# ---------------------------------------------------------------------------


def check_chunk_tokens(n: int) -> None:
    """Shared by the runtime encoder and the ``stream-chunk-tokens`` rule."""
    if n >= MAX_CHUNK_TOKENS:
        raise ValueError(f"chunk of {n} tokens exceeds {MAX_CHUNK_TOKENS}")


def meta_budget_error(id_bits: int, step_bits: int) -> Optional[str]:
    """Fragment meta fields each ride one u32 wire word: budgets must fit.

    Backs the ``stream-meta-budget`` analyzer rule; :class:`StreamPlan`
    raises the same message at construction.
    """
    for name, bits in (("id_bits", id_bits), ("step_bits", step_bits)):
        if not (isinstance(bits, int) and 1 <= bits <= 32):
            return (
                f"stream meta budget {name}={bits!r} does not fit the u32 "
                f"fragment-meta word (need 1..32 bits)"
            )
    return None


def elem_size_error(elem_words: int) -> Optional[str]:
    """Element wire size vs. the ``MAX_CHUNK_TOKENS`` count budget.

    The back-to-front parser addresses ``n * elem_words`` words with the
    u32 trailing count, so the largest legal fragment must stay u32
    addressable.  Backs the ``stream-elem-size`` analyzer rule.
    """
    if elem_words < 1:
        return f"stream element is empty ({elem_words} wire words)"
    if MAX_CHUNK_TOKENS * elem_words >= 1 << 32:
        return (
            f"stream element of {elem_words} words makes the largest "
            f"fragment ({MAX_CHUNK_TOKENS - 1} elements) exceed u32 word "
            f"addressing"
        )
    return None


def fragment_meta_error(
    plan: "StreamPlan", stream_id: int, step: int, flags: int = 0
) -> Optional[str]:
    """Out-of-budget fragment metadata.

    Shared by the runtime: ``encode_fragment`` raises this message, and
    ``decode_fragments`` surfaces it as the per-fragment ``corrupt`` flag
    instead of silently attributing elements to a garbage stream.
    """
    if not 0 <= stream_id < (1 << plan.id_bits):
        return (
            f"stream_id {stream_id:#x} outside the {plan.id_bits}-bit "
            f"budget of plan {plan.location!r}"
        )
    if not 0 <= step < (1 << plan.step_bits):
        return (
            f"step {step} outside the {plan.step_bits}-bit budget of "
            f"plan {plan.location!r}"
        )
    if flags & ~FLAG_KNOWN_MASK:
        return (
            f"unknown flag bits {flags & ~FLAG_KNOWN_MASK:#x} in fragment "
            f"of plan {plan.location!r}"
        )
    return None


# ---------------------------------------------------------------------------
# Plans
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StreamPlan:
    """Chunk encode/decode plan generated from one ``Stream<T>`` node.

    ``leaf_paths``/``leaf_nbytes`` are the fixed-size leaves of the
    element type in schema order; ``leaf_words`` is each leaf padded to
    whole u32 words and ``elem_words`` their sum — one element's wire
    footprint.  Elements of a single-leaf plan are plain ints on the
    Python side; multi-leaf elements are tuples in leaf order.
    """

    location: str  # token path of the Stream node, e.g. "tokens"
    leaf_paths: Tuple[str, ...]
    leaf_nbytes: Tuple[int, ...]
    id_bits: int = 2 * STREAM_ID_BITS
    step_bits: int = STREAM_ID_BITS

    def __post_init__(self):
        err = meta_budget_error(self.id_bits, self.step_bits)
        if err is None:
            err = elem_size_error(self.elem_words)
        if err is not None:
            raise SchemaError(f"{self.location}: {err}")

    # cached: these sit on the per-fragment encode/decode hot path, and a
    # frozen dataclass keeps an instance __dict__ for the cache to land in
    @cached_property
    def leaf_words(self) -> Tuple[int, ...]:
        return tuple((n + _WORD - 1) // _WORD for n in self.leaf_nbytes)

    @cached_property
    def elem_words(self) -> int:
        return sum((n + _WORD - 1) // _WORD for n in self.leaf_nbytes)

    @cached_property
    def n_leaves(self) -> int:
        return len(self.leaf_nbytes)


def stream_plans(
    schema: Schema,
    *,
    id_bits: int = 2 * STREAM_ID_BITS,
    step_bits: int = STREAM_ID_BITS,
) -> Dict[str, StreamPlan]:
    """Compile every ``Stream<T>`` node of `schema` into a StreamPlan.

    Plans are derived from the schema ROM (the same compiled form every
    other codec uses), keyed by the stream node's token path.  Stream
    element types must be fixed-size: a nested Array/List/Stream inside
    a stream element has no static wire footprint and is rejected.
    """
    rom = build_rom(schema)
    plans: Dict[str, StreamPlan] = {}
    for i in range(rom.n_nodes):
        if int(rom.kind[i]) != KIND_STREAM:
            continue
        path = rom.paths[i]
        leaf_paths: List[str] = []
        leaf_nbytes: List[int] = []
        j = int(rom.child[i])
        while True:
            k = int(rom.kind[j])
            if k != KIND_BYTES:
                raise SchemaError(
                    f"{path}: stream element must be fixed-size; "
                    f"{rom.paths[j]!r} is a {KIND_NAMES[k]}"
                )
            leaf_paths.append(rom.paths[j])
            leaf_nbytes.append(int(rom.nbytes[j]))
            if int(rom.last[j]):
                break
            j += 1
        plans[path] = StreamPlan(
            location=path,
            leaf_paths=tuple(leaf_paths),
            leaf_nbytes=tuple(leaf_nbytes),
            id_bits=id_bits,
            step_bits=step_bits,
        )
    return plans


# ---------------------------------------------------------------------------
# Fragments
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Fragment:
    """One decoded stream fragment.

    ``tokens`` holds the elements: ints for single-leaf plans, tuples of
    ints (leaf order) otherwise.  ``corrupt`` marks fragments whose
    metadata violated the plan's declared budgets — the payload is kept
    for diagnostics but must not be attributed to the stream.
    """

    stream_id: int
    step: int
    tokens: Tuple
    eos: bool = False
    corrupt: bool = False


def _u32_vec(tokens: Sequence) -> np.ndarray:
    """Mask a token sequence to u32 wire words (C-speed common case)."""
    try:
        return np.asarray(tokens, dtype=np.uint64) & 0xFFFFFFFF
    except (OverflowError, TypeError):
        # out-of-u64-range or negative ints: mask one by one, same
        # wrap-around semantics as the single-fragment reference path
        return np.asarray(
            [int(t) & 0xFFFFFFFF for t in tokens], dtype="<u4"
        )


def _elem_rows(plan: StreamPlan, tokens: Sequence) -> np.ndarray:
    """(n, elem_words) u32 matrix of the elements' wire words."""
    n = len(tokens)
    out = np.zeros((n, plan.elem_words), dtype="<u4")
    if plan.n_leaves == 1 and plan.leaf_words[0] == 1:
        # fast path: the Stream<Bytes 4>-style single-word element
        if n:
            out[:, 0] = _u32_vec(tokens)
        return out
    for r, elem in enumerate(tokens):
        leaves = (elem,) if plan.n_leaves == 1 else tuple(elem)
        if len(leaves) != plan.n_leaves:
            raise ValueError(
                f"element of plan {plan.location!r} needs "
                f"{plan.n_leaves} leaves, got {len(leaves)}"
            )
        c = 0
        for v, nbytes, words in zip(leaves, plan.leaf_nbytes, plan.leaf_words):
            v = int(v) & ((1 << (8 * nbytes)) - 1)
            for w in range(words):
                out[r, c] = (v >> (32 * w)) & 0xFFFFFFFF
                c += 1
    return out


def _rows_to_elems(plan: StreamPlan, rows: np.ndarray) -> Tuple:
    """Inverse of :func:`_elem_rows` (rows: (n, elem_words) u32)."""
    if plan.n_leaves == 1 and plan.leaf_words[0] == 1:
        return tuple(int(t) for t in rows[:, 0])
    elems = []
    for r in range(rows.shape[0]):
        leaves = []
        c = 0
        for nbytes, words in zip(plan.leaf_nbytes, plan.leaf_words):
            v = 0
            for w in range(words):
                v |= int(rows[r, c]) << (32 * w)
                c += 1
            leaves.append(v & ((1 << (8 * nbytes)) - 1))
        elems.append(leaves[0] if plan.n_leaves == 1 else tuple(leaves))
    return tuple(elems)


def encode_fragment(
    plan: StreamPlan,
    stream_id: int,
    step: int,
    tokens: Sequence,
    eos: bool = False,
) -> bytes:
    """Host reference encoder for one fragment (little-endian u32 words)."""
    check_chunk_tokens(len(tokens))
    flags = FLAG_EOS if eos else 0
    err = fragment_meta_error(plan, stream_id, step, flags)
    if err is not None:
        raise ValueError(err)
    words = np.empty(
        CHUNK_META_WORDS + len(tokens) * plan.elem_words + 1, dtype="<u4"
    )
    words[0] = stream_id
    words[1] = step
    words[2] = flags
    words[CHUNK_META_WORDS:-1] = _elem_rows(plan, tokens).reshape(-1)
    words[-1] = len(tokens)
    return words.tobytes()


def encode_fragment_burst(plan: StreamPlan, fragments: Sequence) -> bytes:
    """Encode a burst of fragments via the generated Pallas pack path.

    Accepts anything with ``stream_id``/``step``/``tokens``/``eos``
    attributes (:class:`Fragment`, ``stream.chunks.TokenChunk``).
    Fragments are padded to a power-of-two element capacity, packed by
    ``kernels.ops.encode_chunks_batch`` (one row per fragment, the
    plan's ``elem_words`` as the static element width), then trimmed to
    the exact wire bytes and concatenated in order.
    """
    from ..kernels.ops import encode_chunks_batch

    if not fragments:
        return b""
    counts = [len(f.tokens) for f in fragments]
    b = len(fragments)
    elem_words = plan.elem_words
    one_word = plan.n_leaves == 1 and elem_words == 1
    cap = max(1, max(counts))
    cap = 1 << (cap - 1).bit_length()  # pow2 bucket: stable jit shapes
    bp = 1 << max(b - 1, 0).bit_length()
    meta = np.zeros((bp, CHUNK_META_WORDS), dtype=np.uint32)
    toks = np.zeros((bp, cap * elem_words), dtype=np.uint32)
    cnts = np.zeros((bp,), dtype=np.uint32)
    # inline guard over the same bounds :func:`fragment_meta_error`
    # checks (which stays the single source of the failure message) —
    # a per-fragment call would dominate small-burst encode time
    id_lim, step_lim = 1 << plan.id_bits, 1 << plan.step_bits
    for i, f in enumerate(fragments):
        n = counts[i]
        if n >= MAX_CHUNK_TOKENS:
            check_chunk_tokens(n)
        flags = FLAG_EOS if f.eos else 0
        if not (0 <= f.stream_id < id_lim and 0 <= f.step < step_lim
                and not flags & ~FLAG_KNOWN_MASK):
            raise ValueError(
                fragment_meta_error(plan, f.stream_id, f.step, flags)
            )
        meta[i, 0] = f.stream_id
        meta[i, 1] = f.step
        meta[i, 2] = flags
        if n:
            if one_word:  # Stream<Bytes 4>-style: no row matrix needed
                try:
                    # direct numpy setitem wraps mod 2**32 like the mask
                    toks[i, :n] = f.tokens
                except (OverflowError, TypeError):
                    toks[i, :n] = _u32_vec(f.tokens)
            else:
                toks[i, : n * elem_words] = _elem_rows(
                    plan, f.tokens
                ).reshape(-1)
        cnts[i] = n
    rows = np.asarray(
        encode_chunks_batch(meta, toks, cnts, elem_words=elem_words)
    ).astype("<u4", copy=False)
    out = []
    for i, n in enumerate(counts):
        nw = CHUNK_META_WORDS + n * elem_words
        out.append(rows[i, :nw].tobytes())
        out.append(rows[i, -1:].tobytes())
    return b"".join(out)


def decode_fragments(
    plan: StreamPlan, data: bytes
) -> Tuple[List[Fragment], bool]:
    """Parse a burst back-to-front into fragments (wire order).

    Returns ``(fragments, ok)``.  ``ok=False`` means the burst is
    structurally malformed and parsing stopped (a prefix may be
    missing).  Fragments whose metadata violates the plan's budgets
    parse fine structurally but come back with ``corrupt=True``.
    """
    ok = True
    nbytes = len(data)
    if nbytes % _WORD:
        ok = False  # salvage the aligned prefix of a truncated wire
        nbytes -= nbytes % _WORD
    words = np.frombuffer(data[:nbytes], dtype="<u4")
    frags: List[Fragment] = []
    end = len(words)
    ew = plan.elem_words
    while end > 0:
        if end < CHUNK_MIN_WORDS:
            ok = False
            break
        n = int(words[end - 1])
        lo = end - 1 - n * ew - CHUNK_META_WORDS
        if n >= MAX_CHUNK_TOKENS or lo < 0:
            ok = False
            break
        sid, step, flags = (
            int(words[lo]),
            int(words[lo + 1]),
            int(words[lo + 2]),
        )
        rows = words[lo + CHUNK_META_WORDS:end - 1].reshape(n, ew)
        frags.append(
            Fragment(
                stream_id=sid,
                step=step,
                tokens=_rows_to_elems(plan, rows),
                eos=bool(flags & FLAG_EOS),
                corrupt=fragment_meta_error(plan, sid, step, flags)
                is not None,
            )
        )
        end = lo
    frags.reverse()
    return frags, ok
