"""Software SER/DES functions (paper §III-D, §IV-A1, §IV-B).

Store-and-forward, operating on whole messages and randomly-accessible
buffers, exactly like a software messaging framework:

* ``ser_sw_to_hw``   — software SER, SW->HW direction: counts written *before*
  elements (software buffers the whole message, so Array and List are treated
  identically).  This is the wire format the hardware DES logic consumes.
* ``des_sw_oracle``  — forward parse of that format (test oracle).
* ``des_hw_to_sw``   — software DES, HW->SW direction: the hardware SER wrote
  container counts *after* the elements, so this parses the buffer from the
  END (paper §IV-B).
* ``msg_to_des_tokens`` — the token stream a correct hardware DES module must
  emit for a message (with client-schema tags) — oracle for the FSM engines.
* ``tokens_to_msg``  — reconstruct a message from a DES token stream.
* ``random_message`` — schema-directed random message generator for tests.

Message representation: structs are dicts, containers are python lists,
Bytes(n) fields are unsigned ints (little-endian on the wire).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from .idl import Array, Bytes, ClientSchema, ListT, Schema, StructRef, TypeNode
from .idl import ELEM, END, START
from .schema_tree import COUNT_BYTES
from .tokens import (
    TOK_ARRAY_END,
    TOK_ARRAY_LENGTH,
    TOK_DATA,
    TOK_LIST_BEGIN,
    TOK_LIST_END,
    Token,
)

_CONTAINER = (Array, ListT)


def _check_value(v: int, n: int, where: str) -> int:
    v = int(v)
    if v < 0 or v >= (1 << (8 * n)):
        raise ValueError(f"{where}: value {v} does not fit in {n} bytes")
    return v


# ---------------------------------------------------------------------------
# SW -> HW: software SER (counts before elements)
# ---------------------------------------------------------------------------


def ser_sw_to_hw(schema: Schema, msg: dict) -> bytes:
    """Software serialization per paper §IV-A1 (simple binary protocol)."""
    out = bytearray()

    def ser(t: TypeNode, v, where: str) -> None:
        if isinstance(t, Bytes):
            out.extend(_check_value(v, t.n, where).to_bytes(t.n, "little"))
        elif isinstance(t, StructRef):
            if not isinstance(v, dict):
                raise TypeError(f"{where}: expected dict for struct, got {type(v)}")
            for fname, ftype in schema.structs[t.name]:
                ser(ftype, v[fname], f"{where}.{fname}")
        elif isinstance(t, _CONTAINER):
            if not isinstance(v, list):
                raise TypeError(f"{where}: expected list, got {type(v)}")
            out.extend(len(v).to_bytes(COUNT_BYTES, "little"))
            for i, e in enumerate(v):
                ser(t.elem, e, f"{where}[{i}]")
        else:  # pragma: no cover
            raise TypeError(f"bad type {t!r}")

    for fname, ftype in schema.structs[schema.top]:
        ser(ftype, msg[fname], fname)
    return bytes(out)


def des_sw_oracle(schema: Schema, buf: bytes) -> dict:
    """Forward parse of the SW->HW format (software-side test oracle)."""
    pos = 0

    def des(t: TypeNode):
        nonlocal pos
        if isinstance(t, Bytes):
            v = int.from_bytes(buf[pos : pos + t.n], "little")
            pos += t.n
            return v
        if isinstance(t, StructRef):
            return {f: des(ft) for f, ft in schema.structs[t.name]}
        if isinstance(t, _CONTAINER):
            n = int.from_bytes(buf[pos : pos + COUNT_BYTES], "little")
            pos += COUNT_BYTES
            return [des(t.elem) for _ in range(n)]
        raise TypeError(f"bad type {t!r}")  # pragma: no cover

    msg = {f: des(ft) for f, ft in schema.structs[schema.top]}
    if pos != len(buf):
        raise ValueError(f"trailing bytes: consumed {pos} of {len(buf)}")
    return msg


# ---------------------------------------------------------------------------
# HW -> SW: hardware SER wrote counts AFTER elements; parse from the end.
# ---------------------------------------------------------------------------


def ser_hw_to_sw_reference(schema: Schema, msg: dict) -> bytes:
    """Reference for what the hardware SER emits in the HW->SW direction:
    identical to ``ser_sw_to_hw`` except container counts trail the elements
    (paper §IV-B)."""
    out = bytearray()

    def ser(t: TypeNode, v, where: str) -> None:
        if isinstance(t, Bytes):
            out.extend(_check_value(v, t.n, where).to_bytes(t.n, "little"))
        elif isinstance(t, StructRef):
            for fname, ftype in schema.structs[t.name]:
                ser(ftype, v[fname], f"{where}.{fname}")
        elif isinstance(t, _CONTAINER):
            for i, e in enumerate(v):
                ser(t.elem, e, f"{where}[{i}]")
            out.extend(len(v).to_bytes(COUNT_BYTES, "little"))
        else:  # pragma: no cover
            raise TypeError(f"bad type {t!r}")

    for fname, ftype in schema.structs[schema.top]:
        ser(ftype, msg[fname], fname)
    return bytes(out)


def des_hw_to_sw(schema: Schema, buf: bytes) -> dict:
    """Software DES for the HW->SW direction: parse the buffer from the END
    (paper §IV-B), reconstructing fields in reverse schema order."""
    pos = len(buf)

    def des(t: TypeNode):
        nonlocal pos
        if isinstance(t, Bytes):
            pos -= t.n
            return int.from_bytes(buf[pos : pos + t.n], "little")
        if isinstance(t, StructRef):
            fields = schema.structs[t.name]
            vals = {}
            for fname, ftype in reversed(fields):
                vals[fname] = des(ftype)
            return {f: vals[f] for f, _ in fields}  # restore field order
        if isinstance(t, _CONTAINER):
            pos -= COUNT_BYTES
            n = int.from_bytes(buf[pos : pos + COUNT_BYTES], "little")
            save = pos
            elems = []
            for _ in range(n):
                elems.append(des(t.elem))
            elems.reverse()
            if pos > save:  # pragma: no cover - defensive
                raise ValueError("reverse parse overran container")
            return elems
        raise TypeError(f"bad type {t!r}")  # pragma: no cover

    fields = schema.structs[schema.top]
    vals = {}
    for fname, ftype in reversed(fields):
        vals[fname] = des(ftype)
    if pos != 0:
        raise ValueError(f"leading bytes left: {pos}")
    return {f: vals[f] for f, _ in fields}


# ---------------------------------------------------------------------------
# Token-stream oracles (paper §III-C1)
# ---------------------------------------------------------------------------


def msg_to_des_tokens(
    schema: Schema, msg: dict, client: Optional[ClientSchema] = None
) -> List[Token]:
    """The token stream a correct DES module emits for `msg` (§III-C1)."""
    client = client or ClientSchema()
    out: List[Token] = []

    def walk(t: TypeNode, v, path: str) -> None:
        if isinstance(t, Bytes):
            out.append(Token(TOK_DATA, value=int(v), tag=client.tag_for(path), path=path))
        elif isinstance(t, StructRef):
            for fname, ftype in schema.structs[t.name]:
                walk(ftype, v[fname], f"{path}.{fname}" if path else fname)
        elif isinstance(t, Array):
            out.append(
                Token(
                    TOK_ARRAY_LENGTH,
                    value=len(v),
                    tag=client.tag_for(f"{path}.{START}"),
                    path=f"{path}.{START}",
                )
            )
            for e in v:
                walk(t.elem, e, f"{path}.{ELEM}")
            end_tag = client.tag_for(f"{path}.{END}")
            if end_tag >= 0:  # array-end emitted iff tagged (§III-C1)
                out.append(Token(TOK_ARRAY_END, tag=end_tag, path=f"{path}.{END}"))
        elif isinstance(t, ListT):
            out.append(
                Token(
                    TOK_LIST_BEGIN,
                    tag=client.tag_for(f"{path}.{START}"),
                    path=f"{path}.{START}",
                )
            )
            for e in v:
                walk(t.elem, e, f"{path}.{ELEM}")
            out.append(
                Token(
                    TOK_LIST_END,
                    value=len(v),
                    tag=client.tag_for(f"{path}.{END}"),
                    path=f"{path}.{END}",
                )
            )
        else:  # pragma: no cover
            raise TypeError(f"bad type {t!r}")

    for fname, ftype in schema.structs[schema.top]:
        walk(ftype, msg[fname], fname)
    return out


def tokens_to_msg(
    schema: Schema, tokens: List[Token], client: Optional[ClientSchema] = None
) -> dict:
    """Reconstruct a message from a DES-side token stream (user-logic view).

    `client` must be the client schema the DES module was generated with so
    that optional array-end tokens are consumed exactly when they were
    emitted (paper §III-C1).
    """
    client = client or ClientSchema()
    pos = 0

    def take(kind: int) -> Token:
        nonlocal pos
        if pos >= len(tokens):
            raise ValueError(f"token stream ended, expected kind {kind}")
        t = tokens[pos]
        if t.kind != kind:
            raise ValueError(f"expected token kind {kind}, got {t!r} at {pos}")
        pos += 1
        return t

    def peek() -> Optional[Token]:
        return tokens[pos] if pos < len(tokens) else None

    def walk(t: TypeNode, path: str):
        if isinstance(t, Bytes):
            return take(TOK_DATA).value
        if isinstance(t, StructRef):
            return {
                f: walk(ft, f"{path}.{f}" if path else f)
                for f, ft in schema.structs[t.name]
            }
        if isinstance(t, Array):
            n = take(TOK_ARRAY_LENGTH).value
            elems = [walk(t.elem, f"{path}.{ELEM}") for _ in range(n)]
            if client.tag_for(f"{path}.{END}") >= 0:
                take(TOK_ARRAY_END)
            return elems
        if isinstance(t, ListT):
            take(TOK_LIST_BEGIN)
            elems = []
            while True:
                nxt = peek()
                if nxt is None:
                    raise ValueError("token stream ended inside a list")
                if nxt.kind == TOK_LIST_END:
                    take(TOK_LIST_END)
                    return elems
                elems.append(walk(t.elem, f"{path}.{ELEM}"))
        raise TypeError(f"bad type {t!r}")  # pragma: no cover

    msg = {}
    for fname, ftype in schema.structs[schema.top]:
        msg[fname] = walk(ftype, fname)
    if pos != len(tokens):
        raise ValueError(f"trailing tokens: consumed {pos} of {len(tokens)}")
    return msg


# ---------------------------------------------------------------------------
# Random messages for property tests
# ---------------------------------------------------------------------------


def random_message(
    schema: Schema,
    rng: np.random.Generator,
    max_elems: int = 4,
    depth_decay: float = 0.7,
) -> dict:
    """Generate a random message conforming to `schema`."""

    def gen(t: TypeNode, depth: int):
        if isinstance(t, Bytes):
            nbits = 8 * t.n
            if nbits <= 62:
                return int(rng.integers(0, 1 << nbits))
            # wide fields: compose 32-bit limbs (numpy bounds are int64)
            v = 0
            for i in range(0, nbits, 32):
                limb_bits = min(32, nbits - i)
                v |= int(rng.integers(0, 1 << limb_bits)) << i
            return v
        if isinstance(t, StructRef):
            return {f: gen(ft, depth) for f, ft in schema.structs[t.name]}
        if isinstance(t, _CONTAINER):
            cap = max(0, int(max_elems * (depth_decay**depth)))
            n = int(rng.integers(0, cap + 1))
            return [gen(t.elem, depth + 1) for _ in range(n)]
        raise TypeError(f"bad type {t!r}")  # pragma: no cover

    return {f: gen(ft, 0) for f, ft in schema.structs[schema.top]}
