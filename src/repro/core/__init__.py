"""repro.core — HGum: schema-driven streaming SER/DES (the paper's contribution).

Public API:

* IDL: :class:`Schema`, :class:`ClientSchema`, type constructors.
* Compilation: :func:`build_rom` (schema tree -> schema ROM).
* Software (store-and-forward) functions: ``ser_sw_to_hw`` / ``des_hw_to_sw`` etc.
* Hardware (streaming, cycle-accurate) engines: :class:`DesFSM` / :class:`SerFSM`.
* TPU-native engines: :mod:`repro.core.vectorized` + ``repro.kernels``.
"""
from .idl import (
    Array,
    Bytes,
    ClientSchema,
    ListT,
    Schema,
    SchemaError,
    StreamT,
    StructRef,
    all_token_paths,
)
from .schema_tree import (
    COUNT_BYTES,
    KIND_ARRAY,
    KIND_BYTES,
    KIND_END,
    KIND_LIST,
    KIND_STREAM,
    STREAM_META_WORDS,
    SchemaROM,
    build_rom,
    build_tree,
    tree_depth,
)
from .stream_plans import (
    Fragment,
    StreamPlan,
    decode_fragments,
    encode_fragment,
    encode_fragment_burst,
    stream_plans,
)
from .tokens import (
    TOK_ARRAY_END,
    TOK_ARRAY_LENGTH,
    TOK_DATA,
    TOK_LIST_BEGIN,
    TOK_LIST_END,
    Token,
    strip_for_ser,
)
from .sw_serdes import (
    des_hw_to_sw,
    des_sw_oracle,
    msg_to_des_tokens,
    random_message,
    ser_hw_to_sw_reference,
    ser_sw_to_hw,
    tokens_to_msg,
)
from .fsm import DesFSM, EngineResult, SerFSM
from .framing import (
    DEFAULT_FRAME_PHITS,
    DEFAULT_PHIT_BYTES,
    FrameHeader,
    FrameWriter,
)
from .vectorized import (
    BatchedDecodePlan,
    DecodePlan,
    batch_plans,
    build_plan,
    decode_batch,
    decode_leaf,
    decode_message,
    encode_leaf,
    encode_message,
    lanes_to_int,
    plan_from_wire,
    stack_wires,
    wire_to_u8,
)

__all__ = [n for n in dir() if not n.startswith("_")]
