"""Cycle-accurate hardware SER/DES engines (paper §IV).

These execute the paper's microarchitecture literally: a schema-independent
FSM walking the schema ROM with a context stack.  One FSM action == one
hardware cycle; the returned cycle counts drive the throughput reproduction
of paper Fig. 14 (see ``benchmarks/bench_fig14_*``).

Cycle model (constants documented; the paper reports only "a few extra
cycles" per container / frame):

* emitting any token (data / array-length / list-begin / array-end /
  list-end) costs 1 cycle;
* completing a container whose end token is *not* emitted still costs 1
  bookkeeping cycle (finding the next node);
* restarting a container element (ChildPtr jump) is combinational — 0 cycles;
* consuming or producing a frame header costs ``FrameWriter.cycles_per_frame``
  (SER, default 2: header fixup + flush) / 1 cycle (DES header read);
* visiting the END node costs 1 cycle.

Directions implemented (paper Figures 8-10):
  * ``DesFSM(direction="sw2hw")``  — hardware DES of the software SER format
    (in-band, length-prefixed counts);
  * ``SerFSM(direction="hw2sw")``  — hardware SER writing counts *after*
    elements (software parses from the end);
  * ``SerFSM(direction="hw2hw")`` / ``DesFSM(direction="hw2hw")`` — framed
    lists per §IV-C.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from .framing import (
    DEFAULT_FRAME_PHITS,
    DEFAULT_PHIT_BYTES,
    FrameHeader,
    FrameWriter,
    payload_wire_bytes,
)
from .schema_tree import (
    COUNT_BYTES,
    KIND_ARRAY,
    KIND_BYTES,
    KIND_END,
    KIND_LIST,
    SchemaROM,
)
from .tokens import (
    TOK_ARRAY_END,
    TOK_ARRAY_LENGTH,
    TOK_DATA,
    TOK_LIST_BEGIN,
    TOK_LIST_END,
    Token,
)

NULL = -1


def fsm_step_bound(rom, n_items: int) -> int:
    """Static step bound of one DES/SER engine run over ``n_items`` input
    units (wire bytes or tokens): linear in the input plus a per-node
    allowance for container bookkeeping.  Shared by both engines' runtime
    guards and the ``repro.analysis`` schema pass, so the bound the
    analyzer reports is the bound the engines enforce."""
    return 8 * n_items + 64 * rom.n_nodes + 64


@dataclass
class Context:
    """One context-stack entry (paper §IV-A2)."""

    num: Optional[int]  # remaining elements; None for framed Lists (unknown)
    ctype: int  # KIND_ARRAY or KIND_LIST
    child_ptr: int
    next_ptr: int  # NULL when the container is the last child
    emit_end: bool
    tag_end: int
    path_idx: int  # ROM index of the container node (debug / end-token path)
    done: int = 0  # elements completed so far (list-end carries this count)


@dataclass
class EngineResult:
    tokens: List[Token]
    cycles: int
    wire: bytes = b""
    frames: int = 0

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)


class _ProtocolError(ValueError):
    pass


# ---------------------------------------------------------------------------
# DES
# ---------------------------------------------------------------------------


class DesFSM:
    """Hardware deserializer: phit/byte stream -> token stream + cycle count."""

    def __init__(
        self,
        rom: SchemaROM,
        direction: str = "sw2hw",
        phit_bytes: int = DEFAULT_PHIT_BYTES,
    ):
        if direction not in ("sw2hw", "hw2hw"):
            raise ValueError(f"bad DES direction {direction!r}")
        self.rom = rom
        self.direction = direction
        self.phit_bytes = phit_bytes

    # -- byte-stream plumbing ------------------------------------------------

    def _read_raw(self, n: int) -> bytes:
        b = self._buf[self._pos : self._pos + n]
        if len(b) != n:
            raise _ProtocolError(f"stream underrun: wanted {n} at {self._pos}")
        self._pos += n
        return b

    def _align(self) -> None:
        self._pos += (-self._pos) % self.phit_bytes

    def _n_list_ctx(self) -> int:
        return sum(1 for c in self._stack if c.ctype == KIND_LIST)

    def _read_header(self) -> FrameHeader:
        self._align()
        hdr, self._pos = FrameHeader.unpack(self._buf, self._pos, self.phit_bytes)
        self._cycles += 1  # header-consume cycle
        self._frames += 1
        return hdr

    def _take_header(self) -> FrameHeader:
        if self._pending_hdr is not None:
            hdr, self._pending_hdr = self._pending_hdr, None
            return hdr
        return self._read_header()

    def _read(self, n: int) -> bytes:
        """Read n payload bytes, crossing frame boundaries when framed."""
        if self.direction == "sw2hw" or self._n_list_ctx() == 0:
            return self._read_raw(n)
        out = bytearray()
        while len(out) < n:
            if self._frame_left == 0:
                hdr = self._take_header()
                if hdr.is_end_of_list or hdr.list_level != self._n_list_ctx():
                    raise _ProtocolError(
                        f"unexpected frame {hdr} mid-element at level "
                        f"{self._n_list_ctx()}"
                    )
                self._frame_left = hdr.size
                self._frame_pad = payload_wire_bytes(hdr.size, self.phit_bytes) - hdr.size
            take = min(n - len(out), self._frame_left)
            out.extend(self._read_raw(take))
            self._frame_left -= take
            if self._frame_left == 0:
                self._read_raw(self._frame_pad)  # skip phit padding
                self._frame_pad = 0
        return bytes(out)

    # -- token emission --------------------------------------------------------

    def _emit(self, kind: int, value: int = 0, tag: int = -1, path: str = "") -> None:
        self._tokens.append(Token(kind, value=value, tag=tag, path=path))
        self._cycles += 1

    # -- main traversal (paper §IV-A2) -----------------------------------------

    def run(self, wire: bytes) -> EngineResult:
        rom = self.rom
        self._buf = wire
        self._pos = 0
        self._cycles = 0
        self._frames = 0
        self._tokens = []
        self._stack: List[Context] = []
        self._frame_left = 0
        self._frame_pad = 0
        self._pending_hdr: Optional[FrameHeader] = None

        ptr = rom.root_first
        guard = 0
        max_steps = fsm_step_bound(rom, len(wire))
        while True:
            guard += 1
            if guard > max_steps:  # defensive: malformed wire must not hang
                raise _ProtocolError("DES FSM exceeded step bound")
            kind = int(rom.kind[ptr])
            if kind == KIND_END:
                self._cycles += 1
                break
            if kind == KIND_BYTES:
                n = int(rom.nbytes[ptr])
                val = int.from_bytes(self._read(n), "little")
                self._emit(TOK_DATA, value=val, tag=int(rom.tag[ptr]), path=rom.paths[ptr])
                ptr = self._advance(ptr)
            elif kind == KIND_ARRAY or (kind == KIND_LIST and self.direction == "sw2hw"):
                cnt = int.from_bytes(self._read(COUNT_BYTES), "little")
                tok = TOK_ARRAY_LENGTH if kind == KIND_ARRAY else TOK_LIST_BEGIN
                val = cnt if kind == KIND_ARRAY else 0  # list-begin carries no count
                self._emit(tok, value=val, tag=int(rom.tag_start[ptr]), path=rom.paths[ptr] + ".start")
                if cnt > 0:
                    self._push(ptr, cnt)
                    ptr = int(rom.child[ptr])
                else:
                    ptr = self._end_container_inline(ptr)
            else:  # framed List (hw2hw)
                self._emit(TOK_LIST_BEGIN, tag=int(rom.tag_start[ptr]), path=rom.paths[ptr] + ".start")
                hdr = self._take_header()
                want = self._n_list_ctx() + 1
                if hdr.list_level < want:
                    raise _ProtocolError(f"frame level {hdr.list_level}, expected >= {want}")
                if hdr.list_level > want:
                    # Frame belongs to a descendant list (the first element of
                    # this list begins with a nested list).  Paper: "keep
                    # traversing the schema tree until equality is reached".
                    self._pending_hdr = hdr
                    self._push(ptr, None)
                    ptr = int(rom.child[ptr])
                elif hdr.is_end_of_list:
                    ptr = self._end_container_inline(ptr)  # empty list
                else:
                    self._frame_left = hdr.size
                    self._frame_pad = payload_wire_bytes(hdr.size, self.phit_bytes) - hdr.size
                    self._push(ptr, None)
                    ptr = int(rom.child[ptr])

        return EngineResult(self._tokens, self._cycles, frames=self._frames)

    def _push(self, ptr: int, num: Optional[int]) -> None:
        rom = self.rom
        self._stack.append(
            Context(
                num=num,
                ctype=int(rom.kind[ptr]),
                child_ptr=int(rom.child[ptr]),
                next_ptr=NULL if int(rom.last[ptr]) else ptr + 1,
                emit_end=bool(int(rom.emit_end[ptr])),
                tag_end=int(rom.tag_end[ptr]),
                path_idx=ptr,
            )
        )

    def _emit_container_end(
        self, ctype: int, emit_end: bool, tag_end: int, path: str, count: int
    ) -> None:
        """End-of-container processing: one cycle, token iff emitted."""
        if ctype == KIND_LIST:
            self._emit(TOK_LIST_END, value=count, tag=tag_end, path=path + ".end")
        elif emit_end:
            self._emit(TOK_ARRAY_END, tag=tag_end, path=path + ".end")
        else:
            self._cycles += 1  # silent end-processing cycle

    def _end_container_inline(self, ptr: int) -> int:
        """Zero-element container: end it without having pushed a context."""
        rom = self.rom
        self._emit_container_end(
            int(rom.kind[ptr]),
            bool(int(rom.emit_end[ptr])),
            int(rom.tag_end[ptr]),
            rom.paths[ptr],
            count=0,
        )
        return self._advance(ptr)

    def _list_has_more_elements(self) -> bool:
        """Framed list at an element boundary: does another element follow?"""
        if self._frame_left > 0:
            return True
        hdr = self._take_header()
        lvl = self._n_list_ctx()
        if hdr.list_level == lvl and hdr.is_end_of_list:
            return False
        if hdr.list_level < lvl:
            raise _ProtocolError(f"frame level dropped to {hdr.list_level} < {lvl}")
        # Same-level data frame, or a deeper-level frame (next element begins
        # with a nested list; paper: "keep traversing the schema tree until
        # equality is reached").  Stash it; traversal will consume it.
        self._pending_hdr = hdr
        if hdr.list_level == lvl:
            self._frame_left = hdr.size
            self._frame_pad = payload_wire_bytes(hdr.size, self.phit_bytes) - hdr.size
            self._pending_hdr = None
            if hdr.is_end_of_list:  # pragma: no cover - caught above
                return False
        return True

    def _advance(self, ptr: int) -> int:
        """Find the next node after finishing `ptr` (paper's traversal rules)."""
        rom = self.rom
        while True:
            if not int(rom.last[ptr]):
                return ptr + 1
            if not self._stack:
                raise _ProtocolError("context stack underflow")
            top = self._stack[-1]
            top.done += 1
            if top.num is not None:
                top.num -= 1
                more = top.num > 0
            else:
                more = self._list_has_more_elements()
            if more:
                return top.child_ptr
            self._emit_container_end(
                top.ctype, top.emit_end, top.tag_end, rom.paths[top.path_idx], top.done
            )
            self._stack.pop()
            if top.next_ptr != NULL:
                return top.next_ptr
            ptr = top.path_idx  # cascade: container itself completed an element


# ---------------------------------------------------------------------------
# SER
# ---------------------------------------------------------------------------


class SerFSM:
    """Hardware serializer: SER-side token stream -> wire bytes + cycles."""

    def __init__(
        self,
        rom: SchemaROM,
        direction: str = "hw2hw",
        phit_bytes: int = DEFAULT_PHIT_BYTES,
        frame_phits: int = DEFAULT_FRAME_PHITS,
        frame_cycles: int = 2,
    ):
        if direction not in ("hw2sw", "hw2hw"):
            raise ValueError(f"bad SER direction {direction!r}")
        self.rom = rom
        self.direction = direction
        self.phit_bytes = phit_bytes
        self.frame_phits = frame_phits
        self.frame_cycles = frame_cycles

    # -- token input -----------------------------------------------------------

    def _next(self, expect: int) -> Token:
        if self._tpos >= len(self._toks):
            raise _ProtocolError(f"token underrun, expected kind {expect}")
        t = self._toks[self._tpos]
        if t.kind != expect:
            raise _ProtocolError(f"expected token kind {expect}, got {t!r}")
        self._tpos += 1
        self._cycles += 1  # one consumed token per cycle
        return t

    def _peek(self) -> Optional[Token]:
        return self._toks[self._tpos] if self._tpos < len(self._toks) else None

    # -- byte output -------------------------------------------------------------

    def _write(self, data: bytes) -> None:
        lvl = self._n_list_ctx()
        if self.direction == "hw2hw" and lvl >= 1:
            self._framer.write(data, lvl)
        else:
            self._out.extend(data)

    def _n_list_ctx(self) -> int:
        return sum(1 for c in self._stack if c.ctype == KIND_LIST)

    # -- main traversal ------------------------------------------------------------

    def run(self, tokens: List[Token]) -> EngineResult:
        rom = self.rom
        self._toks = tokens
        self._tpos = 0
        self._cycles = 0
        self._out = bytearray()
        self._stack: List[Context] = []
        self._framer = FrameWriter(
            self._out, self.frame_phits, self.phit_bytes, self.frame_cycles
        )

        ptr = rom.root_first
        guard = 0
        max_steps = fsm_step_bound(rom, len(tokens))
        while True:
            guard += 1
            if guard > max_steps:
                raise _ProtocolError("SER FSM exceeded step bound")
            kind = int(rom.kind[ptr])
            if kind == KIND_END:
                self._cycles += 1
                break
            if kind == KIND_BYTES:
                t = self._next(TOK_DATA)
                self._write(int(t.value).to_bytes(int(rom.nbytes[ptr]), "little"))
                ptr = self._advance(ptr)
            elif kind == KIND_ARRAY:
                t = self._next(TOK_ARRAY_LENGTH)
                cnt = int(t.value)
                if self.direction == "hw2hw":
                    self._write(cnt.to_bytes(COUNT_BYTES, "little"))
                if cnt > 0:
                    self._push(ptr, cnt)
                    ptr = int(rom.child[ptr])
                else:
                    if self.direction == "hw2sw":
                        self._write_trailing_count(0)
                    self._cycles += 1  # end-processing cycle
                    ptr = self._advance(ptr)
            else:  # KIND_LIST — no list-begin token on the SER side (§III-C2)
                lvl = self._n_list_ctx() + 1
                nxt = self._peek()
                if nxt is not None and nxt.kind == TOK_LIST_END and int(nxt.value) == lvl:
                    self._next(TOK_LIST_END)  # empty list
                    if self.direction == "hw2sw":
                        self._write_trailing_count(0)
                    else:
                        self._framer.end_list(lvl)
                    ptr = self._advance(ptr)
                else:
                    self._push(ptr, None)
                    ptr = int(rom.child[ptr])

        if self.direction == "hw2hw":
            self._framer.flush()
        self._cycles += self._framer.overhead_cycles
        if self._tpos != len(tokens):
            raise _ProtocolError(f"trailing tokens: {self._tpos} of {len(tokens)}")
        return EngineResult(
            list(tokens), self._cycles, wire=bytes(self._out), frames=self._framer.frames_emitted
        )

    def _write_trailing_count(self, cnt: int) -> None:
        """HW->SW: counts go AFTER the elements (paper §IV-B); costs a cycle."""
        self._out.extend(cnt.to_bytes(COUNT_BYTES, "little"))
        self._cycles += 1

    def _push(self, ptr: int, num: Optional[int]) -> None:
        rom = self.rom
        self._stack.append(
            Context(
                num=num,
                ctype=int(rom.kind[ptr]),
                child_ptr=int(rom.child[ptr]),
                next_ptr=NULL if int(rom.last[ptr]) else ptr + 1,
                emit_end=False,
                tag_end=-1,
                path_idx=ptr,
            )
        )

    def _advance(self, ptr: int) -> int:
        rom = self.rom
        while True:
            if not int(rom.last[ptr]):
                return ptr + 1
            if not self._stack:
                raise _ProtocolError("context stack underflow")
            top = self._stack[-1]
            top.done += 1
            if top.ctype == KIND_ARRAY:
                top.num -= 1
                if top.num > 0:
                    return top.child_ptr
                if self.direction == "hw2sw":
                    self._write_trailing_count(top.done)
                self._cycles += 1  # end-processing cycle
            else:  # List: decided by the next input token
                lvl = self._n_list_ctx()
                nxt = self._peek()
                if not (nxt is not None and nxt.kind == TOK_LIST_END and int(nxt.value) == lvl):
                    return top.child_ptr  # another element follows
                self._next(TOK_LIST_END)
                if self.direction == "hw2sw":
                    self._write_trailing_count(top.done)
                else:
                    self._framer.end_list(lvl)
            self._stack.pop()
            if top.next_ptr != NULL:
                return top.next_ptr
            ptr = top.path_idx
