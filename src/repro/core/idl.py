"""HGum IDL: JSON schema grammar, parsing and validation (paper §III-B).

Grammar (Fig. 5 of the paper)::

    schema    ::= { structName : structDef, ... }
    structDef ::= [ [fieldName, type], ... ]
    type      ::= ["Bytes", n] | ["Struct", structName]
                | ["Array", type] | ["List", type] | ["Stream", type]

The *central schema* is shared by sender and receiver.  A *client schema*
(paper §III-C1, Fig. 7) assigns integer tags to token paths and is private to
one DES module; multiple client schemas may exist for one central schema.

``["Stream", t]`` extends the paper grammar: a List whose elements are
emitted incrementally across ticks.  Each fragment on the wire carries
``(stream_id, step, flags)`` metadata and keeps the §IV-B
count-after-elements convention, so bursts of fragments still parse
back-to-front.  Chunk codecs for streams are *generated* from the schema
(see ``core.stream_plans``), never hand-written.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Tuple, Union


class SchemaError(ValueError):
    """Raised for malformed schema / client-schema definitions."""


# ---------------------------------------------------------------------------
# Type AST
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Bytes:
    """``["Bytes", n]`` — an n-byte scalar field (byte width configurable)."""

    n: int

    def __post_init__(self):
        if not isinstance(self.n, int) or self.n <= 0:
            raise SchemaError(f"Bytes width must be a positive int, got {self.n!r}")


@dataclass(frozen=True)
class StructRef:
    """``["Struct", name]`` — reference to a named structure."""

    name: str


@dataclass(frozen=True)
class Array:
    """``["Array", t]`` — length known before any element is serialized."""

    elem: "TypeNode"


@dataclass(frozen=True)
class ListT:
    """``["List", t]`` — length unknown until the last element is serialized."""

    elem: "TypeNode"


@dataclass(frozen=True)
class StreamT:
    """``["Stream", t]`` — a List emitted incrementally across ticks.

    Elements travel as chunk fragments tagged ``(stream_id, step, flags)``;
    the element type must be fixed-size (no nested containers) so the chunk
    codec can be generated with static bounds.
    """

    elem: "TypeNode"


TypeNode = Union[Bytes, StructRef, Array, ListT, StreamT]

_CONTAINER = (Array, ListT, StreamT)


def parse_type(obj) -> TypeNode:
    """Parse one ``type`` production from its JSON form."""
    if (not isinstance(obj, (list, tuple))) or len(obj) != 2:
        raise SchemaError(f"type must be a 2-element list, got {obj!r}")
    kind, arg = obj
    if kind == "Bytes":
        if not isinstance(arg, int):
            raise SchemaError(f"Bytes arg must be int, got {arg!r}")
        return Bytes(arg)
    if kind == "Struct":
        if not isinstance(arg, str):
            raise SchemaError(f"Struct arg must be a name, got {arg!r}")
        return StructRef(arg)
    if kind == "Array":
        return Array(parse_type(arg))
    if kind == "List":
        return ListT(parse_type(arg))
    if kind == "Stream":
        return StreamT(parse_type(arg))
    raise SchemaError(f"unknown type constructor {kind!r}")


def type_to_json(t: TypeNode):
    if isinstance(t, Bytes):
        return ["Bytes", t.n]
    if isinstance(t, StructRef):
        return ["Struct", t.name]
    if isinstance(t, Array):
        return ["Array", type_to_json(t.elem)]
    if isinstance(t, ListT):
        return ["List", type_to_json(t.elem)]
    if isinstance(t, StreamT):
        return ["Stream", type_to_json(t.elem)]
    raise SchemaError(f"not a type node: {t!r}")


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------


@dataclass
class Schema:
    """A parsed central schema: named structs, one of which is the message."""

    structs: Dict[str, List[Tuple[str, TypeNode]]]
    top: str  # the message struct name

    # -- construction ------------------------------------------------------

    @staticmethod
    def from_json(obj, top: str | None = None) -> "Schema":
        if isinstance(obj, str):
            obj = json.loads(obj)
        if not isinstance(obj, dict) or not obj:
            raise SchemaError("schema must be a non-empty JSON object")
        structs: Dict[str, List[Tuple[str, TypeNode]]] = {}
        for sname, sdef in obj.items():
            if not isinstance(sdef, (list, tuple)):
                raise SchemaError(f"structDef of {sname!r} must be a list")
            fields: List[Tuple[str, TypeNode]] = []
            seen = set()
            for f in sdef:
                if not isinstance(f, (list, tuple)) or len(f) != 2:
                    raise SchemaError(f"field of {sname!r} must be [name, type]: {f!r}")
                fname, ftype = f
                if not isinstance(fname, str) or not fname:
                    raise SchemaError(f"bad field name {fname!r} in {sname!r}")
                if fname in seen:
                    raise SchemaError(f"duplicate field {fname!r} in {sname!r}")
                seen.add(fname)
                fields.append((fname, parse_type(ftype)))
            structs[sname] = fields
        if top is None:
            # Paper: "The structName of the top level structure should match
            # the name of the message."  With one struct it is unambiguous;
            # otherwise the first key is the message (JSON objects are ordered).
            top = next(iter(obj))
        schema = Schema(structs=structs, top=top)
        schema.validate()
        return schema

    def to_json(self) -> dict:
        return {
            s: [[fn, type_to_json(ft)] for fn, ft in fl]
            for s, fl in self.structs.items()
        }

    # -- validation --------------------------------------------------------

    def validate(self) -> None:
        if self.top not in self.structs:
            raise SchemaError(f"top-level struct {self.top!r} is not defined")
        # every StructRef resolves; no recursive struct cycles (a message is
        # finite; recursion would make the schema tree infinite).
        for sname, fields in self.structs.items():
            for fname, ftype in fields:
                self._check_refs(ftype, f"{sname}.{fname}")
        self._check_acyclic(self.top, stack=())

    def _check_refs(self, t: TypeNode, where: str) -> None:
        if isinstance(t, StructRef):
            if t.name not in self.structs:
                raise SchemaError(f"{where}: undefined struct {t.name!r}")
        elif isinstance(t, _CONTAINER):
            self._check_refs(t.elem, where + "[]")

    def _struct_deps(self, t: TypeNode):
        if isinstance(t, StructRef):
            yield t.name
        elif isinstance(t, _CONTAINER):
            yield from self._struct_deps(t.elem)

    def _check_acyclic(self, sname: str, stack: tuple) -> None:
        if sname in stack:
            raise SchemaError(
                f"recursive struct cycle: {' -> '.join(stack + (sname,))}"
            )
        for fname, ftype in self.structs[sname]:
            for dep in self._struct_deps(ftype):
                self._check_acyclic(dep, stack + (sname,))

    # -- convenience -------------------------------------------------------

    def resolve(self, t: TypeNode) -> TypeNode:
        """Follow a StructRef one level (no-op for other nodes)."""
        return t

    def max_depth(self) -> int:
        """Maximum container (Array/List/Stream) nesting depth of the message."""

        def depth_of(t: TypeNode) -> int:
            if isinstance(t, Bytes):
                return 0
            if isinstance(t, StructRef):
                return max(
                    (depth_of(ft) for _, ft in self.structs[t.name]), default=0
                )
            if isinstance(t, _CONTAINER):
                return 1 + depth_of(t.elem)
            raise SchemaError(f"bad type {t!r}")

        return max((depth_of(ft) for _, ft in self.structs[self.top]), default=0)


# ---------------------------------------------------------------------------
# Client schema (token tags, paper Fig. 7)
# ---------------------------------------------------------------------------

START = "start"  # array-length / list-begin token of a container
END = "end"  # array-end / list-end token of a container
ELEM = "elem"  # descend into the container's element


@dataclass
class ClientSchema:
    """Maps token paths (e.g. ``a.elem.elem.x``, ``a.start``) to integer tags.

    Per the paper, defining an ``end`` tag for an Array makes the DES logic
    emit the (otherwise optional) array-end token.  Lists always emit
    list-begin/list-end.  Tags are small non-negative ints.
    """

    tags: Dict[str, int] = field(default_factory=dict)

    @staticmethod
    def from_json(obj) -> "ClientSchema":
        if isinstance(obj, str):
            obj = json.loads(obj)
        if not isinstance(obj, dict):
            raise SchemaError("client schema must be a JSON object")
        tags = {}
        for path, tag in obj.items():
            if not isinstance(path, str) or not path:
                raise SchemaError(f"bad token path {path!r}")
            if not isinstance(tag, int) or tag < 0:
                raise SchemaError(f"tag for {path!r} must be a non-negative int")
            tags[path] = tag
        cs = ClientSchema(tags)
        cs.validate()
        return cs

    def validate(self) -> None:
        """Tags must be unique: the DES emits (tag, value) pairs, so two
        paths sharing a tag make its output ambiguous."""
        by_tag: Dict[int, List[str]] = {}
        for path, tag in self.tags.items():
            by_tag.setdefault(tag, []).append(path)
        for tag, paths in sorted(by_tag.items()):
            if len(paths) > 1:
                raise SchemaError(
                    f"client-schema tag {tag} is shared by paths "
                    f"{sorted(paths)}"
                )

    def to_json(self) -> dict:
        return dict(self.tags)

    def tag_for(self, path: str) -> int:
        """Tag for a token path, or -1 when unspecified."""
        return self.tags.get(path, -1)

    def validate_against(self, schema: Schema) -> None:
        """Every tag path must name a real token of the schema."""
        valid = set(all_token_paths(schema))
        for path in self.tags:
            if path not in valid:
                raise SchemaError(
                    f"client-schema path {path!r} does not name a token; "
                    f"valid paths include e.g. {sorted(valid)[:6]}"
                )


def all_token_paths(schema: Schema) -> List[str]:
    """Enumerate every legal token path of a schema (pre-preprocessing view)."""
    out: List[str] = []

    def walk(t: TypeNode, prefix: str) -> None:
        if isinstance(t, Bytes):
            out.append(prefix)
        elif isinstance(t, StructRef):
            for fname, ftype in schema.structs[t.name]:
                walk(ftype, f"{prefix}.{fname}" if prefix else fname)
        elif isinstance(t, _CONTAINER):
            out.append(f"{prefix}.{START}")
            out.append(f"{prefix}.{END}")
            walk(t.elem, f"{prefix}.{ELEM}")
        else:  # pragma: no cover
            raise SchemaError(f"bad type {t!r}")

    for fname, ftype in schema.structs[schema.top]:
        walk(ftype, fname)
    return out
