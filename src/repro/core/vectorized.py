"""TPU-native HGum decode/encode: prefix-sum + segmented gather.

This is the hardware adaptation of the paper's §IV-A2 traversal (see
DESIGN.md §3).  An FPGA walks the schema ROM with a 1-token-per-cycle FSM; a
TPU has no cheap sequential byte automaton, but it has wide gathers and
prefix scans.  We therefore split deserialization into:

* **structure pass** — compute, for every instance of every schema-ROM node,
  its byte offset in the wire.  The side that *can* buffer (the host for
  SW->HW, exactly the asymmetry the paper exploits in §IV-B) computes this
  `DecodePlan` in O(#field instances) with numpy; for device-resident wires
  the plan is recovered from the counts in the wire itself
  (``plan_from_wire``).
* **payload pass** — one vectorized gather per leaf node moves all payload
  bytes at once (``decode_leaf`` below; the Pallas kernel in
  ``repro.kernels.phit_unpack`` is the tiled production version, this module
  is its jnp oracle).

Outputs are padded to static capacities (`caps`) with validity masks, as jit
requires static shapes.  Encoding (`encode_from_plan`) is the mirrored
scatter.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .idl import Array, Bytes, ListT, Schema, StructRef, TypeNode, ELEM
from .schema_tree import COUNT_BYTES

_CONTAINER = (Array, ListT)


# ---------------------------------------------------------------------------
# Decode plan (structure pass)
# ---------------------------------------------------------------------------


@dataclass
class DecodePlan:
    """Byte offsets of every instance of every field path, padded to caps."""

    offsets: Dict[str, np.ndarray]  # path -> int32[cap] byte offsets (pad = 0)
    counts: Dict[str, int]  # path -> true instance count
    nbytes: Dict[str, int]  # path -> field width (COUNT_BYTES for containers)
    is_container: Dict[str, bool]
    wire_len: int

    def cap(self, path: str) -> int:
        return int(self.offsets[path].shape[0])


def _walk_paths(schema: Schema) -> List[Tuple[str, TypeNode]]:
    """All (path, type) pairs of the flattened schema in traversal order."""
    out: List[Tuple[str, TypeNode]] = []

    def walk(t: TypeNode, path: str) -> None:
        if isinstance(t, Bytes):
            out.append((path, t))
        elif isinstance(t, StructRef):
            for f, ft in schema.structs[t.name]:
                walk(ft, f"{path}.{f}" if path else f)
        elif isinstance(t, _CONTAINER):
            out.append((path, t))
            walk(t.elem, f"{path}.{ELEM}")
        else:  # pragma: no cover
            raise TypeError(f"bad type {t!r}")

    for f, ft in schema.structs[schema.top]:
        walk(ft, f)
    return out


def build_plan(
    schema: Schema, msg: dict, caps: Optional[Dict[str, int]] = None
) -> DecodePlan:
    """Host-side structure pass over a message (SW->HW wire format)."""
    offs: Dict[str, List[int]] = {p: [] for p, _ in _walk_paths(schema)}
    widths: Dict[str, int] = {}
    is_cont: Dict[str, bool] = {}
    for p, t in _walk_paths(schema):
        widths[p] = t.n if isinstance(t, Bytes) else COUNT_BYTES
        is_cont[p] = isinstance(t, _CONTAINER)
    pos = 0

    def walk(t: TypeNode, v, path: str) -> None:
        nonlocal pos
        if isinstance(t, Bytes):
            offs[path].append(pos)
            pos += t.n
        elif isinstance(t, StructRef):
            for f, ft in schema.structs[t.name]:
                walk(ft, v[f], f"{path}.{f}" if path else f)
        elif isinstance(t, _CONTAINER):
            offs[path].append(pos)
            pos += COUNT_BYTES
            for e in v:
                walk(t.elem, e, f"{path}.{ELEM}")
        else:  # pragma: no cover
            raise TypeError(f"bad type {t!r}")

    for f, ft in schema.structs[schema.top]:
        walk(ft, msg[f], f)

    out_offs, out_counts = {}, {}
    for p, lst in offs.items():
        cap = (caps or {}).get(p, max(1, len(lst)))
        if len(lst) > cap:
            raise ValueError(f"{p}: {len(lst)} instances exceed cap {cap}")
        arr = np.zeros(cap, np.int32)
        arr[: len(lst)] = lst
        out_offs[p] = arr
        out_counts[p] = len(lst)
    return DecodePlan(out_offs, out_counts, widths, is_cont, wire_len=pos)


def plan_from_wire(
    schema: Schema,
    wire: bytes,
    caps: Optional[Dict[str, int]] = None,
    record_paths: Optional[List[str]] = None,
) -> DecodePlan:
    """Structure pass over a received wire (no values needed, counts only).

    Cost is O(#container instances + #recorded instances): when
    `record_paths` restricts recording, fixed-size unrecorded subtrees are
    skipped by multiplication instead of being walked element by element.
    """
    paths = _walk_paths(schema)
    wanted = set(record_paths) if record_paths is not None else {p for p, _ in paths}
    offs: Dict[str, List[int]] = {p: [] for p, _ in paths if p in wanted}
    widths = {p: (t.n if isinstance(t, Bytes) else COUNT_BYTES) for p, t in paths}
    is_cont = {p: isinstance(t, _CONTAINER) for p, t in paths}

    def static_size(t: TypeNode) -> Optional[int]:
        if isinstance(t, Bytes):
            return t.n
        if isinstance(t, StructRef):
            tot = 0
            for _, ft in schema.structs[t.name]:
                s = static_size(ft)
                if s is None:
                    return None
                tot += s
            return tot
        return None  # containers are dynamic

    pos = 0

    def walk(t: TypeNode, path: str) -> None:
        nonlocal pos
        if isinstance(t, Bytes):
            if path in offs:
                offs[path].append(pos)
            pos += t.n
        elif isinstance(t, StructRef):
            for f, ft in schema.structs[t.name]:
                walk(ft, f"{path}.{f}" if path else f)
        elif isinstance(t, _CONTAINER):
            if path in offs:
                offs[path].append(pos)
            n = int.from_bytes(wire[pos : pos + COUNT_BYTES], "little")
            pos += COUNT_BYTES
            es = static_size(t.elem)
            epath = f"{path}.{ELEM}"
            recorded_below = any(p.startswith(epath) for p in offs)
            if es is not None and not recorded_below:
                pos += n * es  # skip the whole fixed-size run
            elif es is not None and recorded_below and _only_leaf(t.elem):
                # uniform run: offsets are an arithmetic sequence (prefix-sum
                # fast path — this is the TPU-native container decode)
                offs[epath].extend(range(pos, pos + n * es, es))
                pos += n * es
            else:
                for _ in range(n):
                    walk(t.elem, epath)
        else:  # pragma: no cover
            raise TypeError(f"bad type {t!r}")

    def _only_leaf(t: TypeNode) -> bool:
        return isinstance(t, Bytes)

    for f, ft in schema.structs[schema.top]:
        walk(ft, f)

    out_offs, out_counts = {}, {}
    for p, lst in offs.items():
        cap = (caps or {}).get(p, max(1, len(lst)))
        arr = np.zeros(cap, np.int32)
        arr[: len(lst)] = lst[:cap]
        out_offs[p] = arr
        out_counts[p] = len(lst)
    return DecodePlan(out_offs, out_counts, widths, is_cont, wire_len=pos)


# ---------------------------------------------------------------------------
# Payload pass (vectorized gather) — jnp oracle for kernels/phit_unpack
# ---------------------------------------------------------------------------


def wire_to_u8(wire: bytes) -> jnp.ndarray:
    return jnp.asarray(np.frombuffer(wire, dtype=np.uint8))


def decode_leaf(
    wire_u8: jnp.ndarray, offsets: jnp.ndarray, nbytes: int
) -> jnp.ndarray:
    """Gather all instances of one leaf field: (cap,) offsets ->
    (cap, ceil(nbytes/4)) uint32 little-endian lanes (jit-friendly)."""
    nlanes = (nbytes + 3) // 4
    byte_idx = offsets[:, None] + jnp.arange(nbytes, dtype=jnp.int32)[None, :]
    byte_idx = jnp.clip(byte_idx, 0, wire_u8.shape[0] - 1)
    b = wire_u8[byte_idx].astype(jnp.uint32)  # (cap, nbytes)
    pad = nlanes * 4 - nbytes
    if pad:
        b = jnp.pad(b, ((0, 0), (0, pad)))
    b = b.reshape(offsets.shape[0], nlanes, 4)
    shifts = jnp.array([0, 8, 16, 24], jnp.uint32)
    return (b << shifts[None, None, :]).sum(axis=-1).astype(jnp.uint32)


def decode_message(
    wire_u8: jnp.ndarray, plan: DecodePlan, paths: Optional[List[str]] = None
) -> Dict[str, jnp.ndarray]:
    """Decode every requested path into padded uint32-lane buffers."""
    out = {}
    for p in paths or plan.offsets.keys():
        out[p] = decode_leaf(wire_u8, jnp.asarray(plan.offsets[p]), plan.nbytes[p])
    return out


def lanes_to_int(lanes: np.ndarray, nbytes: int) -> np.ndarray:
    """uint32 lanes -> python-int-compatible object array (test helper)."""
    lanes = np.asarray(lanes, dtype=np.uint64)
    out = np.zeros(lanes.shape[0], dtype=object)
    for j in range(lanes.shape[1]):
        out = out + (lanes[:, j].astype(object) << (32 * j))
    mask = (1 << (8 * nbytes)) - 1
    return np.array([int(v) & mask for v in out], dtype=object)


# ---------------------------------------------------------------------------
# Encode (scatter) — device-side SER payload pass
# ---------------------------------------------------------------------------


def encode_leaf(
    wire_u8: jnp.ndarray,
    offsets: jnp.ndarray,
    lanes: jnp.ndarray,
    nbytes: int,
    count: jnp.ndarray | int,
) -> jnp.ndarray:
    """Scatter `count` instances of a leaf field into the wire buffer."""
    cap = offsets.shape[0]
    nlanes = (nbytes + 3) // 4
    shifts = jnp.array([0, 8, 16, 24], jnp.uint32)
    bytes_ = (
        (lanes[:, :, None] >> shifts[None, None, :]) & jnp.uint32(0xFF)
    ).astype(jnp.uint8)
    bytes_ = bytes_.reshape(cap, nlanes * 4)[:, :nbytes]
    byte_idx = offsets[:, None] + jnp.arange(nbytes, dtype=jnp.int32)[None, :]
    valid = (jnp.arange(cap, dtype=jnp.int32) < count)[:, None]
    byte_idx = jnp.where(valid, byte_idx, wire_u8.shape[0])  # OOB drops
    return wire_u8.at[byte_idx.reshape(-1)].set(
        bytes_.reshape(-1), mode="drop"
    )


def encode_message(
    wire_len: int, plan: DecodePlan, values: Dict[str, jnp.ndarray]
) -> jnp.ndarray:
    """Software-free device-side encode: scatter all paths into a wire buffer.

    `values[path]` are uint32 lanes shaped (cap, nlanes); container paths must
    be present with their counts as values (they serialize like u32 fields).
    """
    wire = jnp.zeros(wire_len, jnp.uint8)
    for p, lanes in values.items():
        wire = encode_leaf(
            wire,
            jnp.asarray(plan.offsets[p]),
            lanes,
            plan.nbytes[p],
            plan.counts[p],
        )
    return wire
