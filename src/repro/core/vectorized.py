"""TPU-native HGum decode/encode: prefix-sum + segmented gather.

This is the hardware adaptation of the paper's §IV-A2 traversal (see
DESIGN.md §3).  An FPGA walks the schema ROM with a 1-token-per-cycle FSM; a
TPU has no cheap sequential byte automaton, but it has wide gathers and
prefix scans.  We therefore split deserialization into:

* **structure pass** — compute, for every instance of every schema-ROM node,
  its byte offset in the wire.  The side that *can* buffer (the host for
  SW->HW, exactly the asymmetry the paper exploits in §IV-B) computes this
  `DecodePlan` in O(#field instances) with numpy; for device-resident wires
  the plan is recovered from the counts in the wire itself
  (``plan_from_wire``).
* **payload pass** — one vectorized gather per leaf node moves all payload
  bytes at once (``decode_leaf`` below; the Pallas kernel in
  ``repro.kernels.phit_unpack`` is the tiled production version, this module
  is its jnp oracle).

Outputs are padded to static capacities (`caps`) with validity masks, as jit
requires static shapes.  Encoding (`encode_from_plan`) is the mirrored
scatter.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from .idl import Array, Bytes, ListT, Schema, StructRef, TypeNode, ELEM
from .schema_tree import COUNT_BYTES

_CONTAINER = (Array, ListT)


# ---------------------------------------------------------------------------
# Decode plan (structure pass)
# ---------------------------------------------------------------------------


@dataclass
class DecodePlan:
    """Byte offsets of every instance of every field path, padded to caps."""

    offsets: Dict[str, np.ndarray]  # path -> int32[cap] byte offsets (pad = 0)
    counts: Dict[str, int]  # path -> true instance count
    nbytes: Dict[str, int]  # path -> field width (COUNT_BYTES for containers)
    is_container: Dict[str, bool]
    wire_len: int

    def cap(self, path: str) -> int:
        return int(self.offsets[path].shape[0])


def _walk_paths(schema: Schema) -> List[Tuple[str, TypeNode]]:
    """All (path, type) pairs of the flattened schema in traversal order."""
    out: List[Tuple[str, TypeNode]] = []

    def walk(t: TypeNode, path: str) -> None:
        if isinstance(t, Bytes):
            out.append((path, t))
        elif isinstance(t, StructRef):
            for f, ft in schema.structs[t.name]:
                walk(ft, f"{path}.{f}" if path else f)
        elif isinstance(t, _CONTAINER):
            out.append((path, t))
            walk(t.elem, f"{path}.{ELEM}")
        else:  # pragma: no cover
            raise TypeError(f"bad type {t!r}")

    for f, ft in schema.structs[schema.top]:
        walk(ft, f)
    return out


def build_plan(
    schema: Schema, msg: dict, caps: Optional[Dict[str, int]] = None
) -> DecodePlan:
    """Host-side structure pass over a message (SW->HW wire format)."""
    offs: Dict[str, List[int]] = {p: [] for p, _ in _walk_paths(schema)}
    widths: Dict[str, int] = {}
    is_cont: Dict[str, bool] = {}
    for p, t in _walk_paths(schema):
        widths[p] = t.n if isinstance(t, Bytes) else COUNT_BYTES
        is_cont[p] = isinstance(t, _CONTAINER)
    pos = 0

    def walk(t: TypeNode, v, path: str) -> None:
        nonlocal pos
        if isinstance(t, Bytes):
            offs[path].append(pos)
            pos += t.n
        elif isinstance(t, StructRef):
            for f, ft in schema.structs[t.name]:
                walk(ft, v[f], f"{path}.{f}" if path else f)
        elif isinstance(t, _CONTAINER):
            offs[path].append(pos)
            pos += COUNT_BYTES
            for e in v:
                walk(t.elem, e, f"{path}.{ELEM}")
        else:  # pragma: no cover
            raise TypeError(f"bad type {t!r}")

    for f, ft in schema.structs[schema.top]:
        walk(ft, msg[f], f)

    out_offs, out_counts = {}, {}
    for p, lst in offs.items():
        cap = (caps or {}).get(p, max(1, len(lst)))
        if len(lst) > cap:
            raise ValueError(f"{p}: {len(lst)} instances exceed cap {cap}")
        arr = np.zeros(cap, np.int32)
        arr[: len(lst)] = lst
        out_offs[p] = arr
        out_counts[p] = len(lst)
    return DecodePlan(out_offs, out_counts, widths, is_cont, wire_len=pos)


def _static_size(schema: Schema, t: TypeNode) -> Optional[int]:
    """Wire bytes of `t` if fixed-size (containers are dynamic -> None)."""
    if isinstance(t, Bytes):
        return t.n
    if isinstance(t, StructRef):
        tot = 0
        for _, ft in schema.structs[t.name]:
            s = _static_size(schema, ft)
            if s is None:
                return None
            tot += s
        return tot
    return None


def plan_from_wire(
    schema: Schema,
    wire: bytes,
    caps: Optional[Dict[str, int]] = None,
    record_paths: Optional[List[str]] = None,
) -> DecodePlan:
    """Structure pass over a received wire (no values needed, counts only).

    Cost is O(#container instances + #recorded instances): when
    `record_paths` restricts recording, fixed-size unrecorded subtrees are
    skipped by multiplication instead of being walked element by element.
    """
    paths = _walk_paths(schema)
    wanted = set(record_paths) if record_paths is not None else {p for p, _ in paths}
    offs: Dict[str, List[int]] = {p: [] for p, _ in paths if p in wanted}
    widths = {p: (t.n if isinstance(t, Bytes) else COUNT_BYTES) for p, t in paths}
    is_cont = {p: isinstance(t, _CONTAINER) for p, t in paths}

    pos = 0

    def walk(t: TypeNode, path: str) -> None:
        nonlocal pos
        if isinstance(t, Bytes):
            if path in offs:
                offs[path].append(pos)
            pos += t.n
        elif isinstance(t, StructRef):
            for f, ft in schema.structs[t.name]:
                walk(ft, f"{path}.{f}" if path else f)
        elif isinstance(t, _CONTAINER):
            if path in offs:
                offs[path].append(pos)
            n = int.from_bytes(wire[pos : pos + COUNT_BYTES], "little")
            pos += COUNT_BYTES
            es = _static_size(schema, t.elem)
            epath = f"{path}.{ELEM}"
            recorded_below = any(p.startswith(epath) for p in offs)
            if es is not None and not recorded_below:
                pos += n * es  # skip the whole fixed-size run
            elif es is not None and recorded_below and _only_leaf(t.elem):
                # uniform run: offsets are an arithmetic sequence (prefix-sum
                # fast path — this is the TPU-native container decode)
                offs[epath].extend(range(pos, pos + n * es, es))
                pos += n * es
            else:
                for _ in range(n):
                    walk(t.elem, epath)
        else:  # pragma: no cover
            raise TypeError(f"bad type {t!r}")

    def _only_leaf(t: TypeNode) -> bool:
        return isinstance(t, Bytes)

    for f, ft in schema.structs[schema.top]:
        walk(ft, f)

    out_offs, out_counts = {}, {}
    for p, lst in offs.items():
        cap = (caps or {}).get(p, max(1, len(lst)))
        if len(lst) > cap:
            raise ValueError(f"{p}: {len(lst)} instances exceed cap {cap}")
        arr = np.zeros(cap, np.int32)
        arr[: len(lst)] = lst
        out_offs[p] = arr
        out_counts[p] = len(lst)
    return DecodePlan(out_offs, out_counts, widths, is_cont, wire_len=pos)


# ---------------------------------------------------------------------------
# Batched structure pass: one schema walk shared by N wires
# ---------------------------------------------------------------------------


@dataclass
class BatchedDecodePlan:
    """A :class:`DecodePlan` with a leading message axis.

    ``offsets[path]`` is int32[N, cap] (pad = 0), ``counts[path]`` is
    int64[N].  One plan drives one gather per leaf path for *all* messages
    (see :func:`decode_batch`), which is how the message plane amortizes the
    structure pass across a serving batch.
    """

    offsets: Dict[str, np.ndarray]  # path -> int32[N, cap]
    counts: Dict[str, np.ndarray]  # path -> int64[N] true instance counts
    nbytes: Dict[str, int]
    is_container: Dict[str, bool]
    wire_lens: np.ndarray  # int64[N] consumed bytes per wire

    @property
    def n_messages(self) -> int:
        return int(self.wire_lens.shape[0])

    def cap(self, path: str) -> int:
        return int(self.offsets[path].shape[1])

    def plan_for(self, i: int) -> DecodePlan:
        """Slice out message `i` as a plain single-message DecodePlan."""
        return DecodePlan(
            offsets={p: o[i].copy() for p, o in self.offsets.items()},
            counts={p: int(c[i]) for p, c in self.counts.items()},
            nbytes=dict(self.nbytes),
            is_container=dict(self.is_container),
            wire_len=int(self.wire_lens[i]),
        )


def stack_wires(wires: List[bytes], pad_to: Optional[int] = None) -> np.ndarray:
    """Stack N wires into a zero-padded uint8[N, L] matrix."""
    L = max([len(w) for w in wires] + [1])
    if pad_to is not None:
        if pad_to < L:
            raise ValueError(f"pad_to {pad_to} < longest wire {L}")
        L = pad_to
    buf = np.zeros((len(wires), L), np.uint8)
    for i, w in enumerate(wires):
        buf[i, : len(w)] = np.frombuffer(w, np.uint8)
    return buf


def batch_plans(
    schema: Schema,
    wires: List[bytes],
    caps: Optional[Dict[str, int]] = None,
    record_paths: Optional[List[str]] = None,
) -> BatchedDecodePlan:
    """Vectorized :func:`plan_from_wire` across N wires of one schema.

    The schema is walked *once*; every step of the walk operates on a column
    of per-message cursors (`pos[N]`) with an activity mask, so the Python
    recursion depth is bounded by the largest message's structure, not the
    sum over messages.  Fixed-size element runs are recorded as arithmetic
    sequences per message (the same prefix-sum fast path as the scalar walk)
    without touching the wire bytes at all.

    Raises ``ValueError`` if any message overflows a cap (default cap per
    path = max instance count over the batch).
    """
    N = len(wires)
    if N == 0:
        raise ValueError("batch_plans: empty wire list")
    # COUNT_BYTES of zero padding so masked-out count reads never index OOB.
    buf = stack_wires(wires, pad_to=max(len(w) for w in wires) + COUNT_BYTES)
    paths = _walk_paths(schema)
    wanted = set(record_paths) if record_paths is not None else {p for p, _ in paths}
    widths = {p: (t.n if isinstance(t, Bytes) else COUNT_BYTES) for p, t in paths}
    is_cont = {p: isinstance(t, _CONTAINER) for p, t in paths}
    # Recording log per path: ("one", mask, pos) appends one instance to every
    # active message; ("run", mask, start, n, stride) appends n[m] instances
    # at start[m] + stride*k.  Assembled into (N, cap) arrays at the end.
    recs: Dict[str, List[tuple]] = {p: [] for p, _ in paths if p in wanted}

    pos = np.zeros(N, np.int64)
    wlens = np.array([len(w) for w in wires], np.int64)

    def read_counts(mask: np.ndarray) -> np.ndarray:
        """Little-endian COUNT_BYTES at pos[m] for active messages, else 0."""
        n = np.zeros(N, np.int64)
        idx = np.nonzero(mask)[0]
        # A corrupted count earlier in a wire can push its cursor past the
        # end; fail that message loudly instead of indexing OOB.
        bad = idx[pos[idx] + COUNT_BYTES > wlens[idx]]
        if bad.size:
            m = int(bad[0])
            raise ValueError(
                f"message {m}: count field at byte {int(pos[m])} overruns "
                f"wire of {int(wlens[m])} bytes (truncated or corrupt)"
            )
        for k in range(COUNT_BYTES):
            n[idx] |= buf[idx, pos[idx] + k].astype(np.int64) << (8 * k)
        return n

    def walk(t: TypeNode, path: str, mask: np.ndarray) -> None:
        nonlocal pos
        if isinstance(t, Bytes):
            if path in recs:
                recs[path].append(("one", mask, pos.copy()))
            pos = pos + t.n * mask
        elif isinstance(t, StructRef):
            for f, ft in schema.structs[t.name]:
                walk(ft, f"{path}.{f}" if path else f, mask)
        elif isinstance(t, _CONTAINER):
            if path in recs:
                recs[path].append(("one", mask, pos.copy()))
            n = read_counts(mask)
            pos = pos + COUNT_BYTES * mask
            es = _static_size(schema, t.elem)
            epath = f"{path}.{ELEM}"
            recorded_below = any(p.startswith(epath) for p in recs)
            if es is not None and not recorded_below:
                pos = pos + n * es  # skip the whole fixed-size run
            elif es is not None and isinstance(t.elem, Bytes):
                recs[epath].append(("run", mask, pos.copy(), n, es))
                pos = pos + n * es
            else:
                for k in range(int(n.max())):
                    walk(t.elem, epath, mask & (k < n))
        else:  # pragma: no cover
            raise TypeError(f"bad type {t!r}")

    all_on = np.ones(N, bool)
    for f, ft in schema.structs[schema.top]:
        walk(ft, f, all_on)
    over = np.nonzero(pos > wlens)[0]
    if over.size:
        m = int(over[0])
        raise ValueError(
            f"message {m}: structure pass consumed {int(pos[m])} bytes but "
            f"wire has {int(wlens[m])} (truncated or corrupt)"
        )

    out_offs: Dict[str, np.ndarray] = {}
    out_counts: Dict[str, np.ndarray] = {}
    for p, log in recs.items():
        counts = np.zeros(N, np.int64)
        for rec in log:
            if rec[0] == "one":
                counts += rec[1]
            else:
                _, mask, _, n, _ = rec
                counts += np.where(mask, n, 0)
        cap = (caps or {}).get(p, max(1, int(counts.max())))
        over = np.nonzero(counts > cap)[0]
        if over.size:
            m = int(over[0])
            raise ValueError(
                f"{p}: message {m} has {int(counts[m])} instances, exceeds cap {cap}"
            )
        offs = np.zeros((N, cap), np.int32)
        cur = np.zeros(N, np.int64)
        for rec in log:
            if rec[0] == "one":
                _, mask, at = rec
                idx = np.nonzero(mask)[0]
                offs[idx, cur[idx]] = at[idx]
                cur[idx] += 1
            else:
                _, mask, start, n, stride = rec
                idx = np.nonzero(mask & (n > 0))[0]
                if not idx.size:
                    continue
                reps = n[idx]
                rows = np.repeat(idx, reps)
                # per-row 0..n[m]-1 ramp without a Python loop
                ramp = np.arange(reps.sum()) - np.repeat(np.cumsum(reps) - reps, reps)
                offs[rows, np.repeat(cur[idx], reps) + ramp] = (
                    np.repeat(start[idx], reps) + stride * ramp
                )
                cur[idx] += reps
        out_offs[p] = offs
        out_counts[p] = counts
    return BatchedDecodePlan(out_offs, out_counts, widths, is_cont, wire_lens=pos)


def decode_batch(
    wires_u8: jnp.ndarray,  # (N, L) uint8, zero-padded (see stack_wires)
    bplan: BatchedDecodePlan,
    paths: Optional[List[str]] = None,
) -> Dict[str, jnp.ndarray]:
    """Batched payload pass: ONE gather per leaf path moves every instance of
    every message.  Returns path -> uint32[N, cap, nlanes] lanes (rows past
    ``bplan.counts[path][m]`` are padding).  jnp oracle for
    ``repro.kernels.ops.decode_batch_kernel``."""
    N, L = wires_u8.shape
    flat = wires_u8.reshape(-1)
    base = (jnp.arange(N, dtype=jnp.int32) * L)[:, None]
    out = {}
    for p in paths or bplan.offsets.keys():
        cap = bplan.cap(p)
        offs = (jnp.asarray(bplan.offsets[p]) + base).reshape(-1)
        lanes = decode_leaf(flat, offs, bplan.nbytes[p])
        out[p] = lanes.reshape(N, cap, lanes.shape[-1])
    return out


# ---------------------------------------------------------------------------
# Payload pass (vectorized gather) — jnp oracle for kernels/phit_unpack
# ---------------------------------------------------------------------------


def wire_to_u8(wire: bytes) -> jnp.ndarray:
    return jnp.asarray(np.frombuffer(wire, dtype=np.uint8))


def decode_leaf(
    wire_u8: jnp.ndarray, offsets: jnp.ndarray, nbytes: int
) -> jnp.ndarray:
    """Gather all instances of one leaf field: (cap,) offsets ->
    (cap, ceil(nbytes/4)) uint32 little-endian lanes (jit-friendly)."""
    nlanes = (nbytes + 3) // 4
    byte_idx = offsets[:, None] + jnp.arange(nbytes, dtype=jnp.int32)[None, :]
    byte_idx = jnp.clip(byte_idx, 0, wire_u8.shape[0] - 1)
    b = wire_u8[byte_idx].astype(jnp.uint32)  # (cap, nbytes)
    pad = nlanes * 4 - nbytes
    if pad:
        b = jnp.pad(b, ((0, 0), (0, pad)))
    b = b.reshape(offsets.shape[0], nlanes, 4)
    shifts = jnp.array([0, 8, 16, 24], jnp.uint32)
    return (b << shifts[None, None, :]).sum(axis=-1).astype(jnp.uint32)


def decode_message(
    wire_u8: jnp.ndarray, plan: DecodePlan, paths: Optional[List[str]] = None
) -> Dict[str, jnp.ndarray]:
    """Decode every requested path into padded uint32-lane buffers."""
    out = {}
    for p in paths or plan.offsets.keys():
        out[p] = decode_leaf(wire_u8, jnp.asarray(plan.offsets[p]), plan.nbytes[p])
    return out


def lanes_to_int(lanes: np.ndarray, nbytes: int) -> np.ndarray:
    """uint32 lanes -> python-int-compatible object array (test helper)."""
    lanes = np.asarray(lanes, dtype=np.uint64)
    out = np.zeros(lanes.shape[0], dtype=object)
    for j in range(lanes.shape[1]):
        out = out + (lanes[:, j].astype(object) << (32 * j))
    mask = (1 << (8 * nbytes)) - 1
    return np.array([int(v) & mask for v in out], dtype=object)


# ---------------------------------------------------------------------------
# Encode (scatter) — device-side SER payload pass
# ---------------------------------------------------------------------------


def encode_leaf(
    wire_u8: jnp.ndarray,
    offsets: jnp.ndarray,
    lanes: jnp.ndarray,
    nbytes: int,
    count: jnp.ndarray | int,
) -> jnp.ndarray:
    """Scatter `count` instances of a leaf field into the wire buffer."""
    cap = offsets.shape[0]
    nlanes = (nbytes + 3) // 4
    shifts = jnp.array([0, 8, 16, 24], jnp.uint32)
    bytes_ = (
        (lanes[:, :, None] >> shifts[None, None, :]) & jnp.uint32(0xFF)
    ).astype(jnp.uint8)
    bytes_ = bytes_.reshape(cap, nlanes * 4)[:, :nbytes]
    byte_idx = offsets[:, None] + jnp.arange(nbytes, dtype=jnp.int32)[None, :]
    valid = (jnp.arange(cap, dtype=jnp.int32) < count)[:, None]
    byte_idx = jnp.where(valid, byte_idx, wire_u8.shape[0])  # OOB drops
    return wire_u8.at[byte_idx.reshape(-1)].set(
        bytes_.reshape(-1), mode="drop"
    )


def encode_message(
    wire_len: int, plan: DecodePlan, values: Dict[str, jnp.ndarray]
) -> jnp.ndarray:
    """Software-free device-side encode: scatter all paths into a wire buffer.

    `values[path]` are uint32 lanes shaped (cap, nlanes); container paths must
    be present with their counts as values (they serialize like u32 fields).
    """
    wire = jnp.zeros(wire_len, jnp.uint8)
    for p, lanes in values.items():
        wire = encode_leaf(
            wire,
            jnp.asarray(plan.offsets[p]),
            lanes,
            plan.nbytes[p],
            plan.counts[p],
        )
    return wire
