"""Token-stream representation (paper §III-C).

Tokens *out of* DES logic: (kind, tag, value, path)
  - DATA         : one Bytes field (value = little-endian int)
  - ARRAY_LENGTH : count of an Array            (paper "array-length")
  - LIST_BEGIN   : start of a List
  - ARRAY_END    : optional end-of-Array marker (emitted iff tagged)
  - LIST_END     : end of a List

Tokens *into* SER logic (paper §III-C2): no tags, no array-end, no list-begin;
LIST_END carries the list nesting level instead of a value.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

# token kinds (shared by python + JAX FSM implementations)
TOK_DATA = 0
TOK_ARRAY_LENGTH = 1
TOK_LIST_BEGIN = 2
TOK_ARRAY_END = 3
TOK_LIST_END = 4

TOK_NAMES = {
    TOK_DATA: "data",
    TOK_ARRAY_LENGTH: "array-length",
    TOK_LIST_BEGIN: "list-begin",
    TOK_ARRAY_END: "array-end",
    TOK_LIST_END: "list-end",
}


@dataclass(frozen=True)
class Token:
    kind: int
    value: int = 0  # data payload / array length / list nesting level
    tag: int = -1
    path: str = ""  # debug only; "" when not tracked

    def __repr__(self):  # compact for test failures
        t = TOK_NAMES[self.kind]
        return f"<{t} v={self.value} tag={self.tag}{' ' + self.path if self.path else ''}>"

    def eq_untagged(self, other: "Token") -> bool:
        return self.kind == other.kind and self.value == other.value


def strip_for_ser(tokens: List[Token]) -> List[Token]:
    """Convert a DES-side token stream into the SER-side input format.

    Paper §III-C2: drop array-end tokens, drop list-begin tokens, replace the
    value of list-end tokens with the list nesting level, and drop all tags.
    Requires `path`-free operation, so list nesting levels are recomputed from
    the stream structure itself.
    """
    out: List[Token] = []
    level = 0
    for t in tokens:
        if t.kind == TOK_LIST_BEGIN:
            level += 1
            continue
        if t.kind == TOK_ARRAY_END:
            continue
        if t.kind == TOK_LIST_END:
            out.append(Token(TOK_LIST_END, value=level))
            level -= 1
            continue
        out.append(Token(t.kind, value=t.value))
    return out
