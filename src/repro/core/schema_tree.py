"""Schema tree + schema ROM (paper §IV-A2).

Preprocessing (verbatim from the paper):

1. Any array/list element type that is not a structure is wrapped into a new
   Struct, so the element of every container is a structure.
2. Struct-typed fields are replaced by their sub-fields (struct inlining), so
   every node is of Bytes, Array or List type only.

After preprocessing, each field corresponds to a node of the *schema tree*;
each Array/List field is the parent of the fields of its element structure.
A special END node is the last child of the root.

The tree is flattened into the *schema ROM*: children of one parent occupy
consecutive entries (visit-next-sibling = index+1), and container entries
store the index of their first child.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from .idl import (
    Array,
    Bytes,
    ClientSchema,
    ListT,
    Schema,
    SchemaError,
    StreamT,
    StructRef,
    TypeNode,
    ELEM,
    END,
    START,
)

# node kinds in the ROM
KIND_BYTES = 0
KIND_ARRAY = 1
KIND_LIST = 2
KIND_END = 3
KIND_STREAM = 4

KIND_NAMES = {
    KIND_BYTES: "Bytes",
    KIND_ARRAY: "Array",
    KIND_LIST: "List",
    KIND_END: "END",
    KIND_STREAM: "Stream",
}

#: u32 words of per-fragment metadata a Stream node adds on the wire:
#: ``(stream_id, step, flags)`` — see ``core.stream_plans``.
STREAM_META_WORDS = 3

#: wire width of an Array/List length field (paper: software SER "writes the
#: number of elements"; we fix the count encoding at 4 little-endian bytes).
COUNT_BYTES = 4

#: entries one schema ROM may hold (paper §IV-A2: the ROM is a fixed BRAM;
#: we fix the modeled budget so ``repro.analysis`` can prove a schema fits
#: before any ROM is built)
ROM_CAPACITY = 512

#: context-stack slots of the DES/SER engines (max container nesting the
#: hardware can suspend into; checked statically by ``repro.analysis``)
STACK_CAPACITY = 16


@dataclass
class TreeNode:
    """One node of the (preprocessed) schema tree."""

    kind: int
    path: str  # client-schema token path ("a.elem.x", "" only for END)
    nbytes: int = 0  # payload width for Bytes nodes
    children: List["TreeNode"] = field(default_factory=list)
    is_last: bool = False  # last child of its parent
    # filled in by flattening:
    index: int = -1

    def walk(self):
        yield self
        for c in self.children:
            yield from c.walk()


def build_tree(schema: Schema) -> List[TreeNode]:
    """Preprocess `schema` and return the root's children (END included)."""

    def expand(t: TypeNode, path: str) -> List[TreeNode]:
        """Expand one field into tree nodes (inlining structs)."""
        if isinstance(t, Bytes):
            return [TreeNode(KIND_BYTES, path, nbytes=t.n)]
        if isinstance(t, StructRef):
            # transformation 2: inline struct fields
            nodes: List[TreeNode] = []
            for fname, ftype in schema.structs[t.name]:
                sub = f"{path}.{fname}" if path else fname
                nodes.extend(expand(ftype, sub))
            if not nodes:
                raise SchemaError(f"struct {t.name!r} at {path!r} has no fields")
            return nodes
        if isinstance(t, (Array, ListT, StreamT)):
            if isinstance(t, Array):
                kind = KIND_ARRAY
            elif isinstance(t, ListT):
                kind = KIND_LIST
            else:
                kind = KIND_STREAM
            # transformation 1: wrap non-struct element into a struct.  The
            # wrapped field keeps the container's `elem` path so tags resolve.
            children = expand(t.elem, f"{path}.{ELEM}")
            for c in children:
                c.is_last = False
            children[-1].is_last = True
            return [TreeNode(kind, path, children=children)]
        raise SchemaError(f"bad type {t!r}")

    top_nodes: List[TreeNode] = []
    for fname, ftype in schema.structs[schema.top]:
        top_nodes.extend(expand(ftype, fname))
    end = TreeNode(KIND_END, "")
    top_nodes.append(end)
    for n in top_nodes:
        n.is_last = False
    top_nodes[-1].is_last = True
    return top_nodes


def tree_depth(roots: List[TreeNode]) -> int:
    """Maximum container nesting depth (size needed for the context stack)."""

    def d(n: TreeNode) -> int:
        if n.kind in (KIND_ARRAY, KIND_LIST, KIND_STREAM):
            return 1 + max((d(c) for c in n.children), default=0)
        return 0

    return max((d(n) for n in roots), default=0)


# ---------------------------------------------------------------------------
# Schema ROM
# ---------------------------------------------------------------------------


@dataclass
class SchemaROM:
    """Flat encoding of the schema tree (paper: 'schema ROM').

    Arrays are indexed by ROM entry.  Siblings are consecutive, so "visit next
    sibling" is ``index + 1``; `last` marks the final child of a parent.
    Container entries store `child` = index of their first child.

    `emit_end` is 1 when the DES logic must emit the array-end token (always 1
    for Lists; for Arrays only when the client schema tags the `end` path —
    paper §III-C1).  `tag`/`tag_start`/`tag_end` come from the client schema
    (-1 = untagged).  `list_level` counts enclosing List contexts *including*
    the node itself when it is a List (used by the HW-to-HW framing protocol).
    """

    kind: np.ndarray  # int32[N]
    nbytes: np.ndarray  # int32[N]  (Bytes payload width; COUNT_BYTES for containers)
    child: np.ndarray  # int32[N]  (-1 for leaves)
    last: np.ndarray  # int32[N]
    tag: np.ndarray  # int32[N]
    tag_start: np.ndarray  # int32[N]
    tag_end: np.ndarray  # int32[N]
    emit_end: np.ndarray  # int32[N]
    list_level: np.ndarray  # int32[N]
    depth: np.ndarray  # int32[N] container nesting depth of the node
    paths: List[str]  # debug / tooling
    stack_depth: int  # max context-stack depth needed
    root_first: int = 0  # ROM index of the root's first child (always 0)

    @property
    def n_nodes(self) -> int:
        return int(self.kind.shape[0])

    @property
    def max_token_bytes(self) -> int:
        """Widest token payload (bytes)."""
        widths = [COUNT_BYTES]
        widths += [int(b) for k, b in zip(self.kind, self.nbytes) if k == KIND_BYTES]
        return max(widths)

    def static_bounds(self) -> dict:
        """Static resource demands vs. the modeled hardware capacities —
        the numbers the ``repro.analysis`` schema pass compares against
        :data:`ROM_CAPACITY` / :data:`STACK_CAPACITY` / the u8 ListLevel
        header lane."""
        return {
            "n_nodes": self.n_nodes,
            "rom_capacity": ROM_CAPACITY,
            "stack_depth": int(self.stack_depth),
            "stack_capacity": STACK_CAPACITY,
            "max_token_bytes": self.max_token_bytes,
            "max_list_level": int(np.max(self.list_level, initial=0)),
            "n_streams": int(np.sum(self.kind == KIND_STREAM)),
            "stream_meta_words": STREAM_META_WORDS,
        }

    def describe(self) -> str:
        rows = ["idx kind   bytes child last emit_end lvl tag  path"]
        for i in range(self.n_nodes):
            rows.append(
                f"{i:3d} {KIND_NAMES[int(self.kind[i])]:6s} {int(self.nbytes[i]):5d} "
                f"{int(self.child[i]):5d} {int(self.last[i]):4d} "
                f"{int(self.emit_end[i]):8d} {int(self.list_level[i]):3d} "
                f"{int(self.tag[i]):4d} {self.paths[i]}"
            )
        return "\n".join(rows)


def build_rom(schema: Schema, client: Optional[ClientSchema] = None) -> SchemaROM:
    """Compile a central schema (+ optional client schema) into a SchemaROM."""
    client = client or ClientSchema()
    client.validate_against(schema)
    roots = build_tree(schema)

    # breadth-of-children flattening: emit each sibling group contiguously.
    order: List[TreeNode] = []

    def place(group: List[TreeNode]) -> None:
        start = len(order)
        for off, n in enumerate(group):
            n.index = start + off
        order.extend(group)
        for n in group:
            if n.children:
                place(n.children)

    place(roots)

    n = len(order)
    kind = np.full(n, KIND_BYTES, np.int32)
    nbytes = np.zeros(n, np.int32)
    child = np.full(n, -1, np.int32)
    last = np.zeros(n, np.int32)
    tag = np.full(n, -1, np.int32)
    tag_start = np.full(n, -1, np.int32)
    tag_end = np.full(n, -1, np.int32)
    emit_end = np.zeros(n, np.int32)
    list_level = np.zeros(n, np.int32)
    depth = np.zeros(n, np.int32)
    paths = [nd.path for nd in order]

    # container-depth / list-level by re-walking the tree.
    def annotate(group: List[TreeNode], d: int, ll: int) -> None:
        for nd in group:
            depth[nd.index] = d
            # a Stream is an incremental List: it rides ListLevel-tagged
            # lanes, so it counts toward the list level like a List does.
            if nd.kind in (KIND_LIST, KIND_STREAM):
                list_level[nd.index] = ll + 1
            else:
                list_level[nd.index] = ll
            if nd.children:
                annotate(nd.children, d + 1, int(list_level[nd.index]))

    annotate(roots, 0, 0)

    for nd in order:
        i = nd.index
        kind[i] = nd.kind
        last[i] = int(nd.is_last)
        if nd.kind == KIND_BYTES:
            nbytes[i] = nd.nbytes
            tag[i] = client.tag_for(nd.path)
        elif nd.kind in (KIND_ARRAY, KIND_LIST, KIND_STREAM):
            nbytes[i] = COUNT_BYTES
            child[i] = nd.children[0].index
            tag_start[i] = client.tag_for(f"{nd.path}.{START}")
            tag_end[i] = client.tag_for(f"{nd.path}.{END}")
            if nd.kind in (KIND_LIST, KIND_STREAM):
                emit_end[i] = 1  # lists/streams always emit list-end (EOS)
            else:
                emit_end[i] = int(tag_end[i] >= 0)  # arrays: only when tagged
        # END node: all defaults

    return SchemaROM(
        kind=kind,
        nbytes=nbytes,
        child=child,
        last=last,
        tag=tag,
        tag_start=tag_start,
        tag_end=tag_end,
        emit_end=emit_end,
        list_level=list_level,
        depth=depth,
        paths=paths,
        stack_depth=max(1, tree_depth(roots)),
    )
