"""Shared model components: norms, RoPE, initializers, losses, flash attention.

Pure-JAX (pjit-friendly) implementations.  Attention uses a double-blocked
online-softmax (flash) formulation so long-context prefill never materializes
the full (S, T) score matrix.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = object

# ---------------------------------------------------------------------------
# dtype / init helpers
# ---------------------------------------------------------------------------


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32, scale: float = 1.0):
    """Truncated-normal fan-in init (stddev = scale/sqrt(fan_in))."""
    fan_in = shape[in_axis]
    std = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(kind: str, params: Dict, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"], eps)
    return layernorm(x, params["scale"], params["bias"], eps)


def init_norm(kind: str, d: int, dtype) -> Dict:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def act_fn(name: str):
    return {
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "swiglu": jax.nn.silu,  # gate activation for GLU variants
        "geglu": partial(jax.nn.gelu, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Blocked flash attention (pure JAX, online softmax)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _block_mask(
    q_pos: jnp.ndarray,  # (bq,)
    k_pos: jnp.ndarray,  # (bk,)
    causal: bool,
    window: Optional[int],
    q_seg: Optional[jnp.ndarray] = None,  # (B, bq)
    k_seg: Optional[jnp.ndarray] = None,  # (B, bk)
) -> jnp.ndarray:
    """Additive mask (B?, bq, bk) in fp32; True = attend."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    if q_seg is not None:
        seg = q_seg[:, :, None] == k_seg[:, None, :]
        m = m[None] & seg
    return jnp.where(m, 0.0, NEG_INF)


def flash_attention(
    q: jnp.ndarray,  # (B, S, K, G, D)   K = kv heads, G = q heads per kv
    k: jnp.ndarray,  # (B, T, K, D)
    v: jnp.ndarray,  # (B, T, K, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    q_offset: int | jnp.ndarray = 0,
    segment_q: Optional[jnp.ndarray] = None,  # (B, S)
    segment_k: Optional[jnp.ndarray] = None,  # (B, T)
    kv_len: Optional[jnp.ndarray] = None,  # valid prefix length of k/v
    block_q: int = 512,
    block_k: int = 1024,
    scale: Optional[float] = None,
    p_bf16: bool = False,
) -> jnp.ndarray:
    """Double-blocked online-softmax attention.  Never materializes (S, T).

    Returns (B, S, K, G, D).  `q_offset` is the absolute position of q[0]
    (decode/prefill continuation).  `kv_len` masks tail slots of the cache.
    """
    B, S, K, G, D = q.shape
    T = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    # pad S, T to block multiples
    Sp = (S + block_q - 1) // block_q * block_q
    Tp = (T + block_k - 1) // block_k * block_k
    qp = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    sq = jnp.pad(segment_q, ((0, 0), (0, Sp - S)), constant_values=-1) if segment_q is not None else None
    sk = jnp.pad(segment_k, ((0, 0), (0, Tp - T)), constant_values=-2) if segment_k is not None else None

    nq, nk = Sp // block_q, Tp // block_k
    qp = qp.reshape(B, nq, block_q, K, G, D)
    kp = kp.reshape(B, nk, block_k, K, D)
    vp = vp.reshape(B, nk, block_k, K, D)

    valid_t = jnp.arange(Tp, dtype=jnp.int32).reshape(nk, block_k)
    t_ok = valid_t < (T if kv_len is None else kv_len)  # (nk, bk) bool

    def q_block(qi, qb, sqb):
        # qb: (B, bq, K, G, D)
        q_pos = q_offset + qi * block_q + jnp.arange(block_q, dtype=jnp.int32)

        def kv_step(carry, inputs):
            acc, m_run, l_run = carry
            kb, vb, kj, tok, skb = inputs
            k_pos = kj * block_k + jnp.arange(block_k, dtype=jnp.int32)
            s = jnp.einsum(
                "bqkgd,btkd->bqkgt", qb.astype(jnp.float32), kb.astype(jnp.float32)
            ) * scale
            s = softcap(s, logit_cap)
            mask = _block_mask(q_pos, k_pos, causal, window, sqb, skb)  # (B?,bq,bk)
            if mask.ndim == 2:
                mask = mask[None]
            mask = jnp.where(tok[None, None, :], mask, NEG_INF)
            s = s + mask[:, :, None, None, :]  # (B,bq,K,G,bk)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(axis=-1)
            if p_bf16:  # halve the dominant HBM stream (p is the S*T matrix)
                pv = jnp.einsum(
                    "bqkgt,btkd->bqkgd", p.astype(jnp.bfloat16), vb.astype(jnp.bfloat16)
                ).astype(jnp.float32)
            else:
                pv = jnp.einsum("bqkgt,btkd->bqkgd", p, vb.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((B, block_q, K, G, D), jnp.float32)
        m0 = jnp.full((B, block_q, K, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, block_q, K, G), jnp.float32)
        kjs = jnp.arange(nk, dtype=jnp.int32)
        skb = (
            sk.reshape(B, nk, block_k).swapaxes(0, 1)
            if sk is not None
            else jnp.zeros((nk, B, block_k), jnp.int32)
        )
        (acc, m_run, l_run), _ = jax.lax.scan(
            kv_step,
            (acc0, m0, l0),
            (kp.swapaxes(0, 1), vp.swapaxes(0, 1), kjs, t_ok, skb),
        )
        out = acc / jnp.maximum(l_run[..., None], 1e-30)
        return out

    sq_blocks = (
        sq.reshape(B, nq, block_q).swapaxes(0, 1)
        if sq is not None
        else jnp.zeros((nq, B, block_q), jnp.int32)
    )
    outs = jax.lax.map(
        lambda args: q_block(*args),
        (jnp.arange(nq, dtype=jnp.int32), qp.swapaxes(0, 1), sq_blocks),
    )  # (nq, B, bq, K, G, D)
    out = outs.swapaxes(0, 1).reshape(B, Sp, K, G, D)[:, :S]
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, K, G, D)
    k_cache: jnp.ndarray,  # (B, T, K, D)
    v_cache: jnp.ndarray,  # (B, T, K, D)
    kv_len: jnp.ndarray,  # scalar or (B,) valid length
    *,
    logit_cap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Single-token attention against a cache (no blocking needed)."""
    B, T, K, D = k_cache.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum(
        "bqkgd,btkd->bqkgt", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    s = softcap(s, logit_cap)
    pos = jnp.arange(T, dtype=jnp.int32)
    valid = pos[None, :] < jnp.broadcast_to(jnp.asarray(kv_len), (B,))[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgt,btkd->bqkgd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy(
    logits: jnp.ndarray,  # (B, S, V)
    targets: jnp.ndarray,  # (B, S) int32
    mask: Optional[jnp.ndarray] = None,  # (B, S) 0/1
    z_loss: float = 0.0,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse**2
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((logits.argmax(-1) == targets) * mask).sum() / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": mask.sum()}
