"""Feed-forward layers: dense GLU variants and top-k MoE.

The MoE dispatch is *sort-based* (argsort tokens by expert, rank within
expert, capacity-bounded scatter into (E, C, d) buffers).  This is the
HGum-framed-List view of expert dispatch (DESIGN.md §5): per-expert token
groups are variable-length lists packed into fixed-capacity frames with
per-frame counts — the device analogue of the paper's §IV-C framing.

Tokens are processed in **groups** (default 8192): each group's dispatch is
independent with a group-local capacity.  This bounds the (E, C, d) frame
to a few hundred MB regardless of sequence length — a single global
dispatch at prefill_32k scale materializes replicated (E, 327k, d) buffers
that the SPMD partitioner cannot recover from (measured 60 GiB/instance,
3.7 TiB/device peak on mixtral; EXPERIMENTS.md §Perf).  Group-local
capacity also matches how production MoE systems enforce locality.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import act_fn, dense_init
from ..configs.base import ModelConfig
from ..runtime.actshard import constrain as act_constrain

#: tokens per dispatch group (perf-iteration surface; see EXPERIMENTS.md)
TOKEN_GROUP = 8192


def init_dense_ffn(key, cfg: ModelConfig, dtype) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    glu = cfg.act in ("swiglu", "geglu")
    p = {
        "wi": dense_init(k1, (d, ff), dtype=dtype),
        "wo": dense_init(k2, (ff, d), dtype=dtype, scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }
    if glu:
        p["wg"] = dense_init(k3, (d, ff), dtype=dtype)
    return p


def dense_ffn(p: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    act = act_fn(cfg.act)
    h = x @ p["wi"]
    if "wg" in p:
        h = act(x @ p["wg"]) * h
    else:
        h = act(h)
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def init_moe_ffn(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    ff = cfg.moe_dff or cfg.d_ff
    E = cfg.moe_experts
    kr, k1, k2, k3 = jax.random.split(key, 4)
    glu = cfg.act in ("swiglu", "geglu")
    p = {
        "router": dense_init(kr, (d, E), dtype=jnp.float32),  # router in fp32
        "wi": dense_init(k1, (E, d, ff), in_axis=1, dtype=dtype),
        "wo": dense_init(k2, (E, ff, d), in_axis=1, dtype=dtype,
                         scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }
    if glu:
        p["wg"] = dense_init(k3, (E, d, ff), in_axis=1, dtype=dtype)
    return p


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    E, k = cfg.moe_experts, cfg.moe_topk
    cap = int(math.ceil(cfg.capacity_factor * n_tokens * k / E))
    return max(8, -(-cap // 8) * 8)  # round up to 8 for tiling


def _moe_group(p: Dict, xf: jnp.ndarray, cfg: ModelConfig, C: int, act):
    """Dispatch+experts+combine for one token group.  xf: (G, d)."""
    G, d = xf.shape
    E, topk = cfg.moe_experts, cfg.moe_topk

    logits = xf.astype(jnp.float32) @ p["router"]  # (G,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, topk)  # (G,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # (token, slot) pairs, sorted by expert (stable keeps token order)
    pair_expert = gate_idx.reshape(-1)
    pair_token = jnp.repeat(jnp.arange(G, dtype=jnp.int32), topk)
    pair_gate = gate_vals.reshape(-1)
    order = jnp.argsort(pair_expert, stable=True)
    se, st, sg = pair_expert[order], pair_token[order], pair_gate[order]
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(G * topk, dtype=jnp.int32) - starts[se]
    keep = rank < C

    # pack into per-expert frames (HGum Lists with count headers)
    dest = jnp.where(keep, se * C + rank, E * C)
    buf = jnp.zeros((E * C, d), xf.dtype).at[dest].set(xf[st], mode="drop")
    buf = buf.reshape(E, C, d)

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    if "wg" in p:
        h = act(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * h
    else:
        h = act(h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(E * C, d)

    src = jnp.where(keep, se * C + rank, 0)
    pair_out = out_buf[src] * (sg * keep).astype(xf.dtype)[:, None]
    yf = jnp.zeros((G, d), xf.dtype).at[st].add(pair_out)

    frac_tokens = counts.astype(jnp.float32) / jnp.maximum(G * topk, 1)
    balance = cfg.moe_experts * jnp.sum(frac_tokens * probs.mean(axis=0))
    return yf, balance, 1.0 - keep.mean()


def moe_ffn(
    p: Dict, x: jnp.ndarray, cfg: ModelConfig, capacity: Optional[int] = None,
    token_group: int = TOKEN_GROUP,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Top-k capacity-bounded MoE over token groups (see module docstring)."""
    B, S, d = x.shape
    T = B * S
    act = act_fn(cfg.act)
    xf = x.reshape(T, d)

    if T <= token_group:
        C = capacity or moe_capacity(cfg, T)
        yf, balance, dropped = _moe_group(p, xf, cfg, C, act)
        return yf.reshape(B, S, d), {
            "moe_balance_loss": balance, "moe_dropped": dropped,
        }

    n_groups = -(-T // token_group)
    pad = n_groups * token_group - T
    xg = jnp.pad(xf, ((0, pad), (0, 0))).reshape(n_groups, token_group, d)
    C = capacity or moe_capacity(cfg, token_group)

    def body(_, xg_i):
        yf, balance, dropped = _moe_group(p, xg_i, cfg, C, act)
        return None, (yf, balance, dropped)

    _, (yg, bal, drp) = jax.lax.scan(body, None, xg)
    yf = yg.reshape(n_groups * token_group, d)[:T]
    yf = act_constrain(yf, "tokens_flat")
    return yf.reshape(B, S, d), {
        "moe_balance_loss": bal.mean(),
        "moe_dropped": drp.mean(),
    }
