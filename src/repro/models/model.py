"""Unified model: init / forward / prefill / decode for all 10 architectures.

One parameter pytree + three entry points:

* ``forward(params, cfg, batch)``            — full-sequence logits (train).
* ``prefill(params, cfg, batch, cache_len)`` — forward + primed KV/SSM cache.
* ``decode_step(params, cfg, cache, batch)`` — one token, updated cache.

Layer plan comes from ``cfg.layer_kinds() × cfg.ffn_kinds()``; families:
``lm`` (decoder-only), ``encdec`` (whisper: encoder + cross-attn decoder),
``vlm`` (phi-3-vision: patch-embedding stream prepended to token stream).

``scan_layers=True`` groups layers into the minimal repeating period and
scans over stacked parameters (small HLO for the multi-pod dry-run);
``False`` unrolls (exact per-layer cost attribution).  Decode always unrolls.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..runtime.actshard import constrain as act_constrain
from . import attention as attn_mod
from . import ffn as ffn_mod
from . import ssm as ssm_mod
from .common import (
    apply_norm,
    cross_entropy,
    dtype_of,
    embed_init,
    init_norm,
    softcap,
)

PyTree = Any


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------


def layer_plan(cfg: ModelConfig) -> List[Tuple[str, str]]:
    return list(zip(cfg.layer_kinds(), cfg.ffn_kinds()))


def plan_period(cfg: ModelConfig) -> int:
    """Smallest period p (dividing n_layers) such that the layer plan — and
    the local/global attention alternation — repeats with period p."""
    plan = [
        (s, f, cfg.attn_is_local(i))
        for i, (s, f) in enumerate(layer_plan(cfg))
    ]
    n = len(plan)
    for p in range(1, n + 1):
        if n % p == 0 and all(plan[i] == plan[i % p] for i in range(n)):
            return p
    return n


# ---------------------------------------------------------------------------
# Per-layer init / forward
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ModelConfig, seq_kind: str, ffn_kind: str, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict = {"ln1": init_norm(cfg.norm, cfg.d_model, dtype)}
    if seq_kind == "attn":
        p["attn"] = attn_mod.init_attn(ks[0], cfg, dtype)
    elif seq_kind == "mamba":
        p["mamba"] = ssm_mod.init_mamba(ks[0], cfg, dtype)
    elif seq_kind == "mlstm":
        p["mlstm"] = ssm_mod.init_mlstm(ks[0], cfg, dtype)
    elif seq_kind == "slstm":
        p["slstm"] = ssm_mod.init_slstm(ks[0], cfg, dtype)
    else:
        raise ValueError(seq_kind)
    if cfg.sandwich_norm:
        p["ln1_post"] = init_norm(cfg.norm, cfg.d_model, dtype)
    if ffn_kind != "none":
        p["ln2"] = init_norm(cfg.norm, cfg.d_model, dtype)
        if ffn_kind == "dense":
            p["ffn"] = ffn_mod.init_dense_ffn(ks[1], cfg, dtype)
        else:
            p["moe"] = ffn_mod.init_moe_ffn(ks[1], cfg, dtype)
        if cfg.sandwich_norm:
            p["ln2_post"] = init_norm(cfg.norm, cfg.d_model, dtype)
    return p


def layer_forward(
    p: Dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    layer_idx: int,
    seq_kind: str,
    ffn_kind: str,
    *,
    mode: str,  # "full" | "decode"
    cache: Optional[Dict] = None,
    pos: Optional[jnp.ndarray] = None,  # (B,) decode positions
    positions: Optional[jnp.ndarray] = None,  # (B,S) full-seq positions
    segment_ids: Optional[jnp.ndarray] = None,
    q_offset: int | jnp.ndarray = 0,
) -> Tuple[jnp.ndarray, Optional[Dict], Dict]:
    """Returns (x, new_cache, aux)."""
    aux: Dict = {}
    h = apply_norm(cfg.norm, p["ln1"], x, cfg.norm_eps)
    new_cache: Dict = {}
    window = cfg.window if cfg.attn_is_local(layer_idx) else None
    if seq_kind == "attn":
        if mode == "decode":
            out, kv = attn_mod.attn_decode(p["attn"], h, cfg, cache, pos, window=window)
            new_cache = kv
        else:
            out, (k, v) = attn_mod.attn_forward(
                p["attn"], h, cfg, window=window,
                positions=positions, segment_ids=segment_ids, q_offset=q_offset,
            )
            new_cache = {"k": k, "v": v}
    elif seq_kind == "mamba":
        fn = ssm_mod.mamba_decode if mode == "decode" else ssm_mod.mamba_forward
        out, new_cache = fn(p["mamba"], h, cfg, cache)
    elif seq_kind == "mlstm":
        fn = ssm_mod.mlstm_decode if mode == "decode" else ssm_mod.mlstm_forward
        out, new_cache = fn(p["mlstm"], h, cfg, cache)
    elif seq_kind == "slstm":
        fn = ssm_mod.slstm_decode if mode == "decode" else ssm_mod.slstm_forward
        out, new_cache = fn(p["slstm"], h, cfg, cache)
    else:
        raise ValueError(seq_kind)
    if cfg.sandwich_norm:
        out = apply_norm(cfg.norm, p["ln1_post"], out, cfg.norm_eps)
    x = x + out

    if ffn_kind != "none":
        h = apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
        if ffn_kind == "dense":
            out = ffn_mod.dense_ffn(p["ffn"], h, cfg)
        else:
            out, aux = ffn_mod.moe_ffn(p["moe"], h, cfg)
        if cfg.sandwich_norm:
            out = apply_norm(cfg.norm, p["ln2_post"], out, cfg.norm_eps)
        x = x + out
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> PyTree:
    dtype = dtype_of(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 8)
    params: Dict = {
        "embed": embed_init(keys[0], (cfg.padded_vocab, cfg.d_model), dtype),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        "layers": [
            init_layer(keys[2 + i], cfg, s, f, dtype)
            for i, (s, f) in enumerate(layer_plan(cfg))
        ],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[1], (cfg.d_model, cfg.padded_vocab), dtype)
    if cfg.family == "vlm":
        params["vision_proj"] = embed_init(
            keys[-1], (cfg.vision_dim, cfg.d_model), dtype
        )
    if cfg.family == "encdec":
        ekeys = jax.random.split(keys[-2], cfg.enc_layers + 1)
        params["encoder"] = {
            "layers": [
                init_layer(ekeys[i], cfg, "attn", "dense", dtype)
                for i in range(cfg.enc_layers)
            ],
            "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        }
        ckeys = jax.random.split(keys[-3], cfg.n_layers)
        params["cross"] = [
            {
                "ln": init_norm(cfg.norm, cfg.d_model, dtype),
                "attn": attn_mod.init_cross_attn(ckeys[i], cfg, dtype),
            }
            for i in range(cfg.n_layers)
        ]
    return params


def param_count(params: PyTree) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Embedding front-ends (modality stubs live in input_specs, DESIGN.md §5)
# ---------------------------------------------------------------------------


def _embed_tokens(params, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x


def _front_end(params, cfg: ModelConfig, batch: Dict) -> Tuple[jnp.ndarray, int]:
    """Token (+modality) embedding.  Returns (x, n_prefix_positions)."""
    x = _embed_tokens(params, cfg, batch["tokens"])
    if cfg.family == "vlm" and "vision" in batch:
        vis = batch["vision"].astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([vis, x], axis=1)
        return x, vis.shape[1]
    return x, 0


def _unembed(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = x @ head
    logits = act_constrain(logits, "logits")
    if cfg.padded_vocab != cfg.vocab:  # mask the pad rows (see padded_vocab)
        pad = jnp.arange(cfg.padded_vocab, dtype=jnp.int32) >= cfg.vocab
        logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)


def _sinusoidal(S: int, d: int, offset=0) -> jnp.ndarray:
    pos = offset + jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[None]


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------


def encode(params, cfg: ModelConfig, audio: jnp.ndarray) -> jnp.ndarray:
    """audio: (B, enc_seq, d_model) — precomputed conv-frontend embeddings."""
    x = audio.astype(dtype_of(cfg.dtype)) + _sinusoidal(
        audio.shape[1], cfg.d_model
    ).astype(dtype_of(cfg.dtype))
    enc = params["encoder"]
    for i, lp in enumerate(enc["layers"]):
        h = apply_norm(cfg.norm, lp["ln1"], x, cfg.norm_eps)
        out, _ = attn_mod.attn_forward(lp["attn"], h, cfg, causal=False)
        x = x + out
        h = apply_norm(cfg.norm, lp["ln2"], x, cfg.norm_eps)
        x = x + ffn_mod.dense_ffn(lp["ffn"], h, cfg)
    return apply_norm(cfg.norm, enc["final_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill body)
# ---------------------------------------------------------------------------


def forward(
    params: PyTree,
    cfg: ModelConfig,
    batch: Dict,
    *,
    want_cache: bool = False,
    cache_len: Optional[int] = None,
    last_only: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict], Dict]:
    """Returns (logits, cache | None, aux).  ``batch["tokens"]``: (B,S).

    ``last_only`` computes logits for the final position only — prefill
    serving needs just the next token, and (B, S, V) logits at 32k
    context are the single largest prefill buffer (measured 10+ GiB/device
    on granite prefill_32k)."""
    x, n_prefix = _front_end(params, cfg, batch)
    B, S, _ = x.shape
    positions = batch.get("positions")
    segment_ids = batch.get("segment_ids")
    if segment_ids is not None and n_prefix:
        pre = jnp.ones((B, n_prefix), segment_ids.dtype) * segment_ids[:, :1]
        segment_ids = jnp.concatenate([pre, segment_ids], axis=1)
    if positions is not None and n_prefix:
        # vision prefix occupies positions [0, n_prefix); text shifts up
        pre = jnp.tile(jnp.arange(n_prefix, dtype=positions.dtype)[None], (B, 1))
        positions = jnp.concatenate([pre, positions + n_prefix], axis=1)
    if not cfg.use_rope and cfg.family == "encdec":
        x = x + _sinusoidal(S, cfg.d_model).astype(x.dtype)

    enc_kv = None
    if cfg.family == "encdec":
        enc_out = encode(params, cfg, batch["audio"])
        enc_kv = [attn_mod.cross_kv(cp["attn"], enc_out, cfg) for cp in params["cross"]]

    plan = layer_plan(cfg)
    aux_acc: Dict[str, jnp.ndarray] = {}
    caches: List[Dict] = []

    x = act_constrain(x, "residual")

    def run_layer(x, i, lp):
        s, f = plan[i]
        x, kv, aux = layer_forward(
            lp, x, cfg, i, s, f,
            mode="full", positions=positions, segment_ids=segment_ids,
        )
        if cfg.family == "encdec":
            cp = params["cross"][i]
            h = apply_norm(cfg.norm, cp["ln"], x, cfg.norm_eps)
            x = x + attn_mod.cross_attn_forward(cp["attn"], h, enc_kv[i], cfg)
        return act_constrain(x, "residual"), kv, aux

    if cfg.scan_layers and not want_cache and cfg.family == "lm":
        x, aux_acc = _forward_scanned(params, cfg, x, positions, segment_ids)
    else:
        for i, lp in enumerate(params["layers"]):
            fn = run_layer
            if cfg.remat:
                fn = jax.checkpoint(
                    run_layer, policy=_remat_policy(cfg), static_argnums=(1,),
                )
            x, kv, aux = fn(x, i, lp)
            for k, v in aux.items():
                aux_acc[k] = aux_acc.get(k, 0.0) + v / cfg.n_layers
            if want_cache:
                caches.append(kv)

    x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    if n_prefix:
        x = x[:, n_prefix:]
    if last_only:
        x = x[:, -1:]
    logits = _unembed(params, cfg, x)

    cache = None
    if want_cache:
        total = batch["tokens"].shape[1] + n_prefix  # vision prefix holds slots
        want_len = (cache_len + n_prefix) if cache_len is not None else total
        cache = _grow_cache(cfg, caches, batch, total, want_len, enc_kv)
    return logits, cache, aux_acc


def _forward_scanned(params, cfg, x, positions, segment_ids):
    """Scan over stacked layer periods (see module docstring)."""
    p = plan_period(cfg)
    n_periods = cfg.n_layers // p
    plan = layer_plan(cfg)
    stacked = stack_layers(params["layers"], p)

    def body(x, period_params):
        for j in range(p):
            s, f = plan[j]
            x, _, aux = layer_forward(
                period_params[f"pos{j}"], x, cfg, j, s, f,
                mode="full", positions=positions, segment_ids=segment_ids,
            )
            x = act_constrain(x, "residual")
        return x, aux.get("moe_balance_loss", jnp.zeros(()))

    body_fn = jax.checkpoint(body, policy=_remat_policy(cfg)) if cfg.remat else body
    x, bal = jax.lax.scan(body_fn, x, stacked, length=n_periods)
    return x, {"moe_balance_loss": bal.mean()} if bal.size else {}


def _remat_policy(cfg: ModelConfig):
    """Activation-checkpoint policy (perf-iteration surface).

    "nothing": recompute everything in backward (min memory, max recompute).
    "dots": save dot/matmul outputs — trades HBM for a large cut in
    recomputed FLOPs and re-read traffic (EXPERIMENTS.md §Perf).
    """
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint_policies.nothing_saveable


def stack_layers(layers: List[Dict], period: int) -> Dict:
    """[L0..Ln] -> {"pos j": stacked over periods} for scan-over-layers."""
    groups = {
        f"pos{j}": [layers[i] for i in range(j, len(layers), period)]
        for j in range(period)
    }
    return {
        k: jax.tree.map(lambda *xs: jnp.stack(xs), *v) for k, v in groups.items()
    }


def _grow_cache(cfg, caches, batch, total, cache_len, enc_kv):
    """Pad prefill KV to `cache_len` slots (decode appends in place).
    `total` = positions already consumed (text + modality prefix).

    Sliding-window layers keep only the last `window` keys (a ring cache;
    alignment holds because window divides the sequence length) — storing
    the full 32k KV for SWA layers costs 7.5 GiB/device on mixtral."""
    out_layers = []
    for i, ((s, f), kv) in enumerate(zip(layer_plan(cfg), caches)):
        if s == "attn":
            k, v = kv["k"], kv["v"]
            want = cache_len
            if cfg.window is not None and cfg.attn_is_local(i):
                want = min(want, cfg.window)
                if k.shape[1] > want:
                    if k.shape[1] % want != 0:
                        raise ValueError(
                            f"SWA ring alignment needs window|seq, got "
                            f"{want} vs {k.shape[1]}"
                        )
                    k, v = k[:, -want:], v[:, -want:]
            if want > k.shape[1]:
                pad = ((0, 0), (0, want - k.shape[1]), (0, 0), (0, 0))
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
            out_layers.append({"k": k, "v": v})
        else:
            out_layers.append(kv)
    cache = {
        "layers": out_layers,
        "pos": jnp.full((batch["tokens"].shape[0],), total, jnp.int32),
    }
    if enc_kv is not None:
        cache["enc_kv"] = enc_kv
    return cache


# ---------------------------------------------------------------------------
# Cache init / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> Dict:
    dtype = dtype_of(cfg.dtype)
    layers = []
    for i, (s, f) in enumerate(layer_plan(cfg)):
        if s == "attn":
            T = cache_len
            if cfg.window is not None and cfg.attn_is_local(i):
                T = min(T, cfg.window)
            layers.append({
                "k": jnp.zeros((batch, T, cfg.n_kv, cfg.hd), dtype),
                "v": jnp.zeros((batch, T, cfg.n_kv, cfg.hd), dtype),
            })
        elif s == "mamba":
            layers.append(ssm_mod.mamba_init_state(cfg, batch, dtype))
        elif s == "mlstm":
            layers.append(ssm_mod.mlstm_init_state(cfg, batch))
        elif s == "slstm":
            layers.append(ssm_mod.slstm_init_state(cfg, batch))
    cache = {"layers": layers, "pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "encdec":
        cache["enc_kv"] = [
            (
                jnp.zeros((batch, cfg.enc_seq, cfg.n_kv, cfg.hd), dtype),
                jnp.zeros((batch, cfg.enc_seq, cfg.n_kv, cfg.hd), dtype),
            )
            for _ in range(cfg.n_layers)
        ]
    return cache


def decode_step(
    params: PyTree, cfg: ModelConfig, cache: Dict, tokens: jnp.ndarray
) -> Tuple[jnp.ndarray, Dict]:
    """One decode step.  tokens: (B,1).  Returns (logits (B,1,V), cache)."""
    pos = cache["pos"]
    x = _embed_tokens(params, cfg, tokens)
    if not cfg.use_rope and cfg.family == "encdec":
        # per-example position offset of the sinusoid
        x = x + jax.vmap(lambda p: _sinusoidal(1, cfg.d_model, offset=p)[0])(pos).astype(x.dtype)

    plan = layer_plan(cfg)
    new_layers = []
    for i, lp in enumerate(params["layers"]):
        s, f = plan[i]
        window = cfg.window if cfg.attn_is_local(i) else None
        x, kv, _ = layer_forward(
            lp, x, cfg, i, s, f, mode="decode", cache=cache["layers"][i], pos=pos
        )
        if cfg.family == "encdec":
            cp = params["cross"][i]
            h = apply_norm(cfg.norm, cp["ln"], x, cfg.norm_eps)
            x = x + attn_mod.cross_attn_forward(cp["attn"], h, cache["enc_kv"][i], cfg)
        new_layers.append(kv)
    x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    logits = _unembed(params, cfg, x)
    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    new_cache["pos"] = pos + 1
    return logits, new_cache


def prefill(
    params: PyTree, cfg: ModelConfig, batch: Dict, cache_len: Optional[int] = None,
    last_only: bool = False,
) -> Tuple[jnp.ndarray, Dict]:
    logits, cache, _ = forward(
        params, cfg, batch, want_cache=True, cache_len=cache_len,
        last_only=last_only,
    )
    return logits, cache


# ---------------------------------------------------------------------------
# Loss / train step
# ---------------------------------------------------------------------------


def loss_fn(
    params: PyTree, cfg: ModelConfig, batch: Dict
) -> Tuple[jnp.ndarray, Dict]:
    logits, _, aux = forward(params, cfg, batch)
    loss, metrics = cross_entropy(
        logits, batch["labels"], batch.get("loss_mask"), z_loss=1e-4
    )
    if "moe_balance_loss" in aux:
        loss = loss + 0.01 * aux["moe_balance_loss"]
        metrics["moe_balance_loss"] = aux["moe_balance_loss"]
    metrics["loss"] = loss
    return loss, metrics
