"""Sequence-mixing SSM blocks: Mamba-2 (SSD), mLSTM and sLSTM.

All three support a chunkwise-parallel full-sequence form (train / prefill)
and an O(1)-state single-step form (decode) — this is what makes the
``long_500k`` cells runnable for jamba / xlstm (DESIGN.md §5).

Chunked SSD formulation (within-chunk quadratic, inter-chunk recurrent):
for chunk-local log-decay cumsum ``cum``, the intra-chunk term is a masked
(L, L) matmul and the carried state advances by ``exp(cum_L)`` — the same
skeleton serves Mamba (state (H, P, N)) and mLSTM (state (H, Dh, Dh)).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init, rmsnorm
from ..configs.base import ModelConfig

CHUNK = 256


def _pad_to_chunks(x, axis=1, chunk=CHUNK):
    S = x.shape[axis]
    pad = (-S) % chunk
    if pad:
        padw = [(0, 0)] * x.ndim
        padw[axis] = (0, pad)
        x = jnp.pad(x, padw)
    return x, S


# ===========================================================================
# Mamba-2 (SSD)
# ===========================================================================


def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head
    return d_in, nh, cfg.ssm_head, cfg.ssm_state


def init_mamba(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    d_in, nh, P, N = mamba_dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(k1, (d, 2 * d_in + 2 * N + nh), dtype=dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, d_in)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": dense_init(k3, (d_in, d), dtype=dtype,
                               scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }


def _mamba_split(p, x, cfg):
    d_in, nh, P, N = mamba_dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xs, Bm, Cm, dt = jnp.split(zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], -1)
    return z, xs, Bm, Cm, dt


def _causal_conv(xs, w, b, state=None):
    """Depthwise causal conv over time.  xs (B,S,D); w (K,D).  Returns
    (out, new_state) with state = last K-1 inputs."""
    K = w.shape[0]
    B, S, D = xs.shape
    if state is None:
        state = jnp.zeros((B, K - 1, D), xs.dtype)
    xcat = jnp.concatenate([state, xs], axis=1)  # (B, S+K-1, D)
    out = sum(xcat[:, i : i + S] * w[i][None, None, :] for i in range(K))
    new_state = xcat[:, S:, :] if K > 1 else state
    return jax.nn.silu(out + b), new_state


def mamba_forward(
    p: Dict, x: jnp.ndarray, cfg: ModelConfig, state: Optional[Dict] = None
) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence Mamba (chunked SSD).  x (B,S,d) -> (out, new_state)."""
    B, S, d = x.shape
    d_in, nh, P, N = mamba_dims(cfg)
    z, xs, Bm, Cm, dt = _mamba_split(p, x, cfg)
    conv_state = state["conv"] if state else None
    xs, conv_state = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_state)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])  # (nh,)
    loga = dt * A[None, None, :]  # (B,S,nh) log-decay per step
    xh = xs.astype(jnp.float32).reshape(B, S, nh, P)
    Bm = Bm.astype(jnp.float32)  # (B,S,N) shared across heads
    Cm = Cm.astype(jnp.float32)

    # pad to chunks
    L = min(CHUNK, max(16, S))
    xh, _ = _pad_to_chunks(xh, 1, L)
    Bp, _ = _pad_to_chunks(Bm, 1, L)
    Cp, _ = _pad_to_chunks(Cm, 1, L)
    la, _ = _pad_to_chunks(loga, 1, L)
    dtp, _ = _pad_to_chunks(dt, 1, L)
    nC = xh.shape[1] // L
    xh = xh.reshape(B, nC, L, nh, P)
    Bp = Bp.reshape(B, nC, L, N)
    Cp = Cp.reshape(B, nC, L, N)
    la = la.reshape(B, nC, L, nh)
    dtp = dtp.reshape(B, nC, L, nh)

    ssm0 = state["ssm"] if state else jnp.zeros((B, nh, P, N), jnp.float32)

    def chunk_step(S_prev, inp):
        xc, Bc, Cc, lac, dtc = inp  # (B,L,...) for one chunk
        cum = jnp.cumsum(lac, axis=1)  # (B,L,nh)
        # intra-chunk: y[t] += sum_{s<=t} exp(cum_t - cum_s) dt_s (Cc_t.Bc_s) x_s
        seg = cum[:, :, None, :] - cum[:, None, :, :]  # (B,L,L,nh)
        mask = jnp.tril(jnp.ones((L, L), bool))
        # mask BEFORE exp: s > t gives seg >= 0 which overflows (and then
        # poisons the cotangent through jnp.where).
        decay = jnp.exp(jnp.where(mask[None, :, :, None], seg, -1e30))
        cb = jnp.einsum("btn,bsn->bts", Cc, Bc)  # (B,L,L)
        w = cb[:, :, :, None] * decay * dtc[:, None, :, :]  # (B,t,s,nh)
        y = jnp.einsum("btsh,bshp->bthp", w, xc)
        # inter-chunk: y[t] += Cc_t . (exp(cum_t) * S_prev)
        y = y + jnp.einsum("btn,bth,bhpn->bthp", Cc, jnp.exp(cum), S_prev)
        # state advance: S_new = exp(cum_L) S_prev + sum_s exp(cum_L - cum_s) dt_s B_s x_s
        tail = jnp.exp(cum[:, -1:, :] - cum)  # (B,L,nh)
        S_new = (
            jnp.exp(cum[:, -1, :])[:, :, None, None] * S_prev
            + jnp.einsum("bsh,bshp,bsn->bhpn", tail * dtc, xc, Bc)
        )
        return S_new, y

    S_fin, ys = jax.lax.scan(
        chunk_step,
        ssm0,
        (
            xh.swapaxes(0, 1), Bp.swapaxes(0, 1), Cp.swapaxes(0, 1),
            la.swapaxes(0, 1), dtp.swapaxes(0, 1),
        ),
    )
    y = ys.swapaxes(0, 1).reshape(B, nC * L, nh, P)[:, :S]
    y = y + xh.reshape(B, nC * L, nh, P)[:, :S] * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["out_proj"]
    return out, {"conv": conv_state, "ssm": S_fin}


def mamba_decode(
    p: Dict, x: jnp.ndarray, cfg: ModelConfig, state: Dict
) -> Tuple[jnp.ndarray, Dict]:
    """Single-step Mamba.  x (B,1,d)."""
    B = x.shape[0]
    d_in, nh, P, N = mamba_dims(cfg)
    z, xs, Bm, Cm, dt = _mamba_split(p, x, cfg)
    xs, conv_state = _causal_conv(xs, p["conv_w"], p["conv_b"], state["conv"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,nh)
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * A[None, :])  # (B,nh)
    xh = xs.astype(jnp.float32).reshape(B, nh, P)
    Bv = Bm.astype(jnp.float32)[:, 0]  # (B,N)
    Cv = Cm.astype(jnp.float32)[:, 0]
    S_new = da[:, :, None, None] * state["ssm"] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bv
    )
    y = jnp.einsum("bn,bhpn->bhp", Cv, S_new) + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"], {"conv": conv_state, "ssm": S_new}


def mamba_init_state(cfg: ModelConfig, batch: int, dtype) -> Dict:
    d_in, nh, P, N = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, nh, P, N), jnp.float32),
    }


# ===========================================================================
# mLSTM (xLSTM): matrix memory, exponential gating, chunkwise parallel
# ===========================================================================


def mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    nh = cfg.n_heads
    return d_in, nh, d_in // nh


def init_mlstm(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    d_in, nh, dh = mlstm_dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, d_in), dtype=dtype),
        "wk": dense_init(ks[1], (d, d_in), dtype=dtype),
        "wv": dense_init(ks[2], (d, d_in), dtype=dtype),
        "wif": dense_init(ks[3], (d, 2 * nh), dtype=jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((nh,)), 3.0 * jnp.ones((nh,))]),
        "wo_gate": dense_init(ks[4], (d, d_in), dtype=dtype),
        "out_proj": dense_init(ks[5], (d_in, d), dtype=dtype,
                               scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }


def _mlstm_qkvif(p, x, cfg):
    d_in, nh, dh = mlstm_dims(cfg)
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, nh, dh) / (dh**0.5)
    k = (x @ p["wk"]).reshape(B, S, nh, dh)
    v = (x @ p["wv"]).reshape(B, S, nh, dh)
    i_f = x.astype(jnp.float32) @ p["wif"] + p["b_if"]
    i_pre, f_pre = jnp.split(i_f, 2, -1)  # (B,S,nh)
    logf = jax.nn.log_sigmoid(f_pre)
    o = jax.nn.sigmoid(x @ p["wo_gate"])
    return q, k, v, i_pre, logf, o


def mlstm_forward(
    p: Dict, x: jnp.ndarray, cfg: ModelConfig, state: Optional[Dict] = None
) -> Tuple[jnp.ndarray, Dict]:
    """Chunkwise-parallel mLSTM with stabilized exponential gating."""
    B, S, d = x.shape
    d_in, nh, dh = mlstm_dims(cfg)
    q, k, v, i_pre, logf, o = _mlstm_qkvif(p, x, cfg)

    L = min(CHUNK, max(16, S))
    qp, _ = _pad_to_chunks(q.astype(jnp.float32), 1, L)
    kp, _ = _pad_to_chunks(k.astype(jnp.float32), 1, L)
    vp, _ = _pad_to_chunks(v.astype(jnp.float32), 1, L)
    ip, _ = _pad_to_chunks(i_pre, 1, L)
    # padding must not contribute: i = -inf on pad
    if qp.shape[1] != S:
        padmask = jnp.arange(qp.shape[1]) >= S
        ip = jnp.where(padmask[None, :, None], -1e30, ip)
    fp, _ = _pad_to_chunks(logf, 1, L)
    nC = qp.shape[1] // L
    rs = lambda t: t.reshape(B, nC, L, *t.shape[2:]).swapaxes(0, 1)
    qp, kp, vp, ip, fp = map(rs, (qp, kp, vp, ip, fp))

    if state is None:
        C0 = jnp.zeros((B, nh, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, nh, dh), jnp.float32)
        m0 = jnp.full((B, nh), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def chunk_step(carry, inp):
        C_prev, n_prev, m_prev = carry
        qc, kc, vc, ic, fc = inp  # (B,L,...)
        cumf = jnp.cumsum(fc, axis=1)  # (B,L,nh)
        # log-weights: intra  w_ts = cumf_t - cumf_s + i_s   (s <= t)
        #              inter  g_t  = cumf_t + m_prev
        intra = cumf[:, :, None, :] - cumf[:, None, :, :] + ic[:, None, :, :]
        mask = jnp.tril(jnp.ones((L, L), bool))
        intra = jnp.where(mask[None, :, :, None], intra, -1e30)
        inter = cumf + m_prev[:, None, :]  # (B,L,nh)
        m_t = jnp.maximum(jnp.max(intra, axis=2), inter)  # (B,L,nh)
        wi = jnp.exp(intra - m_t[:, :, None, :])  # (B,t,s,nh)
        wg = jnp.exp(inter - m_t)  # (B,L,nh)
        qk = jnp.einsum("bthd,bshd->btsh", qc, kc)
        num = (
            jnp.einsum("btsh,bshd->bthd", qk * wi, vc)
            + wg[..., None] * jnp.einsum("bthd,bhde->bthe", qc, C_prev)
        )
        den = (
            jnp.einsum("btsh,bsh->bth", qk * wi, jnp.ones_like(ic))
            + wg * jnp.einsum("bthd,bhd->bth", qc, n_prev)
        )
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # carry update
        m_new = jnp.maximum(
            cumf[:, -1, :] + m_prev, jnp.max(cumf[:, -1:, :] - cumf + ic, axis=1)
        )
        tailw = jnp.exp(cumf[:, -1:, :] - cumf + ic - m_new[:, None, :])  # (B,L,nh)
        decay = jnp.exp(cumf[:, -1, :] + m_prev - m_new)  # (B,nh)
        C_new = decay[:, :, None, None] * C_prev + jnp.einsum(
            "bsh,bshd,bshe->bhde", tailw, kc, vc
        )
        n_new = decay[:, :, None] * n_prev + jnp.einsum("bsh,bshd->bhd", tailw, kc)
        return (C_new, n_new, m_new), h

    (Cf, nf, mf), hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qp, kp, vp, ip, fp))
    h = hs.swapaxes(0, 1).reshape(B, nC * L, nh, dh)[:, :S]
    h = (h.reshape(B, S, d_in) * o.astype(jnp.float32)).astype(x.dtype)
    return h @ p["out_proj"], {"C": Cf, "n": nf, "m": mf}


def mlstm_decode(
    p: Dict, x: jnp.ndarray, cfg: ModelConfig, state: Dict
) -> Tuple[jnp.ndarray, Dict]:
    B = x.shape[0]
    d_in, nh, dh = mlstm_dims(cfg)
    q, k, v, i_pre, logf, o = _mlstm_qkvif(p, x, cfg)
    q, k, v = q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32), v[:, 0].astype(jnp.float32)
    i_pre, logf = i_pre[:, 0], logf[:, 0]  # (B,nh)
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(logf + m, i_pre)
    fw = jnp.exp(logf + m - m_new)
    iw = jnp.exp(i_pre - m_new)
    C_new = fw[:, :, None, None] * C + iw[:, :, None, None] * jnp.einsum("bhd,bhe->bhde", k, v)
    n_new = fw[:, :, None] * n + iw[:, :, None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new))
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    h = (h.reshape(B, 1, d_in) * o.astype(jnp.float32)).astype(x.dtype)
    return h @ p["out_proj"], {"C": C_new, "n": n_new, "m": m_new}


def mlstm_init_state(cfg: ModelConfig, batch: int) -> Dict:
    d_in, nh, dh = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


# ===========================================================================
# sLSTM (xLSTM): scalar memory + exponential gating; sequential scan
# ===========================================================================


def init_slstm(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    p = {}
    for j, g in enumerate(("i", "f", "z", "o")):
        p[f"w{g}"] = dense_init(ks[j], (d, d), dtype=jnp.float32)
        p[f"r{g}"] = dense_init(ks[4 + j], (d, d), dtype=jnp.float32, scale=0.5)
        p[f"b{g}"] = jnp.zeros((d,)) if g != "f" else 3.0 * jnp.ones((d,))
    return p


def slstm_forward(
    p: Dict, x: jnp.ndarray, cfg: ModelConfig, state: Optional[Dict] = None
) -> Tuple[jnp.ndarray, Dict]:
    B, S, d = x.shape
    xf = x.astype(jnp.float32)
    # precompute input contributions for all steps (the only matmuls over S)
    pre = {g: xf @ p[f"w{g}"] + p[f"b{g}"] for g in ("i", "f", "z", "o")}
    if state is None:
        state = slstm_init_state(cfg, B, d)
    h0 = (state["h"], state["c"], state["n"], state["m"])

    def step(carry, t_in):
        h, c, n, m = carry
        xi, xfg, xz, xo = t_in
        i_pre = xi + h @ p["ri"]
        f_pre = xfg + h @ p["rf"]
        z = jnp.tanh(xz + h @ p["rz"])
        o = jax.nn.sigmoid(xo + h @ p["ro"])
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + m, i_pre)
        iw = jnp.exp(i_pre - m_new)
        fw = jnp.exp(logf + m - m_new)
        c_new = fw * c + iw * z
        n_new = fw * n + iw
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    seq = tuple(pre[g].swapaxes(0, 1) for g in ("i", "f", "z", "o"))
    (h, c, n, m), hs = jax.lax.scan(step, h0, seq)
    out = hs.swapaxes(0, 1).astype(x.dtype)
    return out, {"h": h, "c": c, "n": n, "m": m}


def slstm_decode(p: Dict, x: jnp.ndarray, cfg: ModelConfig, state: Dict):
    out, new_state = slstm_forward(p, x, cfg, state)
    return out, new_state


def slstm_init_state(cfg: ModelConfig, batch: int, d: Optional[int] = None) -> Dict:
    d = d or cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"h": z(), "c": z(), "n": z(), "m": jnp.full((batch, d), -1e30, jnp.float32)}
