"""Attention layers: GQA/MQA/MHA with RoPE, sliding windows, softcaps.

Init + three entry points per layer:
  * ``attn_forward``      — full-sequence (train / prefill), returns new KV.
  * ``attn_decode``       — one token against a KV cache.
  * ``cross_attn_forward``— encoder-decoder cross attention.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import (
    apply_rope,
    decode_attention,
    dense_init,
    flash_attention,
)
from ..configs.base import ModelConfig


def init_attn(key, cfg: ModelConfig, dtype) -> Dict:
    d, hd, nq, nkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d, nq * hd), dtype=dtype),
        "wk": dense_init(kk, (d, nkv * hd), dtype=dtype),
        "wv": dense_init(kv, (d, nkv * hd), dtype=dtype),
        "wo": dense_init(ko, (nq * hd, d), dtype=dtype, scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _qkv(p: Dict, x: jnp.ndarray, cfg: ModelConfig):
    nq, nkv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = _split_heads(x @ p["wq"], nq, hd)  # (B,S,nq,hd)
    k = _split_heads(x @ p["wk"], nkv, hd)
    v = _split_heads(x @ p["wv"], nkv, hd)
    # group q heads by kv head: (B,S,K,G,D)
    B, S = x.shape[:2]
    q = q.reshape(B, S, nkv, nq // nkv, hd)
    return q, k, v


def attn_forward(
    p: Dict,
    x: jnp.ndarray,  # (B,S,d)
    cfg: ModelConfig,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    positions: Optional[jnp.ndarray] = None,  # (B,S)
    segment_ids: Optional[jnp.ndarray] = None,  # (B,S)
    q_offset: int | jnp.ndarray = 0,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence attention; returns (out, (k, v)) for cache priming."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    if cfg.use_rope:
        if positions is None:
            positions = q_offset + jnp.arange(S, dtype=jnp.int32)[None, :]
        q = apply_rope(q.reshape(B, S, cfg.n_heads, cfg.hd), positions, cfg.rope_theta)
        q = q.reshape(B, S, cfg.n_kv, cfg.n_heads // cfg.n_kv, cfg.hd)
        k = apply_rope(k, positions, cfg.rope_theta)
    out = flash_attention(
        q, k, v,
        causal=causal,
        window=window,
        logit_cap=cfg.attn_softcap,
        q_offset=q_offset,
        segment_q=segment_ids,
        segment_k=segment_ids,
        p_bf16=cfg.attn_p_bf16,
    )
    out = out.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, (k, v)


def attn_decode(
    p: Dict,
    x: jnp.ndarray,  # (B,1,d)
    cfg: ModelConfig,
    cache: Dict,  # {"k": (B,T,K,D), "v": (B,T,K,D)}
    pos: jnp.ndarray,  # (B,) current absolute position (== kv_len)
    *,
    window: Optional[int] = None,
) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode; appends to the cache at `pos` (ring for windows)."""
    B = x.shape[0]
    T = cache["k"].shape[1]
    q, k, v = _qkv(p, x, cfg)
    if cfg.use_rope:
        q = apply_rope(q.reshape(B, 1, cfg.n_heads, cfg.hd), pos[:, None], cfg.rope_theta)
        q = q.reshape(B, 1, cfg.n_kv, cfg.n_heads // cfg.n_kv, cfg.hd)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    slot = pos % T if window is not None else pos  # ring buffer for SWA
    # batch-indexed scatter (NOT vmap'd dynamic_update_slice: the per-row
    # DUS defeats SPMD batch partitioning of the cache and replicates it)
    b_idx = jnp.arange(B, dtype=jnp.int32)
    kc = cache["k"].at[b_idx, slot].set(k[:, 0], mode="drop")
    vc = cache["v"].at[b_idx, slot].set(v[:, 0], mode="drop")
    kv_len = jnp.minimum(pos + 1, T) if window is not None else pos + 1
    out = decode_attention(q, kc, vc, kv_len, logit_cap=cfg.attn_softcap)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# Cross attention (encoder-decoder)
# ---------------------------------------------------------------------------


def init_cross_attn(key, cfg: ModelConfig, dtype) -> Dict:
    return init_attn(key, cfg, dtype)


def cross_attn_forward(
    p: Dict,
    x: jnp.ndarray,  # (B,S,d) decoder states
    enc_kv: Tuple[jnp.ndarray, jnp.ndarray],  # precomputed (k, v): (B,T,K,D)
    cfg: ModelConfig,
) -> jnp.ndarray:
    B, S, _ = x.shape
    nq, nkv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = _split_heads(x @ p["wq"], nq, hd).reshape(B, S, nkv, nq // nkv, hd)
    k, v = enc_kv
    out = flash_attention(q, k, v, causal=False, logit_cap=cfg.attn_softcap)
    return out.reshape(B, S, nq * hd) @ p["wo"]


def cross_kv(p: Dict, enc_out: jnp.ndarray, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output (cache once)."""
    k = _split_heads(enc_out @ p["wk"], cfg.n_kv, cfg.hd)
    v = _split_heads(enc_out @ p["wv"], cfg.n_kv, cfg.hd)
    return k, v
