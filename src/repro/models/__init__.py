"""Model substrate: shared components + the unified multi-family model."""
from .model import (
    decode_step,
    forward,
    init_cache,
    init_params,
    layer_plan,
    loss_fn,
    param_count,
    plan_period,
    prefill,
    stack_layers,
)

__all__ = [
    "decode_step", "forward", "init_cache", "init_params", "layer_plan",
    "loss_fn", "param_count", "plan_period", "prefill", "stack_layers",
]
