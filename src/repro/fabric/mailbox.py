"""Whole-message send/recv over the routed fabric.

The :class:`Router` moves *frames*; this module gives them HGum message
semantics.  A :class:`Fabric` owns one router over a device mesh plus one
:class:`Mailbox` per rank:

* ``Mailbox.send(dst, wire)`` queues a whole serialized HGum message for
  any rank.  At :meth:`Fabric.exchange` time every pending send across all
  ranks is framed in ONE batched SER pass, routed by the device-side
  router (multi-hop ppermute, credit flow control), and reassembled here —
  by default framing/routing/RX-split fuse into a single jitted program
  (``Router.deliver_fused``); with ``FabricConfig(fused=False)`` or a
  ``tx_hook`` the PR-2/PR-3 three-program path runs instead
  (``kernels.ops.encode_frames_batch`` + ``Router.deliver`` +
  ``kernels.ops.decode_frames_batch``).
* ``Mailbox.recv()`` drains delivered messages as :class:`Delivery` records.
  Frames from different sources interleave freely on the links; the receiver
  re-orders each source's frames by the route word's ``seq`` (wrap-aware —
  a per-(rank, src) expected counter unwraps the u16) and cuts messages at
  the empty end-of-list terminator frames, exactly the paper's §IV-C rule.
* every delivered frame is CRC32-checked twice: on-device by the router
  (``crc_ok``) and here per message, so one corrupt frame flags exactly the
  message it belongs to (``Delivery.ok = False``) without poisoning others.

The fabric is deliberately host-driven at message granularity (submit /
exchange / drain) — the same tick discipline as ``runtime.scheduler`` — while
all per-frame work (framing, checksums, routing, hop pipelining) stays
jitted on device.

Two tick styles:

* :meth:`Fabric.exchange` — synchronous: frame, route, and reassemble before
  returning (the PR-2 behaviour).
* :meth:`Fabric.exchange_async` + :meth:`Fabric.poll` — double-buffered: the
  framing and the router scan are *dispatched* (JAX async dispatch) and the
  host returns immediately; the RX readback and reassembly happen at the
  next ``poll``.  A serve loop can therefore dispatch tick N's router scan,
  run a compute step while it is in flight, and reap the deliveries
  afterwards — fabric hops hide behind compute (``launch.serve``'s streaming
  plane drives exactly this pipeline).  At most one tick is in flight;
  ``exchange_async`` completes the previous one first, so message order per
  (src, dst) stream is preserved.

Two tick engines (``FabricConfig.fused``):

* **fused** (default): the whole tick — batched framing, TX scatter, the
  routed scan, and the RX split — is ONE jitted program
  (``Router.deliver_fused``).  Frames stay on device end to end; the host
  only computes the tiny scatter index tables and reads bytes back at
  reassembly time.  Tick shapes are pow2-bucketed and the resolved jitted
  callable is memoized per bucket on the Fabric, so steady-state serving
  is a dict lookup + one dispatch per tick; a tick that falls into a NEW
  bucket logs once (``repro.fabric.mailbox`` logger) because it implies an
  XLA recompile — silence there means no recompiles.
* **three-program** (``fused=False``, or whenever ``tx_hook`` is set): the
  PR-2/PR-3 path — framing jit, host scatter, router jit, RX-split jit —
  kept as the fault-injection point and the regression oracle the fused
  tick is tested bit-identical against.

Reliable delivery (``FabricConfig.arq=True``):

PRs 2-8 *detect* wire damage (CRC32, seq gaps, span degradation); the ARQ
layer *recovers* from it.  Senders keep every data message in a bounded
per-(src, dst) retransmit buffer keyed by the route word's seq; receivers
CRC-filter delivered frames, buffer out-of-order survivors in a seq
window, and turn gaps into compact NACK — and steady progress into
cumulative-ACK — control frames (single-frame, self-contained,
magic-tagged records riding QoS class ``arq_level``, loss-tolerant and
idempotent so control traffic itself needs no ARQ).  Senders retransmit
on NACK or on a tick-count timeout with capped exponential backoff, give
up into a dead-letter queue after ``max_retries``, and drop buffered
entries on cumulative ACK.  Duplicates (retransmit races, injected dup
faults) are suppressed by the seq window and answered with an immediate
ACK so a sender whose ACKs were lost converges instead of re-sending
forever.  Delivered messages therefore stay byte-identical and in-order
per (src, dst) stream even under seeded faults (``fabric/faults.py``);
a gap that outlives ``skip_after`` ticks is flagged (``ok=False,
seq_gap``) and resynced past, so a dead peer degrades instead of wedging
the stream.  With ``arq=False`` (default) all of this is off and the
flag-only PR-8 behavior is preserved bit for bit.
"""
from __future__ import annotations

import logging
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..analysis.rules import list_level_error, max_ranks_error
from ..obs.counters import (
    CTR_FIELDS,
    DIR_SLOTS,
    FrameAttribution,
    ctr_index,
    global_index,
    load_drift as _load_drift,
    n_att,
    n_counters,
    observed_link_loads as _observed_link_loads,
)
from ..obs.metrics import ClassWindows, MetricsRegistry
from .faults import FaultPlan
from .frames import (
    HDR_CRC,
    HDR_LEVEL,
    HDR_ROUTE,
    HDR_SIZE,
    HDR_WORDS,
    PHIT_WORDS,
    SEQ_MOD,
    frame_capacity,
)
from .router import FabricConfig, Router

logger = logging.getLogger(__name__)

#: magic word opening every ARQ control record ("ARQ1"), so a control
#: frame is self-describing: no reassembly, no ordering, each payload
#: frame parsed independently
ARQ_MAGIC = 0x41525131
ARQ_ACK = 1
ARQ_NACK = 2

#: fabric.arq.* counter catalog (materialized at init and every tick so
#: zero-fault runs still export the full set for the SLO evaluator —
#: `max_retransmit_ratio` must see 0, not an absent signal)
ARQ_COUNTERS = (
    "retransmits", "nacks", "acks", "dup_suppressed", "timeouts",
    "crc_dropped", "aborts", "evicted", "replays", "skips",
)


class FabricCorruption(RuntimeError):
    """Raised by ``drain(on_corrupt="raise")`` when a drained delivery is
    corrupt (CRC failure or seq gap the ARQ layer could not repair).  The
    inbox is left INTACT so the caller can re-drain with ``"flag"`` and
    inspect the damage."""


@dataclass
class Delivery:
    """One reassembled message: who sent it, its wire bytes, CRC verdict,
    the ListLevel its frames carried (paper §IV-C; senders can use it to
    tag streams, e.g. MoE expert ids or QoS tenant classes), and the router
    scan step its last frame arrived at (in-tick queueing latency — the
    observable the QoS credit classes bound).

    ``attribution`` is the flight-recorder vector of the message's
    *critical* frame (the one that arrived last): queue wait + credit
    stall + per-axis transit + defections, with ``attribution.arrive_step
    == arrive_step`` exactly.  ``request_id`` is the span id the sender
    attached (``Fabric.send(request_id=...)``), correlated back through
    the route word's ``(src, dst, seq)`` range — None for untracked
    sends."""

    src: int
    wire: bytes
    ok: bool = True
    list_level: int = 1
    arrive_step: int = 0
    attribution: Optional[FrameAttribution] = None
    request_id: Optional[int] = None
    #: route-word seq of the message's first frame — the key
    #: ``drain(on_corrupt="retry")`` uses to find the sender's buffered
    #: copy for a replay
    seq0: Optional[int] = None


@dataclass
class _PartialMsg:
    data: bytearray = field(default_factory=bytearray)
    ok: bool = True
    level: int = 1
    step: int = 0
    #: attribution row of the latest-arriving frame folded in so far
    att: Optional[np.ndarray] = None
    #: route-word seq of the message's first frame (rid correlation key)
    seq0: Optional[int] = None
    #: degradation detail — WHY ok went False (span annotations)
    crc_bad: bool = False
    seq_gap: bool = False


def _wire_words(wire: bytes, cap_words: int) -> np.ndarray:
    buf = np.frombuffer(wire, np.uint8)
    pad = cap_words * 4 - len(buf)
    return np.concatenate([buf, np.zeros(pad, np.uint8)]).view(np.uint32)


class Fabric:
    """A routed message fabric over a device mesh (host-side driver)."""

    def __init__(
        self,
        mesh: Optional[Mesh] = None,
        axis_names: Optional[Sequence[str]] = None,
        config: FabricConfig = FabricConfig(),
        n_ranks: Optional[int] = None,
        analyze: bool = False,
        metrics: Optional[MetricsRegistry] = None,
        trace=None,
    ):
        if mesh is None:
            n = n_ranks or len(jax.devices())
            err = max_ranks_error(n)
            if err is not None:
                # fail HERE with the route-word explanation rather than a
                # confusing device-shortage error out of make_mesh (the
                # Router re-checks for meshes passed in directly, with the
                # same shared-rule message)
                raise ValueError(err)
            mesh = jax.make_mesh((n,), ("fabric",), devices=jax.devices()[:n])
        self.router = Router(mesh, axis_names, config)
        self.config = config
        #: run the static analyzer on every tick's demand before dispatch
        #: (and on the config+topology now), raising on ERROR findings
        #: with the rule's fix hint instead of failing mid-scan
        self.analyze = analyze
        if analyze:
            from ..analysis.fabric_passes import analyze_fabric
            from ..analysis.findings import assert_clean

            assert_clean(analyze_fabric(self), "Fabric(analyze=True)")
        R = self.router.n_ranks
        self._pending: List[Tuple[int, int, bytes, int]] = []  # (src, dst, wire, level)
        #: per-send metadata parallel to `_pending` (a separate list so
        #: every consumer of the 4-tuples — analyze_sends, the dispatchers
        #: — keeps its shape): {"rid": span id or None, "seq0": pinned seq
        #: for an ARQ retransmit (None = assign fresh), "ctl": ARQ
        #: control frame}.  The in-flight rid->seq-range table
        #: {(dst, src): [(seq0, n_frames, rid), ...]} is matched back at
        #: reassembly through the route word.
        self._pending_meta: List[dict] = []
        self._send_spans: Dict[Tuple[int, int], List[Tuple[int, int, int]]] = {}
        #: optional obs.spans.SpanTracker — deliveries with a request_id
        #: emit fabric.deliver span events (and degrade on corruption)
        self.spans = None
        # seq counters are per (src, dst) stream so a receiver's expected
        # base never lags: every frame of the (src -> me) stream lands here,
        # keeping the u16 wrap window exact.
        self._tx_seq = [[0] * R for _ in range(R)]  # [src][dst] next seq
        self._rx_seq = [[0] * R for _ in range(R)]  # [rank][src] expected seq
        self._partial = [[_PartialMsg() for _ in range(R)] for _ in range(R)]
        self._inbox: List[List[Delivery]] = [[] for _ in range(R)]
        #: per-(rank, QoS class) trace of recent Delivery.arrive_steps —
        #: the congestion observable the stream plane's backpressure-fed
        #: lane scheduler consumes (class = list_level % n_classes, the
        #: same key the router's WRR credit scheduler uses).  ONE shared
        #: windowing implementation (obs.metrics) with the StreamReader.
        self._arrive: List[ClassWindows] = [
            ClassWindows(maxlen=256) for _ in range(R)
        ]
        #: host-side telemetry: always-on metrics registry (pass one in to
        #: share it with the serve loop) and an optional obs.trace
        #: TraceRecorder for the timeline export
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.trace = trace
        #: on-device counter folds (obs.counters layout): all-time per-rank
        #: totals plus a window of per-tick deltas, and the accumulated
        #: STATIC demand matrix of every dispatched tick — the expected
        #: side of the static-vs-observed load drift check
        NC = n_counters(len(self.router.axis_names))
        self._ctr_total = np.zeros((R, NC), np.int64)
        self._ctr_window: deque = deque(maxlen=256)
        self._expected_loads: List[Dict[Tuple, int]] = [
            {} for _ in self.router.sizes
        ]
        #: the dispatched-but-not-reassembled tick (device arrays + counts)
        self._inflight: Optional[Tuple] = None
        self._inflight_meta: Optional[dict] = None
        #: tick-shape buckets seen so far — a tick landing in a new bucket
        #: implies an XLA compile, which steady-state serving must not do
        #: silently (logged once per bucket).
        self._tick_buckets: set = set()
        self.frames_routed = 0
        self.exchanges = 0
        #: fault-injection hook for tests/chaos: (tx, tx_valid) -> tx, applied
        #: after framing and before routing (simulates link corruption).
        #: Legacy three-program-only hook; prefer ``faults`` below.
        self.tx_hook = None
        #: seeded chaos plan (``fabric.faults.FaultPlan``) applied to BOTH
        #: tick engines at the same logical point: after framing, before
        #: the routed scan.  Fault decisions key on the dispatch count
        #: (``self.exchanges``), so fused and three-program runs of the
        #: same send sequence see identical faults.
        self.faults: Optional[FaultPlan] = None
        #: device-side CRC verdict of the last exchange (router `crc_ok`)
        self.last_crc_ok = True
        #: virtual clock: +1 on EVERY exchange_async call (even idle ones)
        #: — the time base of the ARQ timeouts and the serve plane's
        #: blackout detector
        self.ticks = 0
        # -- ARQ state (inert unless config.arq) --------------------------
        #: control frames use their own per-(src, dst) seq counters so
        #: loss-tolerant ctl traffic never perturbs the data seq window
        self._tx_seq_ctl = [[0] * R for _ in range(R)]
        #: sender retransmit buffers: {(src, dst): deque of entries
        #: {seq0, n, wire, level, rid, last_tx, retries}} bounded by
        #: config.arq_buffer frames (oldest evicted to the dead letters)
        self._retx: Dict[Tuple[int, int], deque] = {}
        #: dead letters: messages the ARQ gave up on (max_retries
        #: exceeded or evicted) — kept for `drain(on_corrupt="retry")`
        self._dead: deque = deque(maxlen=64)
        #: receiver out-of-order window: [rank][src] {seq: (size, level,
        #: payload_row, step, att)} of CRC-clean frames ahead of expected
        self._ooo: List[List[Dict[int, Tuple]]] = [
            [{} for _ in range(R)] for _ in range(R)
        ]
        #: [rank][src] tick a seq gap was first seen (None = no gap) —
        #: drives NACK re-sends and the skip_after give-up horizon
        self._gap_since: List[List[Optional[int]]] = [
            [None] * R for _ in range(R)
        ]
        self._last_nack = [[-(1 << 30)] * R for _ in range(R)]
        #: [rank][src] in-order progress not yet cumulative-ACKed
        self._ack_owed = [[False] * R for _ in range(R)]
        self._last_ack = [[-(1 << 30)] * R for _ in range(R)]
        #: [rank][src] last tick anything (data or ctl) arrived from src —
        #: the serve plane's suspect/blackout signal
        self._last_heard: List[List[Optional[int]]] = [
            [None] * R for _ in range(R)
        ]
        #: (rank, src, seq0) replays already issued by on_corrupt="retry"
        #: (one replay per corrupt message, never a loop)
        self._replayed: set = set()
        if config.arq:
            self._materialize_arq_counters()

    @property
    def n_ranks(self) -> int:
        return self.router.n_ranks

    def mailbox(self, rank: int) -> "Mailbox":
        if not 0 <= rank < self.n_ranks:
            raise ValueError(f"rank {rank} outside fabric of {self.n_ranks}")
        return Mailbox(self, rank)

    # -- send side ---------------------------------------------------------

    def send(self, src: int, dst: int, wire: bytes, list_level: int = 1,
             request_id: Optional[int] = None) -> None:
        """Queue ``wire`` for routed delivery ``src -> dst``.

        ``request_id`` tags the message with a span id (obs.spans): the
        receiver's :class:`Delivery` carries it back, correlated through
        the route word's ``(src, dst, seq)`` range, so one request renders
        as a connected arc across ranks.

        Arguments are validated HERE, with clear errors, rather than
        surfacing as shape mismatches or routing failures deep inside the
        jitted router scan at exchange time.
        """
        if not 0 <= dst < self.n_ranks:
            raise ValueError(f"dst {dst} outside fabric of {self.n_ranks}")
        if not 0 <= src < self.n_ranks:
            raise ValueError(f"src {src} outside fabric of {self.n_ranks}")
        if not isinstance(wire, (bytes, bytearray, memoryview)):
            raise ValueError(
                f"wire must be bytes-like, got {type(wire).__name__}"
            )
        if len(wire) == 0:
            raise ValueError(
                "empty wire: zero-length sends carry no payload frames and "
                "cannot be distinguished from a bare end-of-message "
                "terminator — serialize an empty List instead"
            )
        err = list_level_error(list_level)
        if err is not None:
            # shared analyzer rule fabric-list-level: the ListLevel header
            # lane is u8-budgeted; an out-of-range level would wrap
            # silently and alias another tenant's QoS class (the router
            # keys credit classes on level % n_classes)
            raise ValueError(err)
        if self.config.arq and int(list_level) == self.config.arq_level:
            raise ValueError(
                f"list_level {list_level} is reserved for ARQ ACK/NACK "
                f"control frames while arq=True — pick another level (or "
                f"move FabricConfig.arq_level)"
            )
        self._pending.append((src, dst, bytes(wire), int(list_level)))
        self._pending_meta.append({
            "rid": int(request_id) if request_id is not None else None,
            "seq0": None, "ctl": False,
        })

    def _send_ctl(self, src: int, dst: int, kind: int, ack_seq: int) -> None:
        """Queue one ARQ control record ``src -> dst``: a single-frame,
        self-contained ``[MAGIC, kind, ack_seq, 0]`` payload riding the
        reserved ``arq_level`` QoS class.  Control frames are idempotent
        and loss-tolerant (timeouts re-derive anything a lost ACK/NACK
        carried), so they are never ARQ-buffered themselves."""
        payload = np.array(
            [ARQ_MAGIC, kind, ack_seq, 0], np.uint32
        ).tobytes()
        self._pending.append((src, dst, payload, self.config.arq_level))
        self._pending_meta.append({"rid": None, "seq0": None, "ctl": True})

    # -- the fabric tick ---------------------------------------------------

    def exchange(self) -> None:
        """Frame, route, and deliver every pending send (one fabric tick).

        Synchronous: completes any in-flight async tick first, then blocks
        until this tick's messages are reassembled into the inboxes.
        """
        self.exchange_async()
        self.poll()

    def exchange_async(self) -> bool:
        """Dispatch one fabric tick without waiting for delivery.

        Frames every pending send and launches the router scan; device work
        proceeds in the background (JAX async dispatch) while the host
        returns immediately.  Call :meth:`poll` to reassemble the tick's
        messages into the inboxes.  Depth-1 double buffer: a previous
        in-flight tick is completed first, so per-stream FIFO order holds.
        Returns True when a tick was dispatched (False: nothing pending).
        """
        if self._inflight is not None:
            self._complete()
        # virtual clock: advances on every call (idle ticks included) so
        # ARQ timeouts and the serve plane's blackout detector measure
        # elapsed fabric time, not message counts
        self.ticks += 1
        if self.config.arq:
            # may queue retransmits (sender timeouts), re-NACKs, owed
            # ACKs, and gap skips into _pending — BEFORE the empty check,
            # so recovery traffic flows even when the app has nothing to
            # say
            self._arq_tick()
        if not self._pending:
            return False
        if self.analyze:
            # static pre-flight of this tick's demand: rank ranges, seq
            # windows, rx capacity — raise with the rule's fix hint BEFORE
            # dispatch (the pending sends stay queued, so the caller can
            # drop the offender and retry)
            from ..analysis.fabric_passes import analyze_sends
            from ..analysis.findings import assert_clean

            _, fs = analyze_sends(
                self.router.sizes, self.config, self._pending,
            )
            assert_clean(fs, "Fabric.exchange(analyze=True)")
        sends, self._pending = self._pending, []
        metas, self._pending_meta = self._pending_meta, []
        if len(metas) != len(sends):  # a test poked _pending directly
            metas = (metas + [{}] * len(sends))[: len(sends)]
        phits = self.config.frame_phits
        frame_words = phits * PHIT_WORDS
        B = len(sends)
        n_live = [frame_capacity(len(w), phits) for _, _, w, _ in sends]
        # bucket the payload frame capacity (pow2) so the jitted batched
        # SER pass is reused across ticks with varying wire lengths
        pf = 1 << max(max(n_live) - 2, 0).bit_length()  # payload frames
        cap_words = pf * frame_words
        F_arr = pf + 1  # + terminator: frames emitted per stream
        payloads = np.stack([_wire_words(w, cap_words) for _, _, w, _ in sends])
        nbytes = np.asarray([len(w) for _, _, w, _ in sends], np.int32)
        routes = np.zeros((B, 3), np.int32)
        for i, (src, dst, _, _) in enumerate(sends):
            m = metas[i]
            if m.get("ctl"):
                # control frames: own seq space, never buffered, never
                # span-correlated (each ctl payload frame is parsed
                # standalone by magic — the receiver ignores ctl seqs)
                seq0 = self._tx_seq_ctl[src][dst]
                self._tx_seq_ctl[src][dst] = (seq0 + n_live[i]) % SEQ_MOD
                routes[i] = (src, dst, seq0)
                continue
            if m.get("seq0") is not None:
                # ARQ retransmit: the message keeps its ORIGINAL seq range
                # (no counter advance, no re-registration — the original
                # retx entry and span registration still stand)
                routes[i] = (src, dst, int(m["seq0"]))
                continue
            seq0 = self._tx_seq[src][dst]
            routes[i] = (src, dst, seq0)
            self._tx_seq[src][dst] = (seq0 + n_live[i]) % SEQ_MOD
            if self.config.arq:
                self._retx_register(src, dst, seq0, n_live[i], sends[i][2],
                                    sends[i][3], m.get("rid"))
            if m.get("rid") is not None:
                # rid correlation: the message owns seqs [seq0, seq0+n) of
                # the (src -> dst) stream; reassembly matches the first
                # delivered frame's seq into this range
                self._send_spans.setdefault((dst, src), []).append(
                    (seq0, n_live[i], m["rid"])
                )

        # accumulate the tick's STATIC demand matrix (what the analyzer
        # predicts this traffic should put on every (link, direction)) so
        # `load_drift()` can hold it against the on-device observed side
        self._note_expected(sends, n_live)
        self._inflight_meta = {
            "frames": sum(n_live),
            "sends": len(sends),
            "t0": self.trace.now_us() if self.trace is not None else 0.0,
        }
        # seeded chaos: ONE post-fault frame list per rank, consumed by
        # whichever engine dispatches below — injection dynamics are
        # engine-independent by construction
        fault_lists = self._plan_frame_faults(sends, n_live, routes)
        if self.config.fused and self.tx_hook is None:
            self._dispatch_fused(sends, n_live, payloads, nbytes, routes,
                                 F_arr, fault_lists)
        else:
            fill = [0] * self.n_ranks
            if fault_lists is not None:
                for r, post in enumerate(fault_lists):
                    fill[r] = len(post)
            else:
                for i, (src, _, _, _) in enumerate(sends):
                    fill[src] += n_live[i]
            T = max(1, max(fill))
            T = 1 << (T - 1).bit_length()  # bucket for router jit reuse
            total = self.router.bucket_total(sum(fill), T)
            self._dispatch_programs(
                sends, n_live, payloads, nbytes, routes, T, total,
                pf, frame_words, fault_lists,
            )
        self.exchanges += 1
        return True

    def _plan_frame_faults(self, sends, n_live, routes):
        """Roll the seeded :class:`FaultPlan` over this tick's logical
        frames.  Returns per-rank POST-fault frame lists ``[(send_i,
        frame_idx, xor_word, xor_val), ...]`` in transmit order (a dropped
        frame is absent, a duplicated one appears twice, a reordered rank
        is permuted), or None when no plan is active.  Both engines
        consume exactly this list, so the same seed produces the same
        faults — and the same recovery — on either path."""
        plan = self.faults
        if plan is None or not plan.active:
            return None
        out = []
        for r in range(self.n_ranks):
            idxs = [i for i, s in enumerate(sends) if s[0] == r]
            flat = []  # (src, dst, seq, fidx, send_i) per live frame
            for i in idxs:
                src, dst, seq0 = (int(v) for v in routes[i])
                for f in range(n_live[i]):
                    flat.append((src, dst, (seq0 + f) % SEQ_MOD, f, i))
            ops, perm = plan.frame_ops(
                self.exchanges, [t[:4] for t in flat],
                dup_budget=len(flat),
            )
            post = []
            for op, (_, _, _, f, i) in zip(ops, flat):
                if op.kind == "drop":
                    continue
                if op.kind == "corrupt":
                    post.append((i, f, op.word, op.xor))
                    continue
                post.append((i, f, 0, 0))
                if op.kind == "dup":
                    post.append((i, f, 0, 0))
            if perm is not None:
                post = [post[p] for p in perm]
            out.append(post)
        return out

    def _dispatch_fused(
        self, sends, n_live, payloads, nbytes, routes, F_arr: int,
        fault_lists=None,
    ) -> None:
        """One-jit tick (``Router.deliver_fused``): sends are grouped by
        source rank on the host (tiny tables), then framing, TX layout, the
        routed scan, and the RX split all run per-device inside one
        ``jax.jit(shard_map(...))`` — frames never touch host memory between
        the stages.  The scan bound comes from the tick's actual demand
        (``Router.plan_steps``), not the all-to-all worst case.

        ``fault_lists`` (``_plan_frame_faults``) maps onto this engine's
        canonical row layout — send ``j`` frame ``f`` lives at TX row
        ``j * F_arr + f`` — as a (gather, xor, valid) triple the fused jit
        applies after framing, keeping the injected tick a single
        program."""
        R = self.n_ranks
        per_rank: List[List[int]] = [[] for _ in range(R)]
        for i, (src, _, _, _) in enumerate(sends):
            per_rank[src].append(i)
        Bmax = max(1, max(len(p) for p in per_rank))
        Bmax = 1 << (Bmax - 1).bit_length()  # pow2-bucket sends per rank
        if fault_lists is not None and self.faults.duplicate > 0:
            # duplicated frames need spare TX rows: the post-fault list can
            # reach 2x a rank's live frames, so double the row budget
            Bmax *= 2
        Wcap = payloads.shape[1]
        p_r = np.zeros((R, Bmax, Wcap), np.uint32)
        nb_r = np.zeros((R, Bmax), np.int32)
        rt_r = np.zeros((R, Bmax, 3), np.int32)
        lv_r = np.zeros((R, Bmax), np.uint32)
        sv_r = np.zeros((R, Bmax), bool)
        for r, idxs in enumerate(per_rank):
            for j, i in enumerate(idxs):
                p_r[r, j] = payloads[i]
                nb_r[r, j] = nbytes[i]
                rt_r[r, j] = routes[i]
                lv_r[r, j] = sends[i][3]
                sv_r[r, j] = True
        T = Bmax * F_arr
        if fault_lists is None:
            # finer-grained bucket than the three-program path's pow2: the
            # fused jit key is already demand-differentiated by axis_steps,
            # so a 32-frame granularity adds few compiles but keeps the
            # queue (q_cap scales with total) near the tick's real size
            total = min(-(-sum(n_live) // 32) * 32, R * T)
            axis_steps = self.router.plan_steps(
                [s for s, _, _, _ in sends], [d for _, d, _, _ in sends],
                n_live,
            )
            faults = None
        else:
            # demand bounds from the POST-fault frames (what actually
            # rides the links), one count per surviving frame
            W = self.config.frame_width
            fsrcs: List[int] = []
            fdsts: List[int] = []
            gather = np.zeros((R, T), np.int32)
            xor = np.zeros((R, T, W), np.uint32)
            fvalid = np.zeros((R, T), bool)
            for r, post in enumerate(fault_lists):
                jmap = {i: j for j, i in enumerate(per_rank[r])}
                for k, (i, f, w, x) in enumerate(post[:T]):
                    gather[r, k] = jmap[i] * F_arr + f
                    if x:
                        xor[r, k, w] = x
                    fvalid[r, k] = True
                    fsrcs.append(r)
                    fdsts.append(sends[i][1])
            total = min(-(-max(len(fsrcs), 1) // 32) * 32, R * T)
            axis_steps = self.router.plan_steps(
                fsrcs, fdsts, [1] * len(fsrcs)
            )
            faults = (gather, xor, fvalid)
        self._note_bucket(("fused", Bmax, Wcap, axis_steps, total,
                           faults is not None))
        out = self.router.deliver_fused(
            p_r, nb_r, rt_r, lv_r, sv_r, axis_steps=axis_steps, total=total,
            faults=faults,
        )
        self._inflight = ("fused",) + out

    def _dispatch_programs(
        self, sends, n_live, payloads, nbytes, routes, T: int, total: int,
        pf: int, frame_words: int, fault_lists=None,
    ) -> None:
        """The PR-2/PR-3 three-program tick (framing jit -> host scatter ->
        router jit; RX split happens at completion).  Kept for fault
        injection (``tx_hook`` needs the framed TX on host) and as the
        regression oracle for the fused tick.  ``fault_lists``
        (``_plan_frame_faults``) applies to the host-packed rows — the
        same post-fault frame list the fused engine gathers on device."""
        B = len(sends)
        F_arr = pf + 1
        adaptive = self.config.adaptive
        levels = {lvl for _, _, _, lvl in sends}
        if len(levels) == 1:
            frames = self._encode_bucketed(payloads, nbytes, routes,
                                           levels.pop(), self.config.frame_phits,
                                           adaptive)
        else:  # mixed levels: one batched pass per level, scatter back
            frames = np.zeros((B, F_arr, HDR_WORDS + frame_words), np.uint32)
            for lvl in sorted(levels):
                idx = [i for i, s in enumerate(sends) if s[3] == lvl]
                frames[idx] = self._encode_bucketed(
                    payloads[idx], nbytes[idx], routes[idx], lvl,
                    self.config.frame_phits, adaptive,
                )

        # scatter live frames into per-rank tx rows
        R = self.n_ranks
        rows: List[List[np.ndarray]] = [[] for _ in range(R)]
        if fault_lists is not None:
            for r, post in enumerate(fault_lists):
                for (i, f, w, x) in post:
                    fr = frames[i, f]
                    if x:
                        fr = fr.copy()
                        fr[w] ^= np.uint32(x)
                    rows[r].append(fr)
        else:
            for i, (src, _, _, _) in enumerate(sends):
                rows[src].extend(frames[i, : n_live[i]])
        tx = np.zeros((R, T, HDR_WORDS + frame_words), np.uint32)
        tx_valid = np.zeros((R, T), bool)
        for r, fr in enumerate(rows):
            if fr:
                tx[r, : len(fr)] = np.stack(fr)
                tx_valid[r, : len(fr)] = True

        if self.tx_hook is not None:
            tx = np.asarray(self.tx_hook(tx, tx_valid))
        self._note_bucket(("programs", T, total))
        out = self.router.deliver(
            jnp.asarray(tx), jnp.asarray(tx_valid), total_frames=total
        )
        self._inflight = ("frames",) + out

    def _note_bucket(self, key: Tuple) -> None:
        """Record the tick's jit-shape bucket; when it is new (a new bucket
        means an XLA compile, which steady-state serving must not do
        silently) log once AND bump the machine-readable
        ``fabric.tick.recompiles{bucket=...}`` counter, so a serve run or
        CI can assert the count is flat after warmup."""
        if key not in self._tick_buckets:
            self._tick_buckets.add(key)
            logger.info("fabric tick compiled for new shape bucket %s", key)
            label = "/".join(str(p) for p in key)
            self.metrics.counter("fabric.tick.recompiles", bucket=label).add(1)
            if self.trace is not None:
                self.trace.instant("fabric.recompile", cat="fabric",
                                   args={"bucket": label})

    def poll(self) -> bool:
        """Complete the in-flight async tick, reassembling its messages into
        the inboxes.  Returns True when a tick was completed."""
        if self._inflight is None:
            return False
        self._complete()
        return True

    def _complete(self) -> None:
        """RX readback + reassembly of the in-flight tick (the host half of
        the exchange, deferred by ``exchange_async``).  This is the ONLY
        point where delivered frames are materialized as host bytes."""
        kind, *out = self._inflight
        self._inflight = None
        meta, self._inflight_meta = self._inflight_meta or {}, None
        if kind == "fused":  # RX split already happened inside the tick jit
            rx_hdr, rx_pay, rx_cnt, ok, crc_ok, rx_step, rx_att, ctr = out
        else:
            rx, rx_cnt, ok, crc_ok, rx_step, rx_att, ctr = out
        self.last_crc_ok = bool(np.all(np.asarray(crc_ok)))
        # counter readback rides the SAME host sync this reassembly already
        # pays — the dispatch path stays sync-free with counters on
        self._fold_counters(np.asarray(ctr), kind, meta)
        if not bool(np.all(np.asarray(ok))):
            raise RuntimeError(
                "fabric routing failed (undeliverable frame or buffer "
                "overflow) — check ranks and FabricConfig capacities"
            )
        self.frames_routed += int(np.sum(np.asarray(rx_cnt)))
        rx_step = np.asarray(rx_step)
        rx_att = np.asarray(rx_att)
        counts = [int(c) for c in np.asarray(rx_cnt)]
        if not any(counts):
            return
        steps = np.concatenate([rx_step[r, :c] for r, c in enumerate(counts) if c])
        atts = np.concatenate([rx_att[r, :c] for r, c in enumerate(counts) if c])
        if kind == "fused":
            rx_hdr, rx_pay = np.asarray(rx_hdr), np.asarray(rx_pay)
            hdrs = np.concatenate([rx_hdr[r, :c] for r, c in enumerate(counts) if c])
            pays = np.concatenate([rx_pay[r, :c] for r, c in enumerate(counts) if c])
        else:
            # RX split on the Pallas kernel twin: one batched call separates
            # every delivered frame into header + payload rows
            rx = np.asarray(rx)
            flat = np.concatenate([rx[r, :c] for r, c in enumerate(counts) if c])
            hdrs, pays = self._split_bucketed(flat)
        reassemble = (
            self._reassemble_arq if self.config.arq else self._reassemble
        )
        off = 0
        for r, c in enumerate(counts):
            if c:
                reassemble(
                    r, hdrs[off : off + c], pays[off : off + c],
                    steps[off : off + c], atts[off : off + c],
                )
                off += c

    @staticmethod
    def _encode_bucketed(payloads, nbytes, routes, list_level, phits,
                         adaptive=False):
        """Batched SER with the stream count padded to a pow2 bucket, so
        varying burst sizes reuse the jitted framing pass."""
        # deferred: kernels.frame_pack imports fabric.frames (no cycle at
        # module load, but keep package init order independent)
        from ..kernels.ops import encode_frames_batch

        B = payloads.shape[0]
        Bp = 1 << max(B - 1, 0).bit_length()
        if Bp > B:
            payloads = np.pad(payloads, ((0, Bp - B), (0, 0)))
            nbytes = np.pad(nbytes, (0, Bp - B))
            routes = np.pad(routes, ((0, Bp - B), (0, 0)))
        frames, _ = encode_frames_batch(
            jnp.asarray(payloads), jnp.asarray(nbytes), jnp.asarray(routes),
            list_level=list_level, frame_phits=phits, adaptive=adaptive,
        )
        return np.asarray(frames[:B])

    # -- receive side ------------------------------------------------------

    @staticmethod
    def _split_bucketed(flat: np.ndarray):
        """Split delivered frames into (headers, payloads) via the Pallas RX
        kernel, with the row count padded to a pow2 bucket for jit reuse."""
        from ..kernels.ops import decode_frames_batch

        N = flat.shape[0]
        Np = 1 << max(N - 1, 0).bit_length()
        hdr, pay = decode_frames_batch(
            jnp.asarray(np.pad(flat, ((0, Np - N), (0, 0))))
        )
        return np.asarray(hdr[:N]), np.asarray(pay[:N])

    def _reassemble(
        self, rank: int, hdrs: np.ndarray, pays: np.ndarray,
        steps: Optional[np.ndarray] = None,
        atts: Optional[np.ndarray] = None,
    ) -> None:
        """Order a rank's delivered frames per source and cut messages at
        the end-of-list terminators."""
        if steps is None:
            steps = np.zeros(len(hdrs), np.int32)
        if atts is None:
            atts = np.zeros(
                (len(hdrs), n_att(len(self.router.axis_names))), np.int32
            )
        srcs = (hdrs[:, HDR_ROUTE] >> 24) & 0x7F  # bit 31 = adaptive flag
        for src in sorted(set(int(s) for s in srcs)):
            sel = srcs == src
            mh, mp, ms, ma = hdrs[sel], pays[sel], steps[sel], atts[sel]
            base = self._rx_seq[rank][src]
            seqs = (mh[:, HDR_ROUTE] & 0xFFFF).astype(np.int64)
            order = np.argsort((seqs - base) % SEQ_MOD)
            part = self._partial[rank][src]
            expected = base
            for j in order:
                size = int(mh[j, HDR_SIZE])
                part.level = int(mh[j, HDR_LEVEL])
                if part.seq0 is None:
                    part.seq0 = int(seqs[j])
                # the message's attribution is its CRITICAL frame's — the
                # one that arrived last (ties: the later seq wins; equal
                # steps mean equal component sums)
                sj = int(ms[j])
                if part.att is None or sj >= part.step:
                    part.att = ma[j].copy()
                # scan steps restart at 0 each tick, but a message's frames
                # all ride ONE tick (exchange frames every pending send
                # together), so the max is within-tick; a partial spanning
                # ticks means lost frames and the message is flagged anyway
                part.step = max(part.step, sj)
                # CRC covers size | level | route | payload (frames.py)
                covered = np.concatenate(
                    [mh[j, [HDR_SIZE, HDR_LEVEL, HDR_ROUTE]], mp[j]]
                )
                if int(mh[j, HDR_CRC]) != zlib.crc32(covered.tobytes()):
                    part.ok = False
                    part.crc_bad = True
                if int(seqs[j]) != expected:
                    # gap in the stream (lost/misrouted frame): the message
                    # around it cannot be trusted
                    part.ok = False
                    part.seq_gap = True
                expected = (int(seqs[j]) + 1) % SEQ_MOD
                if size == 0:  # terminator: message complete
                    self._deliver(rank, src, part)
                    self._partial[rank][src] = part = _PartialMsg()
                else:
                    part.data.extend(mp[j].tobytes()[:size])
            self._rx_seq[rank][src] = expected

    # -- ARQ: reliable delivery (config.arq) -------------------------------

    def _materialize_arq_counters(self) -> None:
        """Touch every ``fabric.arq.*`` counter so zero-fault snapshots
        export the full catalog (the SLO ``max_retransmit_ratio`` must
        observe 0, never an absent signal) — re-run each tick because the
        serve plane swaps in its own registry post-construction."""
        for name in ARQ_COUNTERS:
            self.metrics.counter(f"fabric.arq.{name}").add(0)

    def _reassemble_arq(
        self, rank: int, hdrs: np.ndarray, pays: np.ndarray,
        steps: np.ndarray, atts: np.ndarray,
    ) -> None:
        """The ARQ receive path: CRC-filter, demux control records, buffer
        out-of-order survivors in the seq window, drain in-order runs into
        deliveries, and turn gaps into NACKs.

        Unlike the legacy path, a CRC failure or gap here produces NO
        flagged delivery — the damage becomes recovery traffic and the
        message arrives intact (byte-identical) on a later tick.  Only a
        gap that outlives ``skip_after`` degrades to a flagged delivery
        (``_arq_skip``)."""
        cfg = self.config
        # CRC-filter EVERYTHING first: a corrupt frame's route word is
        # untrustworthy, so grouping by src — or liveness bookkeeping —
        # keyed on it could misattribute damage to a healthy peer
        good = np.ones(len(hdrs), bool)
        for j in range(len(hdrs)):
            covered = np.concatenate(
                [hdrs[j, [HDR_SIZE, HDR_LEVEL, HDR_ROUTE]], pays[j]]
            )
            if int(hdrs[j, HDR_CRC]) != zlib.crc32(covered.tobytes()):
                good[j] = False
        dropped = int(len(hdrs) - good.sum())
        if dropped:
            self.metrics.counter("fabric.arq.crc_dropped").add(dropped)
        hdrs, pays = hdrs[good], pays[good]
        steps, atts = steps[good], atts[good]
        srcs = (hdrs[:, HDR_ROUTE] >> 24) & 0x7F
        levels = hdrs[:, HDR_LEVEL]
        seqs = (hdrs[:, HDR_ROUTE] & 0xFFFF).astype(np.int64)
        for src in sorted(set(int(s) for s in srcs)):
            sel = srcs == src
            self._last_heard[rank][src] = self.ticks
            ctl = sel & (levels == cfg.arq_level)
            # control records are single-frame and self-contained: parse
            # each payload frame standalone by magic, ignore terminators
            for j in np.nonzero(ctl)[0]:
                if int(hdrs[j, HDR_SIZE]) >= 12 \
                        and int(pays[j, 0]) == ARQ_MAGIC:
                    self._handle_ctl(rank, src, int(pays[j, 1]),
                                     int(pays[j, 2]))
            data = np.nonzero(sel & ~ctl)[0]
            if len(data) == 0:
                continue
            ooo = self._ooo[rank][src]
            expected = self._rx_seq[rank][src]
            dup = 0
            for j in data:
                seq = int(seqs[j])
                d = (seq - expected) % SEQ_MOD
                if d >= SEQ_MOD // 2 or seq in ooo:
                    # behind the window (already drained) or already
                    # buffered: a retransmit race or an injected dup
                    dup += 1
                    continue
                ooo[seq] = (int(hdrs[j, HDR_SIZE]), int(levels[j]),
                            pays[j].copy(), int(steps[j]), atts[j].copy())
            if dup:
                self.metrics.counter("fabric.arq.dup_suppressed").add(dup)
                # a duplicate means the sender never got our ACK (or a
                # fault cloned the frame): answer with an immediate
                # cumulative ACK so timeout retransmission of
                # already-delivered data stops instead of looping
                self._ack_now(rank, src)
            self._drain_inorder(rank, src)

    def _drain_inorder(self, rank: int, src: int) -> None:
        """Drain the in-order run at the front of the (rank, src) seq
        window into partials/deliveries; note gaps (NACK) and owed ACKs."""
        ooo = self._ooo[rank][src]
        expected = self._rx_seq[rank][src]
        progressed = False
        part = self._partial[rank][src]
        while expected in ooo:
            size, level, pay, step, att = ooo.pop(expected)
            part.level = level
            if part.seq0 is None:
                part.seq0 = expected
            if part.att is None or step >= part.step:
                part.att = att.copy()
            part.step = max(part.step, step)
            if size == 0:  # terminator: message complete — and clean
                self._deliver(rank, src, part)
                self._partial[rank][src] = part = _PartialMsg()
            else:
                part.data.extend(pay.tobytes()[:size])
            expected = (expected + 1) % SEQ_MOD
            progressed = True
        self._rx_seq[rank][src] = expected
        if progressed:
            self._ack_owed[rank][src] = True
        if ooo:
            # frames beyond a hole: the run above stopped at a lost or
            # still-in-flight seq — NACK it now, re-NACK on the timeout
            # cadence (_arq_tick) while it persists.  Progress moves the
            # gap FRONT, so it restarts the skip horizon too: only a
            # stream making no progress at all for skip_after ticks is
            # given up on, not one steadily recovering a long burst.
            if self._gap_since[rank][src] is None or progressed:
                self._gap_since[rank][src] = self.ticks
                self._nack_now(rank, src)
        else:
            self._gap_since[rank][src] = None

    def _ack_now(self, rank: int, src: int) -> None:
        self._send_ctl(rank, src, ARQ_ACK, self._rx_seq[rank][src])
        self._last_ack[rank][src] = self.ticks
        self._ack_owed[rank][src] = False
        self.metrics.counter("fabric.arq.acks").add(1)

    def _nack_now(self, rank: int, src: int) -> None:
        self._send_ctl(rank, src, ARQ_NACK, self._rx_seq[rank][src])
        self._last_nack[rank][src] = self.ticks
        self.metrics.counter("fabric.arq.nacks").add(1)

    def _handle_ctl(self, rank: int, src: int, kind: int, ack: int) -> None:
        """One control record arrived at ``rank`` from ``src`` — it talks
        about the data stream ``rank -> src``.  Cumulative ACK drops the
        covered prefix of the retransmit buffer; a NACK additionally
        retransmits the entry holding the seq the receiver is stuck at
        (only that entry — later ones may already sit in its window, and
        blind retransmission would burn their retry budgets)."""
        buf = self._retx.get((rank, src))
        if not buf:
            return
        while buf:  # entries registered in seq order: ACK covers a prefix
            e = buf[0]
            d = (ack - e["seq0"]) % SEQ_MOD
            if e["n"] <= d < SEQ_MOD // 2:
                buf.popleft()
            else:
                break
        if kind != ARQ_NACK or not buf:
            return
        e = buf[0]
        d = (ack - e["seq0"]) % SEQ_MOD
        if d < e["n"] and e["last_tx"] < self.ticks:
            if e["retries"] >= self.config.max_retries:
                self._abort_entry(rank, src, e, buf)
            else:
                e["retries"] += 1
                e["last_tx"] = self.ticks
                self._queue_retransmit(rank, src, e)

    def _retx_register(self, src: int, dst: int, seq0: int, n: int,
                       wire: bytes, level: int,
                       rid: Optional[int]) -> None:
        buf = self._retx.setdefault((src, dst), deque())
        buf.append({"seq0": seq0, "n": n, "wire": wire, "level": level,
                    "rid": rid, "last_tx": self.ticks, "retries": 0})
        total = sum(e["n"] for e in buf)
        # bounded buffer (config.arq_buffer FRAMES): evict oldest to the
        # dead letters — but never the entry just added, however large
        while total > self.config.arq_buffer and len(buf) > 1:
            ev = buf.popleft()
            total -= ev["n"]
            self._dead.append(dict(ev, src=src, dst=dst))
            self.metrics.counter("fabric.arq.evicted").add(1)

    def _queue_retransmit(self, src: int, dst: int, e: dict) -> None:
        """Re-queue a buffered message under its ORIGINAL (pinned) seq
        range — the receiver's window dedups if the original arrives
        after all.  Counted in FRAMES so ``max_retransmit_ratio`` divides
        like for like against ``fabric.frames.delivered``."""
        self._pending.append((src, dst, e["wire"], e["level"]))
        self._pending_meta.append({"rid": None, "seq0": e["seq0"],
                                   "ctl": False})
        self.metrics.counter("fabric.arq.retransmits").add(e["n"])

    def _abort_entry(self, src: int, dst: int, e: dict, buf: deque) -> None:
        """Give up on a message past ``max_retries``: out of the live
        buffer, into the dead letters (``drain(on_corrupt='retry')`` and
        the serve plane's re-placement can still reach the bytes)."""
        try:
            buf.remove(e)
        except ValueError:
            pass
        self._dead.append(dict(e, src=src, dst=dst))
        self.metrics.counter("fabric.arq.aborts").add(1)
        if self.spans is not None:
            self.spans.anomaly(
                "fabric.arq.abort", src=src, dst=dst, seq0=e["seq0"],
                retries=e["retries"], rid=e.get("rid"),
            )

    def _arq_tick(self) -> None:
        """Host-side ARQ clockwork, run once per fabric tick BEFORE
        dispatch: sender timeout retransmits (capped exponential backoff),
        receiver owed-ACK coalescing, gap re-NACKs, and skip give-ups.
        Anything queued here rides THIS tick's exchange."""
        cfg = self.config
        for (src, dst), buf in self._retx.items():
            for e in list(buf):
                wait = cfg.retransmit_timeout * min(1 << e["retries"], 32)
                if self.ticks - e["last_tx"] < wait:
                    continue
                if e["retries"] >= cfg.max_retries:
                    self._abort_entry(src, dst, e, buf)
                    continue
                e["retries"] += 1
                e["last_tx"] = self.ticks
                self.metrics.counter("fabric.arq.timeouts").add(1)
                self._queue_retransmit(src, dst, e)
        skip_after = cfg.skip_after
        R = self.n_ranks
        for rank in range(R):
            for src in range(R):
                gap = self._gap_since[rank][src]
                if gap is not None:
                    if self.ticks - gap >= skip_after:
                        self._arq_skip(rank, src)
                    elif (self.ticks - self._last_nack[rank][src]
                          >= cfg.retransmit_timeout):
                        self._nack_now(rank, src)
                elif self._ack_owed[rank][src] and (
                    self.ticks - self._last_ack[rank][src]
                    >= cfg.arq_ack_every
                ):
                    self._ack_now(rank, src)

    def _arq_skip(self, rank: int, src: int) -> None:
        """Give up on a gap that outlived the whole retransmit schedule:
        flag the partial (``ok=False, seq_gap``), walk the buffered
        out-of-order frames legacy-style (every residual hole keeps
        flagging), and resync ``expected`` past them — a dead peer
        degrades the stream instead of wedging it.  Sender convergence
        needs no extra protocol: the next cumulative ACK (owed below)
        covers the skipped seqs and clears its buffer."""
        ooo = self._ooo[rank][src]
        expected = self._rx_seq[rank][src]
        part = self._partial[rank][src]
        part.ok = False
        part.seq_gap = True
        for seq in sorted(ooo, key=lambda s: (s - expected) % SEQ_MOD):
            size, level, pay, step, att = ooo.pop(seq)
            part.level = level
            if part.seq0 is None:
                part.seq0 = seq
            if part.att is None or step >= part.step:
                part.att = att.copy()
            part.step = max(part.step, step)
            if seq != expected:
                part.ok = False
                part.seq_gap = True
            expected = (seq + 1) % SEQ_MOD
            if size == 0:
                self._deliver(rank, src, part)
                self._partial[rank][src] = part = _PartialMsg()
            else:
                part.data.extend(pay.tobytes()[:size])
        self._rx_seq[rank][src] = expected
        self._gap_since[rank][src] = None
        self._ack_owed[rank][src] = True
        self.metrics.counter("fabric.arq.skips").add(1)

    def last_heard_tick(self, rank: int, src: int) -> Optional[int]:
        """Tick anything (data or control) last arrived at ``rank`` from
        ``src`` — None until the first frame.  The serve plane's blackout
        detector compares this against its suspect horizon."""
        return self._last_heard[rank][src]

    def ticks_since_heard(self, rank: int, src: int) -> Optional[int]:
        t = self._last_heard[rank][src]
        return None if t is None else self.ticks - t

    def _deliver(self, rank: int, src: int, part: _PartialMsg) -> None:
        """Finalize one reassembled message: attach its flight-recorder
        attribution and (when the sender tagged it) its request id, emit
        the span events, and append the Delivery to the rank's inbox."""
        n_axes = len(self.router.axis_names)
        att = FrameAttribution.from_vector(
            n_axes, part.att if part.att is not None else [0] * n_att(n_axes)
        )
        rid = self._match_rid(rank, src, part.seq0)
        self._inbox[rank].append(
            Delivery(src, bytes(part.data), part.ok, part.level, part.step,
                     attribution=att, request_id=rid, seq0=part.seq0)
        )
        self._record_arrive(rank, part.level, part.step, att)
        if self.spans is None:
            return
        if rid is not None:
            self.spans.event(
                rid, "fabric.deliver", pid=rank,
                src=src, dst=rank, arrive_step=part.step,
                **att.components(),
            )
            for name, v in att.components().items():
                self.spans.add_component(rid, f"fabric.{name}", v)
            if not part.ok:
                reasons = [r for r, bad in
                           (("crc", part.crc_bad), ("seq-gap", part.seq_gap))
                           if bad]
                self.spans.degrade(rid, ",".join(reasons) or "corrupt",
                                   src=src, dst=rank)
        elif not part.ok:
            # a corrupted message that cannot be correlated back to its
            # request (e.g. its first frame's route word was mangled) must
            # surface as a tracker anomaly, never vanish silently
            self.spans.anomaly(
                "fabric.deliver.unmatched", src=src, dst=rank,
                seq0=part.seq0, crc=part.crc_bad, seq_gap=part.seq_gap,
            )

    def _match_rid(self, rank: int, src: int,
                   seq0: Optional[int]) -> Optional[int]:
        """Match a reassembled message's first-frame seq into the pending
        (src -> rank) rid ranges recorded at dispatch (wrap-aware)."""
        spans = self._send_spans.get((rank, src))
        if not spans or seq0 is None:
            return None
        for i, (s0, n, rid) in enumerate(spans):
            if (seq0 - s0) % SEQ_MOD < n:
                spans.pop(i)
                return rid
        return None

    def drain(self, rank: int, on_corrupt: str = "flag") -> List[Delivery]:
        """Drain messages delivered to ``rank``.

        ``on_corrupt`` picks the corruption posture:

        * ``"flag"`` (default) — return corrupt deliveries with
          ``ok=False``, exactly the PR-8 behavior.
        * ``"raise"`` — raise :class:`FabricCorruption` when any drained
          delivery is corrupt, with the inbox left INTACT so the caller
          can re-drain with ``"flag"`` and inspect the damage.
        * ``"retry"`` (requires ``arq=True``) — ask the SENDER to replay
          its buffered copy under a fresh seq: the corrupt delivery is
          dropped here and the clean replay arrives on a later tick.  One
          replay per message; a message the sender no longer holds
          (buffer evicted and rotated out of the dead letters) is
          returned flagged as the fallback.
        """
        if on_corrupt not in ("flag", "raise", "retry"):
            raise ValueError(
                f"on_corrupt must be 'flag', 'raise' or 'retry', got "
                f"{on_corrupt!r}"
            )
        if on_corrupt == "retry" and not self.config.arq:
            raise ValueError(
                "on_corrupt='retry' needs FabricConfig(arq=True): replays "
                "come from the sender's ARQ retransmit buffer"
            )
        if on_corrupt == "raise":
            bad = sorted({d.src for d in self._inbox[rank] if not d.ok})
            if bad:
                raise FabricCorruption(
                    f"rank {rank}: corrupt deliveries from src(s) {bad} "
                    f"(CRC failure or unrepaired seq gap) — drain with "
                    f"on_corrupt='flag' to inspect"
                )
        out, self._inbox[rank] = self._inbox[rank], []
        if on_corrupt != "retry" or all(d.ok for d in out):
            return out
        kept = []
        for d in out:
            if d.ok or not self._replay(rank, d):
                kept.append(d)
        return kept

    def _replay(self, rank: int, d: Delivery) -> bool:
        """Queue a sender-side replay of a corrupt delivery: same wire /
        level / rid, FRESH seq range (the original range was consumed by
        the flagged delivery, so pinning would dedup the replay away).
        Returns False when no buffered copy exists or this message was
        already replayed once (``_replayed`` breaks retry loops)."""
        if d.seq0 is None:
            return False
        key = (rank, d.src, d.seq0)
        if key in self._replayed:
            return False
        entry = None
        for e in self._retx.get((d.src, rank), ()):  # still buffered
            if (d.seq0 - e["seq0"]) % SEQ_MOD < e["n"]:
                entry = e
                break
        if entry is None:
            for e in self._dead:  # aborted / evicted copies
                if e.get("src") == d.src and e.get("dst") == rank \
                        and (d.seq0 - e["seq0"]) % SEQ_MOD < e["n"]:
                    entry = e
                    break
        if entry is None:
            return False
        self._replayed.add(key)
        self._pending.append((d.src, rank, entry["wire"], entry["level"]))
        self._pending_meta.append({
            "rid": entry.get("rid"), "seq0": None, "ctl": False,
        })
        self.metrics.counter("fabric.arq.replays").add(1)
        return True

    # -- telemetry folds (the host half of the obs plane) ------------------

    def _note_expected(self, sends, n_live) -> None:
        """Fold this tick's STATIC per-(link, direction) demand —
        ``analysis.comm.demand_link_loads`` of exactly the sends being
        dispatched — into the accumulated expected-load matrix."""
        from ..analysis.comm import demand_link_loads

        loads = demand_link_loads(
            self.router.sizes,
            [s for s, _, _, _ in sends],
            [d for _, d, _, _ in sends],
            n_live,
            self.config.adaptive,
        )
        for ai, group in enumerate(loads):
            acc = self._expected_loads[ai]
            for key, ll in group.items():
                acc[key] = acc.get(key, 0) + ll.frames

    def _fold_counters(self, ctr: np.ndarray, kind: str, meta: dict) -> None:
        """Fold one tick's per-rank on-device counter block into the
        all-time totals, the per-tick delta window, and the metrics
        registry (plus the trace timeline when one is attached)."""
        delta = ctr.astype(np.int64)
        if self.config.arq:
            self._materialize_arq_counters()
        self._ctr_total += delta
        self._ctr_window.append(delta)
        axes = self.router.axis_names
        tot = delta.sum(axis=0)
        m = self.metrics
        m.counter("fabric.ticks", engine=kind).add(1)
        m.counter("fabric.frames.delivered").add(
            int(tot[global_index(len(axes), "delivered")])
        )
        m.counter("fabric.crc.failures").add(
            int(tot[global_index(len(axes), "crc_fail")])
        )
        for ai, axis in enumerate(axes):
            for di, dname in enumerate(DIR_SLOTS):
                for fname in CTR_FIELDS:
                    v = int(tot[ctr_index(ai, di, fname)])
                    if v:
                        m.counter(f"fabric.link.{fname}",
                                  axis=axis, dir=dname).add(v)
        if self.trace is not None:
            t0 = meta.get("t0", 0.0)
            self.trace.complete(
                "fabric.tick", t0, self.trace.now_us() - t0, cat="fabric",
                args={
                    "engine": kind,
                    "frames": meta.get("frames", 0),
                    "sends": meta.get("sends", 0),
                    "delivered": int(
                        tot[global_index(len(axes), "delivered")]
                    ),
                },
            )

    def counters_total(self) -> np.ndarray:
        """All-time per-rank on-device counter block, ``(ranks,
        n_counters)`` int64 in the ``repro.obs.counters`` layout."""
        return self._ctr_total.copy()

    def observed_link_loads(self, window: Optional[int] = None):
        """The OBSERVED per-(link, direction) load matrix, folded from the
        on-device ``entered`` counters and keyed exactly like the static
        ``analysis.comm.demand_link_loads`` matrix.  ``window`` restricts
        the fold to the most recent N ticks (the live view ROADMAP item 4's
        self-tuning consumes); default is all-time."""
        if window is not None:
            ticks = list(self._ctr_window)[-window:]
            delta = (
                np.sum(ticks, axis=0) if ticks
                else np.zeros_like(self._ctr_total)
            )
        else:
            delta = self._ctr_total
        return _observed_link_loads(self.router.sizes, delta)

    def expected_link_loads(self):
        """Accumulated static demand matrix of every dispatched tick (the
        expected side of the drift check), per-axis ``{(ring, dir):
        frames}``."""
        return tuple(dict(g) for g in self._expected_loads)

    def load_drift(self) -> Dict[Tuple, Tuple[int, int]]:
        """Static-vs-observed load divergence: empty dict when every frame
        rode exactly the link the analyzer predicted; a dropped, misrouted
        or defected frame shows up as ``{(axis, ring, dir): (expected,
        observed)}``.  Deterministic workloads without defection must see
        ``{}`` — property-tested."""
        return _load_drift(self.expected_link_loads(),
                           self.observed_link_loads())

    # -- congestion observability -----------------------------------------

    @property
    def n_classes(self) -> int:
        """QoS credit classes the router schedules (1 = single-class FIFO)."""
        return len(self.config.qos_weights) if self.config.qos_weights else 1

    def _record_arrive(self, rank: int, level: int, step: int,
                       att: Optional[FrameAttribution] = None) -> None:
        cls = level % self.n_classes
        self._arrive[rank].record(cls, step)
        self.metrics.histogram("fabric.arrive.step", cls=cls).observe(step)
        if att is not None:
            # latency-attribution histograms (flight recorder fold): where
            # each message's in-fabric time went, by QoS class
            for name, v in att.components().items():
                self.metrics.histogram(
                    f"fabric.attr.{name}", cls=cls
                ).observe(v)

    def class_arrive_stats(self, rank: int) -> Dict[int, Dict[str, float]]:
        """Per-QoS-class arrive-step percentiles of the messages recently
        delivered to ``rank`` (sliding window of 256 per class): ``{class:
        {n, mean, p95, max, jitter}}`` — the congestion signal a
        backpressure-fed sender (``stream.plane.ChunkLane``) clamps on.
        Classes key as ``list_level % n_classes``, matching the router's
        WRR credit scheduler.  The window math is ``obs.metrics``'s shared
        implementation — byte-identical to ``StreamReader``'s, so the two
        ends of the feedback loop can never disagree on "p95"."""
        return self._arrive[rank].stats()


class Mailbox:
    """Per-rank send/recv endpoint on a :class:`Fabric`."""

    def __init__(self, fabric: Fabric, rank: int):
        self.fabric = fabric
        self.rank = rank

    def send(self, dst: int, wire: bytes, list_level: int = 1,
             request_id: Optional[int] = None) -> None:
        """Queue a whole HGum wire for delivery to ``dst`` (routed, framed).

        ``request_id`` tags the message with an obs.spans span id; the
        receiver's Delivery carries it back (see :meth:`Fabric.send`)."""
        self.fabric.send(self.rank, dst, wire, list_level,
                         request_id=request_id)

    def recv(self, on_corrupt: str = "flag") -> List[Delivery]:
        """Drain messages delivered to this rank (run ``exchange`` first).
        ``on_corrupt`` = ``"flag"`` / ``"raise"`` / ``"retry"`` — see
        :meth:`Fabric.drain`."""
        return self.fabric.drain(self.rank, on_corrupt=on_corrupt)

    def arrive_stats(self) -> Dict[int, Dict[str, float]]:
        """Per-QoS-class arrive-step percentiles of this rank's recent
        deliveries (see :meth:`Fabric.class_arrive_stats`)."""
        return self.fabric.class_arrive_stats(self.rank)
