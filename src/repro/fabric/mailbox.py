"""Whole-message send/recv over the routed fabric.

The :class:`Router` moves *frames*; this module gives them HGum message
semantics.  A :class:`Fabric` owns one router over a device mesh plus one
:class:`Mailbox` per rank:

* ``Mailbox.send(dst, wire)`` queues a whole serialized HGum message for
  any rank.  At :meth:`Fabric.exchange` time every pending send across all
  ranks is framed in ONE batched SER pass, routed by the device-side
  router (multi-hop ppermute, credit flow control), and reassembled here —
  by default framing/routing/RX-split fuse into a single jitted program
  (``Router.deliver_fused``); with ``FabricConfig(fused=False)`` or a
  ``tx_hook`` the PR-2/PR-3 three-program path runs instead
  (``kernels.ops.encode_frames_batch`` + ``Router.deliver`` +
  ``kernels.ops.decode_frames_batch``).
* ``Mailbox.recv()`` drains delivered messages as :class:`Delivery` records.
  Frames from different sources interleave freely on the links; the receiver
  re-orders each source's frames by the route word's ``seq`` (wrap-aware —
  a per-(rank, src) expected counter unwraps the u16) and cuts messages at
  the empty end-of-list terminator frames, exactly the paper's §IV-C rule.
* every delivered frame is CRC32-checked twice: on-device by the router
  (``crc_ok``) and here per message, so one corrupt frame flags exactly the
  message it belongs to (``Delivery.ok = False``) without poisoning others.

The fabric is deliberately host-driven at message granularity (submit /
exchange / drain) — the same tick discipline as ``runtime.scheduler`` — while
all per-frame work (framing, checksums, routing, hop pipelining) stays
jitted on device.

Two tick styles:

* :meth:`Fabric.exchange` — synchronous: frame, route, and reassemble before
  returning (the PR-2 behaviour).
* :meth:`Fabric.exchange_async` + :meth:`Fabric.poll` — double-buffered: the
  framing and the router scan are *dispatched* (JAX async dispatch) and the
  host returns immediately; the RX readback and reassembly happen at the
  next ``poll``.  A serve loop can therefore dispatch tick N's router scan,
  run a compute step while it is in flight, and reap the deliveries
  afterwards — fabric hops hide behind compute (``launch.serve``'s streaming
  plane drives exactly this pipeline).  At most one tick is in flight;
  ``exchange_async`` completes the previous one first, so message order per
  (src, dst) stream is preserved.

Two tick engines (``FabricConfig.fused``):

* **fused** (default): the whole tick — batched framing, TX scatter, the
  routed scan, and the RX split — is ONE jitted program
  (``Router.deliver_fused``).  Frames stay on device end to end; the host
  only computes the tiny scatter index tables and reads bytes back at
  reassembly time.  Tick shapes are pow2-bucketed and the resolved jitted
  callable is memoized per bucket on the Fabric, so steady-state serving
  is a dict lookup + one dispatch per tick; a tick that falls into a NEW
  bucket logs once (``repro.fabric.mailbox`` logger) because it implies an
  XLA recompile — silence there means no recompiles.
* **three-program** (``fused=False``, or whenever ``tx_hook`` is set): the
  PR-2/PR-3 path — framing jit, host scatter, router jit, RX-split jit —
  kept as the fault-injection point and the regression oracle the fused
  tick is tested bit-identical against.
"""
from __future__ import annotations

import logging
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..analysis.rules import list_level_error, max_ranks_error
from ..obs.counters import (
    CTR_FIELDS,
    DIR_SLOTS,
    FrameAttribution,
    ctr_index,
    global_index,
    load_drift as _load_drift,
    n_att,
    n_counters,
    observed_link_loads as _observed_link_loads,
)
from ..obs.metrics import ClassWindows, MetricsRegistry
from .frames import (
    HDR_CRC,
    HDR_LEVEL,
    HDR_ROUTE,
    HDR_SIZE,
    HDR_WORDS,
    PHIT_WORDS,
    SEQ_MOD,
    frame_capacity,
)
from .router import FabricConfig, Router

logger = logging.getLogger(__name__)


@dataclass
class Delivery:
    """One reassembled message: who sent it, its wire bytes, CRC verdict,
    the ListLevel its frames carried (paper §IV-C; senders can use it to
    tag streams, e.g. MoE expert ids or QoS tenant classes), and the router
    scan step its last frame arrived at (in-tick queueing latency — the
    observable the QoS credit classes bound).

    ``attribution`` is the flight-recorder vector of the message's
    *critical* frame (the one that arrived last): queue wait + credit
    stall + per-axis transit + defections, with ``attribution.arrive_step
    == arrive_step`` exactly.  ``request_id`` is the span id the sender
    attached (``Fabric.send(request_id=...)``), correlated back through
    the route word's ``(src, dst, seq)`` range — None for untracked
    sends."""

    src: int
    wire: bytes
    ok: bool = True
    list_level: int = 1
    arrive_step: int = 0
    attribution: Optional[FrameAttribution] = None
    request_id: Optional[int] = None


@dataclass
class _PartialMsg:
    data: bytearray = field(default_factory=bytearray)
    ok: bool = True
    level: int = 1
    step: int = 0
    #: attribution row of the latest-arriving frame folded in so far
    att: Optional[np.ndarray] = None
    #: route-word seq of the message's first frame (rid correlation key)
    seq0: Optional[int] = None
    #: degradation detail — WHY ok went False (span annotations)
    crc_bad: bool = False
    seq_gap: bool = False


def _wire_words(wire: bytes, cap_words: int) -> np.ndarray:
    buf = np.frombuffer(wire, np.uint8)
    pad = cap_words * 4 - len(buf)
    return np.concatenate([buf, np.zeros(pad, np.uint8)]).view(np.uint32)


class Fabric:
    """A routed message fabric over a device mesh (host-side driver)."""

    def __init__(
        self,
        mesh: Optional[Mesh] = None,
        axis_names: Optional[Sequence[str]] = None,
        config: FabricConfig = FabricConfig(),
        n_ranks: Optional[int] = None,
        analyze: bool = False,
        metrics: Optional[MetricsRegistry] = None,
        trace=None,
    ):
        if mesh is None:
            n = n_ranks or len(jax.devices())
            err = max_ranks_error(n)
            if err is not None:
                # fail HERE with the route-word explanation rather than a
                # confusing device-shortage error out of make_mesh (the
                # Router re-checks for meshes passed in directly, with the
                # same shared-rule message)
                raise ValueError(err)
            mesh = jax.make_mesh((n,), ("fabric",), devices=jax.devices()[:n])
        self.router = Router(mesh, axis_names, config)
        self.config = config
        #: run the static analyzer on every tick's demand before dispatch
        #: (and on the config+topology now), raising on ERROR findings
        #: with the rule's fix hint instead of failing mid-scan
        self.analyze = analyze
        if analyze:
            from ..analysis.fabric_passes import analyze_fabric
            from ..analysis.findings import assert_clean

            assert_clean(analyze_fabric(self), "Fabric(analyze=True)")
        R = self.router.n_ranks
        self._pending: List[Tuple[int, int, bytes, int]] = []  # (src, dst, wire, level)
        #: request ids parallel to `_pending` (a separate list so every
        #: consumer of the 4-tuples — analyze_sends, the dispatchers —
        #: keeps its shape), and the in-flight rid->seq-range table:
        #: {(dst, src): [(seq0, n_frames, rid), ...]} matched back at
        #: reassembly through the route word.
        self._pending_rids: List[Optional[int]] = []
        self._send_spans: Dict[Tuple[int, int], List[Tuple[int, int, int]]] = {}
        #: optional obs.spans.SpanTracker — deliveries with a request_id
        #: emit fabric.deliver span events (and degrade on corruption)
        self.spans = None
        # seq counters are per (src, dst) stream so a receiver's expected
        # base never lags: every frame of the (src -> me) stream lands here,
        # keeping the u16 wrap window exact.
        self._tx_seq = [[0] * R for _ in range(R)]  # [src][dst] next seq
        self._rx_seq = [[0] * R for _ in range(R)]  # [rank][src] expected seq
        self._partial = [[_PartialMsg() for _ in range(R)] for _ in range(R)]
        self._inbox: List[List[Delivery]] = [[] for _ in range(R)]
        #: per-(rank, QoS class) trace of recent Delivery.arrive_steps —
        #: the congestion observable the stream plane's backpressure-fed
        #: lane scheduler consumes (class = list_level % n_classes, the
        #: same key the router's WRR credit scheduler uses).  ONE shared
        #: windowing implementation (obs.metrics) with the StreamReader.
        self._arrive: List[ClassWindows] = [
            ClassWindows(maxlen=256) for _ in range(R)
        ]
        #: host-side telemetry: always-on metrics registry (pass one in to
        #: share it with the serve loop) and an optional obs.trace
        #: TraceRecorder for the timeline export
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.trace = trace
        #: on-device counter folds (obs.counters layout): all-time per-rank
        #: totals plus a window of per-tick deltas, and the accumulated
        #: STATIC demand matrix of every dispatched tick — the expected
        #: side of the static-vs-observed load drift check
        NC = n_counters(len(self.router.axis_names))
        self._ctr_total = np.zeros((R, NC), np.int64)
        self._ctr_window: deque = deque(maxlen=256)
        self._expected_loads: List[Dict[Tuple, int]] = [
            {} for _ in self.router.sizes
        ]
        #: the dispatched-but-not-reassembled tick (device arrays + counts)
        self._inflight: Optional[Tuple] = None
        self._inflight_meta: Optional[dict] = None
        #: tick-shape buckets seen so far — a tick landing in a new bucket
        #: implies an XLA compile, which steady-state serving must not do
        #: silently (logged once per bucket).
        self._tick_buckets: set = set()
        self.frames_routed = 0
        self.exchanges = 0
        #: fault-injection hook for tests/chaos: (tx, tx_valid) -> tx, applied
        #: after framing and before routing (simulates link corruption).
        self.tx_hook = None
        #: device-side CRC verdict of the last exchange (router `crc_ok`)
        self.last_crc_ok = True

    @property
    def n_ranks(self) -> int:
        return self.router.n_ranks

    def mailbox(self, rank: int) -> "Mailbox":
        if not 0 <= rank < self.n_ranks:
            raise ValueError(f"rank {rank} outside fabric of {self.n_ranks}")
        return Mailbox(self, rank)

    # -- send side ---------------------------------------------------------

    def send(self, src: int, dst: int, wire: bytes, list_level: int = 1,
             request_id: Optional[int] = None) -> None:
        """Queue ``wire`` for routed delivery ``src -> dst``.

        ``request_id`` tags the message with a span id (obs.spans): the
        receiver's :class:`Delivery` carries it back, correlated through
        the route word's ``(src, dst, seq)`` range, so one request renders
        as a connected arc across ranks.

        Arguments are validated HERE, with clear errors, rather than
        surfacing as shape mismatches or routing failures deep inside the
        jitted router scan at exchange time.
        """
        if not 0 <= dst < self.n_ranks:
            raise ValueError(f"dst {dst} outside fabric of {self.n_ranks}")
        if not 0 <= src < self.n_ranks:
            raise ValueError(f"src {src} outside fabric of {self.n_ranks}")
        if not isinstance(wire, (bytes, bytearray, memoryview)):
            raise ValueError(
                f"wire must be bytes-like, got {type(wire).__name__}"
            )
        if len(wire) == 0:
            raise ValueError(
                "empty wire: zero-length sends carry no payload frames and "
                "cannot be distinguished from a bare end-of-message "
                "terminator — serialize an empty List instead"
            )
        err = list_level_error(list_level)
        if err is not None:
            # shared analyzer rule fabric-list-level: the ListLevel header
            # lane is u8-budgeted; an out-of-range level would wrap
            # silently and alias another tenant's QoS class (the router
            # keys credit classes on level % n_classes)
            raise ValueError(err)
        self._pending.append((src, dst, bytes(wire), int(list_level)))
        self._pending_rids.append(
            int(request_id) if request_id is not None else None
        )

    # -- the fabric tick ---------------------------------------------------

    def exchange(self) -> None:
        """Frame, route, and deliver every pending send (one fabric tick).

        Synchronous: completes any in-flight async tick first, then blocks
        until this tick's messages are reassembled into the inboxes.
        """
        self.exchange_async()
        self.poll()

    def exchange_async(self) -> bool:
        """Dispatch one fabric tick without waiting for delivery.

        Frames every pending send and launches the router scan; device work
        proceeds in the background (JAX async dispatch) while the host
        returns immediately.  Call :meth:`poll` to reassemble the tick's
        messages into the inboxes.  Depth-1 double buffer: a previous
        in-flight tick is completed first, so per-stream FIFO order holds.
        Returns True when a tick was dispatched (False: nothing pending).
        """
        if self._inflight is not None:
            self._complete()
        if not self._pending:
            return False
        if self.analyze:
            # static pre-flight of this tick's demand: rank ranges, seq
            # windows, rx capacity — raise with the rule's fix hint BEFORE
            # dispatch (the pending sends stay queued, so the caller can
            # drop the offender and retry)
            from ..analysis.fabric_passes import analyze_sends
            from ..analysis.findings import assert_clean

            _, fs = analyze_sends(
                self.router.sizes, self.config, self._pending,
            )
            assert_clean(fs, "Fabric.exchange(analyze=True)")
        sends, self._pending = self._pending, []
        rids, self._pending_rids = self._pending_rids, []
        phits = self.config.frame_phits
        frame_words = phits * PHIT_WORDS
        B = len(sends)
        n_live = [frame_capacity(len(w), phits) for _, _, w, _ in sends]
        # bucket the payload frame capacity (pow2) so the jitted batched
        # SER pass is reused across ticks with varying wire lengths
        pf = 1 << max(max(n_live) - 2, 0).bit_length()  # payload frames
        cap_words = pf * frame_words
        F_arr = pf + 1  # + terminator: frames emitted per stream
        payloads = np.stack([_wire_words(w, cap_words) for _, _, w, _ in sends])
        nbytes = np.asarray([len(w) for _, _, w, _ in sends], np.int32)
        routes = np.zeros((B, 3), np.int32)
        for i, (src, dst, _, _) in enumerate(sends):
            seq0 = self._tx_seq[src][dst]
            routes[i] = (src, dst, seq0)
            self._tx_seq[src][dst] = (seq0 + n_live[i]) % SEQ_MOD
            if rids[i] is not None:
                # rid correlation: the message owns seqs [seq0, seq0+n) of
                # the (src -> dst) stream; reassembly matches the first
                # delivered frame's seq into this range
                self._send_spans.setdefault((dst, src), []).append(
                    (seq0, n_live[i], rids[i])
                )

        # accumulate the tick's STATIC demand matrix (what the analyzer
        # predicts this traffic should put on every (link, direction)) so
        # `load_drift()` can hold it against the on-device observed side
        self._note_expected(sends, n_live)
        self._inflight_meta = {
            "frames": sum(n_live),
            "sends": len(sends),
            "t0": self.trace.now_us() if self.trace is not None else 0.0,
        }
        if self.config.fused and self.tx_hook is None:
            self._dispatch_fused(sends, n_live, payloads, nbytes, routes,
                                 F_arr)
        else:
            fill = [0] * self.n_ranks
            for i, (src, _, _, _) in enumerate(sends):
                fill[src] += n_live[i]
            T = max(1, max(fill))
            T = 1 << (T - 1).bit_length()  # bucket for router jit reuse
            total = self.router.bucket_total(sum(n_live), T)
            self._dispatch_programs(
                sends, n_live, payloads, nbytes, routes, T, total,
                pf, frame_words,
            )
        self.exchanges += 1
        return True

    def _dispatch_fused(
        self, sends, n_live, payloads, nbytes, routes, F_arr: int
    ) -> None:
        """One-jit tick (``Router.deliver_fused``): sends are grouped by
        source rank on the host (tiny tables), then framing, TX layout, the
        routed scan, and the RX split all run per-device inside one
        ``jax.jit(shard_map(...))`` — frames never touch host memory between
        the stages.  The scan bound comes from the tick's actual demand
        (``Router.plan_steps``), not the all-to-all worst case."""
        R = self.n_ranks
        per_rank: List[List[int]] = [[] for _ in range(R)]
        for i, (src, _, _, _) in enumerate(sends):
            per_rank[src].append(i)
        Bmax = max(1, max(len(p) for p in per_rank))
        Bmax = 1 << (Bmax - 1).bit_length()  # pow2-bucket sends per rank
        Wcap = payloads.shape[1]
        p_r = np.zeros((R, Bmax, Wcap), np.uint32)
        nb_r = np.zeros((R, Bmax), np.int32)
        rt_r = np.zeros((R, Bmax, 3), np.int32)
        lv_r = np.zeros((R, Bmax), np.uint32)
        sv_r = np.zeros((R, Bmax), bool)
        for r, idxs in enumerate(per_rank):
            for j, i in enumerate(idxs):
                p_r[r, j] = payloads[i]
                nb_r[r, j] = nbytes[i]
                rt_r[r, j] = routes[i]
                lv_r[r, j] = sends[i][3]
                sv_r[r, j] = True
        T = Bmax * F_arr
        # finer-grained bucket than the three-program path's pow2: the
        # fused jit key is already demand-differentiated by axis_steps, so
        # a 32-frame granularity adds few compiles but keeps the queue
        # (q_cap scales with total) near the tick's real size
        total = min(-(-sum(n_live) // 32) * 32, R * T)
        axis_steps = self.router.plan_steps(
            [s for s, _, _, _ in sends], [d for _, d, _, _ in sends], n_live
        )
        self._note_bucket(("fused", Bmax, Wcap, axis_steps, total))
        out = self.router.deliver_fused(
            p_r, nb_r, rt_r, lv_r, sv_r, axis_steps=axis_steps, total=total
        )
        self._inflight = ("fused",) + out

    def _dispatch_programs(
        self, sends, n_live, payloads, nbytes, routes, T: int, total: int,
        pf: int, frame_words: int,
    ) -> None:
        """The PR-2/PR-3 three-program tick (framing jit -> host scatter ->
        router jit; RX split happens at completion).  Kept for fault
        injection (``tx_hook`` needs the framed TX on host) and as the
        regression oracle for the fused tick."""
        B = len(sends)
        F_arr = pf + 1
        adaptive = self.config.adaptive
        levels = {lvl for _, _, _, lvl in sends}
        if len(levels) == 1:
            frames = self._encode_bucketed(payloads, nbytes, routes,
                                           levels.pop(), self.config.frame_phits,
                                           adaptive)
        else:  # mixed levels: one batched pass per level, scatter back
            frames = np.zeros((B, F_arr, HDR_WORDS + frame_words), np.uint32)
            for lvl in sorted(levels):
                idx = [i for i, s in enumerate(sends) if s[3] == lvl]
                frames[idx] = self._encode_bucketed(
                    payloads[idx], nbytes[idx], routes[idx], lvl,
                    self.config.frame_phits, adaptive,
                )

        # scatter live frames into per-rank tx rows
        R = self.n_ranks
        rows: List[List[np.ndarray]] = [[] for _ in range(R)]
        for i, (src, _, _, _) in enumerate(sends):
            rows[src].extend(frames[i, : n_live[i]])
        tx = np.zeros((R, T, HDR_WORDS + frame_words), np.uint32)
        tx_valid = np.zeros((R, T), bool)
        for r, fr in enumerate(rows):
            if fr:
                tx[r, : len(fr)] = np.stack(fr)
                tx_valid[r, : len(fr)] = True

        if self.tx_hook is not None:
            tx = np.asarray(self.tx_hook(tx, tx_valid))
        self._note_bucket(("programs", T, total))
        out = self.router.deliver(
            jnp.asarray(tx), jnp.asarray(tx_valid), total_frames=total
        )
        self._inflight = ("frames",) + out

    def _note_bucket(self, key: Tuple) -> None:
        """Record the tick's jit-shape bucket; when it is new (a new bucket
        means an XLA compile, which steady-state serving must not do
        silently) log once AND bump the machine-readable
        ``fabric.tick.recompiles{bucket=...}`` counter, so a serve run or
        CI can assert the count is flat after warmup."""
        if key not in self._tick_buckets:
            self._tick_buckets.add(key)
            logger.info("fabric tick compiled for new shape bucket %s", key)
            label = "/".join(str(p) for p in key)
            self.metrics.counter("fabric.tick.recompiles", bucket=label).add(1)
            if self.trace is not None:
                self.trace.instant("fabric.recompile", cat="fabric",
                                   args={"bucket": label})

    def poll(self) -> bool:
        """Complete the in-flight async tick, reassembling its messages into
        the inboxes.  Returns True when a tick was completed."""
        if self._inflight is None:
            return False
        self._complete()
        return True

    def _complete(self) -> None:
        """RX readback + reassembly of the in-flight tick (the host half of
        the exchange, deferred by ``exchange_async``).  This is the ONLY
        point where delivered frames are materialized as host bytes."""
        kind, *out = self._inflight
        self._inflight = None
        meta, self._inflight_meta = self._inflight_meta or {}, None
        if kind == "fused":  # RX split already happened inside the tick jit
            rx_hdr, rx_pay, rx_cnt, ok, crc_ok, rx_step, rx_att, ctr = out
        else:
            rx, rx_cnt, ok, crc_ok, rx_step, rx_att, ctr = out
        self.last_crc_ok = bool(np.all(np.asarray(crc_ok)))
        # counter readback rides the SAME host sync this reassembly already
        # pays — the dispatch path stays sync-free with counters on
        self._fold_counters(np.asarray(ctr), kind, meta)
        if not bool(np.all(np.asarray(ok))):
            raise RuntimeError(
                "fabric routing failed (undeliverable frame or buffer "
                "overflow) — check ranks and FabricConfig capacities"
            )
        self.frames_routed += int(np.sum(np.asarray(rx_cnt)))
        rx_step = np.asarray(rx_step)
        rx_att = np.asarray(rx_att)
        counts = [int(c) for c in np.asarray(rx_cnt)]
        if not any(counts):
            return
        steps = np.concatenate([rx_step[r, :c] for r, c in enumerate(counts) if c])
        atts = np.concatenate([rx_att[r, :c] for r, c in enumerate(counts) if c])
        if kind == "fused":
            rx_hdr, rx_pay = np.asarray(rx_hdr), np.asarray(rx_pay)
            hdrs = np.concatenate([rx_hdr[r, :c] for r, c in enumerate(counts) if c])
            pays = np.concatenate([rx_pay[r, :c] for r, c in enumerate(counts) if c])
        else:
            # RX split on the Pallas kernel twin: one batched call separates
            # every delivered frame into header + payload rows
            rx = np.asarray(rx)
            flat = np.concatenate([rx[r, :c] for r, c in enumerate(counts) if c])
            hdrs, pays = self._split_bucketed(flat)
        off = 0
        for r, c in enumerate(counts):
            if c:
                self._reassemble(
                    r, hdrs[off : off + c], pays[off : off + c],
                    steps[off : off + c], atts[off : off + c],
                )
                off += c

    @staticmethod
    def _encode_bucketed(payloads, nbytes, routes, list_level, phits,
                         adaptive=False):
        """Batched SER with the stream count padded to a pow2 bucket, so
        varying burst sizes reuse the jitted framing pass."""
        # deferred: kernels.frame_pack imports fabric.frames (no cycle at
        # module load, but keep package init order independent)
        from ..kernels.ops import encode_frames_batch

        B = payloads.shape[0]
        Bp = 1 << max(B - 1, 0).bit_length()
        if Bp > B:
            payloads = np.pad(payloads, ((0, Bp - B), (0, 0)))
            nbytes = np.pad(nbytes, (0, Bp - B))
            routes = np.pad(routes, ((0, Bp - B), (0, 0)))
        frames, _ = encode_frames_batch(
            jnp.asarray(payloads), jnp.asarray(nbytes), jnp.asarray(routes),
            list_level=list_level, frame_phits=phits, adaptive=adaptive,
        )
        return np.asarray(frames[:B])

    # -- receive side ------------------------------------------------------

    @staticmethod
    def _split_bucketed(flat: np.ndarray):
        """Split delivered frames into (headers, payloads) via the Pallas RX
        kernel, with the row count padded to a pow2 bucket for jit reuse."""
        from ..kernels.ops import decode_frames_batch

        N = flat.shape[0]
        Np = 1 << max(N - 1, 0).bit_length()
        hdr, pay = decode_frames_batch(
            jnp.asarray(np.pad(flat, ((0, Np - N), (0, 0))))
        )
        return np.asarray(hdr[:N]), np.asarray(pay[:N])

    def _reassemble(
        self, rank: int, hdrs: np.ndarray, pays: np.ndarray,
        steps: Optional[np.ndarray] = None,
        atts: Optional[np.ndarray] = None,
    ) -> None:
        """Order a rank's delivered frames per source and cut messages at
        the end-of-list terminators."""
        if steps is None:
            steps = np.zeros(len(hdrs), np.int32)
        if atts is None:
            atts = np.zeros(
                (len(hdrs), n_att(len(self.router.axis_names))), np.int32
            )
        srcs = (hdrs[:, HDR_ROUTE] >> 24) & 0x7F  # bit 31 = adaptive flag
        for src in sorted(set(int(s) for s in srcs)):
            sel = srcs == src
            mh, mp, ms, ma = hdrs[sel], pays[sel], steps[sel], atts[sel]
            base = self._rx_seq[rank][src]
            seqs = (mh[:, HDR_ROUTE] & 0xFFFF).astype(np.int64)
            order = np.argsort((seqs - base) % SEQ_MOD)
            part = self._partial[rank][src]
            expected = base
            for j in order:
                size = int(mh[j, HDR_SIZE])
                part.level = int(mh[j, HDR_LEVEL])
                if part.seq0 is None:
                    part.seq0 = int(seqs[j])
                # the message's attribution is its CRITICAL frame's — the
                # one that arrived last (ties: the later seq wins; equal
                # steps mean equal component sums)
                sj = int(ms[j])
                if part.att is None or sj >= part.step:
                    part.att = ma[j].copy()
                # scan steps restart at 0 each tick, but a message's frames
                # all ride ONE tick (exchange frames every pending send
                # together), so the max is within-tick; a partial spanning
                # ticks means lost frames and the message is flagged anyway
                part.step = max(part.step, sj)
                # CRC covers size | level | route | payload (frames.py)
                covered = np.concatenate(
                    [mh[j, [HDR_SIZE, HDR_LEVEL, HDR_ROUTE]], mp[j]]
                )
                if int(mh[j, HDR_CRC]) != zlib.crc32(covered.tobytes()):
                    part.ok = False
                    part.crc_bad = True
                if int(seqs[j]) != expected:
                    # gap in the stream (lost/misrouted frame): the message
                    # around it cannot be trusted
                    part.ok = False
                    part.seq_gap = True
                expected = (int(seqs[j]) + 1) % SEQ_MOD
                if size == 0:  # terminator: message complete
                    self._deliver(rank, src, part)
                    self._partial[rank][src] = part = _PartialMsg()
                else:
                    part.data.extend(mp[j].tobytes()[:size])
            self._rx_seq[rank][src] = expected

    def _deliver(self, rank: int, src: int, part: _PartialMsg) -> None:
        """Finalize one reassembled message: attach its flight-recorder
        attribution and (when the sender tagged it) its request id, emit
        the span events, and append the Delivery to the rank's inbox."""
        n_axes = len(self.router.axis_names)
        att = FrameAttribution.from_vector(
            n_axes, part.att if part.att is not None else [0] * n_att(n_axes)
        )
        rid = self._match_rid(rank, src, part.seq0)
        self._inbox[rank].append(
            Delivery(src, bytes(part.data), part.ok, part.level, part.step,
                     attribution=att, request_id=rid)
        )
        self._record_arrive(rank, part.level, part.step, att)
        if self.spans is None:
            return
        if rid is not None:
            self.spans.event(
                rid, "fabric.deliver", pid=rank,
                src=src, dst=rank, arrive_step=part.step,
                **att.components(),
            )
            for name, v in att.components().items():
                self.spans.add_component(rid, f"fabric.{name}", v)
            if not part.ok:
                reasons = [r for r, bad in
                           (("crc", part.crc_bad), ("seq-gap", part.seq_gap))
                           if bad]
                self.spans.degrade(rid, ",".join(reasons) or "corrupt",
                                   src=src, dst=rank)
        elif not part.ok:
            # a corrupted message that cannot be correlated back to its
            # request (e.g. its first frame's route word was mangled) must
            # surface as a tracker anomaly, never vanish silently
            self.spans.anomaly(
                "fabric.deliver.unmatched", src=src, dst=rank,
                seq0=part.seq0, crc=part.crc_bad, seq_gap=part.seq_gap,
            )

    def _match_rid(self, rank: int, src: int,
                   seq0: Optional[int]) -> Optional[int]:
        """Match a reassembled message's first-frame seq into the pending
        (src -> rank) rid ranges recorded at dispatch (wrap-aware)."""
        spans = self._send_spans.get((rank, src))
        if not spans or seq0 is None:
            return None
        for i, (s0, n, rid) in enumerate(spans):
            if (seq0 - s0) % SEQ_MOD < n:
                spans.pop(i)
                return rid
        return None

    def drain(self, rank: int) -> List[Delivery]:
        out, self._inbox[rank] = self._inbox[rank], []
        return out

    # -- telemetry folds (the host half of the obs plane) ------------------

    def _note_expected(self, sends, n_live) -> None:
        """Fold this tick's STATIC per-(link, direction) demand —
        ``analysis.comm.demand_link_loads`` of exactly the sends being
        dispatched — into the accumulated expected-load matrix."""
        from ..analysis.comm import demand_link_loads

        loads = demand_link_loads(
            self.router.sizes,
            [s for s, _, _, _ in sends],
            [d for _, d, _, _ in sends],
            n_live,
            self.config.adaptive,
        )
        for ai, group in enumerate(loads):
            acc = self._expected_loads[ai]
            for key, ll in group.items():
                acc[key] = acc.get(key, 0) + ll.frames

    def _fold_counters(self, ctr: np.ndarray, kind: str, meta: dict) -> None:
        """Fold one tick's per-rank on-device counter block into the
        all-time totals, the per-tick delta window, and the metrics
        registry (plus the trace timeline when one is attached)."""
        delta = ctr.astype(np.int64)
        self._ctr_total += delta
        self._ctr_window.append(delta)
        axes = self.router.axis_names
        tot = delta.sum(axis=0)
        m = self.metrics
        m.counter("fabric.ticks", engine=kind).add(1)
        m.counter("fabric.frames.delivered").add(
            int(tot[global_index(len(axes), "delivered")])
        )
        m.counter("fabric.crc.failures").add(
            int(tot[global_index(len(axes), "crc_fail")])
        )
        for ai, axis in enumerate(axes):
            for di, dname in enumerate(DIR_SLOTS):
                for fname in CTR_FIELDS:
                    v = int(tot[ctr_index(ai, di, fname)])
                    if v:
                        m.counter(f"fabric.link.{fname}",
                                  axis=axis, dir=dname).add(v)
        if self.trace is not None:
            t0 = meta.get("t0", 0.0)
            self.trace.complete(
                "fabric.tick", t0, self.trace.now_us() - t0, cat="fabric",
                args={
                    "engine": kind,
                    "frames": meta.get("frames", 0),
                    "sends": meta.get("sends", 0),
                    "delivered": int(
                        tot[global_index(len(axes), "delivered")]
                    ),
                },
            )

    def counters_total(self) -> np.ndarray:
        """All-time per-rank on-device counter block, ``(ranks,
        n_counters)`` int64 in the ``repro.obs.counters`` layout."""
        return self._ctr_total.copy()

    def observed_link_loads(self, window: Optional[int] = None):
        """The OBSERVED per-(link, direction) load matrix, folded from the
        on-device ``entered`` counters and keyed exactly like the static
        ``analysis.comm.demand_link_loads`` matrix.  ``window`` restricts
        the fold to the most recent N ticks (the live view ROADMAP item 4's
        self-tuning consumes); default is all-time."""
        if window is not None:
            ticks = list(self._ctr_window)[-window:]
            delta = (
                np.sum(ticks, axis=0) if ticks
                else np.zeros_like(self._ctr_total)
            )
        else:
            delta = self._ctr_total
        return _observed_link_loads(self.router.sizes, delta)

    def expected_link_loads(self):
        """Accumulated static demand matrix of every dispatched tick (the
        expected side of the drift check), per-axis ``{(ring, dir):
        frames}``."""
        return tuple(dict(g) for g in self._expected_loads)

    def load_drift(self) -> Dict[Tuple, Tuple[int, int]]:
        """Static-vs-observed load divergence: empty dict when every frame
        rode exactly the link the analyzer predicted; a dropped, misrouted
        or defected frame shows up as ``{(axis, ring, dir): (expected,
        observed)}``.  Deterministic workloads without defection must see
        ``{}`` — property-tested."""
        return _load_drift(self.expected_link_loads(),
                           self.observed_link_loads())

    # -- congestion observability -----------------------------------------

    @property
    def n_classes(self) -> int:
        """QoS credit classes the router schedules (1 = single-class FIFO)."""
        return len(self.config.qos_weights) if self.config.qos_weights else 1

    def _record_arrive(self, rank: int, level: int, step: int,
                       att: Optional[FrameAttribution] = None) -> None:
        cls = level % self.n_classes
        self._arrive[rank].record(cls, step)
        self.metrics.histogram("fabric.arrive.step", cls=cls).observe(step)
        if att is not None:
            # latency-attribution histograms (flight recorder fold): where
            # each message's in-fabric time went, by QoS class
            for name, v in att.components().items():
                self.metrics.histogram(
                    f"fabric.attr.{name}", cls=cls
                ).observe(v)

    def class_arrive_stats(self, rank: int) -> Dict[int, Dict[str, float]]:
        """Per-QoS-class arrive-step percentiles of the messages recently
        delivered to ``rank`` (sliding window of 256 per class): ``{class:
        {n, mean, p95, max, jitter}}`` — the congestion signal a
        backpressure-fed sender (``stream.plane.ChunkLane``) clamps on.
        Classes key as ``list_level % n_classes``, matching the router's
        WRR credit scheduler.  The window math is ``obs.metrics``'s shared
        implementation — byte-identical to ``StreamReader``'s, so the two
        ends of the feedback loop can never disagree on "p95"."""
        return self._arrive[rank].stats()


class Mailbox:
    """Per-rank send/recv endpoint on a :class:`Fabric`."""

    def __init__(self, fabric: Fabric, rank: int):
        self.fabric = fabric
        self.rank = rank

    def send(self, dst: int, wire: bytes, list_level: int = 1,
             request_id: Optional[int] = None) -> None:
        """Queue a whole HGum wire for delivery to ``dst`` (routed, framed).

        ``request_id`` tags the message with an obs.spans span id; the
        receiver's Delivery carries it back (see :meth:`Fabric.send`)."""
        self.fabric.send(self.rank, dst, wire, list_level,
                         request_id=request_id)

    def recv(self) -> List[Delivery]:
        """Drain messages delivered to this rank (run ``exchange`` first)."""
        return self.fabric.drain(self.rank)

    def arrive_stats(self) -> Dict[int, Dict[str, float]]:
        """Per-QoS-class arrive-step percentiles of this rank's recent
        deliveries (see :meth:`Fabric.class_arrive_stats`)."""
        return self.fabric.class_arrive_stats(self.rank)
