"""Routed message fabric: multi-hop framed transport between mesh ranks.

The HGum paper frames Lists so neither side of a HW-to-HW link needs to
buffer a whole message (§IV-C); this package generalizes that link into a
*network*: frames carry a ``(src, dst, seq)`` route word, a :class:`Router`
delivers them across arbitrary hop counts by composing ``ppermute`` steps
(dimension-ordered on 2D meshes) under credit-based flow control, and a
:class:`Mailbox` gives whole-message ``send(dst, wire)`` / ``recv()`` with
CRC32 verification and terminator-delimited reassembly.

Layers (each importable on its own):

* ``frames``  — wire format: CRC32, route words, frame/unframe (shared with
  ``runtime.channels``);
* ``router``  — device-side multi-hop delivery (shard_map + ppermute scan);
* ``mailbox`` — host-side message API over the router (plus the ARQ
  retransmission layer, ``FabricConfig(arq=True)``);
* ``faults``  — seeded deterministic chaos injection (:class:`FaultPlan`),
  applied identically to both tick engines.
"""
from .faults import FaultPlan, parse_chaos
from .frames import (
    ADAPTIVE_BIT,
    FRAME_PHITS,
    HDR_WORDS,
    MAX_RANKS,
    PHIT_WORDS,
    SEQ_MOD,
    crc32_words,
    frame_capacity,
    frame_parts,
    frame_parts_batch,
    frame_stream,
    pack_route,
    route_adaptive,
    route_dst,
    route_seq,
    route_src,
    unframe_stream,
    unpack_route,
    verify_frames,
)
from .mailbox import Delivery, Fabric, FabricCorruption, Mailbox
from .router import FabricConfig, Router

__all__ = [
    "FaultPlan", "parse_chaos", "FabricCorruption",
    "ADAPTIVE_BIT", "FRAME_PHITS", "HDR_WORDS", "MAX_RANKS", "PHIT_WORDS",
    "SEQ_MOD", "crc32_words", "frame_capacity", "frame_parts",
    "frame_parts_batch", "frame_stream", "pack_route", "route_adaptive",
    "route_dst", "route_seq", "route_src", "unframe_stream", "unpack_route",
    "verify_frames",
    "Delivery", "Fabric", "Mailbox", "FabricConfig", "Router",
]
