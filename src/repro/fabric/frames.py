"""Wire-level framing core shared by the fabric and the point-to-point
channels (``runtime.channels``).

The paper's §IV-C HW-to-HW frame header carries ``(size, ListLevel)``.  Two
extensions live here so every framed path uses ONE implementation:

* **CRC32** — a real CRC-32 (IEEE 802.3, the zlib polynomial) replaces the
  seed's additive checksum.  The additive sum is blind to byte reorders
  (``a+b == b+a``); CRC32 is not.  Implemented slicing-by-4: one 256-entry
  table per input byte lane, one scan step per u32 word, so a whole frame
  checksums in ``frame_words`` sequential steps instead of ``4x`` that.
* **route word** — the fourth header word becomes ``(adaptive, src, dst,
  seq)`` packed ``adaptive:u1 | src:u7 | dst:u8 | seq:u16`` so a frame is
  self-routing: any hop can read its destination without out-of-band state,
  and the receiver can reorder interleaved frames per source by ``seq``.
  ``seq`` increments per frame (not per message) and wraps at 2**16.  The
  top ``adaptive`` bit marks a frame as free to take the *shortest* ring
  direction on each axis (go -1 when the +1 distance exceeds half the
  ring); with the bit clear the frame rides the legacy +1 ring only, so
  both routing disciplines coexist on the same wire format.

Frame layout (u32 words)::

    [ size | list_level | crc32 | route ] [ payload ... frame_words ]

The CRC is computed over ``size | list_level | route | payload`` (every
word of the frame except the CRC slot itself), so header corruption — a
flipped size, level, or destination byte — is as detectable as payload
corruption.  ``size`` is the true payload byte count of the frame; a size-0
frame is the end-of-list terminator (paper rule) and doubles as the
end-of-message marker for fabric sends.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: paper §V: 128-bit phits; frame = up to 500 phits (Altera 512-deep BRAM).
PHIT_WORDS = 4  # 16 B in u32 lanes
FRAME_PHITS = 500
HDR_WORDS = 4  # size, list_level, crc32, route -> one phit

#: header word indices
HDR_SIZE, HDR_LEVEL, HDR_CRC, HDR_ROUTE = 0, 1, 2, 3


def _crc32_tables() -> np.ndarray:
    """Slicing-by-4 CRC-32 tables, (4, 256) uint32.

    ``T[0]`` is the classic byte-at-a-time table; ``T[k]`` advances a byte
    through ``k`` extra zero bytes, so one u32 word folds in a single step:
    ``crc' = T3[b0^crc] ^ T2[b1^(crc>>8)] ^ T1[b2^(crc>>16)] ^ T0[b3^(crc>>24)]``.
    """
    poly = np.uint32(0xEDB88320)
    t0 = np.zeros(256, np.uint64)
    for i in range(256):
        c = np.uint64(i)
        for _ in range(8):
            c = (c >> np.uint64(1)) ^ (np.uint64(poly) if c & np.uint64(1) else np.uint64(0))
        t0[i] = c
    tables = np.zeros((4, 256), np.uint64)
    tables[0] = t0
    for k in range(1, 4):
        tables[k] = t0[tables[k - 1] & np.uint64(0xFF)] ^ (tables[k - 1] >> np.uint64(8))
    return tables.astype(np.uint32)


_CRC_TABLES = _crc32_tables()


def crc32_words(words: jnp.ndarray) -> jnp.ndarray:
    """CRC-32 (zlib-compatible) of the little-endian bytes of a u32 vector.

    Matches ``zlib.crc32(words.tobytes())`` for ``words`` viewed as LE u32.
    One scan step per word (slicing-by-4).
    """
    t = jnp.asarray(_CRC_TABLES)  # (4, 256)

    def step(crc, w):
        b0 = (w ^ crc) & 0xFF
        b1 = ((w >> 8) ^ (crc >> 8)) & 0xFF
        b2 = ((w >> 16) ^ (crc >> 16)) & 0xFF
        b3 = ((w >> 24) ^ (crc >> 24)) & 0xFF
        crc = t[3, b0] ^ t[2, b1] ^ t[1, b2] ^ t[0, b3]
        return crc, None

    crc, _ = jax.lax.scan(step, jnp.uint32(0xFFFFFFFF), words.astype(jnp.uint32))
    return crc ^ jnp.uint32(0xFFFFFFFF)


# ---------------------------------------------------------------------------
# route word
# ---------------------------------------------------------------------------

MAX_RANKS = 128  # src is a u7 lane (bit 31 = adaptive flag); dst is u8
SEQ_MOD = 1 << 16
ADAPTIVE_BIT = 1 << 31  # route-word flag: frame may take the -1 direction


def route_word_budget() -> dict:
    """Static lane widths of the frame header (the budgets the
    ``repro.analysis`` fabric pass checks configs/demands against):
    the u32 route word packs ``adaptive:u1|src:u7|dst:u8|seq:u16`` and
    the ListLevel header word carries a u8 lane."""
    return {
        "adaptive_bits": 1,
        "src_bits": 7,
        "dst_bits": 8,
        "seq_bits": 16,
        "level_bits": 8,
        "max_ranks": MAX_RANKS,
        "seq_mod": SEQ_MOD,
        "max_list_level": 255,
    }


def pack_route(src, dst, seq, adaptive: bool = False) -> jnp.ndarray:
    """(src, dst, seq) -> u32 route word ``adaptive:u1|src:u7|dst:u8|seq:u16``.

    ``adaptive`` (static) sets the shortest-path flag: the router may move
    the frame in the -1 ring direction on an axis when that way is shorter.
    """
    src = jnp.asarray(src, jnp.uint32) & 0x7F
    dst = jnp.asarray(dst, jnp.uint32) & 0xFF
    seq = jnp.asarray(seq, jnp.uint32) & 0xFFFF
    word = (src << 24) | (dst << 16) | seq
    if adaptive:
        word = word | jnp.uint32(ADAPTIVE_BIT)
    return word


def unpack_route(word: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    word = jnp.asarray(word, jnp.uint32)
    return (word >> 24) & 0x7F, (word >> 16) & 0xFF, word & 0xFFFF


def route_src(frames: jnp.ndarray) -> jnp.ndarray:
    """(…, width) frames -> (…,) src rank (int32)."""
    return ((frames[..., HDR_ROUTE] >> 24) & 0x7F).astype(jnp.int32)


def route_dst(frames: jnp.ndarray) -> jnp.ndarray:
    return ((frames[..., HDR_ROUTE] >> 16) & 0xFF).astype(jnp.int32)


def route_seq(frames: jnp.ndarray) -> jnp.ndarray:
    return (frames[..., HDR_ROUTE] & 0xFFFF).astype(jnp.int32)


def route_adaptive(frames: jnp.ndarray) -> jnp.ndarray:
    """(…, width) frames -> (…,) bool: shortest-path routing allowed."""
    return (frames[..., HDR_ROUTE] >> 31) != 0


# ---------------------------------------------------------------------------
# framing / unframing (pure jnp, static frame capacity)
# ---------------------------------------------------------------------------


def frame_parts(
    payload_u32: jnp.ndarray,  # (W,) u32 — serialized list data (padded cap)
    nbytes: jnp.ndarray,  # true byte length (traced)
    list_level=1,  # int or traced scalar
    frame_phits: int = FRAME_PHITS,
    route: Optional[Tuple] = None,  # (src, dst, seq0) scalars, or None
    adaptive: bool = False,  # stamp the shortest-path route-word flag
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Structure half of framing: (headers (F, HDR_WORDS), masked payload
    (F, frame_words), n_frames).  ``frame_stream`` concatenates the two; the
    Pallas ``pack_frames_batch`` kernel assembles them on-device instead.
    """
    frame_words = frame_phits * PHIT_WORDS
    W = payload_u32.shape[0]
    F = -(-W // frame_words) + 1  # + terminator
    pad = F * frame_words - W
    data = jnp.pad(payload_u32, (0, pad)).reshape(F, frame_words)
    word_len = (nbytes + 3) // 4
    start = jnp.arange(F, dtype=jnp.int32) * frame_words
    remaining = jnp.maximum(word_len - start, 0)
    words_in = jnp.minimum(remaining, frame_words)  # (F,)
    bytes_in = jnp.minimum(jnp.maximum(nbytes - start * 4, 0), frame_words * 4)
    # zero tail garbage inside each frame
    col = jnp.arange(frame_words, dtype=jnp.int32)[None, :]
    data = jnp.where(col < words_in[:, None], data, 0)
    if route is None:
        route_words = jnp.zeros((F,), jnp.uint32)
    else:
        src, dst, seq0 = route
        seq = (jnp.asarray(seq0, jnp.uint32) + jnp.arange(F, dtype=jnp.uint32)) % SEQ_MOD
        route_words = pack_route(src, dst, seq, adaptive=adaptive)
    sizes = bytes_in.astype(jnp.uint32)
    levels = jnp.broadcast_to(jnp.asarray(list_level, jnp.uint32), (F,))
    # CRC covers the OTHER header words too (size, level, route) — a flipped
    # size or dst byte must be as detectable as a flipped payload byte
    crc = jax.vmap(crc32_words)(_crc_input(sizes, levels, route_words, data))
    hdr = jnp.stack([sizes, levels, crc, route_words], axis=1)
    n_frames = jnp.sum(words_in > 0) + 1  # + empty terminator
    return hdr, data, n_frames


def _crc_input(sizes, levels, routes, data) -> jnp.ndarray:
    """Words the frame CRC is computed over: size | level | route | payload."""
    return jnp.concatenate(
        [sizes[:, None], levels[:, None], routes[:, None], data], axis=1
    ).astype(jnp.uint32)


def frame_stream(
    payload_u32: jnp.ndarray,
    nbytes: jnp.ndarray,
    list_level: int = 1,
    frame_phits: int = FRAME_PHITS,
    route: Optional[Tuple] = None,
    adaptive: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cut a byte stream into frames.

    Returns (frames, n_frames): frames (F, HDR_WORDS + frame_words) u32 with
    per-frame headers; F is the static capacity bound incl. the empty
    end-of-list terminator frame.  With ``route`` set, every frame carries a
    ``(src, dst, seq0 + i)`` route word (terminator included) so the fabric
    can deliver and reorder it.
    """
    hdr, data, n_frames = frame_parts(
        payload_u32, nbytes, list_level, frame_phits, route, adaptive=adaptive
    )
    return jnp.concatenate([hdr, data], axis=1), n_frames


def frame_parts_batch(
    payloads_u32: jnp.ndarray,  # (B, Wcap) u32
    nbytes: jnp.ndarray,  # (B,) int32
    routes: jnp.ndarray,  # (B, 3) int32 — (src, dst, seq0) per stream
    list_level=1,  # int, or (B,) per-stream ListLevels (traced)
    frame_phits: int = FRAME_PHITS,
    adaptive: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Batched ``frame_parts`` for multi-destination sends: one vectorized
    structure pass over B streams.  ``list_level`` may be a (B,) array so a
    mixed-tenant burst serializes in ONE pass (the fused tick path).
    Returns (headers (B, F, HDR_WORDS), payload (B, F, frame_words),
    n_frames (B,))."""
    B = payloads_u32.shape[0]
    levels = jnp.broadcast_to(jnp.asarray(list_level, jnp.uint32), (B,))
    fn = lambda p, nb, r, lv: frame_parts(
        p, nb, lv, frame_phits, route=(r[0], r[1], r[2]), adaptive=adaptive
    )
    return jax.vmap(fn)(
        payloads_u32, jnp.asarray(nbytes), jnp.asarray(routes), levels
    )


def verify_frames(frames: jnp.ndarray) -> jnp.ndarray:
    """Per-frame CRC check (headers included): (…, F, width) -> (…, F) bool."""
    flat = frames.reshape(-1, frames.shape[-1])
    got = jax.vmap(crc32_words)(
        _crc_input(flat[:, HDR_SIZE], flat[:, HDR_LEVEL], flat[:, HDR_ROUTE],
                   flat[:, HDR_WORDS:])
    )
    ok = got == flat[:, HDR_CRC]
    return ok.reshape(frames.shape[:-1])


def unframe_stream(
    frames: jnp.ndarray, verify: bool = True
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Frames -> (payload_u32 (W,), nbytes, ok).  Zeroed past the true end."""
    F, width = frames.shape
    hdr = frames[:, :HDR_WORDS]
    data = frames[:, HDR_WORDS:]
    bytes_in = hdr[:, HDR_SIZE].astype(jnp.int32)
    ok = jnp.array(True)
    if verify:
        ok = jnp.all(verify_frames(frames))
    # terminator = first frame with size 0; ignore frames after it
    is_end = bytes_in == 0
    first_end = jnp.argmax(is_end)  # frames are contiguous by construction
    live = jnp.arange(F) < first_end
    nbytes = jnp.sum(jnp.where(live, bytes_in, 0))
    payload = jnp.where(live[:, None], data, 0).reshape(-1)
    return payload, nbytes, ok


def frame_capacity(wire_bytes: int, frame_phits: int) -> int:
    """Frames emitted for a wire of ``wire_bytes`` (incl. the terminator)."""
    frame_words = frame_phits * PHIT_WORDS
    words = -(-wire_bytes // 4)
    return -(-words // frame_words) + 1  # 0 bytes -> terminator only
