"""Deterministic chaos injection for the message fabric.

A :class:`FaultPlan` is a *seeded, stateless* description of link faults —
drop, payload corruption, header corruption, duplication, reordering, and
rank blackout windows — that both fabric tick engines (the fused
single-jit tick and the three-program tick) consume at the same logical
point: after frames are framed and laid out for transmission, before the
routed scan sees them.  Every fault decision is a pure function of
``(seed, tick, src, dst, seq)``, so

* the same plan produces the same faults on the fused and three-program
  paths (the engine-parity regression gate in ``tests/test_reliability.py``
  relies on this),
* a retransmitted frame gets a *fresh* tick value and therefore an
  independent fault roll — recovery is possible, and
* any recovery claim in a test or CI log is reproducible from the seed.

The plan operates on **logical frames**: each dispatch presents its
per-rank ordered frame list as ``(src, dst, seq, frame_index_in_message)``
tuples and receives back an ordered list of :class:`FrameOp`\\ s — keep,
drop, xor-a-word, duplicate — plus an optional permutation.  The engines
map ops back onto their own memory layouts; relative order per rank is
preserved, so injection dynamics (and the router's counters) match
bit-for-bit across engines.

Header corruption flips the ``list_level`` header word — guaranteed CRC
failure *without* touching the route word (a corrupted destination could
leave the mesh and abort the whole tick instead of exercising recovery).

``parse_chaos("drop=0.02,corrupt=0.01")`` builds a plan from the CLI
syntax used by ``--chaos`` on the serve entry points.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from .frames import HDR_LEVEL, HDR_WORDS

__all__ = ["FaultPlan", "FrameOp", "parse_chaos"]


def _mix(*vals: int) -> int:
    """Stateless 64-bit integer hash (splitmix64 finalizer over a fold)."""
    h = 0x9E3779B97F4A7C15
    for v in vals:
        h ^= (v & 0xFFFFFFFFFFFFFFFF) * 0xBF58476D1CE4E5B9
        h &= 0xFFFFFFFFFFFFFFFF
        h ^= h >> 30
        h *= 0x94D049BB133111EB
        h &= 0xFFFFFFFFFFFFFFFF
        h ^= h >> 31
    return h


def _unit(*vals: int) -> float:
    """Deterministic uniform float in [0, 1) from the hashed key."""
    return (_mix(*vals) >> 11) / float(1 << 53)


@dataclass(frozen=True)
class FrameOp:
    """One fault decision on one logical frame.

    ``kind``: ``"keep"`` | ``"drop"`` | ``"corrupt"`` | ``"dup"``.
    ``word``/``xor`` describe the corruption (word index into the frame,
    value XORed in); a ``dup`` keeps the original AND inserts a copy
    immediately after it.
    """

    kind: str
    index: int  # position in the rank's pre-fault ordered frame list
    word: int = 0
    xor: int = 0


@dataclass(frozen=True)
class FaultPlan:
    """Seeded per-link fault rates.  All rates are per-frame probabilities
    in [0, 1]; decisions are independent per (tick, src, dst, seq) so a
    retransmit re-rolls.  ``blackout_rank`` drops every frame to or from
    that rank while ``blackout_from <= tick < blackout_from +
    blackout_ticks`` — the "rank goes dark for k ticks" scenario the
    failure-aware serve plane must survive.

    ``link_rates`` / ``rank_rates`` override the global ``drop`` rate for
    specific ``(src, dst)`` links / source ranks (the starved-link and
    flaky-link benchmarks use these).
    """

    seed: int = 0
    drop: float = 0.0
    corrupt: float = 0.0  # payload word XOR -> CRC failure
    corrupt_header: float = 0.0  # list_level word XOR -> CRC failure
    duplicate: float = 0.0
    reorder: float = 0.0  # probability a rank's tick frame list is shuffled
    blackout_rank: Optional[int] = None
    blackout_from: int = 0
    blackout_ticks: int = 0
    link_rates: Dict[Tuple[int, int], float] = field(default_factory=dict)
    rank_rates: Dict[int, float] = field(default_factory=dict)

    def with_seed(self, seed: int) -> "FaultPlan":
        return replace(self, seed=seed)

    # -- per-frame decisions ------------------------------------------------

    def _blacked_out(self, tick: int, src: int, dst: int) -> bool:
        if self.blackout_rank is None or self.blackout_ticks <= 0:
            return False
        if not (self.blackout_from <= tick < self.blackout_from + self.blackout_ticks):
            return False
        return src == self.blackout_rank or dst == self.blackout_rank

    def _drop_rate(self, src: int, dst: int) -> float:
        r = self.link_rates.get((src, dst))
        if r is None:
            r = self.rank_rates.get(src)
        return self.drop if r is None else r

    def frame_ops(
        self,
        tick: int,
        frames: Sequence[Tuple[int, int, int, int]],
        dup_budget: int = 0,
    ) -> Tuple[List[FrameOp], Optional[List[int]]]:
        """Fault decisions for ONE rank's ordered tick frame list.

        ``frames`` is the rank's pre-fault transmit order as ``(src, dst,
        seq, frame_idx)`` tuples.  Returns ``(ops, perm)``: one op per
        input frame in order (``dup`` ops insert after their original),
        and ``perm`` — a seeded permutation of the *post-fault* list when
        this rank's tick reorders, else None.  ``dup_budget`` caps how many
        duplicates may be inserted (the engines pass their spare transmit
        rows; 0 disables duplication for this rank's tick).
        """
        ops: List[FrameOp] = []
        dups = 0
        words = 0  # post-fault frame count, for the permutation below
        for i, (src, dst, seq, fidx) in enumerate(frames):
            key = (self.seed, tick, src, dst, seq, fidx)
            if self._blacked_out(tick, src, dst):
                ops.append(FrameOp("drop", i))
                continue
            if _unit(*key, 1) < self._drop_rate(src, dst):
                ops.append(FrameOp("drop", i))
                continue
            if _unit(*key, 2) < self.corrupt:
                # flip a payload word; which one is itself seeded
                w = HDR_WORDS + _mix(*key, 3) % 4
                ops.append(FrameOp("corrupt", i, word=w,
                                   xor=0x5A5A0000 | (_mix(*key, 4) & 0xFFFF)))
            elif _unit(*key, 5) < self.corrupt_header:
                ops.append(FrameOp("corrupt", i, word=HDR_LEVEL,
                                   xor=0x00A50000))
            elif self.duplicate and dups < dup_budget \
                    and _unit(*key, 6) < self.duplicate:
                ops.append(FrameOp("dup", i))
                dups += 1
                words += 1
            else:
                ops.append(FrameOp("keep", i))
            words += 1
        perm: Optional[List[int]] = None
        if self.reorder and words > 1 and frames:
            src0 = frames[0][0]
            if _unit(self.seed, tick, src0, 0, 0, 0, 7) < self.reorder:
                # seeded Fisher-Yates over the post-fault positions
                perm = list(range(words))
                for j in range(words - 1, 0, -1):
                    k = _mix(self.seed, tick, src0, j, 8) % (j + 1)
                    perm[j], perm[k] = perm[k], perm[j]
        return ops, perm

    @property
    def active(self) -> bool:
        """False when the plan can never produce a fault (all rates 0)."""
        return bool(
            self.drop or self.corrupt or self.corrupt_header
            or self.duplicate or self.reorder or self.link_rates
            or self.rank_rates
            or (self.blackout_rank is not None and self.blackout_ticks > 0)
        )


_CHAOS_KEYS = {
    "drop": float, "corrupt": float, "corrupt_header": float,
    "duplicate": float, "reorder": float,
    "blackout_rank": int, "blackout_from": int, "blackout_ticks": int,
}


def parse_chaos(spec: str, seed: int = 0) -> FaultPlan:
    """Parse the ``--chaos`` CLI syntax: ``"drop=0.02,corrupt=0.01"``.

    Keys: drop, corrupt, corrupt_header, duplicate, reorder (rates in
    [0, 1]); blackout_rank, blackout_from, blackout_ticks (ints).
    """
    kwargs: Dict[str, object] = {"seed": seed}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        if "=" not in part:
            raise ValueError(f"chaos spec entry {part!r} is not key=value")
        k, v = (s.strip() for s in part.split("=", 1))
        if k not in _CHAOS_KEYS:
            raise ValueError(
                f"unknown chaos key {k!r} (known: {sorted(_CHAOS_KEYS)})"
            )
        kwargs[k] = _CHAOS_KEYS[k](v)
    return FaultPlan(**kwargs)
