"""Multi-hop frame router over the JAX device mesh.

The seed's ``pod_ring_exchange`` moves frames exactly one hop between ring
neighbours; every multi-device pattern had to be hand-wired out of single
hops.  This module generalizes it to a packet-switched fabric in the spirit
of "Framework for Application Mapping over Packet-Switched Network of
FPGAs": frames carry a ``(src, dst, seq)`` route word (``frames.py``) and a
:class:`Router` delivers them to arbitrary ranks by composing
``jax.lax.ppermute`` steps.

Topology and algorithm
----------------------
* Ranks are the row-major flattening of the mesh coordinates along
  ``axis_names`` (so a ``(4, 2)`` x/y mesh has ``rank = x*2 + y``).
* **Dimension-ordered routing**: frames first travel along the first axis
  (+1 ring direction) until their destination coordinate on that axis
  matches, then along the next axis, and so on — deadlock-free and
  deterministic, the standard mesh/torus discipline.
* **Credit-based flow control**: each link carries at most
  ``config.credits`` frames per step (the paper's bounded-BRAM
  back-pressure analog).  Frames that cannot be injected wait in a
  per-device queue; transiting frames have priority over fresh injections,
  which preserves per-source FIFO order along a path.
* **QoS credit classes** (``config.qos_weights``): instead of handing the
  per-link credits to the frontmost frames FIFO, the inject step can run
  *weighted round-robin* over credit classes keyed by the frame's
  ``ListLevel`` (``class = level % n_classes``).  Each class holds a static
  quota of the link credits (largest-remainder split of the weights) and
  unused quota spills to the other classes in queue order, so the scheduler
  stays work-conserving: a noisy tenant saturating a link cannot starve
  another tenant's frames, yet idle classes cost nothing.  ``deliver``
  additionally reports the scan step at which every frame arrived
  (``rx_step``), which makes in-tick queueing delay — and therefore
  starvation — observable to the mailbox layer.
* Every step is one ``ppermute`` of a ``(credits, width)`` link buffer
  inside a ``lax.scan``; the step count is a static worst-case bound
  (pipeline fill + total frames over the busiest possible link), so the
  whole delivery jits to one XLA program with no host round-trips.

The router works on *stacked* buffers — ``tx`` is ``(ranks, T, width)``
sharded over the mesh axes — matching the repo's shard_map test idiom.
Higher-level message semantics (reassembly, per-message corruption flags)
live in ``mailbox.py``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .frames import (
    HDR_LEVEL,
    HDR_WORDS,
    MAX_RANKS,
    PHIT_WORDS,
    route_dst,
    verify_frames,
)


@dataclass(frozen=True)
class FabricConfig:
    """Knobs of the routed fabric."""

    frame_phits: int = 16  # payload phits per frame
    credits: int = 4  # max in-flight frames per link per step
    rx_frames: Optional[int] = None  # per-rank delivery capacity (default R*T)
    #: weighted round-robin credit classes at the inject step, keyed by
    #: ``ListLevel % len(qos_weights)``.  None = single-class FIFO (legacy).
    qos_weights: Optional[Tuple[int, ...]] = None

    def __post_init__(self) -> None:
        if self.frame_phits < 1 or self.credits < 1:
            raise ValueError(
                f"frame_phits/credits must be >= 1, got "
                f"{self.frame_phits}/{self.credits}"
            )
        if self.qos_weights is not None:
            if len(self.qos_weights) < 1 or any(
                w < 1 for w in self.qos_weights
            ):
                raise ValueError(
                    f"qos_weights must be positive, got {self.qos_weights}"
                )
            if self.credits < len(self.qos_weights):
                raise ValueError(
                    f"need credits >= qos classes so every class holds at "
                    f"least one credit, got credits={self.credits} for "
                    f"{len(self.qos_weights)} classes"
                )

    @property
    def frame_width(self) -> int:
        return HDR_WORDS + self.frame_phits * PHIT_WORDS


def qos_quotas(credits: int, weights: Sequence[int]) -> Tuple[int, ...]:
    """Largest-remainder split of the link credits across credit classes.

    Every class gets >= 1 credit (guaranteed feasible by the config check
    ``credits >= len(weights)``) and the quotas sum to exactly ``credits``,
    so the per-step link capacity is unchanged by QoS.
    """
    w = np.asarray(weights, np.float64)
    raw = credits * w / w.sum()
    q = np.maximum(np.floor(raw).astype(np.int64), 1)
    while q.sum() > credits:  # trim overflow from the largest class
        q[int(np.argmax(q))] -= 1
    rem = raw - np.floor(raw)
    while q.sum() < credits:  # hand slack to the largest remainders
        i = int(np.argmax(rem))
        q[i] += 1
        rem[i] -= 1.0
    return tuple(int(x) for x in q)


def _compact(buf: jnp.ndarray, valid: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stable-move valid rows to the front (order-preserving)."""
    n = buf.shape[0]
    idx = jnp.arange(n)
    order = jnp.argsort(jnp.where(valid, idx, idx + n))
    return buf[order], valid[order]


def _append(rx, rx_cnt, rx_step, ok, frames, take, step_no):
    """Append ``frames[take]`` rows to the rx buffer at ``rx_cnt``, recording
    the scan step each row arrived at."""
    rx_cap = rx.shape[0]
    pos = jnp.where(take, rx_cnt + jnp.cumsum(take) - 1, rx_cap)
    rx = rx.at[pos].set(frames, mode="drop")
    rx_step = rx_step.at[pos].set(step_no, mode="drop")
    new_cnt = rx_cnt + jnp.sum(take)
    ok = ok & (new_cnt <= rx_cap)
    return rx, jnp.minimum(new_cnt, rx_cap), rx_step, ok


class Router:
    """Routed delivery of framed streams between arbitrary mesh ranks."""

    def __init__(
        self,
        mesh: Mesh,
        axis_names: Optional[Sequence[str]] = None,
        config: FabricConfig = FabricConfig(),
    ):
        self.mesh = mesh
        self.axis_names = tuple(axis_names or mesh.axis_names)
        self.sizes = tuple(mesh.shape[a] for a in self.axis_names)
        self.n_ranks = math.prod(self.sizes)
        if self.n_ranks > MAX_RANKS:
            raise ValueError(f"route word holds u8 ranks; got {self.n_ranks}")
        self.config = config
        self._jitted = {}

    # -- coordinate helpers (row-major rank <-> per-axis coords) ----------

    def _stride(self, ai: int) -> int:
        return math.prod(self.sizes[ai + 1 :])

    def _coord(self, rank: jnp.ndarray, ai: int) -> jnp.ndarray:
        return (rank // self._stride(ai)) % self.sizes[ai]

    def hops(self, src: int, dst: int) -> int:
        """Total +1-ring hops a frame takes from src to dst."""
        return sum(
            (self._coord(jnp.asarray(dst), ai) - self._coord(jnp.asarray(src), ai))
            % n
            for ai, n in enumerate(self.sizes)
        ).item()

    # -- delivery ----------------------------------------------------------

    def deliver(
        self,
        tx: jnp.ndarray,
        tx_valid: jnp.ndarray,
        total_frames: Optional[int] = None,
    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Route every valid tx frame to its destination rank.

        ``tx`` is ``(ranks, T, width)`` u32 (width = HDR + payload words),
        ``tx_valid`` ``(ranks, T)`` bool.  ``total_frames`` is an optional
        upper bound on valid frames across all ranks (default ``R*T``): the
        scan length derives from it, so a tight bound means fewer hop steps.
        Returns ``(rx, rx_count, ok, crc_ok, rx_step)``: delivered frames
        per rank in arrival order, the per-rank count, a routing flag (False
        on undeliverable frames or buffer overflow — both indicate a
        misconfigured fabric), a CRC flag (False when a delivered frame
        fails its checksum), and the scan step each frame arrived at
        (in-tick queueing latency: self-sends arrive at step 0, each
        ppermute hop or credit stall adds one).
        """
        R, T, W = tx.shape
        if R != self.n_ranks or W != self.config.frame_width:
            raise ValueError(
                f"tx shape {tx.shape} vs ranks={self.n_ranks}, "
                f"width={self.config.frame_width}"
            )
        total = min(total_frames or R * T, R * T)
        if total < R * T:  # bucket so the jit cache is reused across ticks
            total = min(1 << max(total - 1, 0).bit_length(), R * T)
        key = (T, total)
        fn = self._jitted.get(key)
        if fn is None:
            fn = self._jitted[key] = self._build(T, total)
        return fn(tx, tx_valid)

    def _build(self, T: int, total: int):
        cfg = self.config
        W = cfg.frame_width
        R = self.n_ranks
        credits = cfg.credits
        rx_cap = cfg.rx_frames or min(R * T, total)
        # worst case: every live frame parks at one rank
        q_cap = max(total, T) + credits
        axes = self.axis_names
        quotas = (
            qos_quotas(credits, cfg.qos_weights) if cfg.qos_weights else None
        )

        def select(queue, elig):
            """Pick this step's link occupants: FIFO, or weighted
            round-robin over ListLevel credit classes (work-conserving —
            quota a class leaves unused spills to the others)."""
            if quotas is None:
                return elig & (jnp.cumsum(elig) <= credits)
            cls = queue[:, HDR_LEVEL].astype(jnp.int32) % len(quotas)
            take = jnp.zeros_like(elig)
            for c, qc in enumerate(quotas):
                in_c = elig & (cls == c)
                take = take | (in_c & (jnp.cumsum(in_c) <= qc))
            rest = elig & ~take
            spill = credits - jnp.sum(take)
            return take | (rest & (jnp.cumsum(rest) <= spill))

        def local(tx, tx_valid):  # (1, T, W), (1, T) — one device's view
            coords = [jax.lax.axis_index(a) for a in axes]
            me = sum(
                c * self._stride(ai) for ai, c in enumerate(coords)
            ).astype(jnp.int32)

            pad = q_cap - T
            queue = jnp.pad(tx[0], ((0, pad), (0, 0)))
            qvalid = jnp.pad(tx_valid[0], (0, pad))
            rx = jnp.zeros((rx_cap, W), jnp.uint32)
            rx_cnt = jnp.int32(0)
            rx_step = jnp.zeros((rx_cap,), jnp.int32)
            ok = jnp.array(True)
            step_no = jnp.int32(0)

            # self-sends never cross a link: deliver them up front
            self_take = qvalid & (route_dst(queue) == me)
            rx, rx_cnt, rx_step, ok = _append(
                rx, rx_cnt, rx_step, ok, queue, self_take, step_no
            )
            qvalid = qvalid & ~self_take

            for ai, axis in enumerate(axes):
                n_axis = self.sizes[ai]
                if n_axis == 1:
                    continue
                perm = [(i, (i + 1) % n_axis) for i in range(n_axis)]
                # worst case every live frame crosses the busiest link, plus
                # pipeline fill around the ring (QoS keeps the per-step link
                # capacity at `credits`, so the bound is scheduler-agnostic)
                steps = -(-total // credits) + n_axis + 1

                def step(carry, _):
                    queue, qvalid, rx, rx_cnt, rx_step, ok, step_no = carry
                    step_no = step_no + 1
                    # inject: frames still off-coordinate on this axis, up
                    # to `credits` per step, scheduled by `select` (transit
                    # priority comes from arrivals being re-queued at the
                    # front below)
                    dstc = self._coord(route_dst(queue), ai)
                    elig = qvalid & (dstc != coords[ai])
                    take = select(queue, elig)
                    rank1 = jnp.cumsum(take)
                    pos = jnp.where(take, rank1 - 1, credits)
                    link = jnp.zeros((credits, W), jnp.uint32).at[pos].set(
                        queue, mode="drop"
                    )
                    lvalid = jnp.zeros((credits,), bool).at[pos].set(
                        take, mode="drop"
                    )
                    qvalid = qvalid & ~take
                    # one hop
                    arr = jax.lax.ppermute(link, axis, perm)
                    avalid = jax.lax.ppermute(lvalid, axis, perm)
                    # deliver frames that reached their full destination
                    done = avalid & (route_dst(arr) == me)
                    rx, rx_cnt, rx_step, ok = _append(
                        rx, rx_cnt, rx_step, ok, arr, done, step_no
                    )
                    # transit frames re-queue at the FRONT (FIFO per path)
                    comb = jnp.concatenate([arr, queue])
                    cvalid = jnp.concatenate([avalid & ~done, qvalid])
                    comb, cvalid = _compact(comb, cvalid)
                    ok = ok & ~jnp.any(cvalid[q_cap:])
                    return (
                        comb[:q_cap], cvalid[:q_cap], rx, rx_cnt, rx_step,
                        ok, step_no,
                    ), None

                (queue, qvalid, rx, rx_cnt, rx_step, ok, step_no), _ = (
                    jax.lax.scan(
                        step,
                        (queue, qvalid, rx, rx_cnt, rx_step, ok, step_no),
                        None,
                        length=steps,
                    )
                )

            # anything still queued is undeliverable (bad dst / starved link)
            ok = ok & ~jnp.any(qvalid)
            live = jnp.arange(rx_cap) < rx_cnt
            crc_ok = jnp.all(jnp.where(live, verify_frames(rx), True))
            return rx[None], rx_cnt[None], ok[None], crc_ok[None], rx_step[None]

        spec = P(axes)
        return jax.jit(
            shard_map(
                local,
                mesh=self.mesh,
                in_specs=(spec, spec),
                out_specs=(spec, spec, spec, spec, spec),
                check_rep=False,
            )
        )
