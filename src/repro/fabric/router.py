"""Multi-hop frame router over the JAX device mesh.

The seed's ``pod_ring_exchange`` moves frames exactly one hop between ring
neighbours; every multi-device pattern had to be hand-wired out of single
hops.  This module generalizes it to a packet-switched fabric in the spirit
of "Framework for Application Mapping over Packet-Switched Network of
FPGAs": frames carry a ``(src, dst, seq)`` route word (``frames.py``) and a
:class:`Router` delivers them to arbitrary ranks by composing
``jax.lax.ppermute`` steps.

Topology and algorithm
----------------------
* Ranks are the row-major flattening of the mesh coordinates along
  ``axis_names`` (so a ``(4, 2)`` x/y mesh has ``rank = x*2 + y``).
* **Dimension-ordered routing**: frames first travel along the first axis
  until their destination coordinate on that axis matches, then along the
  next axis, and so on — deadlock-free and deterministic, the standard
  mesh/torus discipline.
* **Shortest-path direction choice** (``config.routing = "shortest"``, the
  default): on each axis a frame whose +1 distance exceeds half the ring
  takes the -1 direction instead, so the worst case halves from ``n - 1``
  hops to ``n // 2``.  Every scan step moves BOTH directions (two
  ``ppermute``s over disjoint link buffers), each direction with its own
  ``credits`` budget and its own QoS weighted-round-robin pass — a
  bidirectional ring has twice the link capacity of the +1 ring, and the
  scheduler treats each physical direction as the independent link it is.
  The choice is per *frame*: the route word's adaptive bit (``frames.py``)
  gates it, so legacy +1-only frames and shortest-path frames coexist in
  one tick.  ``routing = "dimension"`` keeps the PR-2/PR-3 +1-ring
  discipline bit-for-bit.
* **Credit-based flow control**: each directed link carries at most
  ``config.credits`` frames per step (the paper's bounded-BRAM
  back-pressure analog).  Frames that cannot be injected wait in a
  per-device queue; transiting frames have priority over fresh injections,
  which preserves per-source FIFO order along a path.
* **Congestion-aware direction defection** (``config.defect_after = k``,
  default 0 = off): every device tracks, per (outgoing link, direction), how
  many *consecutive* scan steps that link's credit budget left eligible
  demand waiting.  A queued frame whose route word carries the adaptive bit
  may *defect* to the opposite ring direction once its preferred link has
  been starved for ``k`` straight steps — but only into that direction's
  *spare* credits (after its natural traffic was scheduled), so at most
  ``credits`` frames defect per step and a starved queue cannot stampede
  onto the other ring.  A defector commits to its new direction for the
  rest of the axis (the commitment travels with the frame through the
  ppermutes), which bounds its path at ``n - 1`` hops and rules out
  ping-pong oscillation.  Defection changes *paths*, never bytes: the
  receiver reorders frames by ``seq``, so delivery stays byte-identical to
  static shortest-path and dimension-order routing (property-tested).
* **Early-exit scans** (``config.early_exit``, default on): each axis scan
  runs as a ``lax.while_loop`` that stops as soon as no device still holds
  a frame needing the axis (one cheap global ``psum`` of a bool per step),
  with the static per-axis bound as the cap.  The demand bound therefore
  prices the *worst case* while the tick pays only for the traffic it
  actually carries — in particular the conservative defection bound (a
  defector may ride the long way around) costs nothing when nothing
  defects.
* **QoS credit classes** (``config.qos_weights``): instead of handing the
  per-link credits to the frontmost frames FIFO, the inject step can run
  *weighted round-robin* over credit classes keyed by the frame's
  ``ListLevel`` (``class = level % n_classes``).  Each class holds a static
  quota of the link credits (largest-remainder split of the weights) and
  unused quota spills to the other classes in queue order, so the scheduler
  stays work-conserving.  ``deliver`` additionally reports the scan step at
  which every frame arrived (``rx_step``), which makes in-tick queueing
  delay — and therefore starvation — observable to the mailbox layer.
* Every step is one ``ppermute`` per active direction of a
  ``(credits, width)`` link buffer inside a ``lax.scan``; the step count is
  a static worst-case bound (pipeline fill + frames over the busiest
  possible link), so the whole delivery jits to one XLA program with no
  host round-trips.  :meth:`Router.plan_steps` tightens the bound from the
  tick's *actual* demand (per-ring directed link loads and true hop
  distances) and reports which directions each axis really uses, so a
  one-destination burst does not pay for the all-to-all worst case — and an
  axis nobody crosses costs zero scan steps.

Two delivery entry points:

* :meth:`Router.deliver` — takes already-framed ``(ranks, T, width)`` TX
  buffers (the PR-2/PR-3 three-program path; ``mailbox.py`` frames on a
  separate jit and scatters on host).
* :meth:`Router.deliver_fused` — the whole tick as ONE jitted program:
  batched framing (structure pass + Pallas assembly), device-side scatter
  into per-rank TX rows, the routed scan, and the Pallas RX split all fuse
  into a single ``jax.jit``, so frames never bounce through host memory
  between the three stages.

The router works on *stacked* buffers — ``tx`` is ``(ranks, T, width)``
sharded over the mesh axes — matching the repo's shard_map test idiom.
Higher-level message semantics (reassembly, per-message corruption flags)
live in ``mailbox.py``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .frames import (
    HDR_LEVEL,
    HDR_WORDS,
    PHIT_WORDS,
    route_adaptive,
    route_dst,
    route_src,
    verify_frames,
)

#: on-device counter-block layout (import-pure, so no cycle): the scan
#: carry accumulates one int32 vector per device and returns it alongside
#: the delivered frames — the fused no-host-sync path stays sync-free.
#: The attribution layout (``ATT_*``/``n_att``) is the per-FRAME flight
#: recorder: those columns ride WITH each frame through the link-buffer
#: ppermutes instead of aggregating per device.
from ..obs.counters import (
    ATT_DEFECT,
    ATT_ENTER,
    ATT_STALL,
    ATT_WAIT,
    N_ATT_FIXED,
    ctr_index,
    global_index,
    n_att,
    n_counters,
)

#: shared validation rules — the static analyzer and the runtime raise the
#: SAME messages (repro.analysis.rules is fabric-free at import time)
from ..analysis.findings import Severity
from ..analysis.rules import fabric_config_findings, max_ranks_error

#: direction masks for plan_steps / the per-axis scan builder, shared with
#: the analyzer's communication pass (defined there before any import, so
#: this line is cycle-safe whichever package loads first)
from ..analysis.comm import DIR_BWD, DIR_FWD


@dataclass(frozen=True)
class FabricConfig:
    """Knobs of the routed fabric."""

    frame_phits: int = 16  # payload phits per frame
    credits: int = 4  # max in-flight frames per directed link per step
    rx_frames: Optional[int] = None  # per-rank delivery capacity (default R*T)
    #: weighted round-robin credit classes at the inject step, keyed by
    #: ``ListLevel % len(qos_weights)``.  None = single-class FIFO (legacy).
    qos_weights: Optional[Tuple[int, ...]] = None
    #: "shortest" = per-frame direction choice (go -1 when it is the shorter
    #: way around the ring); "dimension" = the legacy +1-only discipline.
    routing: str = "shortest"
    #: run the tick as one fused jit (pack -> route -> RX split) instead of
    #: three programs with host syncs between them.  The three-program path
    #: remains for fault injection (``Fabric.tx_hook``) and as the
    #: regression oracle.
    fused: bool = True
    #: congestion-aware direction defection: an adaptive frame whose
    #: preferred link has been credit-starved for this many CONSECUTIVE
    #: scan steps may take the opposite ring direction instead (into that
    #: direction's spare credits only).  0 = off — the static per-frame
    #: shortest-path choice of PR 4, bit-for-bit.
    defect_after: int = 0
    #: stop each axis scan as soon as no device still holds a frame that
    #: needs the axis (one global psum of a bool per step); the static
    #: demand bound becomes a cap instead of the price every tick pays.
    early_exit: bool = True
    #: ARQ reliability layer (``mailbox.py``): senders keep sent messages
    #: in a bounded per-(src, dst) retransmit buffer keyed by the route
    #: word's seq; receivers turn CRC failures and seq gaps into compact
    #: NACK / cumulative-ACK control frames riding QoS class
    #: ``arq_level``; senders retransmit on NACK or on a tick-count
    #: timeout with capped exponential backoff.  Off by default — the
    #: detection-only (flag-and-deliver) behavior of PRs 2-8, bit for
    #: bit.  The serve plane opts in (``default_serve_fabric``).
    arq: bool = False
    #: ticks without an ACK before a sender retransmits unprompted
    #: (doubles per retry, capped at 32x)
    retransmit_timeout: int = 8
    #: retransmits per message before the sender gives up and dead-letters
    #: it (0 = a single NACK/timeout aborts immediately)
    max_retries: int = 4
    #: retransmit-buffer bound per (src, dst) stream, in FRAMES — must
    #: stay under SEQ_MOD // 2 or cumulative ACKs turn ambiguous
    #: (rule ``fabric-arq-window``)
    arq_buffer: int = 1024
    #: ListLevel the ACK/NACK control frames ride (reserved: user sends
    #: at this level are rejected while arq is on) — under qos_weights it
    #: maps to credit class ``arq_level % n_classes``, which must earn a
    #: nonzero quota (rule ``fabric-arq-control-class``)
    arq_level: int = 255
    #: receiver give-up horizon: after this many ticks stuck on one seq
    #: gap, flag the partial message and resync past it.  0 = derive from
    #: the retransmit schedule (timeout * (max_retries + 2))
    arq_skip_after: int = 0
    #: receiver ACK cadence: cumulative-ACK every Nth tick that delivered
    #: in-order frames (1 = every tick; coalescing keeps control traffic
    #: sublinear in message rate)
    arq_ack_every: int = 2

    def __post_init__(self) -> None:
        # the analyzer's fabric pass is the single source of these checks
        # (repro.analysis.rules): construction raises the first ERROR
        # finding's message verbatim, so the error a user hits here and
        # the finding `python -m repro.analysis` reports are identical.
        for f in fabric_config_findings(
            self.frame_phits, self.credits, self.routing,
            self.defect_after, self.qos_weights,
            arq=self.arq, retransmit_timeout=self.retransmit_timeout,
            max_retries=self.max_retries, arq_buffer=self.arq_buffer,
            arq_level=self.arq_level, arq_skip_after=self.arq_skip_after,
        ):
            if f.severity is Severity.ERROR:
                raise ValueError(f.message)

    @property
    def skip_after(self) -> int:
        """Effective receiver give-up horizon (resolves the 0 default
        from the retransmit schedule: every retry must have had a chance
        to arrive before the receiver resyncs past the gap)."""
        if self.arq_skip_after > 0:
            return self.arq_skip_after
        return self.retransmit_timeout * (self.max_retries + 2)

    @property
    def frame_width(self) -> int:
        return HDR_WORDS + self.frame_phits * PHIT_WORDS

    @property
    def adaptive(self) -> bool:
        return self.routing == "shortest"

    @property
    def defection(self) -> bool:
        """Congestion-aware defection active (adaptive routing + k > 0)."""
        return self.adaptive and self.defect_after > 0


def qos_quotas(credits: int, weights: Sequence[int]) -> Tuple[int, ...]:
    """Largest-remainder split of the link credits across credit classes.

    Every class gets >= 1 credit (guaranteed feasible by the config check
    ``credits >= len(weights)``) and the quotas sum to exactly ``credits``,
    so the per-step link capacity is unchanged by QoS.
    """
    w = np.asarray(weights, np.float64)
    raw = credits * w / w.sum()
    q = np.maximum(np.floor(raw).astype(np.int64), 1)
    while q.sum() > credits:  # trim overflow from the largest class
        q[int(np.argmax(q))] -= 1
    rem = raw - np.floor(raw)
    while q.sum() < credits:  # hand slack to the largest remainders
        i = int(np.argmax(rem))
        q[i] += 1
        rem[i] -= 1.0
    return tuple(int(x) for x in q)


def _compact_to(valid: jnp.ndarray, cap: int, *cols):
    """Stable partition: scatter valid rows (order-preserving) to the front
    of fresh ``cap``-row buffers.  One cumsum + one scatter per column —
    O(n), replacing the old O(n log n) argsort — and rows past ``cap`` are
    dropped (reported via the overflow flag) instead of silently kept.
    Returns (valid', cols', overflow)."""
    pos = jnp.where(valid, jnp.cumsum(valid) - 1, cap)
    out_valid = jnp.zeros((cap,), bool).at[pos].set(valid, mode="drop")
    outs = tuple(
        jnp.zeros((cap,) + c.shape[1:], c.dtype).at[pos].set(c, mode="drop")
        for c in cols
    )
    overflow = jnp.sum(valid) > cap
    return out_valid, outs, overflow


def _append(rx, rx_cnt, rx_step, rx_att, ok, frames, take, step_no, att):
    """Append ``frames[take]`` rows to the rx buffer at ``rx_cnt``, recording
    the scan step each row arrived at and its attribution vector."""
    rx_cap = rx.shape[0]
    pos = jnp.where(take, rx_cnt + jnp.cumsum(take) - 1, rx_cap)
    rx = rx.at[pos].set(frames, mode="drop")
    rx_step = rx_step.at[pos].set(step_no, mode="drop")
    rx_att = rx_att.at[pos].set(att, mode="drop")
    new_cnt = rx_cnt + jnp.sum(take)
    ok = ok & (new_cnt <= rx_cap)
    return rx, jnp.minimum(new_cnt, rx_cap), rx_step, rx_att, ok


class Router:
    """Routed delivery of framed streams between arbitrary mesh ranks."""

    def __init__(
        self,
        mesh: Mesh,
        axis_names: Optional[Sequence[str]] = None,
        config: FabricConfig = FabricConfig(),
    ):
        self.mesh = mesh
        self.axis_names = tuple(axis_names or mesh.axis_names)
        self.sizes = tuple(mesh.shape[a] for a in self.axis_names)
        self.n_ranks = math.prod(self.sizes)
        err = max_ranks_error(self.n_ranks)
        if err is not None:  # same rule (and words) as Fabric.__init__
            raise ValueError(err)
        self.config = config
        self._jitted = {}
        self._fused = {}

    # -- coordinate helpers (row-major rank <-> per-axis coords) ----------

    def _stride(self, ai: int) -> int:
        return math.prod(self.sizes[ai + 1 :])

    def _coord(self, rank: jnp.ndarray, ai: int) -> jnp.ndarray:
        return (rank // self._stride(ai)) % self.sizes[ai]

    def _coord_int(self, rank: int, ai: int) -> int:
        return (rank // self._stride(ai)) % self.sizes[ai]

    def hops(self, src: int, dst: int) -> int:
        """Total +1-ring (dimension-order) hops from src to dst.

        Pure host integer math — ``place_requests`` calls this per request,
        so it must not build device arrays or force a sync.
        """
        return sum(
            (self._coord_int(dst, ai) - self._coord_int(src, ai)) % n
            for ai, n in enumerate(self.sizes)
        )

    def min_hops(self, src: int, dst: int) -> int:
        """Total hops under shortest-path routing (per-axis min of the two
        ring directions) — what a ``routing="shortest"`` frame traverses."""
        total = 0
        for ai, n in enumerate(self.sizes):
            d = (self._coord_int(dst, ai) - self._coord_int(src, ai)) % n
            total += min(d, n - d)
        return total

    def route_hops(self, src: int, dst: int) -> int:
        """Hops under THIS router's configured routing mode (placement must
        rank shards by the distance frames actually travel)."""
        if self.config.adaptive:
            return self.min_hops(src, dst)
        return self.hops(src, dst)

    # -- demand-aware scan bounds -----------------------------------------

    def default_steps(self, total: int) -> Tuple[Tuple[int, int], ...]:
        """Worst-case per-axis (steps, dirs): every live frame crosses the
        busiest link and needs the full pipeline fill.  Shortest-path halves
        the fill term (max hops per axis drop from ``n`` to ``n // 2``);
        with defection enabled a starved frame may wait ``defect_after``
        steps and then ride the long way around (up to ``n - 1`` hops), so
        the fill term grows back to ``n + defect_after`` — early-exit scans
        make the looser cap free whenever nothing actually defects."""
        credits = self.config.credits
        out = []
        for n in self.sizes:
            if n == 1:
                out.append((0, 0))
                continue
            if self.config.defection:
                fill, dirs = n + self.config.defect_after, DIR_FWD | DIR_BWD
            elif self.config.adaptive:
                fill, dirs = n // 2, DIR_FWD | DIR_BWD
            else:
                fill, dirs = n, DIR_FWD
            out.append((-(-total // credits) + fill + 1, dirs))
        return tuple(out)

    def plan_steps(
        self,
        srcs: Sequence[int],
        dsts: Sequence[int],
        counts: Sequence[int],
    ) -> Tuple[Tuple[int, int], ...]:
        """Per-axis (scan steps, direction mask) from the tick's ACTUAL
        demand — pure host numpy, no device work.

        Frames route dimension-ordered, so while a frame crosses axis ``ai``
        its other coordinates are pinned (axes before ``ai`` already at the
        destination, axes after still at the source); that tuple names the
        physical ring the frame rides.  Frames on different rings — or
        moving in opposite directions on one ring — never compete for a
        link, so the busiest-contention-set bound is per (ring, direction):
        ``ceil(group_frames / credits) + group_max_hops + 1``.  The result
        is never looser than :meth:`default_steps` and is rounded up to an
        even step count so nearby traffic shapes share a jit cache entry.
        An axis no frame crosses costs 0 steps (skipped entirely), and a
        direction no frame takes skips its ppermute.

        With **defection** enabled, a ring whose load exceeds the per-step
        credit budget can starve frames into the opposite direction, so for
        those rings the two direction groups merge: the bound becomes
        ``ceil(ring_load / credits) + (n - 1) + defect_after + 1`` (the
        preferred link always drains >= ``credits``/step — defectors only
        ever consume the other direction's *spare* credits — and a defector
        rides at most ``n - 1`` hops after waiting ``defect_after`` steps),
        and both directions keep their ppermutes.  Rings that can never
        starve (``load <= credits``) keep the tight per-direction bound.
        The early-exit scan makes the slack free when nothing defects.
        """
        # the load matrix + bounds live in the analyzer's communication
        # pass (lazy import: those functions are defined after the module
        # cycle re-entry point), so the matrix `python -m repro.analysis`
        # reports and the bounds this router jits from cannot disagree.
        from ..analysis.comm import bounds_from_loads, demand_link_loads

        defect = self.config.defect_after if self.config.defection else 0
        loads = demand_link_loads(
            self.sizes, srcs, dsts, counts, self.config.adaptive
        )
        return bounds_from_loads(
            loads, self.sizes, self.config.credits, defect,
            self.default_steps(sum(counts)),
        )

    # -- delivery ----------------------------------------------------------

    def deliver(
        self,
        tx: jnp.ndarray,
        tx_valid: jnp.ndarray,
        total_frames: Optional[int] = None,
    ) -> Tuple[jnp.ndarray, ...]:
        """Route every valid tx frame to its destination rank.

        ``tx`` is ``(ranks, T, width)`` u32 (width = HDR + payload words),
        ``tx_valid`` ``(ranks, T)`` bool.  ``total_frames`` is an optional
        upper bound on valid frames across all ranks (default ``R*T``): the
        scan length derives from it, so a tight bound means fewer hop steps.
        Returns ``(rx, rx_count, ok, crc_ok, rx_step, rx_att, counters)``:
        delivered frames per rank in arrival order, the per-rank count, a
        routing flag (False on undeliverable frames or buffer overflow —
        both indicate a misconfigured fabric), a CRC flag (False when a
        delivered frame fails its checksum), the scan step each frame
        arrived at (in-tick queueing latency: self-sends arrive at step 0,
        each ppermute hop or credit stall adds one), the per-frame
        attribution block (``repro.obs.counters`` ``ATT_*`` layout — the
        flight recorder: ``wait + stall + sum(transit) == rx_step``
        exactly, per frame), and the per-rank telemetry counter block
        (``repro.obs.counters`` layout), all accumulated device-side
        inside the scan.
        """
        R, T, W = tx.shape
        if R != self.n_ranks or W != self.config.frame_width:
            raise ValueError(
                f"tx shape {tx.shape} vs ranks={self.n_ranks}, "
                f"width={self.config.frame_width}"
            )
        total = self.bucket_total(total_frames, T)
        key = (T, total)
        fn = self._jitted.get(key)
        if fn is None:
            fn = self._jitted[key] = self._build(T, total)
        return fn(tx, tx_valid)

    def bucket_total(self, total_frames: Optional[int], T: int) -> int:
        """Pow2-bucket the live-frame bound so the jit cache is reused
        across ticks (idempotent: feeding a bucketed value back is a
        no-op — the Mailbox memoizes on exactly this value)."""
        R = self.n_ranks
        total = min(total_frames or R * T, R * T)
        if total < R * T:
            total = min(1 << max(total - 1, 0).bit_length(), R * T)
        return total

    def _capacities(self, T: int, total: int) -> Tuple[int, int]:
        """(rx_cap, q_cap) for a tick of ``total`` live frames and per-rank
        TX depth ``T`` — ONE derivation shared by the fused and
        three-program builders, so the two paths always agree on queue/RX
        sizing (the bit-identity regression tests rely on that)."""
        cfg = self.config
        rx_cap = cfg.rx_frames or min(self.n_ranks * T, total)
        arrivals = cfg.credits * (2 if cfg.adaptive else 1)
        return rx_cap, max(total, T) + arrivals

    def _build(self, T: int, total: int):
        axis_steps = self.default_steps(total)
        rx_cap, q_cap = self._capacities(T, total)
        local = self._build_local(T, axis_steps, q_cap, rx_cap)
        spec = P(self.axis_names)
        return jax.jit(
            shard_map(
                local,
                mesh=self.mesh,
                in_specs=(spec, spec),
                out_specs=(spec,) * 7,
                check_rep=False,
            )
        )

    def _build_local(
        self,
        T: int,
        axis_steps: Tuple[Tuple[int, int], ...],
        q_cap: int,
        rx_cap: int,
    ):
        """The per-device routing program: inject/hop/deliver scan per axis.

        ``axis_steps`` is a static (steps, direction-mask) per axis —
        ``plan_steps`` output for demand-tight ticks, ``default_steps`` for
        the worst case.  A 0-step axis is skipped entirely; a direction
        absent from the mask skips its ppermute.
        """
        cfg = self.config
        W = cfg.frame_width
        credits = cfg.credits
        axes = self.axis_names
        quotas = (
            qos_quotas(credits, cfg.qos_weights) if cfg.qos_weights else None
        )

        def select(levels, elig):
            """Pick one direction's link occupants: FIFO, or weighted
            round-robin over ListLevel credit classes (work-conserving —
            quota a class leaves unused spills to the others).  Also
            returns the number of frames admitted via the spill (the
            ``link.spilled`` telemetry counter — 0 in FIFO mode, where no
            class quotas exist to spill)."""
            if quotas is None:
                return elig & (jnp.cumsum(elig) <= credits), jnp.int32(0)
            cls = levels.astype(jnp.int32) % len(quotas)
            take = jnp.zeros_like(elig)
            for c, qc in enumerate(quotas):
                in_c = elig & (cls == c)
                take = take | (in_c & (jnp.cumsum(in_c) <= qc))
            rest = elig & ~take
            spill = credits - jnp.sum(take)
            spilled = rest & (jnp.cumsum(rest) <= spill)
            return take | spilled, jnp.sum(spilled, dtype=jnp.int32)

        K = n_att(len(axes))

        def hop(queue, take, axis, perm, att, extra=None):
            """Scatter this direction's occupants into the link buffer and
            move it one hop.  The valid flag, the per-frame attribution
            vector, and — with defection — the direction commitment ride
            as trailing u32 columns of the SAME buffer, so each direction
            costs exactly ONE ppermute per step regardless of how much
            per-frame state travels with the frames."""
            E = 2 if extra is not None else 1
            pos = jnp.where(take, jnp.cumsum(take) - 1, credits)
            buf = jnp.pad(queue, ((0, 0), (0, E + K)))
            buf = buf.at[:, W].set(take.astype(jnp.uint32))
            if extra is not None:
                buf = buf.at[:, W + 1].set(extra.astype(jnp.uint32))
            buf = buf.at[:, W + E:].set(att.astype(jnp.uint32))
            link = jnp.zeros((credits, W + E + K), jnp.uint32).at[pos].set(
                buf, mode="drop"
            )
            arr = jax.lax.ppermute(link, axis, perm)
            avalid = arr[:, W] != 0
            adir = arr[:, W + 1].astype(jnp.int32) if extra is not None else None
            aatt = arr[:, W + E:].astype(jnp.int32)
            return arr[:, :W], avalid, adir, aatt

        NC = n_counters(len(axes))
        IDX_DELIVERED = global_index(len(axes), "delivered")
        IDX_CRC_FAIL = global_index(len(axes), "crc_fail")

        def local(tx, tx_valid):  # (1, T, W), (1, T) — one device's view
            coords = [jax.lax.axis_index(a) for a in axes]
            me = sum(
                c * self._stride(ai) for ai, c in enumerate(coords)
            ).astype(jnp.int32)

            pad = q_cap - T
            queue = jnp.pad(tx[0], ((0, pad), (0, 0)))
            qvalid = jnp.pad(tx_valid[0], (0, pad))
            rx = jnp.zeros((rx_cap, W), jnp.uint32)
            rx_cnt = jnp.int32(0)
            rx_step = jnp.zeros((rx_cap,), jnp.int32)
            ok = jnp.array(True)
            step_no = jnp.int32(0)
            # telemetry counter block (obs.counters layout), accumulated
            # device-side alongside the routing itself.  Every field is an
            # order-independent EVENT count (sums of takes, anys of demand)
            # so the fused and three-program paths — whose queue layouts
            # and static scan bounds differ — agree bit-for-bit.
            ctr = jnp.zeros((NC,), jnp.int32)
            # per-frame flight recorder: one attribution vector per queue
            # row, updated once per EXECUTED scan step.  At every step a
            # live queued frame lands in exactly one of {hopped, stalled,
            # waiting}, so per frame `wait + stall + sum(transit)` counts
            # every step from 1 to its arrival — i.e. equals rx_step
            # exactly, on either engine (the step schedules are identical
            # under the default early-exit scans).
            qatt = jnp.zeros((q_cap, K), jnp.int32)
            rx_att = jnp.zeros((rx_cap, K), jnp.int32)

            # self-sends never cross a link: deliver them up front (step 0,
            # all attribution components zero)
            self_take = qvalid & (route_dst(queue) == me)
            rx, rx_cnt, rx_step, rx_att, ok = _append(
                rx, rx_cnt, rx_step, rx_att, ok, queue, self_take, step_no,
                qatt,
            )
            ctr = ctr.at[IDX_DELIVERED].add(
                jnp.sum(self_take, dtype=jnp.int32)
            )
            qvalid = qvalid & ~self_take

            for ai, axis in enumerate(axes):
                n_axis = self.sizes[ai]
                steps, dirs = axis_steps[ai]
                if n_axis == 1 or steps == 0:
                    continue
                fwd_perm = [(i, (i + 1) % n_axis) for i in range(n_axis)]
                bwd_perm = [(i, (i - 1) % n_axis) for i in range(n_axis)]
                myc = coords[ai]
                half = n_axis // 2
                use_fwd = bool(dirs & DIR_FWD)
                use_bwd = bool(dirs & DIR_BWD)
                # defection needs both ppermutes live on the axis (plan_steps
                # only emits a one-direction mask when no ring can starve)
                defect = cfg.defect_after if (
                    cfg.defection and use_fwd and use_bwd
                ) else 0
                # hoisted: the per-frame scheduling keys (destination coord
                # on this axis, ListLevel class, adaptive flag) are computed
                # ONCE for the resident queue and only for the <= arrivals
                # rows each step, instead of re-derived for all q_cap rows
                # every step.
                qdst = self._coord(route_dst(queue), ai).astype(jnp.int32)
                qlvl = queue[:, HDR_LEVEL]
                qadp = route_adaptive(queue)
                # source coordinate on this axis: a frame's FIRST hop on
                # the axis happens on the device still at that coordinate,
                # which is how `link.entered` counts each frame exactly
                # once per axis (the observed demand_link_loads fold).
                qsrc = self._coord(route_src(queue), ai).astype(jnp.int32)
                ix_f = {
                    f: ctr_index(ai, 0, f)
                    for f in ("entered", "forwarded", "starved",
                              "defect_out", "spare_in", "spilled",
                              "occupied")
                }
                ix_b = {f: ctr_index(ai, 1, f) for f in ix_f}

                def step(carry, ai=ai, axis=axis, n_axis=n_axis,
                         myc=myc, half=half, use_fwd=use_fwd,
                         use_bwd=use_bwd, fwd_perm=fwd_perm,
                         bwd_perm=bwd_perm, defect=defect,
                         ix_f=ix_f, ix_b=ix_b):
                    # new carry state (qsrc, ctr, qatt, rx_att) rides at
                    # the END of the tuple so `more_of`'s positional reads
                    # stay valid
                    if defect:
                        (queue, qdst, qlvl, qadp, qdir, qvalid,
                         rx, rx_cnt, rx_step, ok, step_no, sf, sb,
                         qsrc, ctr, qatt, rx_att) = carry
                    else:
                        (queue, qdst, qlvl, qadp, qvalid,
                         rx, rx_cnt, rx_step, ok, step_no,
                         qsrc, ctr, qatt, rx_att) = carry
                    step_no = step_no + 1

                    def count(take):
                        return jnp.sum(take, dtype=jnp.int32)
                    # inject: frames still off-coordinate on this axis, up
                    # to `credits` per direction per step, scheduled by
                    # `select` (transit priority comes from arrivals being
                    # re-queued at the front below)
                    fwd = (qdst - myc) % n_axis
                    elig = qvalid & (fwd != 0)
                    prefer_bwd = qadp & (fwd > half) if use_bwd else (
                        jnp.zeros_like(elig)
                    )
                    if defect:
                        # a committed defector keeps its direction for the
                        # rest of the axis; everyone else uses the static
                        # shortest-path preference
                        go_bwd = jnp.where(qdir == 0, prefer_bwd, qdir == 2)
                    else:
                        go_bwd = prefer_bwd
                    take_f, spill_f = (
                        select(qlvl, elig & ~go_bwd) if use_fwd
                        else (None, None)
                    )
                    take_b, spill_b = (
                        select(qlvl, elig & go_bwd) if use_bwd
                        else (None, None)
                    )
                    if defect:
                        # per-(link, direction) starvation: demand this
                        # direction's credits left waiting THIS step
                        starved_f = jnp.any(elig & ~go_bwd & ~take_f)
                        starved_b = jnp.any(elig & go_bwd & ~take_b)
                        # defectors: uncommitted adaptive frames whose
                        # preferred link has starved `defect` straight
                        # steps, admitted only into the OPPOSITE
                        # direction's spare credits (after its natural
                        # traffic) — at most `credits` defect per step, so
                        # a starved queue cannot stampede onto the other
                        # ring and re-congest it
                        can_b = (elig & ~go_bwd & ~take_f & qadp
                                 & (qdir == 0) & (sf >= defect))
                        extra_b = can_b & (
                            jnp.cumsum(can_b) <= credits - jnp.sum(take_b)
                        )
                        can_f = (elig & go_bwd & ~take_b & qadp
                                 & (qdir == 0) & (sb >= defect))
                        extra_f = can_f & (
                            jnp.cumsum(can_f) <= credits - jnp.sum(take_f)
                        )
                        take_f = take_f | extra_f
                        take_b = take_b | extra_b
                        # commitment travels with the frame (hopped below)
                        qdir = jnp.where(
                            extra_b, 2, jnp.where(extra_f, 1, qdir)
                        ).astype(jnp.int32)
                        sf = jnp.where(starved_f, sf + 1, 0)
                        sb = jnp.where(starved_b, sb + 1, 0)
                        # a defector leaves its preferred direction
                        # (defect_out) and consumes the opposite one's
                        # spare credits (spare_in): globally the two sum
                        # to the same total
                        ctr = ctr.at[ix_f["defect_out"]].add(count(extra_b))
                        ctr = ctr.at[ix_b["spare_in"]].add(count(extra_b))
                        ctr = ctr.at[ix_b["defect_out"]].add(count(extra_f))
                        ctr = ctr.at[ix_f["spare_in"]].add(count(extra_f))
                    # per-(direction) telemetry — all pure event counts
                    # over demand and takes, so identical whatever static
                    # scan bound or queue layout produced them: `entered`
                    # only at a frame's first hop on the axis (device
                    # coordinate still equals the frame's source
                    # coordinate), `occupied`/`starved` as per-step demand
                    # booleans (steps with no eligible demand add 0, which
                    # is what keeps differing scan bounds invisible).
                    if use_fwd:
                        el_f = elig & ~go_bwd
                        ctr = ctr.at[ix_f["entered"]].add(
                            count(take_f & (qsrc == myc)))
                        ctr = ctr.at[ix_f["forwarded"]].add(count(take_f))
                        ctr = ctr.at[ix_f["spilled"]].add(spill_f)
                        ctr = ctr.at[ix_f["occupied"]].add(
                            jnp.any(el_f).astype(jnp.int32))
                        ctr = ctr.at[ix_f["starved"]].add(
                            jnp.any(el_f & ~take_f).astype(jnp.int32))
                    if use_bwd:
                        el_b = elig & go_bwd
                        ctr = ctr.at[ix_b["entered"]].add(
                            count(take_b & (qsrc == myc)))
                        ctr = ctr.at[ix_b["forwarded"]].add(count(take_b))
                        ctr = ctr.at[ix_b["spilled"]].add(spill_b)
                        ctr = ctr.at[ix_b["occupied"]].add(
                            jnp.any(el_b).astype(jnp.int32))
                        ctr = ctr.at[ix_b["starved"]].add(
                            jnp.any(el_b & ~take_b).astype(jnp.int32))
                    # flight-recorder update — BEFORE the hops, against the
                    # step-start qvalid, so a taken frame's vector already
                    # includes this step's transit when it rides the link.
                    # The three predicates are disjoint and cover every
                    # live queued frame: taken (one hop on this axis),
                    # eligible-but-left-waiting (credit/QoS stall), or
                    # valid-but-off-axis (ingress/phase queue wait).
                    taken = jnp.zeros_like(qvalid)
                    if use_fwd:
                        taken = taken | take_f
                    if use_bwd:
                        taken = taken | take_b
                    enter = qatt[:, ATT_ENTER]
                    qatt = qatt.at[:, ATT_ENTER].set(
                        jnp.where(taken & (enter == 0), step_no, enter)
                    )
                    qatt = qatt.at[:, N_ATT_FIXED + ai].add(
                        taken.astype(jnp.int32)
                    )
                    qatt = qatt.at[:, ATT_STALL].add(
                        (elig & ~taken).astype(jnp.int32)
                    )
                    qatt = qatt.at[:, ATT_WAIT].add(
                        (qvalid & ~elig).astype(jnp.int32)
                    )
                    if defect:
                        qatt = qatt.at[:, ATT_DEFECT].add(
                            (extra_b | extra_f).astype(jnp.int32)
                        )
                    arrs, avalids, adirs, aatts = [], [], [], []
                    ex = qdir if defect else None
                    if use_fwd:
                        arr_f, av_f, ad_f, aa_f = hop(queue, take_f, axis,
                                                      fwd_perm, qatt,
                                                      extra=ex)
                        qvalid = qvalid & ~take_f
                        arrs.append(arr_f)
                        avalids.append(av_f)
                        adirs.append(ad_f)
                        aatts.append(aa_f)
                    if use_bwd:
                        arr_b, av_b, ad_b, aa_b = hop(queue, take_b, axis,
                                                      bwd_perm, qatt,
                                                      extra=ex)
                        qvalid = qvalid & ~take_b
                        arrs.append(arr_b)
                        avalids.append(av_b)
                        adirs.append(ad_b)
                        aatts.append(aa_b)
                    arr = jnp.concatenate(arrs)
                    avalid = jnp.concatenate(avalids)
                    aatt = jnp.concatenate(aatts)
                    # deliver frames that reached their full destination
                    done = avalid & (route_dst(arr) == me)
                    rx, rx_cnt, rx_step, rx_att, ok = _append(
                        rx, rx_cnt, rx_step, rx_att, ok, arr, done, step_no,
                        aatt,
                    )
                    ctr = ctr.at[IDX_DELIVERED].add(count(done))
                    # transit frames re-queue at the FRONT (FIFO per path);
                    # the hoisted columns ride the same stable partition
                    cvalid = jnp.concatenate([avalid & ~done, qvalid])
                    comb = jnp.concatenate([arr, queue])
                    catt = jnp.concatenate([aatt, qatt])
                    cdst = jnp.concatenate([
                        self._coord(route_dst(arr), ai).astype(jnp.int32),
                        qdst,
                    ])
                    clvl = jnp.concatenate([arr[:, HDR_LEVEL], qlvl])
                    cadp = jnp.concatenate([route_adaptive(arr), qadp])
                    csrc = jnp.concatenate([
                        self._coord(route_src(arr), ai).astype(jnp.int32),
                        qsrc,
                    ])
                    if defect:
                        cdir = jnp.concatenate([jnp.concatenate(adirs), qdir])
                        qvalid, (queue, qdst, qlvl, qadp, qdir, qsrc,
                                 qatt), over = \
                            _compact_to(cvalid, q_cap, comb, cdst, clvl,
                                        cadp, cdir, csrc, catt)
                        ok = ok & ~over
                        return (queue, qdst, qlvl, qadp, qdir, qvalid,
                                rx, rx_cnt, rx_step, ok, step_no, sf, sb,
                                qsrc, ctr, qatt, rx_att)
                    qvalid, (queue, qdst, qlvl, qadp, qsrc, qatt), over = \
                        _compact_to(cvalid, q_cap, comb, cdst, clvl, cadp,
                                    csrc, catt)
                    ok = ok & ~over
                    return (queue, qdst, qlvl, qadp, qvalid,
                            rx, rx_cnt, rx_step, ok, step_no,
                            qsrc, ctr, qatt, rx_att)

                if defect:
                    init = (queue, qdst, qlvl, qadp,
                            jnp.zeros((q_cap,), jnp.int32), qvalid,
                            rx, rx_cnt, rx_step, ok, step_no,
                            jnp.int32(0), jnp.int32(0), qsrc, ctr,
                            qatt, rx_att)
                else:
                    init = (queue, qdst, qlvl, qadp, qvalid,
                            rx, rx_cnt, rx_step, ok, step_no, qsrc, ctr,
                            qatt, rx_att)

                if cfg.early_exit:
                    # stop as soon as no device anywhere still holds a frame
                    # that needs this axis: the static bound becomes a cap,
                    # not the price every tick pays.  `more` must be GLOBAL
                    # (psum over the whole mesh) so every device agrees on
                    # the trip count and the ppermutes stay matched.
                    def more_of(c, n_axis=n_axis, myc=myc):
                        # c[1] = qdst, c[5 or 4] = qvalid (defect carries an
                        # extra qdir column before it)
                        live = c[5 if defect else 4] & (
                            ((c[1] - myc) % n_axis) != 0
                        )
                        return jax.lax.psum(
                            jnp.any(live).astype(jnp.int32), axes
                        ) > 0

                    def body(c, step=step, more_of=more_of):
                        it, c = c[0], step(c[1:-1])
                        return (it + 1,) + c + (more_of(c),)

                    def wcond(c, steps=steps):
                        return (c[0] < steps) & c[-1]

                    out = jax.lax.while_loop(
                        wcond, body,
                        (jnp.int32(0),) + init + (jnp.bool_(True),),
                    )[1:-1]
                else:
                    out, _ = jax.lax.scan(
                        lambda c, _, step=step: (step(c), None),
                        init, None, length=steps,
                    )
                if defect:
                    (queue, qdst, qlvl, qadp, _, qvalid,
                     rx, rx_cnt, rx_step, ok, step_no, _, _, _, ctr,
                     qatt, rx_att) = out
                else:
                    (queue, qdst, qlvl, qadp, qvalid,
                     rx, rx_cnt, rx_step, ok, step_no, _, ctr,
                     qatt, rx_att) = out

            # anything still queued is undeliverable (bad dst / starved link)
            ok = ok & ~jnp.any(qvalid)
            live = jnp.arange(rx_cap) < rx_cnt
            frame_crc = verify_frames(rx)
            crc_ok = jnp.all(jnp.where(live, frame_crc, True))
            ctr = ctr.at[IDX_CRC_FAIL].add(
                jnp.sum(live & ~frame_crc, dtype=jnp.int32)
            )
            return (rx[None], rx_cnt[None], ok[None], crc_ok[None],
                    rx_step[None], rx_att[None], ctr[None])

        return local

    # -- fused single-jit tick ---------------------------------------------

    def deliver_fused(
        self,
        payloads: np.ndarray,  # (R, Bmax, Wcap) u32 — sends grouped by src
        nbytes: np.ndarray,  # (R, Bmax) int32 true byte lengths
        routes: np.ndarray,  # (R, Bmax, 3) int32 (src, dst, seq0)
        levels: np.ndarray,  # (R, Bmax) uint32 per-send ListLevels
        send_valid: np.ndarray,  # (R, Bmax) bool — real send vs padding row
        axis_steps: Tuple[Tuple[int, int], ...],
        total: int,
        faults: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None,
    ):
        """One fused tick: frame every rank's sends, lay the live frames out
        as that rank's TX queue, run the routed scan, and split the
        delivered frames into (headers, payloads) — ONE
        ``jax.jit(shard_map(...))``, every stage per-device, no host round
        trips and no cross-device data motion beyond the routing ppermutes
        themselves.

        ``faults`` (the :class:`~repro.fabric.faults.FaultPlan` injection
        point, mapped to this engine's canonical row layout by the
        mailbox) is ``(gather (R, T) int32, xor (R, T, W) u32, valid
        (R, T) bool)``: after framing, each rank's TX queue becomes
        ``tx[gather] ^ xor`` with ``valid`` as the post-fault liveness —
        drop, corrupt, duplicate, and reorder all reduce to this one
        gather+xor, so the injected tick stays a single jit.

        Returns device arrays ``(rx_hdr (R, cap, HDR_WORDS), rx_pay
        (R, cap, frame_words), rx_cnt, ok, crc_ok, rx_step, rx_att,
        counters)`` (``rx_att`` per-frame in the ``ATT_*`` layout,
        ``counters`` per-rank in the ``repro.obs.counters`` layout); the
        caller materializes host bytes only at reassembly time
        (``Mailbox.recv``).
        """
        key = (payloads.shape[1], payloads.shape[2], axis_steps, total,
               faults is not None)
        fn = self._fused.get(key)
        if fn is None:
            fn = self._fused[key] = self._build_fused(
                payloads.shape[1], payloads.shape[2], axis_steps, total,
                faulted=faults is not None,
            )
        base = (
            jnp.asarray(payloads), jnp.asarray(nbytes), jnp.asarray(routes),
            jnp.asarray(levels), jnp.asarray(send_valid),
        )
        if faults is None:
            return fn(*base)
        gather, xor, fvalid = faults
        return fn(*base, jnp.asarray(gather), jnp.asarray(xor),
                  jnp.asarray(fvalid))

    def _build_fused(
        self, Bmax: int, Wcap: int,
        axis_steps: Tuple[Tuple[int, int], ...], total: int,
        faulted: bool = False,
    ):
        # deferred import: keep package init order independent
        from .frames import frame_parts_batch

        cfg = self.config
        W = cfg.frame_width
        phits = cfg.frame_phits
        frame_words = phits * PHIT_WORDS
        F = Wcap // frame_words + 1  # + terminator
        T = Bmax * F  # a rank's TX queue is exactly its own frames
        rx_cap, q_cap = self._capacities(T, total)
        route_local = self._build_local(T, axis_steps, q_cap, rx_cap)
        adaptive = cfg.adaptive

        def local(payloads, nbytes, routes, levels, svalid,
                  gather=None, xorv=None, fvalid=None):
            # (1, Bmax, …) — one device's pending sends.  Framing here means
            # the frames are BORN on the rank that owns them: no global
            # scatter, no resharding — the only cross-device traffic in the
            # whole tick is the routing ppermutes.
            hdr, data, _ = frame_parts_batch(
                payloads[0], nbytes[0], routes[0], list_level=levels[0],
                frame_phits=phits, adaptive=adaptive,
            )
            # wire-layout assembly (the Pallas assemble kernel's jnp twin —
            # inside shard_map the concat is free; the kernel remains the
            # unfused/TPU path)
            frames = jnp.concatenate([hdr, data], axis=-1)  # (Bmax, F, W)
            tx = frames.reshape(1, T, W)
            # frame f of send i is live iff f < frame_capacity(nbytes_i)
            words = (nbytes[0] + 3) // 4
            n_live = -(-words // frame_words) + 1
            fidx = jnp.arange(F, dtype=jnp.int32)[None, :]
            tx_valid = (
                svalid[0][:, None] & (fidx < n_live[:, None])
            ).reshape(1, T)
            if gather is not None:
                # fault injection: the post-fault queue is a gather of the
                # canonical rows (drop = row masked out, dup = row sourced
                # twice, reorder = permuted gather) XOR a corruption mask
                tx = (tx[0][gather[0]] ^ xorv[0])[None]
                tx_valid = fvalid
            rx, rx_cnt, ok, crc_ok, rx_step, rx_att, ctr = route_local(
                tx, tx_valid
            )
            # RX split, per-device (slicing — bit-identical to the Pallas
            # ``unpack_frames_batch`` twin used by the three-program path)
            return (
                rx[:, :, :HDR_WORDS], rx[:, :, HDR_WORDS:],
                rx_cnt, ok, crc_ok, rx_step, rx_att, ctr,
            )

        spec = P(self.axis_names)
        return jax.jit(
            shard_map(
                local,
                mesh=self.mesh,
                in_specs=(spec,) * (8 if faulted else 5),
                out_specs=(spec,) * 8,
                check_rep=False,
            )
        )
