"""Metrics registry: labeled Counters, Gauges, log2-bucket Histograms, and
bounded Series, plus the ONE shared arrive-step windowing implementation.

HGum's pitch is schema-driven correctness *and* hardware-quality
performance — but a claim like that is only checkable against live
numbers.  This module is the host-side half of the telemetry plane
(``repro.obs``): every subsystem (fabric ticks, the continuous batcher,
stream lanes, the serve loop) registers its observables here, and one
``snapshot()`` turns the whole registry into a JSON-ready dict that
``obs.report`` renders and ``python -m repro.obs`` summarizes.

Metric types
------------
* :class:`Counter` — monotonically increasing event count (``add``).
* :class:`Gauge`   — last-write-wins instantaneous value (``set``).
* :class:`Histogram` — fixed log2 buckets (upper bounds ``base * 2**i``),
  so the snapshot is a constant-size vector no matter how many samples
  land in it; tracks count/sum/min/max alongside the buckets.
* :class:`Series`  — a bounded append-only trace (e.g. per-tick
  backpressure p95 values) for observables whose *trajectory* matters.

Every metric is keyed by ``(name, sorted labels)``; asking for the same
key returns the same instance, so call sites never coordinate.

Shared windowing (the ``arrive_steps`` dedupe)
----------------------------------------------
``Fabric.class_arrive_stats`` and ``StreamReader.class_arrive_stats``
both used to hand-roll deque windows over router arrive steps.  The
window math now lives HERE — :func:`window_stats` (the percentile
definition both ends of the backpressure feedback loop must agree on)
and :class:`ClassWindows` (per-class bounded traces) — and both call it,
so the stats are byte-identical by construction.

``p95`` is nearest-rank with a CEIL rank (``ceil(0.95 * n)``): the
smallest value with >= 95% of the trace at or below it.  (A floor index
is biased one rank high — at n=20 it reports the maximum as "p95",
inflating the very tail signal the lane scheduler clamps on.)
"""
from __future__ import annotations

import json
import math
from collections import deque
from typing import Deque, Dict, Iterable, List, Optional, Tuple

#: bump when the snapshot layout changes (readers ignore unknown keys, so
#: additions are forward-compatible without a bump)
SNAPSHOT_SCHEMA = 1


def window_stats(steps: Iterable[float]) -> Dict[str, float]:
    """Latency statistics over a trace of router arrive steps (or any
    latency samples): ``mean`` tracks hop count + queueing, ``p50``/
    ``p95``/``max`` expose the tail a far-shard or starved tenant
    produces, and ``jitter`` is the stddev — the time-to-token wobble the
    shortest-path router shrinks.  Shared by
    ``StreamReader.class_arrive_stats``, ``Fabric.class_arrive_stats``,
    and the benchmarks so the producers and consumers of the backpressure
    feedback loop can never disagree on what "p95" means."""
    arr = sorted(steps)
    if not arr:
        return {"n": 0, "mean": 0.0, "p95": 0.0, "max": 0.0, "jitter": 0.0}
    n = len(arr)
    mean = sum(arr) / n
    var = sum((s - mean) ** 2 for s in arr) / n
    return {
        "n": n,
        "mean": mean,
        "p95": float(arr[min(n - 1, math.ceil(0.95 * n) - 1)]),
        "max": float(arr[-1]),
        "jitter": var ** 0.5,
    }


def quantile_from_buckets(
    base: float, buckets: List[int], count: int,
    vmin: Optional[float], vmax: Optional[float], q: float,
) -> Optional[float]:
    """Quantile estimate over a log2-bucket vector with within-bucket
    linear interpolation — the shared math behind
    :meth:`Histogram.quantile` and ``obs.report``'s snapshot diffs.

    The CEIL rank convention matches :func:`window_stats` (the smallest
    value with ``>= q`` of the mass at or below it); the hit bucket's
    span ``(lo, hi]`` is interpolated by the rank's position inside the
    bucket and the result is clamped to the exact observed ``[min,
    max]`` (so a one-sample histogram reports that sample, not a bucket
    edge).  Returns None on an empty histogram."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile q must be in [0, 1], got {q}")
    if count <= 0:
        return None
    rank = q * count
    cum = 0
    for i, c in enumerate(buckets):
        if c > 0 and cum + c >= rank:
            lo = 0.0 if i == 0 else base * (1 << (i - 1))
            hi = base * (1 << i)
            if i == len(buckets) - 1 and vmax is not None:
                hi = max(vmax, lo)  # overflow bucket: cap at observed max
            frac = min(1.0, max(0.0, (rank - cum) / c))
            v = lo + frac * (hi - lo)
            if vmin is not None:
                v = max(v, vmin)
            if vmax is not None:
                v = min(v, vmax)
            return v
        cum += c
    return vmax


class ClassWindows:
    """Per-class bounded traces of latency samples with shared stats.

    The one implementation of the "deque window per QoS class" pattern:
    ``record(cls, value)`` appends into a ``maxlen``-bounded deque and
    ``stats()`` runs :func:`window_stats` per class.  ``stats(window=k)``
    restricts each class to its most recent ``k`` samples so a clamped
    tenant can *recover* once its congested tail drains instead of being
    haunted by old congestion forever."""

    def __init__(self, maxlen: int = 256):
        self.maxlen = maxlen
        self._traces: Dict[int, Deque[float]] = {}

    def record(self, cls: int, value: float) -> None:
        self._traces.setdefault(cls, deque(maxlen=self.maxlen)).append(value)

    def trace(self, cls: int) -> List[float]:
        return list(self._traces.get(cls, ()))

    def stats(self, window: Optional[int] = None) -> Dict[int, Dict[str, float]]:
        return {
            cls: window_stats(list(tr)[-window:] if window else tr)
            for cls, tr in sorted(self._traces.items())
        }


# ---------------------------------------------------------------------------
# metric instances
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic event counter."""

    kind = "counter"

    def __init__(self) -> None:
        self.value = 0

    def add(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter increments must be >= 0, got {n}")
        self.value += n

    def _snap(self) -> dict:
        return {"value": self.value}


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def _snap(self) -> dict:
        return {"value": self.value}


class Histogram:
    """Fixed log2-bucket histogram: bucket ``i`` counts samples with
    ``value <= base * 2**i`` (the last bucket is the +inf overflow), so
    the snapshot stays constant-size regardless of sample volume.  The
    bucketed view costs resolution; ``count``/``sum``/``min``/``max``
    ride alongside exactly."""

    kind = "histogram"

    def __init__(self, base: float = 1.0, n_buckets: int = 24) -> None:
        if base <= 0 or n_buckets < 2:
            raise ValueError(f"bad histogram shape base={base} n={n_buckets}")
        self.base = base
        self.buckets = [0] * n_buckets
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: float) -> None:
        v = float(v)
        if v <= self.base:
            i = 0
        else:
            i = min(len(self.buckets) - 1,
                    int(math.ceil(math.log2(v / self.base))))
        self.buckets[i] += 1
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def bounds(self) -> List[float]:
        """Upper bound of each bucket (the last is open / +inf)."""
        return [self.base * (1 << i) for i in range(len(self.buckets))]

    def quantile(self, q: float) -> Optional[float]:
        """Quantile estimate with within-bucket linear interpolation,
        clamped to the exact observed [min, max] (see
        :func:`quantile_from_buckets`).  None when empty."""
        return quantile_from_buckets(
            self.base, self.buckets, self.count, self.min, self.max, q
        )

    def _snap(self) -> dict:
        return {
            "base": self.base, "buckets": list(self.buckets),
            "count": self.count, "sum": self.sum,
            "min": self.min, "max": self.max,
        }


class Series:
    """Bounded append-only value trace (per-tick trajectories)."""

    kind = "series"

    def __init__(self, maxlen: int = 4096) -> None:
        self.values: Deque[float] = deque(maxlen=maxlen)

    def append(self, v: float) -> None:
        self.values.append(float(v))

    def _snap(self) -> dict:
        return {"values": [float(v) for v in self.values]}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def _label_key(labels: Dict[str, object]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def format_key(name: str, labels: Dict[str, object]) -> str:
    """Canonical flat key: ``name{a=1,b=x}`` (labels sorted), ``name``
    when unlabeled — what reports and tests address metrics by."""
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in _label_key(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Process-local registry of labeled metrics.

    ``counter/gauge/histogram/series(name, **labels)`` get-or-create the
    instance for that (name, labels) key.  A name is pinned to ONE metric
    type at first use — re-registering it as another type raises, so two
    subsystems cannot silently fight over a name."""

    def __init__(self) -> None:
        self._metrics: Dict[Tuple[str, Tuple], object] = {}
        self._kinds: Dict[str, str] = {}

    def _get(self, cls, name: str, labels: Dict[str, object], **kw):
        kind = self._kinds.setdefault(name, cls.kind)
        if kind != cls.kind:
            raise ValueError(
                f"metric {name!r} already registered as a {kind}, "
                f"cannot re-register as a {cls.kind}"
            )
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = cls(**kw)
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, base: float = 1.0, n_buckets: int = 24,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, base=base,
                         n_buckets=n_buckets)

    def series(self, name: str, maxlen: int = 4096, **labels) -> Series:
        return self._get(Series, name, labels, maxlen=maxlen)

    # -- snapshot ----------------------------------------------------------

    def snapshot(self) -> dict:
        """The whole registry as a JSON-ready dict (stable ordering):
        ``{"schema": 1, "metrics": [{"name", "type", "labels", ...}]}``.
        Readers MUST ignore unknown keys — that is the forward-compat
        contract the bench perf gate and CI schema checks rely on."""
        rows = []
        for (name, labels), m in sorted(
            self._metrics.items(), key=lambda kv: (kv[0][0], kv[0][1])
        ):
            row = {"name": name, "type": m.kind, "labels": dict(labels)}
            row.update(m._snap())
            rows.append(row)
        return {"schema": SNAPSHOT_SCHEMA, "metrics": rows}

    def flat(self) -> Dict[str, object]:
        """``{format_key(...): value}`` view — counters/gauges map to
        their value, histograms to ``{count, sum, min, max}``, series to
        the value list.  The convenient form for asserts and reports."""
        out: Dict[str, object] = {}
        for (name, labels), m in self._metrics.items():
            key = format_key(name, dict(labels))
            if isinstance(m, (Counter, Gauge)):
                out[key] = m.value
            elif isinstance(m, Histogram):
                out[key] = {"count": m.count, "sum": m.sum,
                            "min": m.min, "max": m.max}
            else:
                out[key] = [float(v) for v in m.values]
        return out

    def to_json(self, **kw) -> str:
        return json.dumps(self.snapshot(), **kw)


def validate_snapshot(snap: dict) -> List[str]:
    """Schema check of a metrics snapshot (the CI artifact gate): returns
    a list of problems, empty when the snapshot is well-formed.  Unknown
    top-level or per-metric keys are NOT problems (forward-compat)."""
    errs: List[str] = []
    if not isinstance(snap, dict):
        return [f"snapshot must be a dict, got {type(snap).__name__}"]
    if not isinstance(snap.get("schema"), int):
        errs.append("missing/invalid 'schema' (int) field")
    rows = snap.get("metrics")
    if not isinstance(rows, list):
        return errs + ["missing/invalid 'metrics' (list) field"]
    for i, row in enumerate(rows):
        where = f"metrics[{i}]"
        if not isinstance(row, dict):
            errs.append(f"{where}: not a dict")
            continue
        name, typ = row.get("name"), row.get("type")
        if not isinstance(name, str) or not name:
            errs.append(f"{where}: missing metric name")
        if typ not in ("counter", "gauge", "histogram", "series"):
            errs.append(f"{where} ({name}): unknown type {typ!r}")
            continue
        if not isinstance(row.get("labels", {}), dict):
            errs.append(f"{where} ({name}): labels must be a dict")
        if typ in ("counter", "gauge") and not isinstance(
            row.get("value"), (int, float)
        ):
            errs.append(f"{where} ({name}): missing numeric value")
        if typ == "histogram":
            if not isinstance(row.get("buckets"), list):
                errs.append(f"{where} ({name}): missing bucket list")
            elif row.get("count") != sum(row["buckets"]):
                errs.append(f"{where} ({name}): count != sum(buckets)")
        if typ == "series" and not isinstance(row.get("values"), list):
            errs.append(f"{where} ({name}): missing values list")
    return errs
