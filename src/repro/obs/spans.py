"""Causal request spans: one id from ingress to first token.

A :class:`SpanTracker` mints a request id at ingress and every layer the
request touches appends events to it: the mailbox correlates deliveries
back through the route word's ``(src, dst, seq)`` range
(``Fabric.send(request_id=...)``), the continuous batcher marks
admit/evict, stream lanes mark first flush, and the serve loop marks the
first token.  The result is a *causal* record — which tick each leg
happened on — that the attribution report turns into per-request latency
breakdowns, with the tick marks telescoping exactly: the component sums
equal end-to-end TTFT in ticks by construction.

When a :class:`~repro.obs.trace.TraceRecorder` is attached, every span
event also emits a Chrome-trace **flow event** (``ph: s/t/f``, one
shared ``id`` per request) anchored to a tiny slice, so a single request
renders as one connected arc across ranks and layers in Perfetto
(ui.perfetto.dev: enable "Flow events" in the track menu).

Degradation is first-class: a corrupted or gap-ridden delivery marks its
span ``degraded`` with the reason (``crc``/``seq-gap``), and a message
that cannot be correlated at all surfaces as a tracker *anomaly* — a
request can degrade but never silently vanish (property-tested under
seeded ``tx_hook`` corruption).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

#: bump when the export layout changes (readers ignore unknown keys)
SPANS_SCHEMA = 1

#: ordered tick marks of the serve pipeline and the component names of
#: the deltas between consecutive *present* marks; the final component
#: sum telescopes to ``first_token_tick - ingress_tick`` exactly.
TICK_MARKS: Tuple[str, ...] = (
    "serve.ingress", "batcher.admit", "stream.first_flush",
    "serve.first_token",
)
_DELTA_NAMES: Dict[Tuple[str, str], str] = {
    ("serve.ingress", "batcher.admit"): "admit_wait",
    ("batcher.admit", "stream.first_flush"): "decode",
    ("stream.first_flush", "serve.first_token"): "return",
}


@dataclass
class SpanEvent:
    """One point on a request's arc."""

    name: str
    ts_us: float
    tick: Optional[int] = None
    pid: int = 0
    args: Dict[str, object] = field(default_factory=dict)


@dataclass
class RequestSpan:
    """Everything recorded about one request id."""

    rid: int
    label: str
    args: Dict[str, object] = field(default_factory=dict)
    events: List[SpanEvent] = field(default_factory=list)
    #: accumulated numeric latency components (fabric.queue_wait, ...)
    components: Dict[str, float] = field(default_factory=dict)
    degraded: bool = False
    reasons: List[str] = field(default_factory=list)
    done: bool = False

    def first_tick(self, name: str) -> Optional[int]:
        for ev in self.events:
            if ev.name == name and ev.tick is not None:
                return ev.tick
        return None


def tick_breakdown(span: RequestSpan) -> Dict[str, int]:
    """Per-request latency breakdown in TICKS from the span's mark events.

    Deltas between consecutive present :data:`TICK_MARKS` (named
    ``admit_wait`` / ``decode`` / ``return``; a skipped mark merges its
    delta into the next one under a ``a->b`` key) plus ``ttft_ticks``,
    the end-to-end total.  Because the deltas are consecutive
    differences, ``sum(components) == ttft_ticks`` EXACTLY — the
    telescoping identity the attribution tests pin."""
    marks = [(n, span.first_tick(n)) for n in TICK_MARKS]
    present = [(n, t) for n, t in marks if t is not None]
    if len(present) < 2:
        return {}
    out: Dict[str, int] = {}
    for (a, ta), (b, tb) in zip(present, present[1:]):
        out[_DELTA_NAMES.get((a, b), f"{a}->{b}")] = tb - ta
    out["ttft_ticks"] = present[-1][1] - present[0][1]
    return out


class SpanTracker:
    """Mints request ids and collects their causal event arcs.

    Pure host-side bookkeeping (no device work, no syncs); with a
    ``trace`` attached it additionally emits Perfetto flow events.  All
    methods tolerate unknown rids by recording an anomaly instead of
    raising — a miswired call site must surface in the export, not crash
    the serve loop."""

    def __init__(self, trace=None, clock=None):
        self.trace = trace
        self._clock = clock
        self._t0 = time.perf_counter()
        self._next_rid = 1
        self._spans: Dict[int, RequestSpan] = {}
        self.anomalies: List[Dict[str, object]] = []
        self._tick: Optional[int] = None

    # -- time/tick bases ---------------------------------------------------

    def now_us(self) -> float:
        if self._clock is not None:
            return float(self._clock())
        if self.trace is not None:
            return self.trace.now_us()
        return (time.perf_counter() - self._t0) * 1e6

    def set_tick(self, tick: Optional[int]) -> None:
        """Set the serve-loop tick subsequent events are stamped with."""
        self._tick = None if tick is None else int(tick)

    # -- span lifecycle ----------------------------------------------------

    def start(self, label: str, pid: int = 0, **args) -> int:
        """Mint a request id and open its span (flow origin ``ph: s``)."""
        rid = self._next_rid
        self._next_rid += 1
        span = RequestSpan(rid=rid, label=label, args=dict(args))
        self._spans[rid] = span
        self._mark(span, label, pid, args, flow_ph="s")
        return rid

    def event(self, rid: int, name: str, pid: int = 0, **args) -> None:
        """Append one arc point (flow step ``ph: t``)."""
        span = self._spans.get(rid)
        if span is None:
            self.anomaly("span.unknown_rid", rid=rid, event=name, **args)
            return
        self._mark(span, name, pid, args, flow_ph="t")

    def finish(self, rid: int, pid: int = 0, **args) -> None:
        """Close the span (flow terminus ``ph: f``, binding point e)."""
        span = self._spans.get(rid)
        if span is None:
            self.anomaly("span.unknown_rid", rid=rid, event="finish", **args)
            return
        span.done = True
        self._mark(span, f"{span.label}.done", pid, args, flow_ph="f")

    def degrade(self, rid: int, reason: str, pid: int = 0, **args) -> None:
        """Mark the span degraded (corruption/gap) — annotated, kept."""
        span = self._spans.get(rid)
        if span is None:
            self.anomaly("span.unknown_rid", rid=rid, event="degrade",
                         reason=reason, **args)
            return
        span.degraded = True
        for r in reason.split(","):
            if r and r not in span.reasons:
                span.reasons.append(r)
        self._mark(span, "degraded", pid, dict(args, reason=reason),
                   flow_ph="t")

    def add_component(self, rid: int, name: str, value: float) -> None:
        """Accumulate a named latency component onto the span."""
        span = self._spans.get(rid)
        if span is None:
            self.anomaly("span.unknown_rid", rid=rid, component=name)
            return
        span.components[name] = span.components.get(name, 0) + value

    def anomaly(self, name: str, **args) -> None:
        """Record a tracker-level anomaly (uncorrelatable delivery,
        unknown rid) — visible in the export and on the trace."""
        self.anomalies.append(
            {"name": name, "ts_us": self.now_us(), "tick": self._tick,
             **args}
        )
        if self.trace is not None:
            self.trace.instant(name, cat="span.anomaly",
                               args={k: _jsonable(v) for k, v in args.items()})

    # -- internals ---------------------------------------------------------

    def _mark(self, span: RequestSpan, name: str, pid: int,
              args: Dict[str, object], flow_ph: str) -> None:
        ts = self.now_us()
        span.events.append(SpanEvent(
            name=name, ts_us=ts, tick=self._tick, pid=pid,
            args={k: _jsonable(v) for k, v in args.items()},
        ))
        if self.trace is None:
            return
        # a flow point must bind to a slice at the same (pid, tid, ts):
        # emit a 1us anchor slice plus the flow event sharing the span id
        ev_args = {"rid": span.rid, **{k: _jsonable(v) for k, v in args.items()}}
        if self._tick is not None:
            ev_args["tick"] = self._tick
        self.trace.complete(name, ts, 1.0, cat="span", pid=pid,
                            args=ev_args)
        flow = {
            "name": span.label, "ph": flow_ph, "cat": "span",
            "id": span.rid, "pid": pid, "tid": 0, "ts": ts,
        }
        if flow_ph == "f":
            flow["bp"] = "e"  # bind to the enclosing slice
        self.trace.events.append(flow)

    # -- views -------------------------------------------------------------

    def get(self, rid: int) -> Optional[RequestSpan]:
        return self._spans.get(rid)

    def requests(self) -> List[RequestSpan]:
        return [self._spans[r] for r in sorted(self._spans)]

    def export(self) -> dict:
        """JSON-ready dump: per-request events, components, degradation,
        and the tick breakdown — the flight-recorder attribution report
        artifact CI uploads."""
        return {
            "schema": SPANS_SCHEMA,
            "requests": [
                {
                    "rid": s.rid,
                    "label": s.label,
                    "args": s.args,
                    "done": s.done,
                    "degraded": s.degraded,
                    "reasons": list(s.reasons),
                    "components": dict(s.components),
                    "breakdown": tick_breakdown(s),
                    "events": [
                        {"name": e.name, "ts_us": e.ts_us, "tick": e.tick,
                         "pid": e.pid, "args": e.args}
                        for e in s.events
                    ],
                }
                for s in self.requests()
            ],
            "anomalies": [dict(a) for a in self.anomalies],
        }


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    try:  # numpy scalars
        return v.item()
    except AttributeError:
        return str(v)
