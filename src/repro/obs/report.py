"""Render metrics snapshots + shared environment metadata.

``render_text`` turns a :func:`repro.obs.metrics.MetricsRegistry.snapshot`
dict into a human-readable report (one line per counter/gauge, a bucket
sketch per histogram, tail stats per series); ``render_json`` is the
machine form.  Both read metrics by ``name``/``type`` and ignore unknown
keys, per the snapshot forward-compat contract.

:func:`environment_meta` is the ONE place run provenance is assembled —
the ``meta`` block in ``BENCH_*.json`` smoke snapshots, serve
``--metrics-json`` exports, and CI artifacts all embed it, so a perf-gate
comparison across machines can tell "regression" from "different
hardware"."""
from __future__ import annotations

import datetime
import json
import subprocess
from typing import List, Optional

from .metrics import SNAPSHOT_SCHEMA, format_key, validate_snapshot

__all__ = ["environment_meta", "render_text", "render_json"]


def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5,
        )
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except Exception:
        return None


def environment_meta() -> dict:
    """Run provenance: schema version, jax/backend/device identity, git
    sha (None outside a checkout), and a UTC timestamp.  Readers treat
    every field as optional."""
    meta = {
        "schema_version": SNAPSHOT_SCHEMA,
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "git_sha": _git_sha(),
    }
    try:
        import jax

        devs = jax.devices()
        meta.update({
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "platform": devs[0].platform if devs else None,
            "device_kind": devs[0].device_kind if devs else None,
            "n_devices": len(devs),
        })
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        meta["jax_version"] = None
    return meta


def _hist_sketch(row: dict, width: int = 20) -> str:
    buckets = row.get("buckets") or []
    peak = max(buckets) if buckets else 0
    if not peak:
        return "(empty)"
    base = row.get("base", 1.0)
    parts = []
    for i, c in enumerate(buckets):
        if c:
            parts.append(f"<={base * (1 << i):g}:{c}")
    return " ".join(parts)


def render_text(snap: dict) -> str:
    """Human-readable report of a metrics snapshot."""
    lines: List[str] = [f"metrics snapshot (schema {snap.get('schema')})"]
    problems = validate_snapshot(snap)
    for p in problems:
        lines.append(f"  !! {p}")
    by_type = {"counter": [], "gauge": [], "histogram": [], "series": []}
    for row in snap.get("metrics", []):
        if isinstance(row, dict) and row.get("type") in by_type:
            by_type[row["type"]].append(row)
    for typ in ("counter", "gauge", "histogram", "series"):
        rows = by_type[typ]
        if not rows:
            continue
        lines.append(f"{typ}s ({len(rows)}):")
        for row in rows:
            key = format_key(row.get("name", "?"), row.get("labels") or {})
            if typ in ("counter", "gauge"):
                v = row.get("value")
                v = f"{v:g}" if isinstance(v, float) else str(v)
                lines.append(f"  {key} = {v}")
            elif typ == "histogram":
                lines.append(
                    f"  {key}: n={row.get('count')} sum={row.get('sum'):g}"
                    f" min={row.get('min')} max={row.get('max')}"
                    f"  [{_hist_sketch(row)}]"
                )
            else:
                vals = row.get("values") or []
                tail = ", ".join(f"{v:g}" for v in vals[-6:])
                lines.append(
                    f"  {key}: n={len(vals)} last=[{tail}]"
                )
    if len(lines) == 1:
        lines.append("  (no metrics)")
    return "\n".join(lines)


def render_json(snap: dict, meta: bool = True, **kw) -> str:
    """Machine form: the snapshot itself, optionally wrapped with
    :func:`environment_meta` provenance under ``meta``."""
    out = dict(snap)
    if meta:
        out["meta"] = environment_meta()
    return json.dumps(out, **kw)
