"""Render metrics snapshots + shared environment metadata.

``render_text`` turns a :func:`repro.obs.metrics.MetricsRegistry.snapshot`
dict into a human-readable report (one line per counter/gauge, a bucket
sketch per histogram, tail stats per series); ``render_json`` is the
machine form.  Both read metrics by ``name``/``type`` and ignore unknown
keys, per the snapshot forward-compat contract.

:func:`environment_meta` is the ONE place run provenance is assembled —
the ``meta`` block in ``BENCH_*.json`` smoke snapshots, serve
``--metrics-json`` exports, and CI artifacts all embed it, so a perf-gate
comparison across machines can tell "regression" from "different
hardware"."""
from __future__ import annotations

import datetime
import json
import subprocess
from typing import Dict, List, Optional

from .metrics import (
    SNAPSHOT_SCHEMA,
    format_key,
    quantile_from_buckets,
    validate_snapshot,
)

__all__ = [
    "environment_meta", "render_text", "render_json",
    "diff_snapshots", "render_diff",
    "attribution_rows", "render_attribution",
]


def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5,
        )
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except Exception:
        return None


def environment_meta() -> dict:
    """Run provenance: schema version, jax/backend/device identity, git
    sha (None outside a checkout), and a UTC timestamp.  Readers treat
    every field as optional."""
    meta = {
        "schema_version": SNAPSHOT_SCHEMA,
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "git_sha": _git_sha(),
    }
    try:
        import jax

        devs = jax.devices()
        meta.update({
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "platform": devs[0].platform if devs else None,
            "device_kind": devs[0].device_kind if devs else None,
            "n_devices": len(devs),
        })
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        meta["jax_version"] = None
    return meta


def _hist_sketch(row: dict, width: int = 20) -> str:
    buckets = row.get("buckets") or []
    peak = max(buckets) if buckets else 0
    if not peak:
        return "(empty)"
    base = row.get("base", 1.0)
    parts = []
    for i, c in enumerate(buckets):
        if c:
            parts.append(f"<={base * (1 << i):g}:{c}")
    return " ".join(parts)


def render_text(snap: dict) -> str:
    """Human-readable report of a metrics snapshot."""
    lines: List[str] = [f"metrics snapshot (schema {snap.get('schema')})"]
    problems = validate_snapshot(snap)
    for p in problems:
        lines.append(f"  !! {p}")
    by_type = {"counter": [], "gauge": [], "histogram": [], "series": []}
    for row in snap.get("metrics", []):
        if isinstance(row, dict) and row.get("type") in by_type:
            by_type[row["type"]].append(row)
    for typ in ("counter", "gauge", "histogram", "series"):
        rows = by_type[typ]
        if not rows:
            continue
        lines.append(f"{typ}s ({len(rows)}):")
        for row in rows:
            key = format_key(row.get("name", "?"), row.get("labels") or {})
            if typ in ("counter", "gauge"):
                v = row.get("value")
                v = f"{v:g}" if isinstance(v, float) else str(v)
                lines.append(f"  {key} = {v}")
            elif typ == "histogram":
                lines.append(
                    f"  {key}: n={row.get('count')} sum={row.get('sum'):g}"
                    f" min={row.get('min')} max={row.get('max')}"
                    f"  [{_hist_sketch(row)}]"
                )
            else:
                vals = row.get("values") or []
                tail = ", ".join(f"{v:g}" for v in vals[-6:])
                lines.append(
                    f"  {key}: n={len(vals)} last=[{tail}]"
                )
    if len(lines) == 1:
        lines.append("  (no metrics)")
    return "\n".join(lines)


def render_json(snap: dict, meta: bool = True, **kw) -> str:
    """Machine form: the snapshot itself, optionally wrapped with
    :func:`environment_meta` provenance under ``meta``."""
    out = dict(snap)
    if meta:
        out["meta"] = environment_meta()
    return json.dumps(out, **kw)


# ---------------------------------------------------------------------------
# snapshot diff (the perf-gate debugging tool)
# ---------------------------------------------------------------------------


def _by_key(snap: dict) -> Dict[str, dict]:
    out = {}
    for row in snap.get("metrics", []):
        if isinstance(row, dict) and row.get("name"):
            out[format_key(row["name"], row.get("labels") or {})] = row
    return out


def _row_summary(row: dict) -> object:
    typ = row.get("type")
    if typ in ("counter", "gauge"):
        return row.get("value")
    if typ == "histogram":
        return {
            "count": row.get("count"), "sum": row.get("sum"),
            "p95": quantile_from_buckets(
                row.get("base", 1.0), row.get("buckets") or [],
                int(row.get("count") or 0), row.get("min"), row.get("max"),
                0.95,
            ),
        }
    return {"n": len(row.get("values") or []),
            "last": (row.get("values") or [None])[-1]}


def diff_snapshots(a: dict, b: dict) -> dict:
    """Structured comparison of two metrics snapshots (a = baseline,
    b = candidate): ``{"added": {key: summary}, "removed": {...},
    "changed": {key: {"a", "b", "delta", "ratio"}}}``.  Counters and
    gauges get numeric delta + ratio; histograms compare count/sum and
    the interpolated p95; series compare length and last value.
    Unchanged metrics are omitted — an empty diff means the snapshots
    agree on every metric they share."""
    ka, kb = _by_key(a), _by_key(b)
    out = {
        "added": {k: _row_summary(kb[k]) for k in sorted(set(kb) - set(ka))},
        "removed": {k: _row_summary(ka[k]) for k in sorted(set(ka) - set(kb))},
        "changed": {},
    }
    for k in sorted(set(ka) & set(kb)):
        ra, rb = ka[k], kb[k]
        if ra.get("type") != rb.get("type"):
            out["changed"][k] = {
                "a": f"type={ra.get('type')}", "b": f"type={rb.get('type')}",
            }
            continue
        sa, sb = _row_summary(ra), _row_summary(rb)
        if sa == sb:
            continue
        entry: dict = {"a": sa, "b": sb}
        if isinstance(sa, (int, float)) and isinstance(sb, (int, float)):
            entry["delta"] = sb - sa
            entry["ratio"] = (sb / sa) if sa else None
        out["changed"][k] = entry
    return out


def render_diff(diff: dict) -> str:
    """Human-readable snapshot diff."""
    lines: List[str] = ["snapshot diff (a -> b):"]
    for k, s in diff.get("added", {}).items():
        lines.append(f"  + {k} = {json.dumps(s)}")
    for k, s in diff.get("removed", {}).items():
        lines.append(f"  - {k} = {json.dumps(s)}")
    for k, e in diff.get("changed", {}).items():
        extra = ""
        if "ratio" in e and e["ratio"] is not None:
            extra = f"  ({e['ratio']:.3g}x)"
        elif "delta" in e:
            extra = f"  (delta {e['delta']:+g})"
        lines.append(
            f"  ~ {k}: {json.dumps(e.get('a'))} -> {json.dumps(e.get('b'))}"
            f"{extra}"
        )
    n = sum(len(diff.get(k, {})) for k in ("added", "removed", "changed"))
    lines.append(f"{n} difference(s)" if n else "snapshots agree")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# flight-recorder attribution tables (obs.spans exports)
# ---------------------------------------------------------------------------

#: per-request table columns, in render order: the fabric legs come from
#: the on-device flight recorder (Delivery.attribution), the tick legs
#: from the span tick marks (exactly telescoping to ttft_ticks)
ATTR_COLUMNS = (
    "fabric.queue_wait", "fabric.stall", "fabric.transit",
    "fabric.defections", "admit_wait", "decode", "return", "ttft_ticks",
)


def attribution_rows(export: dict) -> List[dict]:
    """Flatten an ``obs.spans`` export into per-request attribution rows:
    one dict per request with label/degraded flags and every
    :data:`ATTR_COLUMNS` component present on the span."""
    rows = []
    for req in export.get("requests", ()):
        comp = dict(req.get("components") or {})
        comp.update(req.get("breakdown") or {})
        row = {
            "rid": req.get("rid"),
            "label": req.get("label"),
            "class": (req.get("args") or {}).get("cls"),
            "degraded": bool(req.get("degraded")),
            "reasons": ",".join(req.get("reasons") or ()),
            "done": bool(req.get("done")),
        }
        for c in ATTR_COLUMNS:
            if c in comp:
                row[c] = comp[c]
        rows.append(row)
    return rows


def render_attribution(export: dict) -> str:
    """The latency-attribution report: a per-request breakdown table plus
    per-class aggregate means — where each request's time went, column by
    column (fabric queue wait / stall / transit vs. admit wait / decode /
    return ticks)."""
    rows = attribution_rows(export)
    lines = [f"request attribution ({len(rows)} request(s)):"]
    if not rows:
        lines.append("  (no requests tracked)")
        return "\n".join(lines)
    cols = [c for c in ATTR_COLUMNS if any(c in r for r in rows)]
    hdr = ["rid", "label", "cls"] + [c.split(".")[-1] for c in cols] + ["flags"]
    table = [hdr]
    for r in rows:
        flags = []
        if r["degraded"]:
            flags.append(f"DEGRADED[{r['reasons']}]")
        if not r["done"]:
            flags.append("open")
        table.append(
            [str(r.get("rid")), str(r.get("label")),
             str(r.get("class", "") if r.get("class") is not None else "-")]
            + [f"{r[c]:g}" if c in r else "-" for c in cols]
            + [",".join(flags) or "ok"]
        )
    widths = [max(len(row[i]) for row in table) for i in range(len(hdr))]
    for row in table:
        lines.append("  " + "  ".join(c.ljust(w) for c, w in zip(row, widths)))
    # per-class aggregate (means per component)
    by_cls: Dict[object, List[dict]] = {}
    for r in rows:
        by_cls.setdefault(r.get("class"), []).append(r)
    if len(by_cls) > 1 or any(k is not None for k in by_cls):
        lines.append("per-class means:")
        for cls in sorted(by_cls, key=lambda c: (c is None, c)):
            grp = by_cls[cls]
            parts = []
            for c in cols:
                vals = [r[c] for r in grp if c in r]
                if vals:
                    parts.append(
                        f"{c.split('.')[-1]}={sum(vals) / len(vals):.2f}"
                    )
            lines.append(
                f"  class {cls if cls is not None else '-'} "
                f"(n={len(grp)}): " + " ".join(parts)
            )
    anomalies = export.get("anomalies") or []
    if anomalies:
        lines.append(f"anomalies ({len(anomalies)}):")
        for a in anomalies:
            lines.append(f"  !! {json.dumps(a)}")
    degraded = [r for r in rows if r["degraded"]]
    if degraded:
        lines.append(f"{len(degraded)} degraded request(s)")
    return "\n".join(lines)
