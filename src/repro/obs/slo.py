"""SLO declaration + evaluation over metrics snapshots.

The perf gate (``benchmarks.run``) compares wall-clock ratios; this
module gates on *service* objectives: declared latency/throughput/
integrity targets evaluated against a metrics snapshot (or a live
registry), each with a **burn rate** — observed / target for upper
bounds, target / observed for lower bounds — so "how close to the
budget" is a number, not a boolean.  ``python -m repro.obs slo`` and the
``--slo`` flags on the serve and bench CLIs run exactly this evaluator,
so CI can fail on budget violations.

Spec forms (``parse_slo``)::

    ttft_p95_s=0.5,arrive_p95_steps=12,drift_free     # inline text
    slo.json                                          # {"ttft_p95_s": 0.5, ...}

Built-in objectives:

* ``ttft_p95_s`` / ``ttft_p99_s`` / ``ttft_mean_s`` — first-token
  latency over the ``serve.ttft_s.series`` trace (windowed; falls back
  to the ``serve.ttft_s`` histogram quantile with within-bucket
  interpolation).
* ``arrive_p95_steps`` — router arrive-step p95 over the merged
  ``fabric.arrive.step`` class histograms (the fabric-side latency SLO).
* ``tokens_per_s_min`` — decode throughput lower bound
  (``serve.tokens_per_s`` gauge).
* ``drift_free`` — zero static-vs-observed load drift entries
  (``fabric.load_drift.entries`` gauge): every frame rode the link the
  analyzer predicted.
* ``max_retransmit_ratio`` — ARQ recovery overhead upper bound:
  ``fabric.arq.retransmits / max(1, fabric.frames.delivered)`` (both
  counted in frames).  A zero-fault ARQ run measures 0.0; the delivered
  counter must be present (an ARQ SLO over a run that never delivered a
  frame fails as unobservable).
* ``max:<flat-key>`` / ``min:<flat-key>`` — generic bound on any
  counter/gauge by its ``format_key`` name (also matches plain numeric
  dicts, e.g. bench ``LAST_METRICS``), so new metrics are gateable
  without touching this module.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .metrics import format_key, quantile_from_buckets

_BUILTIN = (
    "ttft_p95_s", "ttft_p99_s", "ttft_mean_s", "arrive_p95_steps",
    "tokens_per_s_min", "drift_free", "max_retransmit_ratio",
)


def parse_slo(spec) -> Dict[str, object]:
    """Parse an SLO spec: a dict (returned as-is), a path to a JSON file,
    or ``k=v,k=v`` inline text (a bare key means True)."""
    if isinstance(spec, dict):
        return dict(spec)
    text = str(spec).strip()
    if os.path.exists(text) or text.endswith(".json"):
        with open(text) as f:
            obj = json.load(f)
        if not isinstance(obj, dict):
            raise ValueError(f"SLO file {text} must hold a JSON object")
        return obj
    out: Dict[str, object] = {}
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            k, v = part.split("=", 1)
            try:
                out[k.strip()] = float(v)
            except ValueError:
                out[k.strip()] = v.strip()
        else:
            out[part] = True
    if not out:
        raise ValueError(f"empty SLO spec: {spec!r}")
    return out


@dataclass
class SLOResult:
    """One evaluated objective."""

    name: str
    target: object
    observed: Optional[float]
    ok: bool
    #: budget consumption: >= 1.0 means violated, None when unobservable
    burn_rate: Optional[float] = None
    detail: str = ""


@dataclass
class SLOReport:
    results: List[SLOResult] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.results)

    def violations(self) -> List[SLOResult]:
        return [r for r in self.results if not r.ok]

    def render_text(self) -> str:
        lines = ["slo evaluation:"]
        for r in self.results:
            mark = "PASS" if r.ok else "FAIL"
            obs = "n/a" if r.observed is None else f"{r.observed:.6g}"
            burn = "" if r.burn_rate is None else f"  burn={r.burn_rate:.2f}"
            det = f"  ({r.detail})" if r.detail else ""
            lines.append(
                f"  [{mark}] {r.name}: observed {obs} vs target "
                f"{r.target}{burn}{det}"
            )
        lines.append(
            "slo: " + ("all objectives met"
                       if self.ok else
                       f"{len(self.violations())} objective(s) VIOLATED")
        )
        return "\n".join(lines)


# -- snapshot access helpers -------------------------------------------------


def _rows(snapshot: dict, name: str) -> List[dict]:
    return [r for r in snapshot.get("metrics", ())
            if isinstance(r, dict) and r.get("name") == name]


def _series_values(snapshot: dict, name: str,
                   window: Optional[int]) -> List[float]:
    vals: List[float] = []
    for r in _rows(snapshot, name):
        if r.get("type") == "series":
            vals.extend(float(v) for v in r.get("values", ()))
    return vals[-window:] if window else vals


def _merged_hist_quantile(snapshot: dict, name: str,
                          q: float) -> Optional[float]:
    """Quantile over every labeled variant of a histogram merged into one
    bucket vector (requires — and asserts — a shared base)."""
    rows = [r for r in _rows(snapshot, name) if r.get("type") == "histogram"]
    if not rows:
        return None
    base = rows[0].get("base", 1.0)
    n = max(len(r.get("buckets", ())) for r in rows)
    buckets = [0] * n
    count, vmin, vmax = 0, None, None
    for r in rows:
        if r.get("base", 1.0) != base:
            raise ValueError(f"histogram {name}: mixed bucket bases")
        for i, c in enumerate(r.get("buckets", ())):
            buckets[i] += int(c)
        count += int(r.get("count", 0))
        for bound, pick in (("min", min), ("max", max)):
            v = r.get(bound)
            if v is not None:
                cur = vmin if bound == "min" else vmax
                picked = v if cur is None else pick(cur, v)
                if bound == "min":
                    vmin = picked
                else:
                    vmax = picked
    return quantile_from_buckets(base, buckets, count, vmin, vmax, q)


def _flat_value(snapshot: dict, values: Optional[Dict[str, object]],
                key: str) -> Optional[float]:
    """Look a flat key up in the plain values dict first (bench
    LAST_METRICS), then among the snapshot's counters/gauges by
    ``format_key``."""
    if values is not None and key in values:
        v = values[key]
        return float(v) if isinstance(v, (int, float)) else None
    for r in snapshot.get("metrics", ()):
        if not isinstance(r, dict) or r.get("type") not in ("counter", "gauge"):
            continue
        if format_key(r.get("name", ""), r.get("labels", {})) == key:
            return float(r.get("value", 0))
    return None


def _ttft(snapshot: dict, q: Optional[float],
          window: Optional[int]) -> Optional[float]:
    vals = _series_values(snapshot, "serve.ttft_s.series", window)
    if vals:
        if q is None:
            return sum(vals) / len(vals)
        arr = sorted(vals)
        import math
        return float(arr[min(len(arr) - 1,
                             max(0, math.ceil(q * len(arr)) - 1))])
    if q is None:
        rows = [r for r in _rows(snapshot, "serve.ttft_s")
                if r.get("type") == "histogram"]
        count = sum(int(r.get("count", 0)) for r in rows)
        total = sum(float(r.get("sum", 0.0)) for r in rows)
        return total / count if count else None
    return _merged_hist_quantile(snapshot, "serve.ttft_s", q)


# -- the evaluator -----------------------------------------------------------


def evaluate_slo(
    spec,
    snapshot: Optional[dict] = None,
    values: Optional[Dict[str, object]] = None,
    window: Optional[int] = None,
) -> SLOReport:
    """Evaluate a parsed (or parseable) SLO spec against a metrics
    snapshot and/or a plain ``{flat_key: number}`` values dict.  Every
    objective yields an :class:`SLOResult`; an objective whose signal is
    absent FAILS (detail says so) — an SLO that silently passes because
    nothing was measured is worse than no SLO."""
    spec = parse_slo(spec)
    snapshot = snapshot or {"metrics": []}
    rep = SLOReport()

    def upper(name, target, observed, detail=""):
        t = float(target)
        if observed is None:
            rep.results.append(SLOResult(
                name, t, None, False, None,
                detail or "signal absent from snapshot"))
        else:
            burn = observed / t if t > 0 else float("inf")
            rep.results.append(SLOResult(
                name, t, float(observed), observed <= t, burn, detail))

    def lower(name, target, observed, detail=""):
        t = float(target)
        if observed is None:
            rep.results.append(SLOResult(
                name, t, None, False, None,
                detail or "signal absent from snapshot"))
        else:
            burn = t / observed if observed > 0 else float("inf")
            rep.results.append(SLOResult(
                name, t, float(observed), observed >= t, burn, detail))

    for name, target in spec.items():
        if name == "ttft_p95_s":
            upper(name, target, _ttft(snapshot, 0.95, window))
        elif name == "ttft_p99_s":
            upper(name, target, _ttft(snapshot, 0.99, window))
        elif name == "ttft_mean_s":
            upper(name, target, _ttft(snapshot, None, window))
        elif name == "arrive_p95_steps":
            upper(name, target,
                  _merged_hist_quantile(snapshot, "fabric.arrive.step", 0.95))
        elif name == "tokens_per_s_min":
            lower(name, target,
                  _flat_value(snapshot, values, "serve.tokens_per_s"))
        elif name == "drift_free":
            if not target:  # drift_free=false: explicitly waived
                continue
            drift = _flat_value(snapshot, values, "fabric.load_drift.entries")
            if drift is None:
                rep.results.append(SLOResult(
                    name, 0, None, False, None,
                    "fabric.load_drift.entries absent from snapshot"))
            else:
                rep.results.append(SLOResult(
                    name, 0, drift, drift == 0,
                    None if drift == 0 else float("inf"),
                    "static-vs-observed link-load drift entries"))
        elif name == "max_retransmit_ratio":
            retx = _flat_value(snapshot, values, "fabric.arq.retransmits")
            delivered = _flat_value(snapshot, values,
                                    "fabric.frames.delivered")
            ratio = (None if retx is None or delivered is None
                     else retx / max(1.0, delivered))
            upper(name, target, ratio,
                  detail=("fabric.arq.retransmits / fabric.frames.delivered "
                          "absent from snapshot — not an ARQ run?"
                          if ratio is None else
                          f"retransmits={retx:.0f} delivered={delivered:.0f}"))
        elif name.startswith("max:"):
            upper(name, target, _flat_value(snapshot, values, name[4:]))
        elif name.startswith("min:"):
            lower(name, target, _flat_value(snapshot, values, name[4:]))
        else:
            rep.results.append(SLOResult(
                name, target, None, False, None,
                f"unknown objective (builtins: {', '.join(_BUILTIN)}; "
                f"or max:<key> / min:<key>)"))
    return rep
