"""On-device fabric counter block: layout + host-side folds.

The router scan carry (``fabric/router.py``) accumulates a small int32
counter vector per device — entirely device-side, returned alongside the
delivered frames, so the fused no-host-sync tick stays sync-free.  This
module owns the *layout* of that vector and the host-side folds that turn
per-rank counter deltas into human/machine aggregates, most importantly
the **observed per-(link, direction) load matrix** shaped exactly like the
static ``repro.analysis.comm.demand_link_loads`` matrix, so static-vs-
observed drift is a first-class, assertable signal.

Layout (per device, ``n_counters(n_axes)`` int32 slots)::

    [axis 0 fwd | axis 0 bwd | axis 1 fwd | ... ] [delivered, crc_fail]

with each (axis, direction) block holding :data:`CTR_FIELDS`:

* ``entered``   — frames taking their FIRST hop on this (axis, direction)
  (the device's axis coordinate still equals the frame's source
  coordinate).  A frame enters each axis at most once, so summing entered
  over a ring's devices counts *frames riding the ring* — the exact
  quantity ``demand_link_loads`` predicts statically.
* ``forwarded`` — frames moved one hop (link occupancy; transit frames
  count once per hop, so ``forwarded >= entered``).
* ``starved``   — scan steps where eligible demand was left waiting by
  this direction's credit budget (the defection trigger signal).
* ``defect_out``— frames that defected AWAY from this (preferred)
  direction after ``defect_after`` straight starved steps.
* ``spare_in``  — defectors admitted INTO this direction's spare credits
  (post-natural-traffic); globally ``sum(defect_out) == sum(spare_in)``.
* ``spilled``   — frames admitted via the QoS weighted-round-robin
  work-conserving spill (credits a class left unused, consumed by
  another class's frames).
* ``occupied``  — scan steps where this device held eligible demand for
  this direction (counts *events*, not loop trips, so fused and
  three-program ticks agree bit-for-bit even when their static scan
  bounds differ).

Globals: ``delivered`` (frames appended to this device's RX, self-sends
included) and ``crc_fail`` (delivered frames failing their CRC32).

This module is import-pure (no jax, no intra-repo imports at module
scope) so the router can depend on it without cycles.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

#: per-(axis, direction) counter fields, in slot order
CTR_FIELDS: Tuple[str, ...] = (
    "entered", "forwarded", "starved", "defect_out", "spare_in",
    "spilled", "occupied",
)
N_FIELDS = len(CTR_FIELDS)
#: per-device global counters appended after the (axis, direction) blocks
CTR_GLOBALS: Tuple[str, ...] = ("delivered", "crc_fail")

#: direction slot order within an axis (maps to analysis.comm DIR_* masks)
DIR_SLOTS = ("fwd", "bwd")


def n_counters(n_axes: int) -> int:
    """Length of one device's counter vector."""
    return n_axes * len(DIR_SLOTS) * N_FIELDS + len(CTR_GLOBALS)


def ctr_index(ai: int, dir_slot: int, field: str) -> int:
    """Slot of ``field`` for (axis ``ai``, direction slot 0=fwd/1=bwd)."""
    return (ai * len(DIR_SLOTS) + dir_slot) * N_FIELDS + \
        CTR_FIELDS.index(field)


def global_index(n_axes: int, field: str) -> int:
    return n_axes * len(DIR_SLOTS) * N_FIELDS + CTR_GLOBALS.index(field)


def counters_to_dict(axis_names: Sequence[str],
                     ctr: Sequence[int]) -> Dict[str, int]:
    """One device's (or a summed) counter vector as a flat name->value
    dict: ``link.<field>{axis=<name>,dir=fwd|bwd}`` plus the globals."""
    n_axes = len(axis_names)
    out: Dict[str, int] = {}
    for ai, axis in enumerate(axis_names):
        for di, dname in enumerate(DIR_SLOTS):
            for field in CTR_FIELDS:
                key = f"link.{field}{{axis={axis},dir={dname}}}"
                out[key] = int(ctr[ctr_index(ai, di, field)])
    for field in CTR_GLOBALS:
        out[field] = int(ctr[global_index(n_axes, field)])
    return out


# ---------------------------------------------------------------------------
# Per-frame attribution columns (the flight recorder)
#
# Where the counter block above aggregates *events per device*, the
# attribution block rides WITH each frame through the link-buffer
# ppermute: ``n_att(n_axes)`` int32 columns appended to the frame's
# queue-side state, updated once per executed scan step, delivered
# alongside the frame.  Layout::
#
#     [enter_step, stall, wait, defections, transit axis 0, transit axis 1, ...]
#
# * ``enter_step`` — the 1-based ``step_no`` of the frame's FIRST hop
#   (0 == never hopped, i.e. a self-send delivered before the scan).
# * ``stall``     — steps the frame was eligible on the active axis but
#   left waiting by credits/QoS (starvation, the defection trigger).
# * ``wait``      — steps the frame sat queued but NOT eligible on the
#   active axis (ingress queue wait: wrong-axis phase or already home).
# * ``defections``— times the frame defected to the opposite direction.
# * ``transit[ai]`` — hops the frame took on axis ``ai``.
#
# At every executed step a live queued frame lands in exactly one of
# {hopped, stalled, waiting}, so the per-frame invariant
# ``wait + stall + sum(transit) == arrive_step`` holds EXACTLY, and —
# because the updates are per-event like ``occupied`` — bit-identically
# across the fused and three-program engines.

#: fixed attribution slots, before the per-axis transit block
ATT_FIELDS: Tuple[str, ...] = ("enter_step", "stall", "wait", "defections")
ATT_ENTER, ATT_STALL, ATT_WAIT, ATT_DEFECT = 0, 1, 2, 3
N_ATT_FIXED = len(ATT_FIELDS)


def n_att(n_axes: int) -> int:
    """Width of one frame's attribution vector."""
    return N_ATT_FIXED + n_axes


def att_transit_index(ai: int) -> int:
    """Column of axis ``ai``'s transit (hop) count."""
    return N_ATT_FIXED + ai


@dataclass(frozen=True)
class FrameAttribution:
    """Host-side view of one delivered frame's attribution vector.

    ``queue_wait + stall + total_transit == arrive_step`` exactly, on
    every engine and routing mode (property-tested).  For a multi-frame
    message the :class:`~repro.fabric.mailbox.Delivery` carries the
    attribution of its *critical* frame — the one that arrived last."""

    enter_step: int = 0
    stall: int = 0
    wait: int = 0
    defections: int = 0
    transit: Tuple[int, ...] = field(default_factory=tuple)

    @property
    def total_transit(self) -> int:
        return sum(self.transit)

    @property
    def arrive_step(self) -> int:
        """The reconstructed arrival step (== ``Delivery.arrive_step``)."""
        return self.wait + self.stall + self.total_transit

    def components(self) -> Dict[str, int]:
        """Flat dict for reports: queue_wait / stall / transit / defections."""
        return {
            "queue_wait": self.wait,
            "stall": self.stall,
            "transit": self.total_transit,
            "defections": self.defections,
        }

    @classmethod
    def from_vector(cls, n_axes: int, vec: Sequence[int]) -> "FrameAttribution":
        return cls(
            enter_step=int(vec[ATT_ENTER]),
            stall=int(vec[ATT_STALL]),
            wait=int(vec[ATT_WAIT]),
            defections=int(vec[ATT_DEFECT]),
            transit=tuple(int(vec[att_transit_index(a)]) for a in range(n_axes)),
        )


def observed_link_loads(
    sizes: Sequence[int], per_rank_ctr: Sequence[Sequence[int]],
) -> Tuple[Dict[Tuple[Tuple[int, int], int], int], ...]:
    """Fold per-rank ``entered`` counters into the observed load matrix,
    keyed exactly like ``analysis.comm.demand_link_loads``: per axis,
    ``{((ring_hi, ring_lo), direction_mask): frames}``.

    A frame's first hop on an axis happens on the device whose axis
    coordinate equals the frame's source coordinate — i.e. *somewhere on
    the ring the frame rides* — and every ring device folds into the same
    ring id, so summing ``entered`` over ranks reproduces the static
    per-(ring, direction) frame counts for any deterministic demand.
    Zero-count keys are omitted (matching the static matrix, which only
    holds rings with demand)."""
    import math

    from ..analysis.comm import DIR_BWD, DIR_FWD

    masks = (DIR_FWD, DIR_BWD)  # index-aligned with DIR_SLOTS
    out: List[Dict[Tuple[Tuple[int, int], int], int]] = []
    for ai, n in enumerate(sizes):
        group: Dict[Tuple[Tuple[int, int], int], int] = {}
        if n > 1:
            stride = math.prod(sizes[ai + 1:])
            for r, ctr in enumerate(per_rank_ctr):
                ring = (r // (stride * n), r % stride)
                for di, dmask in enumerate(masks):
                    frames = int(ctr[ctr_index(ai, di, "entered")])
                    if frames:
                        key = (ring, dmask)
                        group[key] = group.get(key, 0) + frames
        out.append(group)
    return tuple(out)


def static_load_frames(
    loads: Sequence[Dict],
) -> Tuple[Dict[Tuple[Tuple[int, int], int], int], ...]:
    """Project a static ``demand_link_loads`` matrix (LinkLoad values)
    onto plain frame counts — the comparable view of the static side."""
    return tuple(
        {key: ll.frames for key, ll in group.items()} for group in loads
    )


def load_drift(
    expected: Sequence[Dict[Tuple[Tuple[int, int], int], int]],
    observed: Sequence[Dict[Tuple[Tuple[int, int], int], int]],
) -> Dict[Tuple[int, Tuple[int, int], int], Tuple[int, int]]:
    """Static-vs-observed divergence: ``{(axis, ring, direction):
    (expected_frames, observed_frames)}`` for every key where the two
    matrices disagree.  Empty dict == no drift — the assertable signal
    (a dropped, misrouted, or defected frame shows up as a nonzero
    entry on the link it should have ridden)."""
    out: Dict[Tuple[int, Tuple[int, int], int], Tuple[int, int]] = {}
    for ai in range(max(len(expected), len(observed))):
        e = expected[ai] if ai < len(expected) else {}
        o = observed[ai] if ai < len(observed) else {}
        for key in set(e) | set(o):
            ev, ov = int(e.get(key, 0)), int(o.get(key, 0))
            if ev != ov:
                out[(ai,) + key] = (ev, ov)
    return out
