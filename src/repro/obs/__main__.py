"""CLI: summarize or schema-check a telemetry artifact.

::

    python -m repro.obs metrics.json              # render a text report
    python -m repro.obs trace.json --validate     # schema-check (CI gate)

The file kind is auto-detected: a ``traceEvents`` key (or a bare JSON
array) is a Chrome trace; anything with a ``metrics`` list is a metrics
snapshot (a wrapping ``meta`` block is surfaced, not required).  With
``--validate`` the exit code is nonzero on any schema problem — that is
what CI runs against the uploaded artifacts."""
from __future__ import annotations

import argparse
import json
import sys

from .metrics import validate_snapshot
from .report import render_text
from .trace import validate_trace


def _detect(obj) -> str:
    if isinstance(obj, list):
        return "trace"
    if isinstance(obj, dict):
        if "traceEvents" in obj:
            return "trace"
        if isinstance(obj.get("metrics"), list):
            return "metrics"
    return "unknown"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize or validate a repro telemetry artifact "
        "(metrics snapshot or Chrome-trace JSON).",
    )
    ap.add_argument("file", help="metrics snapshot or trace JSON file")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check only; exit nonzero on problems")
    ap.add_argument("--kind", choices=("auto", "metrics", "trace"),
                    default="auto", help="override artifact detection")
    args = ap.parse_args(argv)

    try:
        with open(args.file) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot load {args.file}: {e}", file=sys.stderr)
        return 2

    kind = _detect(obj) if args.kind == "auto" else args.kind
    if kind == "unknown":
        print(f"error: {args.file} is neither a metrics snapshot nor a "
              "Chrome trace (use --kind to force)", file=sys.stderr)
        return 2

    if kind == "trace":
        errs = validate_trace(obj)
        n = len(obj if isinstance(obj, list) else obj.get("traceEvents", []))
        if errs:
            for e in errs:
                print(f"invalid trace: {e}", file=sys.stderr)
            return 1
        print(f"{args.file}: valid Chrome trace, {n} events")
        if not args.validate:
            names = {}
            events = obj if isinstance(obj, list) else obj["traceEvents"]
            for ev in events:
                if isinstance(ev, dict) and ev.get("ph") != "M":
                    names[ev.get("name")] = names.get(ev.get("name"), 0) + 1
            for name, cnt in sorted(names.items()):
                print(f"  {name}: {cnt}")
        return 0

    errs = validate_snapshot(obj)
    if errs:
        for e in errs:
            print(f"invalid snapshot: {e}", file=sys.stderr)
        return 1
    if args.validate:
        print(f"{args.file}: valid metrics snapshot, "
              f"{len(obj.get('metrics', []))} metrics")
        return 0
    meta = obj.get("meta")
    if isinstance(meta, dict):
        ident = " ".join(
            f"{k}={meta[k]}" for k in
            ("backend", "n_devices", "jax_version", "git_sha")
            if meta.get(k) is not None
        )
        if ident:
            print(f"meta: {ident}")
    print(render_text(obj))
    return 0


if __name__ == "__main__":  # pragma: no cover
    try:
        raise SystemExit(main())
    except BrokenPipeError:  # `... | head` closed the pipe mid-report
        raise SystemExit(0)
