"""CLI: summarize, schema-check, diff, or SLO-gate telemetry artifacts.

::

    python -m repro.obs metrics.json              # render a text report
    python -m repro.obs trace.json --validate     # schema-check (CI gate)
    python -m repro.obs diff a.json b.json        # compare two snapshots
    python -m repro.obs attribution spans.json    # latency breakdown table
    python -m repro.obs slo "ttft_p95_s=0.5" --metrics m.json
    python -m repro.obs history [bench_history.jsonl]

The single-file form auto-detects the kind: a ``traceEvents`` key (or a
bare JSON array) is a Chrome trace; anything with a ``metrics`` list is
a metrics snapshot (a wrapping ``meta`` block is surfaced, not
required).  With ``--validate`` the exit code is nonzero on any schema
problem — that is what CI runs against the uploaded artifacts.  The
subcommands dispatch on the first argument, so the legacy single-file
invocation keeps working unchanged."""
from __future__ import annotations

import argparse
import json
import sys

from .metrics import validate_snapshot
from .report import render_text
from .trace import validate_trace

SUBCOMMANDS = ("diff", "attribution", "slo", "history")


def _load_json(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot load {path}: {e}", file=sys.stderr)
        raise SystemExit(2)


def _cmd_diff(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs diff",
        description="Compare two metrics snapshots (new/removed/changed "
        "metrics with delta + ratio).",
    )
    ap.add_argument("a", help="baseline snapshot JSON")
    ap.add_argument("b", help="candidate snapshot JSON")
    ap.add_argument("--json", action="store_true", help="machine output")
    ap.add_argument("--fail-on-change", action="store_true",
                    help="exit nonzero when the snapshots differ")
    args = ap.parse_args(argv)
    from .report import diff_snapshots, render_diff

    diff = diff_snapshots(_load_json(args.a), _load_json(args.b))
    print(json.dumps(diff, indent=1) if args.json else render_diff(diff))
    n = sum(len(diff[k]) for k in ("added", "removed", "changed"))
    return 1 if (args.fail_on_change and n) else 0


def _cmd_attribution(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs attribution",
        description="Render a spans export (serve --attribution-json) as "
        "per-request / per-class latency-breakdown tables.",
    )
    ap.add_argument("file", help="spans export JSON")
    ap.add_argument("--json", action="store_true",
                    help="emit the flattened rows as JSON")
    args = ap.parse_args(argv)
    from .report import attribution_rows, render_attribution

    export = _load_json(args.file)
    if args.json:
        print(json.dumps(attribution_rows(export), indent=1))
    else:
        print(render_attribution(export))
    return 0


def _cmd_slo(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs slo",
        description="Evaluate declared SLO targets against a metrics "
        "snapshot; exit 1 on any violated objective.",
    )
    ap.add_argument("spec", help="inline 'k=v,k=v' spec or JSON file path")
    ap.add_argument("--metrics", required=True,
                    help="metrics snapshot JSON to evaluate against")
    ap.add_argument("--window", type=int, default=None,
                    help="restrict series objectives to the last N samples")
    args = ap.parse_args(argv)
    from .slo import evaluate_slo

    rep = evaluate_slo(args.spec, snapshot=_load_json(args.metrics),
                       window=args.window)
    print(rep.render_text())
    return 0 if rep.ok else 1


def _cmd_history(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs history",
        description="Summarize the bench trajectory "
        "(experiments/bench_history.jsonl rows appended by "
        "benchmarks.run --smoke).",
    )
    ap.add_argument("file", nargs="?", default="experiments/bench_history.jsonl")
    ap.add_argument("--metric", action="append", default=None,
                    help="metric key(s) to tabulate (default: a few headline "
                    "fabric/stream numbers present in the rows)")
    args = ap.parse_args(argv)
    try:
        with open(args.file) as f:
            rows = [json.loads(ln) for ln in f if ln.strip()]
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot load {args.file}: {e}", file=sys.stderr)
        return 2
    if not rows:
        print(f"{args.file}: no history rows yet")
        return 0
    flat_rows = []
    for r in rows:
        flat = {}
        for mod, metrics in (r.get("metrics") or {}).items():
            if isinstance(metrics, dict):
                for k, v in metrics.items():
                    if isinstance(v, (int, float)):
                        flat[f"{mod}.{k}"] = v
        flat_rows.append((r.get("git_sha"), r.get("timestamp"), flat))
    keys = args.metric
    if not keys:
        seen = sorted({k for _, _, f in flat_rows for k in f})
        prefer = [k for k in seen if any(
            t in k for t in ("frames_per_s", "ttft", "tokens_per_s", "p95")
        )]
        keys = (prefer or seen)[:6]
    print(f"bench history: {len(rows)} run(s) from {args.file}")
    hdr = ["sha", "timestamp"] + keys
    table = [hdr]
    for sha, ts, flat in flat_rows:
        table.append(
            [str(sha)[:9] if sha else "-", str(ts or "-")]
            + [f"{flat[k]:g}" if k in flat else "-" for k in keys]
        )
    widths = [max(len(row[i]) for row in table) for i in range(len(hdr))]
    for row in table:
        print("  " + "  ".join(c.ljust(w) for c, w in zip(row, widths)))
    return 0


def _detect(obj) -> str:
    if isinstance(obj, list):
        return "trace"
    if isinstance(obj, dict):
        if "traceEvents" in obj:
            return "trace"
        if isinstance(obj.get("metrics"), list):
            return "metrics"
    return "unknown"


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    # subcommand dispatch on the FIRST token only, so the legacy
    # single-file form (`python -m repro.obs metrics.json --validate`,
    # what CI runs) is untouched — a file named "diff" would need ./diff
    if argv and argv[0] in SUBCOMMANDS:
        return {
            "diff": _cmd_diff,
            "attribution": _cmd_attribution,
            "slo": _cmd_slo,
            "history": _cmd_history,
        }[argv[0]](argv[1:])
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize or validate a repro telemetry artifact "
        "(metrics snapshot or Chrome-trace JSON); subcommands: "
        "diff, attribution, slo, history.",
    )
    ap.add_argument("file", help="metrics snapshot or trace JSON file")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check only; exit nonzero on problems")
    ap.add_argument("--kind", choices=("auto", "metrics", "trace"),
                    default="auto", help="override artifact detection")
    args = ap.parse_args(argv)

    try:
        with open(args.file) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot load {args.file}: {e}", file=sys.stderr)
        return 2

    kind = _detect(obj) if args.kind == "auto" else args.kind
    if kind == "unknown":
        print(f"error: {args.file} is neither a metrics snapshot nor a "
              "Chrome trace (use --kind to force)", file=sys.stderr)
        return 2

    if kind == "trace":
        errs = validate_trace(obj)
        n = len(obj if isinstance(obj, list) else obj.get("traceEvents", []))
        if errs:
            for e in errs:
                print(f"invalid trace: {e}", file=sys.stderr)
            return 1
        print(f"{args.file}: valid Chrome trace, {n} events")
        if not args.validate:
            names = {}
            events = obj if isinstance(obj, list) else obj["traceEvents"]
            for ev in events:
                if isinstance(ev, dict) and ev.get("ph") != "M":
                    names[ev.get("name")] = names.get(ev.get("name"), 0) + 1
            for name, cnt in sorted(names.items()):
                print(f"  {name}: {cnt}")
        return 0

    errs = validate_snapshot(obj)
    if errs:
        for e in errs:
            print(f"invalid snapshot: {e}", file=sys.stderr)
        return 1
    if args.validate:
        print(f"{args.file}: valid metrics snapshot, "
              f"{len(obj.get('metrics', []))} metrics")
        return 0
    meta = obj.get("meta")
    if isinstance(meta, dict):
        ident = " ".join(
            f"{k}={meta[k]}" for k in
            ("backend", "n_devices", "jax_version", "git_sha")
            if meta.get(k) is not None
        )
        if ident:
            print(f"meta: {ident}")
    print(render_text(obj))
    return 0


if __name__ == "__main__":  # pragma: no cover
    try:
        raise SystemExit(main())
    except BrokenPipeError:  # `... | head` closed the pipe mid-report
        raise SystemExit(0)
