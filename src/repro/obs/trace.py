"""Chrome-trace / Perfetto JSON timeline export.

A :class:`TraceRecorder` collects events in the Chrome Trace Event Format
(the JSON array form under a ``traceEvents`` key), which both
``chrome://tracing`` and https://ui.perfetto.dev load directly:

* ``complete(name, start_us, dur_us)`` / ``span(name)`` — duration slices
  (``ph: "X"``): fabric ticks, decode ticks, bench modules;
* ``instant(name)`` — point events (``ph: "i"``): per-stream chunk
  arrivals, recompiles, deliveries (with the router ``arrive_step`` — the
  in-tick scan-step timeline — in ``args``);
* ``counter(name, values)`` — counter tracks (``ph: "C"``): live scan
  steps per tick, queue depths, occupancy.

Timestamps are microseconds since the recorder was created
(``time.perf_counter`` based — monotonic, sub-tick resolution).  Tracks
are named via pid/tid metadata events (``process_name``/``thread_name``),
so fabric ranks and serve shards render as separate rows.

:func:`validate_trace` is the CI schema gate: it checks a loaded trace
is a well-formed Chrome-trace event stream (list shape, required keys,
known phases, numeric timestamps) without constraining event *content*,
so new event kinds stay forward-compatible.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

#: event phases this recorder emits (validate_trace accepts the superset
#: chrome://tracing documents, so hand-written traces can use more)
PH_COMPLETE, PH_INSTANT, PH_COUNTER, PH_META = "X", "i", "C", "M"
KNOWN_PHASES = frozenset("BEXiICMPSTFsftNODabe()")


class TraceRecorder:
    """Collects Chrome-trace events; ``save()`` writes the JSON object."""

    def __init__(self) -> None:
        self._t0 = time.perf_counter()
        self.events: List[dict] = []
        self._named: set = set()

    def now_us(self) -> float:
        """Microseconds since the recorder started (event timebase)."""
        return (time.perf_counter() - self._t0) * 1e6

    def _base(self, name: str, ph: str, cat: str, pid: int, tid: int,
              ts: Optional[float], args: Optional[dict]) -> dict:
        ev = {
            "name": name, "ph": ph, "cat": cat, "pid": pid, "tid": tid,
            "ts": self.now_us() if ts is None else float(ts),
        }
        if args:
            ev["args"] = args
        return ev

    def name_track(self, pid: int, process: str,
                   tid: Optional[int] = None,
                   thread: Optional[str] = None) -> None:
        """Label a pid (and optionally a tid) row; idempotent."""
        key = (pid, None)
        if key not in self._named:
            self._named.add(key)
            self.events.append({
                "name": "process_name", "ph": PH_META, "pid": pid, "tid": 0,
                "ts": 0.0, "args": {"name": process},
            })
        if tid is not None and (pid, tid) not in self._named:
            self._named.add((pid, tid))
            self.events.append({
                "name": "thread_name", "ph": PH_META, "pid": pid, "tid": tid,
                "ts": 0.0, "args": {"name": thread or f"tid {tid}"},
            })

    def instant(self, name: str, cat: str = "obs", pid: int = 0,
                tid: int = 0, ts: Optional[float] = None,
                args: Optional[dict] = None) -> None:
        ev = self._base(name, PH_INSTANT, cat, pid, tid, ts, args)
        ev["s"] = "t"  # thread-scoped instant
        self.events.append(ev)

    def complete(self, name: str, start_us: float, dur_us: float,
                 cat: str = "obs", pid: int = 0, tid: int = 0,
                 args: Optional[dict] = None) -> None:
        ev = self._base(name, PH_COMPLETE, cat, pid, tid, start_us, args)
        ev["dur"] = max(0.0, float(dur_us))
        self.events.append(ev)

    def span(self, name: str, cat: str = "obs", pid: int = 0, tid: int = 0,
             args: Optional[dict] = None) -> "_Span":
        """``with trace.span("serve.tick"):`` — a complete event whose
        duration is the with-block's wall time."""
        return _Span(self, name, cat, pid, tid, args)

    def counter(self, name: str, values: Dict[str, float], cat: str = "obs",
                pid: int = 0, ts: Optional[float] = None) -> None:
        self.events.append(
            self._base(name, PH_COUNTER, cat, pid, 0, ts,
                       {k: float(v) for k, v in values.items()})
        )

    # -- export ------------------------------------------------------------

    def to_json(self) -> dict:
        """The JSON-object form chrome://tracing / Perfetto load."""
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
        }

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
            f.write("\n")


class _Span:
    def __init__(self, rec: TraceRecorder, name: str, cat: str, pid: int,
                 tid: int, args: Optional[dict]):
        self.rec, self.name, self.cat = rec, name, cat
        self.pid, self.tid, self.args = pid, tid, args

    def __enter__(self) -> "_Span":
        self._start = self.rec.now_us()
        return self

    def __exit__(self, *exc) -> None:
        self.rec.complete(self.name, self._start,
                          self.rec.now_us() - self._start, cat=self.cat,
                          pid=self.pid, tid=self.tid, args=self.args)


def validate_trace(obj) -> List[str]:
    """Schema-check a loaded trace JSON; returns problems (empty = valid).

    Accepts both the JSON-object form (``{"traceEvents": [...]}``) and
    the bare JSON-array form — the two shapes chrome://tracing loads.
    Event ``args`` and unknown extra keys are not constrained
    (forward-compatible, like the metrics snapshot contract)."""
    errs: List[str] = []
    if isinstance(obj, dict):
        events = obj.get("traceEvents")
        if not isinstance(events, list):
            return ["object form must carry a 'traceEvents' list"]
    elif isinstance(obj, list):
        events = obj
    else:
        return [f"trace must be a dict or list, got {type(obj).__name__}"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not a dict")
            continue
        if not isinstance(ev.get("name"), str) or not ev.get("name"):
            errs.append(f"{where}: missing event name")
        ph = ev.get("ph")
        if not isinstance(ph, str) or ph not in KNOWN_PHASES:
            errs.append(f"{where} ({ev.get('name')}): unknown phase {ph!r}")
            continue
        if ph != PH_META and not isinstance(ev.get("ts"), (int, float)):
            errs.append(f"{where} ({ev.get('name')}): missing numeric ts")
        if ph == PH_COMPLETE and not isinstance(ev.get("dur"), (int, float)):
            errs.append(f"{where} ({ev.get('name')}): X event missing dur")
        if ph == PH_COUNTER and not isinstance(ev.get("args"), dict):
            errs.append(f"{where} ({ev.get('name')}): C event missing args")
    return errs
