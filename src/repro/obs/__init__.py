"""repro.obs — the telemetry plane.

Four layers (see ISSUE/README "Observability"):

* **device counters + flight recorder** (:mod:`repro.obs.counters`):
  layout of the int32 counter block the router scan carry accumulates
  on-device — including the per-FRAME attribution columns (queue wait /
  stall / per-axis transit / defections) that ride with every frame and
  reconstruct its arrive step exactly — plus the host folds that turn
  per-rank deltas into the observed per-(link, direction) load matrix,
  the runtime counterpart of the static
  ``repro.analysis.comm.demand_link_loads`` matrix;
* **metrics registry** (:mod:`repro.obs.metrics`): labeled Counter /
  Gauge / log2-bucket Histogram (with interpolated ``quantile``) /
  Series with one ``snapshot()``, and the shared arrive-window
  statistics both the fabric and the stream reader report through;
* **causal spans + SLOs** (:mod:`repro.obs.spans`,
  :mod:`repro.obs.slo`): request ids minted at ingress flow through
  mailbox / batcher / stream lanes / serve as one connected Perfetto
  arc, and declared latency/throughput targets evaluate against
  snapshots with burn-rate output;
* **export** (:mod:`repro.obs.trace`, :mod:`repro.obs.report`):
  Chrome-trace JSON timelines, text/JSON metric reports, snapshot
  diffs, attribution tables, plus ``python -m repro.obs`` to summarize,
  ``--validate``, ``diff``, ``attribution``, ``slo``, or ``history``.
"""
from .counters import (
    ATT_FIELDS,
    CTR_FIELDS,
    CTR_GLOBALS,
    FrameAttribution,
    att_transit_index,
    counters_to_dict,
    ctr_index,
    global_index,
    load_drift,
    n_att,
    n_counters,
    observed_link_loads,
    static_load_frames,
)
from .metrics import (
    SNAPSHOT_SCHEMA,
    ClassWindows,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Series,
    format_key,
    quantile_from_buckets,
    validate_snapshot,
    window_stats,
)
from .report import (
    attribution_rows,
    diff_snapshots,
    environment_meta,
    render_attribution,
    render_diff,
    render_json,
    render_text,
)
from .slo import SLOReport, SLOResult, evaluate_slo, parse_slo
from .spans import RequestSpan, SpanEvent, SpanTracker, tick_breakdown
from .trace import TraceRecorder, validate_trace

__all__ = [
    "ATT_FIELDS",
    "CTR_FIELDS",
    "CTR_GLOBALS",
    "ClassWindows",
    "Counter",
    "FrameAttribution",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "RequestSpan",
    "SLOReport",
    "SLOResult",
    "SNAPSHOT_SCHEMA",
    "Series",
    "SpanEvent",
    "SpanTracker",
    "TraceRecorder",
    "att_transit_index",
    "attribution_rows",
    "counters_to_dict",
    "ctr_index",
    "diff_snapshots",
    "environment_meta",
    "evaluate_slo",
    "format_key",
    "global_index",
    "load_drift",
    "n_att",
    "n_counters",
    "observed_link_loads",
    "parse_slo",
    "quantile_from_buckets",
    "render_attribution",
    "render_diff",
    "render_json",
    "render_text",
    "static_load_frames",
    "tick_breakdown",
    "validate_snapshot",
    "validate_trace",
    "window_stats",
]
