"""repro.obs — the telemetry plane.

Three layers (see ISSUE/README "Observability"):

* **device counters** (:mod:`repro.obs.counters`): layout of the int32
  counter block the router scan carry accumulates on-device, plus the
  host folds that turn per-rank deltas into the observed per-(link,
  direction) load matrix — the runtime counterpart of the static
  ``repro.analysis.comm.demand_link_loads`` matrix;
* **metrics registry** (:mod:`repro.obs.metrics`): labeled Counter /
  Gauge / log2-bucket Histogram / Series with one ``snapshot()``, and
  the shared arrive-window statistics both the fabric and the stream
  reader report through;
* **export** (:mod:`repro.obs.trace`, :mod:`repro.obs.report`):
  Chrome-trace JSON timelines and text/JSON metric reports, plus
  ``python -m repro.obs`` to summarize or ``--validate`` either artifact.
"""
from .counters import (
    CTR_FIELDS,
    CTR_GLOBALS,
    counters_to_dict,
    ctr_index,
    global_index,
    load_drift,
    n_counters,
    observed_link_loads,
    static_load_frames,
)
from .metrics import (
    SNAPSHOT_SCHEMA,
    ClassWindows,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Series,
    format_key,
    validate_snapshot,
    window_stats,
)
from .report import environment_meta, render_json, render_text
from .trace import TraceRecorder, validate_trace

__all__ = [
    "CTR_FIELDS",
    "CTR_GLOBALS",
    "ClassWindows",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "SNAPSHOT_SCHEMA",
    "Series",
    "TraceRecorder",
    "counters_to_dict",
    "ctr_index",
    "environment_meta",
    "format_key",
    "global_index",
    "load_drift",
    "n_counters",
    "observed_link_loads",
    "render_json",
    "render_text",
    "static_load_frames",
    "validate_snapshot",
    "validate_trace",
    "window_stats",
]
