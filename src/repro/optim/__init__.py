"""Optimizer substrate: AdamW with fp32 master weights, schedules, clipping,
and gradient-accumulation microbatching."""
from .adamw import (
    AdamWConfig,
    OptState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
)
from .schedule import cosine_schedule, linear_warmup_cosine
from .microbatch import microbatched_grads

__all__ = [
    "AdamWConfig", "OptState", "adamw_init", "adamw_update",
    "clip_by_global_norm", "global_norm", "cosine_schedule",
    "linear_warmup_cosine", "microbatched_grads",
]
