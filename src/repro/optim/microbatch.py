"""Gradient-accumulation microbatching: scan over microbatch slices.

Keeps per-microbatch live activations 1/k of the full batch — the knob that
lets ``train_4k`` cells fit 16 GB/chip (see EXPERIMENTS.md per-cell notes).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def microbatched_grads(
    loss_fn: Callable[[PyTree, Dict], Tuple[jnp.ndarray, Dict]],
    params: PyTree,
    batch: Dict[str, jnp.ndarray],
    n_micro: int,
    constrain: Callable[[PyTree], PyTree] = lambda g: g,
    constrain_micro: Callable[[PyTree], PyTree] = lambda b: b,
) -> Tuple[jnp.ndarray, PyTree, Dict]:
    """Mean loss/grads over `n_micro` slices of the leading batch axis.

    `constrain` (e.g. with_sharding_constraint to the param layout) pins the
    gradient accumulator's sharding — without it the scan carry can
    materialize unsharded (full-size per device) and OOM the dry-run.
    `constrain_micro` pins the (n_micro, b/n_micro, ...) reshape to
    P(None, batch_axes, ...): the SPMD partitioner otherwise re-shards the
    split batch across the wrong axes and every activation downstream
    inherits the damage (measured: 4x per-device batch inflation).
    """
    if n_micro <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, constrain(grads), metrics

    def reshape(x):
        b = x.shape[0]
        assert b % n_micro == 0, f"batch {b} not divisible by {n_micro}"
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    micro = constrain_micro(jax.tree.map(reshape, batch))

    def body(carry, mb):
        acc_loss, acc_grads, acc_metrics = carry
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc_grads = constrain(jax.tree.map(jnp.add, acc_grads, grads))
        acc_metrics = {
            k: acc_metrics.get(k, 0.0) + v for k, v in metrics.items()
        }
        return (acc_loss + loss, acc_grads, acc_metrics), None

    zero_grads = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    # run one microbatch eagerly to learn the metrics structure
    (l0, m0), g0 = jax.value_and_grad(loss_fn, has_aux=True)(
        params, jax.tree.map(lambda x: x[0], micro)
    )
    g0 = constrain(jax.tree.map(lambda a, b: a.astype(jnp.float32) + b, g0, zero_grads))
    rest = jax.tree.map(lambda x: x[1:], micro)
    (loss, grads, metrics), _ = jax.lax.scan(body, (l0, g0, m0), rest)
    inv = 1.0 / n_micro
    grads = jax.tree.map(lambda g: (g * inv).astype(jnp.float32), grads)
    metrics = {k: v * inv for k, v in metrics.items()}
    return loss * inv, grads, metrics
