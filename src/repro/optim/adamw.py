"""AdamW with fp32 master weights (params may be bf16) and global-norm clip.

Hand-rolled (no optax dependency): the state is a plain pytree so the HGum
checkpoint layer serializes it like any other message.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    # "fp32": plain moments.  "q8": first moment int8 (blockwise absmax,
    # block 256) + second moment bf16 — 8.06 B/param of optimizer state
    # instead of 12, the knob that fits 398B AdamW on the 2-pod mesh
    # (EXPERIMENTS.md §Perf; convergence tested in tests/test_optim.py).
    moments: str = "fp32"


Q8_BLOCK = 256


def _q8_encode(x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % Q8_BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, Q8_BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(fp), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(fp / scale[:, None]), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def _q8_decode(enc: Dict[str, jnp.ndarray], shape) -> jnp.ndarray:
    fp = enc["q"].astype(jnp.float32) * enc["s"][:, None]
    n = 1
    for d in shape:
        n *= d
    return fp.reshape(-1)[:n].reshape(shape)


@jax.tree_util.register_dataclass
@dataclass
class OptState:
    step: jnp.ndarray  # scalar int32
    mu: PyTree  # first moment (fp32)
    nu: PyTree  # second moment (fp32)
    master: PyTree  # fp32 master copy of params


def adamw_init(params: PyTree, moments: str = "fp32") -> OptState:
    if moments == "q8":
        mu = jax.tree.map(lambda x: _q8_encode(jnp.zeros(x.shape, jnp.float32)), params)
        nu = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.bfloat16), params)
    else:
        f32 = lambda t: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), t)
        mu, nu = f32(params), f32(params)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=mu,
        nu=nu,
        # copy=True: fp32 params must not alias the master (donation safety)
        master=jax.tree.map(lambda x: jnp.array(x, jnp.float32, copy=True), params),
    )


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(
    grads: PyTree,
    state: OptState,
    params: PyTree,
    cfg: AdamWConfig,
    lr: jnp.ndarray | float,
) -> Tuple[PyTree, OptState, Dict[str, jnp.ndarray]]:
    """One AdamW step.  Returns (new params in original dtype, state, stats)."""
    metrics = {}
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        metrics["grad_norm"] = gnorm
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1**t
    c2 = 1.0 - cfg.b2**t

    q8 = cfg.moments == "q8"

    def upd(g, mu, nu, m):
        g = g.astype(jnp.float32)
        if q8:
            mu_f = _q8_decode(mu, g.shape)
            nu_f = nu.astype(jnp.float32)
        else:
            mu_f, nu_f = mu, nu
        mu_f = cfg.b1 * mu_f + (1 - cfg.b1) * g
        nu_f = cfg.b2 * nu_f + (1 - cfg.b2) * g * g
        delta = (mu_f / c1) / (jnp.sqrt(nu_f / c2) + cfg.eps)
        m = m - lr * (delta + cfg.weight_decay * m)
        if q8:
            return _q8_encode(mu_f), nu_f.astype(jnp.bfloat16), m
        return mu_f, nu_f, m

    flat_g, treedef = jax.tree.flatten(grads)
    is_enc = lambda t: isinstance(t, dict) and set(t) == {"q", "s"}
    flat_mu = treedef.flatten_up_to(state.mu) if not q8 else [
        sub for sub in jax.tree.flatten(state.mu, is_leaf=is_enc)[0]
    ]
    flat_nu = treedef.flatten_up_to(state.nu)
    flat_m = treedef.flatten_up_to(state.master)
    out = [upd(g, mu, nu, m) for g, mu, nu, m in zip(flat_g, flat_mu, flat_nu, flat_m)]
    mu = jax.tree.unflatten(treedef, [o[0] for o in out])  # q8: dict leaves
    nu = jax.tree.unflatten(treedef, [o[1] for o in out])
    master = jax.tree.unflatten(treedef, [o[2] for o in out])
    flat_p = treedef.flatten_up_to(params)
    new_params = jax.tree.unflatten(
        treedef,
        [m.astype(p.dtype) for m, p in zip([o[2] for o in out], flat_p)],
    )
    metrics["param_norm"] = global_norm(master)
    return new_params, OptState(step=step, mu=mu, nu=nu, master=master), metrics
