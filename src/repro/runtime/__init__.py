"""Distributed runtime: sharding rules, framed channels, compression,
pipeline, and the continuous-batching serve scheduler."""
from .sharding import (
    ShardRules,
    batch_pspec,
    batch_shardings,
    cache_shardings,
    param_pspec,
    param_shardings,
    replicated,
)
from .channels import (
    FRAME_PHITS,
    crc32_words,
    frame_stream,
    make_framed_sender,
    pod_ring_exchange,
    unframe_stream,
)
from .compress import (
    compress_tree,
    cross_pod_mean_int8,
    decompress_tree,
    init_error,
    new_error,
)
from .pipeline import gpipe_forward, split_stages, stack_stage_params
from .scheduler import ContinuousBatcher, SchedulerConfig

__all__ = [
    "ContinuousBatcher", "SchedulerConfig",
    "ShardRules", "batch_pspec", "batch_shardings", "cache_shardings",
    "param_pspec", "param_shardings", "replicated",
    "FRAME_PHITS", "crc32_words", "frame_stream", "make_framed_sender",
    "pod_ring_exchange",
    "unframe_stream", "compress_tree", "cross_pod_mean_int8",
    "decompress_tree", "init_error", "new_error",
    "gpipe_forward", "split_stages", "stack_stage_params",
]
