"""Activation-sharding hook.

Models are mesh-agnostic; launchers install a constrainer that pins named
activation classes to PartitionSpecs (with_sharding_constraint).  Without
the pin, SPMD propagation lets weight shardings leak into the residual
stream and every loop iteration downstream pays resharding collectives
(measured on yi-6b train_4k: 894 GB/device of all-reduce in the attention
backward, 47x the constrained layout).

Kinds:
  residual — (B, S, d) layer inputs/outputs: P(batch, None, None)
  logits   — (B, S, V): P(batch, None, vocab_axis)
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Callable

import jax

Constrainer = Callable[[jax.Array, str], jax.Array]

_constrainer: contextvars.ContextVar[Constrainer] = contextvars.ContextVar(
    "act_constrainer", default=lambda x, kind: x
)


def constrain(x, kind: str):
    """Apply the installed activation constraint (identity by default)."""
    return _constrainer.get()(x, kind)


@contextlib.contextmanager
def use_constrainer(fn: Constrainer):
    tok = _constrainer.set(fn)
    try:
        yield
    finally:
        _constrainer.reset(tok)


def mesh_constrainer(mesh, rules, global_batch: int) -> Constrainer:
    """Standard constrainer: batch axes on dim 0, vocab over tensor axis."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from .sharding import batch_pspec

    def fn(x, kind):
        if x.ndim < 2:
            return x
        if kind == "moe_buffer":  # (E, C, d|ff)
            # EP when E divides the tensor axis; otherwise shard the
            # capacity dim over BOTH axes (mixtral E=8 < 16: a replicated
            # buffer measured 3.7 TB/device on prefill_32k).
            tsz = mesh.shape.get(rules.tensor, 1)
            fsz = mesh.shape.get(rules.fsdp, 1) if isinstance(rules.fsdp, str) else 1
            e_ax = rules.tensor if x.shape[0] % tsz == 0 else None
            C = x.shape[1]
            if e_ax is not None:
                c_ax = rules.fsdp if C % fsz == 0 else None
            elif C % (fsz * tsz) == 0:
                c_ax = (rules.fsdp, rules.tensor)
            elif C % fsz == 0:
                c_ax = rules.fsdp
            elif C % tsz == 0:
                c_ax = rules.tensor
            else:
                c_ax = None
            spec = P(e_ax, c_ax, *([None] * (x.ndim - 2)))
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        if kind == "tokens_flat":  # (B*S, d): rows are B-major
            tsz = mesh.shape.get(rules.tensor, 1)
            fsz = mesh.shape.get(rules.fsdp, 1) if isinstance(rules.fsdp, str) else 1
            n = x.shape[0]
            if n % (fsz * tsz) == 0:
                ax = (rules.fsdp, rules.tensor)
            elif n % fsz == 0:
                ax = rules.fsdp
            elif n % tsz == 0:
                ax = rules.tensor
            else:
                ax = None
            spec = P(ax, *([None] * (x.ndim - 1)))
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        bax = batch_pspec(mesh, rules, x.shape[0])
        used = set()
        for entry in bax:
            if isinstance(entry, (tuple, list)):
                used.update(entry)
            elif entry is not None:
                used.add(entry)
        if kind == "residual":
            spec = P(*(list(bax) + [None] * (x.ndim - 1)))
        elif kind == "logits":
            ax = rules.tensor if (
                x.shape[-1] % mesh.shape[rules.tensor] == 0
                and rules.tensor not in used  # batch may own every axis (pure DP)
            ) else None
            spec = P(*(list(bax) + [None] * (x.ndim - 2) + [ax]))
        else:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return fn
