"""Continuous-batching serve scheduler: fixed KV slots, admit/evict per step.

The seed's ``serve_request`` answered one HGum wire at a time: fresh ROM
walk, per-request ``jax.jit`` of prefill/decode, one generate loop per
request.  This module is the compute half of the batched message plane
(ISSUE 1): a :class:`ContinuousBatcher` owns

* a **slot cache** — one KV cache of ``slots`` rows (``init_cache(cfg,
  slots, prompt_cap + max_new)``) that lives across requests;
* **cached jitted steps** — ``launch.steps.cached_serve_steps`` memoizes the
  jitted prefill/decode on (cfg, cache_len), so admission never re-traces;
* an **admit/evict loop** — every :meth:`step` first admits pending
  sequences into free slots (one fixed-shape prefill of ``admit_cap`` rows,
  scattered into the slot cache with an OOB-dropping ``.at[].set``), then
  runs ONE batched decode step for all live slots and evicts the finished
  ones.

:meth:`step` is split into :meth:`step_begin` / :meth:`step_finish` so a
driver can overlap fabric ticks with compute (ISSUE 3's streaming plane):
``step_begin`` dispatches the admit prefill and the batched decode — JAX
async dispatch returns before the device finishes — and ``step_finish``
performs the one host sync, records the tick's tokens, and returns them as
``(seq_id, position, token)`` emissions for the per-sequence StreamWriters.
Between the two calls the host is free to reap/dispatch
``Fabric.exchange_async`` ticks while the decode step runs.

Sequences are plain token lists; the wire plane (``launch.serve``) sits on
either side of this class — batched HGum DES in front, bulk SER behind.
"""
from __future__ import annotations

import functools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig

# NB: launch.steps / models are imported lazily inside ContinuousBatcher —
# models itself pulls in repro.runtime (actshard), so a module-level import
# here would be circular.

PyTree = Any


@dataclass(frozen=True)
class SchedulerConfig:
    """Knobs of the serve loop (documented in launch/serve.py's docstring)."""

    slots: int = 8  # fixed KV-cache rows = max concurrent sequences
    prompt_cap: int = 32  # prompts are padded/truncated to this length
    max_new: int = 16  # greedy tokens generated per sequence
    admit_cap: Optional[int] = None  # prefill width per tick (default: slots)

    def __post_init__(self) -> None:
        if self.slots < 1 or self.prompt_cap < 1 or self.max_new < 1:
            raise ValueError(
                f"slots/prompt_cap/max_new must be >= 1, got "
                f"{self.slots}/{self.prompt_cap}/{self.max_new}"
            )
        if self.admit_cap is not None and self.admit_cap < 1:
            raise ValueError(f"admit_cap must be >= 1 or None, got {self.admit_cap}")

    @property
    def admit_width(self) -> int:
        return self.admit_cap or self.slots

    @property
    def cache_len(self) -> int:
        return self.prompt_cap + self.max_new


@dataclass
class _Sequence:
    seq_id: Hashable
    tokens: List[int]
    out: List[int] = field(default_factory=list)
    remaining: int = 0


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _scatter_rows(cache: PyTree, cur_tok: jnp.ndarray, new_cache: PyTree,
                  new_tok: jnp.ndarray, slot_ids: jnp.ndarray):
    """Insert prefilled rows into their slots.

    ``slot_ids`` is padded with an out-of-range id for unused admit rows, so
    ``mode="drop"`` discards them and the call keeps one static shape.
    """
    cache = jax.tree.map(
        lambda c, n: c.at[slot_ids].set(n.astype(c.dtype), mode="drop"),
        cache, new_cache,
    )
    cur_tok = cur_tok.at[slot_ids].set(new_tok, mode="drop")
    return cache, cur_tok


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_vec(vec: jnp.ndarray, new: jnp.ndarray, slot_ids: jnp.ndarray):
    """Slot-scatter for the per-slot logprob column (same drop rule)."""
    return vec.at[slot_ids].set(new.astype(vec.dtype), mode="drop")


class ContinuousBatcher:
    """Admit/decode/evict loop over a fixed-slot KV cache."""

    def __init__(self, params: PyTree, cfg: ModelConfig,
                 sched: SchedulerConfig, metrics=None, spans=None,
                 logprobs: bool = False):
        from ..launch.steps import cached_serve_steps

        self.params = params
        self.cfg = cfg
        self.sched = sched
        #: optional obs.metrics.MetricsRegistry (admit/evict counters,
        #: occupancy + queue-depth gauges); None = no-op telemetry
        self.metrics = metrics
        #: optional obs.spans.SpanTracker; seq_ids with an entry in
        #: :attr:`span_of` get "batcher.admit"/"batcher.evict" arc points
        self.spans = spans
        self.span_of: Dict[Hashable, int] = {}
        #: when True the steps also return the chosen token's logprob,
        #: surfaced per tick in :attr:`tick_logprobs` (the greedy pick is
        #: unchanged — token output is byte-identical either way)
        self.logprobs = logprobs
        self.prefill_step, self.decode_step = cached_serve_steps(
            cfg, cache_len=sched.cache_len, logprobs=logprobs
        )
        # The slot cache must be row-compatible with what prefill emits —
        # families can grow it beyond prompt_cap + max_new (e.g. vlm KV
        # includes the vision prefix) — so allocate it from prefill's
        # eval_shape with the batch dim widened to `slots`.  The cache is
        # the last output either way (tok[, lp], cache).
        out_spec = jax.eval_shape(
            self.prefill_step, params, self._batch_specs(sched.admit_width)
        )
        cache_spec = out_spec[-1]
        self.cache = jax.tree.map(
            lambda s: jnp.zeros((sched.slots,) + s.shape[1:], s.dtype), cache_spec
        )
        self.cur_tok = jnp.zeros((sched.slots, 1), jnp.int32)
        self.cur_lp = jnp.zeros((sched.slots, 1), jnp.float32)
        #: (seq_id, position) -> logprob of every emission of the last tick
        #: (only filled when ``logprobs=True``); the step_finish triple API
        #: is unchanged so logprob-free callers never pay for it
        self.tick_logprobs: Dict[Tuple[Hashable, int], float] = {}
        # static non-token model inputs (vision/audio placeholders) are
        # allocated once, not per admit tick
        self._extra_inputs = {
            k: jnp.zeros(s.shape, s.dtype)
            for k, s in self._batch_specs(sched.admit_width).items()
            if k != "tokens"
        }
        self.active: List[Optional[_Sequence]] = [None] * sched.slots
        self.pending: Deque[_Sequence] = deque()
        self.done: Dict[Hashable, List[int]] = {}
        self.steps_run = 0
        self._tick_emit: List[Tuple[Hashable, int, int]] = []
        self._stepped = False

    def _batch_specs(self, A: int) -> Dict[str, jax.ShapeDtypeStruct]:
        S = self.sched.prompt_cap
        specs = {"tokens": jax.ShapeDtypeStruct((A, S), jnp.int32)}
        if self.cfg.family == "vlm":
            specs["vision"] = jax.ShapeDtypeStruct(
                (A, self.cfg.vision_tokens, self.cfg.vision_dim), jnp.float32
            )
        if self.cfg.family == "encdec":
            specs["audio"] = jax.ShapeDtypeStruct(
                (A, self.cfg.enc_seq, self.cfg.d_model), jnp.float32
            )
        return specs

    # -- queue -------------------------------------------------------------

    def submit(self, seq_id: Hashable, tokens: List[int]) -> None:
        self.pending.append(_Sequence(seq_id, list(tokens)))

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.active)

    # -- scheduler tick ----------------------------------------------------

    def _admit(self) -> None:
        free = [i for i, s in enumerate(self.active) if s is None]
        if not free or not self.pending:
            return
        A = self.sched.admit_width
        take = min(len(free), A, len(self.pending))
        seqs = [self.pending.popleft() for _ in range(take)]
        S = self.sched.prompt_cap
        toks = np.zeros((A, S), np.int32)
        for j, seq in enumerate(seqs):
            toks[j, : min(len(seq.tokens), S)] = seq.tokens[:S]
        batch = dict(self._extra_inputs)
        batch["tokens"] = jnp.asarray(toks)
        if self.logprobs:
            next_tok, next_lp, new_cache = self.prefill_step(self.params, batch)
        else:
            next_tok, new_cache = self.prefill_step(self.params, batch)
            next_lp = None
        # unused admit rows -> OOB slot id, dropped by the scatter
        slot_ids = np.full(A, self.sched.slots, np.int32)
        slot_ids[:take] = free[:take]
        self.cache, self.cur_tok = _scatter_rows(
            self.cache, self.cur_tok, new_cache, next_tok, jnp.asarray(slot_ids)
        )
        first = np.asarray(next_tok)[:take, 0]
        if next_lp is not None:
            self.cur_lp = _scatter_vec(
                self.cur_lp, next_lp, jnp.asarray(slot_ids)
            )
            first_lp = np.asarray(next_lp)[:take, 0]
        for j, seq in enumerate(seqs):
            seq.out.append(int(first[j]))
            seq.remaining = self.sched.max_new - 1
            self.active[free[j]] = seq
            self._tick_emit.append((seq.seq_id, 0, int(first[j])))
            if next_lp is not None:
                self.tick_logprobs[(seq.seq_id, 0)] = float(first_lp[j])
            if self.spans is not None and seq.seq_id in self.span_of:
                self.spans.event(self.span_of[seq.seq_id], "batcher.admit",
                                 slot=free[j])
        if self.metrics is not None:
            self.metrics.counter("batcher.admitted").add(take)
        self._evict()

    def _evict(self) -> None:
        evicted = 0
        for i, seq in enumerate(self.active):
            if seq is not None and seq.remaining <= 0:
                self.done[seq.seq_id] = seq.out
                self.active[i] = None
                evicted += 1
                if self.spans is not None and seq.seq_id in self.span_of:
                    self.spans.event(self.span_of[seq.seq_id],
                                     "batcher.evict", n_out=len(seq.out))
        if self.metrics is not None and evicted:
            self.metrics.counter("batcher.evicted").add(evicted)

    def step_begin(self) -> bool:
        """Dispatch one scheduler tick: admit into free slots, then launch
        one batched decode step for every live slot.

        Returns immediately after dispatch (JAX async) — the host can run
        fabric work while the decode executes.  Returns True when a decode
        step was dispatched.  Must be paired with :meth:`step_finish`.
        """
        self._tick_emit = []
        self.tick_logprobs = {}
        self._admit()
        if self.metrics is not None:
            self.metrics.gauge("batcher.occupancy").set(self.n_active)
            self.metrics.gauge("batcher.queue_depth").set(len(self.pending))
        if self.n_active == 0:
            self._stepped = False
            return False
        if self.logprobs:
            self.cur_tok, self.cur_lp, self.cache = self.decode_step(
                self.params, self.cache, self.cur_tok
            )
        else:
            self.cur_tok, self.cache = self.decode_step(
                self.params, self.cache, self.cur_tok
            )
        self.steps_run += 1
        self._stepped = True
        if self.metrics is not None:
            self.metrics.counter("batcher.steps").add(1)
        return True

    def step_finish(self) -> List[Tuple[Hashable, int, int]]:
        """Sync the dispatched tick and return its emissions.

        One host sync reads the decode step's tokens; the return value is
        every token the tick produced — admit-time first tokens included —
        as ``(seq_id, position, token)`` triples in emission order.
        """
        emitted, self._tick_emit = self._tick_emit, []
        if not self._stepped:
            return emitted
        self._stepped = False
        toks = np.asarray(self.cur_tok)[:, 0]  # one host sync per tick
        lps = np.asarray(self.cur_lp)[:, 0] if self.logprobs else None
        for i, seq in enumerate(self.active):
            if seq is not None:
                seq.out.append(int(toks[i]))
                seq.remaining -= 1
                pos = len(seq.out) - 1
                emitted.append((seq.seq_id, pos, int(toks[i])))
                if lps is not None:
                    self.tick_logprobs[(seq.seq_id, pos)] = float(lps[i])
        self._evict()
        return emitted

    def step(self) -> None:
        """One synchronous scheduler tick (dispatch + sync back to back)."""
        self.step_begin()
        self.step_finish()

    def run(self) -> Dict[Hashable, List[int]]:
        """Drain the queue; returns seq_id -> generated tokens."""
        while self.pending or self.n_active:
            self.step()
        out, self.done = self.done, {}
        return out
