"""Gradient compression for the slow cross-pod hop: int8 with error feedback.

Within a pod, gradients reduce in full precision over the fast 2-D ICI.
Across pods (the ``pod`` axis), each leaf is quantized to int8 with a
per-leaf scale; the quantization error is carried to the next step
(error-feedback), which keeps SGD/Adam convergence (tested on the
quickstart model in tests/test_compress.py).

Wire accounting: the cross-pod gradient volume drops 4x (fp32) / 2x (bf16);
EXPERIMENTS.md §Perf uses this in the collective-bound cells.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_leaf(g: jnp.ndarray, err: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """fp -> (int8, scale). Error feedback is added before quantization."""
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_leaf(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error(params: PyTree) -> PyTree:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)


def compress_tree(grads: PyTree, err: PyTree):
    qs = jax.tree.map(quantize_leaf, grads, err)
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda t: isinstance(t, tuple))
    return q, s


def decompress_tree(q: PyTree, s: PyTree) -> PyTree:
    return jax.tree.map(dequantize_leaf, q, s)


def new_error(grads: PyTree, err: PyTree, q: PyTree, s: PyTree) -> PyTree:
    """Residual carried to the next step."""
    return jax.tree.map(
        lambda g, e, qq, ss: g.astype(jnp.float32) + e - dequantize_leaf(qq, ss),
        grads, err, q, s,
    )


def cross_pod_mean_int8(
    grads: PyTree, err: PyTree, axis_name: str = "pod"
) -> Tuple[PyTree, PyTree]:
    """Mean-reduce compressed grads over `axis_name` (call inside shard_map
    or pjit with the axis in scope).  Returns (mean grads fp32, new error).

    A *shared* per-leaf scale (pmax of local max-abs — one scalar per leaf
    on the wire) makes the int8 payloads commensurable; the reduction runs
    in int32 (no overflow below 2^23 pods) and dequantizes once.
    """
    n = jax.lax.psum(1, axis_name)
    g32 = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, err)
    scale = jax.tree.map(
        lambda g: jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(g)), 1e-12), axis_name)
        / 127.0,
        g32,
    )
    q = jax.tree.map(
        lambda g, s: jnp.clip(jnp.round(g / s), -127, 127).astype(jnp.int8),
        g32, scale,
    )
    q32 = jax.tree.map(lambda x: jax.lax.psum(x.astype(jnp.int32), axis_name), q)
    mean = jax.tree.map(lambda qq, ss: qq.astype(jnp.float32) * ss / n, q32, scale)
    e_new = jax.tree.map(
        lambda g, qq, ss: g - qq.astype(jnp.float32) * ss, g32, q, scale
    )
    return mean, e_new
