"""Sharding rules: map every parameter / activation / cache leaf to a
PartitionSpec over the production mesh (DESIGN.md §6).

Baseline layout (the perf-iteration surface — see EXPERIMENTS.md §Perf):

* batch over ``("pod", "data")`` (pod is an outer DP axis when present);
* params: FSDP over ``data`` on one matrix dim, TP over ``model`` on the
  other (vocab / d_ff / heads over ``model``);
* MoE experts: EP over ``model`` when the expert count divides the axis,
  otherwise TP inside each expert;
* KV caches: batch over data axes, kv-heads over ``model`` when divisible
  (MQA kv=1 falls back to head-dim or time sharding);
* small vectors (norms, biases, scalars) replicated.

Divisibility is always checked against the actual mesh axis sizes — a rule
that does not divide falls back to replication on that dim, so every config
lowers on every mesh.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

PyTree = Any


@dataclass(frozen=True)
class ShardRules:
    """Tunable layout knobs (hillclimbed per cell in EXPERIMENTS.md §Perf)."""

    batch: Tuple[str, ...] = ("pod", "data")  # filtered by mesh axes present
    fsdp: str = "data"
    tensor: str = "model"
    # MoE
    expert_parallel: bool = True  # EP over `tensor` when divisible
    # caches
    kv_head_sharded: bool = True
    kv_time_sharded_when_b1: bool = True  # long_500k: shard cache time dim
    # embeddings
    vocab_sharded: bool = True
    # activations
    seq_sharded_acts: bool = False  # sequence parallelism for norms/residual
    # replicate params smaller than this many elements (0 = off).  Small
    # models (xlstm-125m) pay per-op resharding collectives worth more than
    # the replicated bytes.
    replicate_below: int = 0


def _axes(mesh: Mesh, names: Tuple[str, ...]) -> Tuple[str, ...]:
    return tuple(n for n in names if n in mesh.axis_names)


def _size(mesh: Mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    s = 1
    for n in names:
        s *= mesh.shape[n]
    return s


def _fit(mesh: Mesh, dim: int, names) -> Optional[Any]:
    """Axis name(s) if `dim` divides their total size, else None."""
    if names is None:
        return None
    if isinstance(names, str):
        names = (names,)
    names = _axes(mesh, tuple(names))
    if not names:
        return None
    if dim % _size(mesh, names) == 0:
        return names if len(names) > 1 else names[0]
    return None


# ---------------------------------------------------------------------------
# Parameter specs (by leaf path)
# ---------------------------------------------------------------------------

# (regex on leaf path, per-dim axis *requests*); first match wins.
# dim requests are resolved against shapes with divisibility fallback.
def _param_rules(rules: ShardRules):
    f, t = rules.fsdp, rules.tensor
    return [
        # embeddings / unembedding.  Vocab over `tensor`, d_model REPLICATED:
        # XLA partitions the token gather over a vocab-sharded operand with
        # the local-mask + all-reduce pattern, and the tied unembed produces
        # vocab-sharded logits.  (Sharding d_model too triggers involuntary
        # full rematerialization in the SPMD partitioner — see DESIGN.md §6.)
        (r"\['embed'\]$", ((t if rules.vocab_sharded else None), None)),
        (r"\['lm_head'\]$", (None, t)),
        (r"\['vision_proj'\]$", (None, f)),
        # attention
        (r"\['attn'\]\['wq'\]$", (f, t)),
        (r"\['attn'\]\['wk'\]$", (f, t)),
        (r"\['attn'\]\['wv'\]$", (f, t)),
        (r"\['attn'\]\['wo'\]$", (t, f)),
        # dense ffn
        (r"\['ffn'\]\['wi'\]$", (f, t)),
        (r"\['ffn'\]\['wg'\]$", (f, t)),
        (r"\['ffn'\]\['wo'\]$", (t, f)),
        # moe (leading dim = experts)
        (r"\['moe'\]\['router'\]$", (f, None)),
        (r"\['moe'\]\['w[ig]'\]$", ("__EP__", f, t)),
        (r"\['moe'\]\['wo'\]$", ("__EP__", t, f)),
        # mamba
        (r"\['mamba'\]\['in_proj'\]$", (f, t)),
        (r"\['mamba'\]\['out_proj'\]$", (t, f)),
        (r"\['mamba'\]\['conv_[wb]'\]$", None),
        # mlstm / slstm
        (r"\['mlstm'\]\['w[qkv]'\]$", (f, t)),
        (r"\['mlstm'\]\['wo_gate'\]$", (f, t)),
        (r"\['mlstm'\]\['out_proj'\]$", (t, f)),
        (r"\['mlstm'\]\['wif'\]$", (f, None)),
        (r"\['slstm'\]\['[wr][ifzo]'\]$", (f, t)),
    ]


def param_pspec(
    path: str, shape: Tuple[int, ...], cfg: ModelConfig, mesh: Mesh, rules: ShardRules
) -> P:
    # q8 optimizer-moment blocks/scales: flattened (n_blocks, 256)/(n_blocks,)
    # — shard the block dim over every available axis (it is huge).
    if re.search(r"\['[qs]'\]$", path):
        for axes in (("pod", "data", "model"), ("data", "model"),
                     ("pod", "data"), ("data",), ("model",)):
            got = _fit(mesh, shape[0], axes)
            if got is not None:
                return P(*( [got] + [None] * (len(shape) - 1) ))
        return P()
    n_elems = 1
    for dim in shape:
        n_elems *= dim
    if rules.replicate_below and n_elems < rules.replicate_below:
        return P()
    for pat, req in _param_rules(rules):
        if re.search(pat, path):
            if req is None or len(shape) != len(req):
                return P()
            out = []
            for dim, want in zip(shape, req):
                if want == "__EP__":
                    want = rules.tensor if rules.expert_parallel else None
                    got = _fit(mesh, dim, want)
                    # EP eats the tensor axis for this tensor: drop later dims'
                    # tensor request if the expert dim took it.
                    if got is not None:
                        out.append(got)
                        # remaining dims may not reuse the same axis
                        rest = [
                            _fit(mesh, d, w if w != got and w != rules.tensor else None)
                            for d, w in zip(shape[len(out):], req[len(out):])
                        ]
                        out.extend(rest)
                        return P(*out)
                    out.append(None)
                    continue
                out.append(_fit(mesh, dim, want))
            return P(*out)
    return P()  # norms, biases, scalars: replicated


def param_shardings(
    params_or_shapes: PyTree, cfg: ModelConfig, mesh: Mesh,
    rules: Optional[ShardRules] = None,
) -> PyTree:
    rules = rules or ShardRules()
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_or_shapes)
    out = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        spec = param_pspec(path, tuple(leaf.shape), cfg, mesh, rules)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Activations / batch / cache specs
# ---------------------------------------------------------------------------


def batch_pspec(mesh: Mesh, rules: ShardRules, global_batch: int) -> P:
    axes = _axes(mesh, rules.batch)
    # drop trailing axes until the batch divides
    while axes and global_batch % _size(mesh, axes) != 0:
        axes = axes[:-1]
    return P(axes if axes else None)


def batch_shardings(batch: PyTree, mesh: Mesh, rules: Optional[ShardRules] = None,
                    global_batch: Optional[int] = None) -> PyTree:
    rules = rules or ShardRules()

    def spec(x):
        gb = global_batch or x.shape[0]
        bp = batch_pspec(mesh, rules, gb)
        return NamedSharding(mesh, P(*(list(bp) + [None] * (len(x.shape) - 1))))

    return jax.tree.map(spec, batch)


def cache_pspec(
    path: str, shape: Tuple[int, ...], cfg: ModelConfig, mesh: Mesh, rules: ShardRules
) -> P:
    bax = batch_pspec(mesh, rules, shape[0])[0] if shape else None
    if re.search(r"\['pos'\]$", path):
        return P(bax)
    if re.search(r"\['(k|v)'\]$", path) or "enc_kv" in path:
        # (B, T, K, D).  Preference order for the tensor axis:
        #   kv heads (GQA with K % axis == 0) > head_dim (MQA/GQA with few
        #   kv heads — the serving-standard layout; D=128 always divides)
        #   > time (only when B=1: decode writes along T, so a time-sharded
        #   cache pays a resharding collective per step otherwise).
        B, T, K, D = shape
        kv_ax = _fit(mesh, K, rules.tensor) if rules.kv_head_sharded else None
        d_ax = None
        t_ax = None
        if kv_ax is None:
            d_ax = _fit(mesh, D, rules.tensor)
        if kv_ax is None and d_ax is None and bax is None and rules.kv_time_sharded_when_b1:
            t_ax = _fit(mesh, T, rules.tensor)
        return P(bax, t_ax, kv_ax, d_ax)
    if re.search(r"\['ssm'\]$", path):  # (B, nh, P, N)
        return P(bax, _fit(mesh, shape[1], rules.tensor), None, None)
    if re.search(r"\['conv'\]$", path):  # (B, K-1, d_in)
        return P(bax, None, _fit(mesh, shape[2], rules.tensor))
    if re.search(r"\['C'\]$", path):  # mlstm (B, nh, dh, dh)
        return P(bax, _fit(mesh, shape[1], rules.tensor), None, None)
    if re.search(r"\['n'\]$", path) and len(shape) == 3:
        return P(bax, _fit(mesh, shape[1], rules.tensor), None)
    if len(shape) == 2:  # slstm states (B, d) / mlstm m (B, nh)
        return P(bax, _fit(mesh, shape[1], rules.tensor))
    return P(*([bax] + [None] * (len(shape) - 1)))


def cache_shardings(
    cache: PyTree, cfg: ModelConfig, mesh: Mesh, rules: Optional[ShardRules] = None
) -> PyTree:
    rules = rules or ShardRules()
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        out.append(NamedSharding(mesh, cache_pspec(path, tuple(leaf.shape), cfg, mesh, rules)))
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
