"""GPipe-style pipeline over the ``pod`` axis (optional multi-pod layout).

The baseline multi-pod config treats ``pod`` as outer data parallelism;
this module provides the alternative: layers split into ``n_stages``
contiguous groups, microbatches stream through stages via
``jax.lax.ppermute`` under ``shard_map``.  Activations cross pods as HGum
frames conceptually — here the activation block itself is the frame payload
(fixed (mb, S, d) size, so a single-frame list; headers would be constant
and are elided in the math but accounted in the channel benchmarks).

Used at small scale in tests (2 stages on 2 fake devices) and selectable in
the dry-run via ``--pipeline``.
"""
from __future__ import annotations

from typing import Any, Callable, List

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


def split_stages(layers: List, n_stages: int) -> List[List]:
    """Contiguous split of the layer list into n_stages groups."""
    n = len(layers)
    per = -(-n // n_stages)
    return [layers[i * per : (i + 1) * per] for i in range(n_stages)]


def stack_stage_params(stage_groups: List[List]) -> PyTree:
    """Stack per-stage param groups on a leading stage axis (must be
    homogeneous across stages — enforced by the caller's layer plan)."""
    stage_trees = [
        jax.tree.map(lambda *xs: jnp.stack(xs), *grp) for grp in stage_groups
    ]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *stage_trees)


def gpipe_forward(
    mesh: Mesh,
    axis: str,
    stage_fn: Callable[[PyTree, jnp.ndarray], jnp.ndarray],
    stage_params: PyTree,  # leaves (n_stages, layers_per_stage, ...)
    x: jnp.ndarray,  # (n_micro, mb, S, d) microbatched activations
) -> jnp.ndarray:
    """Forward-only GPipe schedule: n_micro + n_stages - 1 ticks.

    stage_fn(params_for_stage, acts) -> acts.  Stage s processes microbatch
    m at tick t = s + m; between ticks activations rotate one hop.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]

    def per_stage(params, xs):  # runs under shard_map; xs: (1, n_micro, mb,S,d)
        params = jax.tree.map(lambda p: p[0], params)
        xs = xs[0]
        sid = jax.lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs)  # outputs per microbatch (only stage!=0 uses)
        carry = jnp.zeros_like(xs[0])

        def tick(t, state):
            carry, buf = state
            m_in = t - sid  # microbatch arriving at this stage this tick
            valid = (m_in >= 0) & (m_in < n_micro)
            # stage 0 reads its own input; others read the rotated carry
            mb_idx = jnp.clip(m_in, 0, n_micro - 1)
            x_own = jax.lax.dynamic_index_in_dim(xs, mb_idx, keepdims=False)
            x_in = jnp.where(sid == 0, x_own, carry)
            y = stage_fn(params, x_in)
            y = jnp.where(valid, y, 0)
            # last stage stores its outputs
            buf = jnp.where(
                (sid == n_stages - 1) & valid,
                jax.lax.dynamic_update_index_in_dim(buf, y, mb_idx, 0),
                buf,
            )
            # rotate activations forward one stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            carry = jax.lax.ppermute(y, axis, perm)
            return carry, buf

        carry, buf = jax.lax.fori_loop(0, n_ticks, tick, (carry, buf))
        # only the last stage's buffer holds real outputs (caller slices)
        return buf[None]

    fn = shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(P(axis), P(None)),
        out_specs=P(axis),
        check_rep=False,
    )
    out = fn(stage_params, x[None])
    # row s of `out` is stage s's buffer; the final outputs live in the last
    # stage's row.
    return out[-1]
