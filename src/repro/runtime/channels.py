"""Typed inter-device channels: the HW-to-HW direction on TPU (DESIGN.md §3).

A *framed channel* moves a variable-length byte stream (a List in HGum
terms) between mesh neighbours as fixed-size frames with ``(size,
ListLevel)`` headers — the paper's §IV-C protocol verbatim, carried by
``jax.lax.ppermute`` over the ICI instead of an FPGA link.  An empty frame
terminates the list; a real CRC32 word (IEEE 802.3, zlib-compatible —
see ``repro.fabric.frames``) extends the header for fault detection.

The framing/checksum core is SHARED with the routed fabric
(``repro.fabric``): this module keeps the seed's single-hop API
(``frame_stream`` / ``unframe_stream`` / ``pod_ring_exchange``) as the
point-to-point special case, re-exported from one implementation so the
wire format cannot drift between the neighbour channel and the multi-hop
router.  For arbitrary-rank delivery use ``repro.fabric.Fabric``.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, PartitionSpec as P

# One wire format, one implementation: the fabric owns framing + CRC32.
from ..fabric.frames import (  # noqa: F401  (re-exported public API)
    FRAME_PHITS,
    HDR_WORDS,
    PHIT_WORDS,
    crc32_words,
    frame_stream,
    unframe_stream,
)

__all__ = [
    "FRAME_PHITS", "HDR_WORDS", "PHIT_WORDS", "crc32_words",
    "frame_stream", "unframe_stream", "pod_ring_exchange",
    "make_framed_sender",
]


# ---------------------------------------------------------------------------
# Framed ring exchange over a mesh axis (pod<->pod, stage<->stage)
# ---------------------------------------------------------------------------


def pod_ring_exchange(
    frames: jax.Array, axis_name: str, shift: int = 1
) -> jax.Array:
    """ppermute a framed stream one hop around `axis_name` (call under
    shard_map).  The framed stream is self-describing, so the receiver can
    decode without out-of-band length metadata — the paper's point."""
    # NB: jax.lax.axis_size does not exist in the pinned JAX; psum of ones
    # over the axis is the portable way to recover its size inside shard_map.
    n = int(jax.lax.psum(1, axis_name))
    perm = [(i, (i + shift) % n) for i in range(n)]
    return jax.lax.ppermute(frames, axis_name, perm)


def make_framed_sender(mesh: Mesh, axis_name: str, frame_phits: int = FRAME_PHITS):
    """shard_map-wrapped send along `axis_name`.

    Takes per-member payloads stacked on dim 0: payload (n, W) u32 and
    nbytes (n,) — both sharded over `axis_name` — and returns the rotated
    (payload, nbytes, ok) with the same layout.  The framed stream is
    self-describing, so no out-of-band length metadata crosses the link.
    """
    from jax.experimental.shard_map import shard_map

    def send(payload_u32, nbytes):
        frames, _ = frame_stream(
            payload_u32[0], nbytes[0], frame_phits=frame_phits
        )
        out = pod_ring_exchange(frames, axis_name)
        p, nb, ok = unframe_stream(out)
        return p[None], nb[None], ok[None]

    return shard_map(
        send,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name)),
        out_specs=(P(axis_name), P(axis_name), P(axis_name)),
        check_rep=False,
    )
