"""Typed inter-device channels: the HW-to-HW direction on TPU (DESIGN.md §3).

A *framed channel* moves a variable-length byte stream (a List in HGum
terms) between mesh neighbours as fixed-size frames with ``(size,
ListLevel)`` headers — the paper's §IV-C protocol verbatim, carried by
``jax.lax.ppermute`` over the ICI instead of an FPGA link.  An empty frame
terminates the list; a trailing CRC32-like checksum word (additive, cheap
on-device) extends the header for fault detection.

``frame_stream`` / ``unframe_stream`` are pure jnp (shard_map-friendly,
static frame count = capacity bound); ``pod_ring_exchange`` wires a framed
stream around a mesh axis.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

#: paper §V: 128-bit phits; frame = up to 500 phits (Altera 512-deep BRAM).
PHIT_WORDS = 4  # 16 B in u32 lanes
FRAME_PHITS = 500
HDR_WORDS = 4  # size, list_level, checksum, reserved -> one phit


def _checksum(x: jnp.ndarray) -> jnp.ndarray:
    """Additive 32-bit checksum (device-cheap stand-in for CRC32)."""
    return jnp.sum(x.astype(jnp.uint32), dtype=jnp.uint32)


def frame_stream(
    payload_u32: jnp.ndarray,  # (W,) u32 — serialized list data (padded cap)
    nbytes: jnp.ndarray,  # true byte length (traced)
    list_level: int = 1,
    frame_phits: int = FRAME_PHITS,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cut a byte stream into frames.

    Returns (frames, n_frames): frames (F, HDR_WORDS + frame_words) u32 with
    per-frame headers; F is the static capacity bound incl. the empty
    end-of-list terminator frame.
    """
    frame_words = frame_phits * PHIT_WORDS
    W = payload_u32.shape[0]
    F = -(-W // frame_words) + 1  # + terminator
    pad = F * frame_words - W
    data = jnp.pad(payload_u32, (0, pad)).reshape(F, frame_words)
    word_len = (nbytes + 3) // 4
    start = jnp.arange(F, dtype=jnp.int32) * frame_words
    remaining = jnp.maximum(word_len - start, 0)
    words_in = jnp.minimum(remaining, frame_words)  # (F,)
    bytes_in = jnp.minimum(jnp.maximum(nbytes - start * 4, 0), frame_words * 4)
    # zero tail garbage inside each frame
    col = jnp.arange(frame_words, dtype=jnp.int32)[None, :]
    data = jnp.where(col < words_in[:, None], data, 0)
    hdr = jnp.stack(
        [
            bytes_in.astype(jnp.uint32),
            jnp.full((F,), list_level, jnp.uint32),
            jax.vmap(_checksum)(data),
            jnp.zeros((F,), jnp.uint32),
        ],
        axis=1,
    )
    n_frames = jnp.sum(words_in > 0) + 1  # + empty terminator
    return jnp.concatenate([hdr, data], axis=1), n_frames


def unframe_stream(
    frames: jnp.ndarray, verify: bool = True
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Frames -> (payload_u32 (W,), nbytes, ok).  Zeroed past the true end."""
    F, width = frames.shape
    hdr = frames[:, :HDR_WORDS]
    data = frames[:, HDR_WORDS:]
    bytes_in = hdr[:, 0].astype(jnp.int32)
    ok = jnp.array(True)
    if verify:
        ok = jnp.all(jax.vmap(_checksum)(data) == hdr[:, 2])
    # terminator = first frame with size 0; ignore frames after it
    is_end = bytes_in == 0
    first_end = jnp.argmax(is_end)  # frames are contiguous by construction
    live = jnp.arange(F) < first_end
    nbytes = jnp.sum(jnp.where(live, bytes_in, 0))
    payload = jnp.where(live[:, None], data, 0).reshape(-1)
    return payload, nbytes, ok


# ---------------------------------------------------------------------------
# Framed ring exchange over a mesh axis (pod<->pod, stage<->stage)
# ---------------------------------------------------------------------------


def pod_ring_exchange(
    frames: jnp.ndarray, axis_name: str, shift: int = 1
) -> jnp.ndarray:
    """ppermute a framed stream one hop around `axis_name` (call under
    shard_map).  The framed stream is self-describing, so the receiver can
    decode without out-of-band length metadata — the paper's point."""
    # NB: jax.lax.axis_size does not exist in the pinned JAX; psum of ones
    # over the axis is the portable way to recover its size inside shard_map.
    n = int(jax.lax.psum(1, axis_name))
    perm = [(i, (i + shift) % n) for i in range(n)]
    return jax.lax.ppermute(frames, axis_name, perm)


def make_framed_sender(mesh: Mesh, axis_name: str, frame_phits: int = FRAME_PHITS):
    """shard_map-wrapped send along `axis_name`.

    Takes per-member payloads stacked on dim 0: payload (n, W) u32 and
    nbytes (n,) — both sharded over `axis_name` — and returns the rotated
    (payload, nbytes, ok) with the same layout.  The framed stream is
    self-describing, so no out-of-band length metadata crosses the link.
    """
    from jax.experimental.shard_map import shard_map

    def send(payload_u32, nbytes):
        frames, _ = frame_stream(
            payload_u32[0], nbytes[0], frame_phits=frame_phits
        )
        out = pod_ring_exchange(frames, axis_name)
        p, nb, ok = unframe_stream(out)
        return p[None], nb[None], ok[None]

    return shard_map(
        send,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name)),
        out_specs=(P(axis_name), P(axis_name), P(axis_name)),
        check_rep=False,
    )
