"""StreamWriter / StreamReader: per-request token streams over the fabric.

The writer side lives on a serving shard.  A :class:`ChunkLane` owns the
(shard -> ingress, tenant) direction: every live sequence holds a
:class:`StreamWriter` on the lane, ``write`` queues that decode step's
tokens as a :class:`~repro.stream.chunks.TokenChunk`, and one ``flush`` per
tick serializes ALL of the lane's chunks in a single batched Pallas pass
(``encode_chunk_burst``) and mails the burst as ONE fabric message tagged
with the lane's ``list_level`` — the QoS class the router's weighted
round-robin credit scheduler keys on.

The reader side lives at the ingress.  :meth:`StreamReader.feed` consumes
fabric :class:`~repro.fabric.mailbox.Delivery` records, parses each burst
back-to-front, and demultiplexes chunks into per-``(src, stream_id)``
:class:`StreamState`s:

* **ordering** — bursts arrive per (src, dst) in fabric-seq order and each
  chunk carries its stream-local ``step``; a step gap or a chunk after EOS
  marks the stream corrupt (lost/duplicated burst), mirroring the frame-seq
  gap rule one layer down;
* **corruption** — a delivery whose frames failed CRC32 (or whose burst
  does not parse) poisons exactly the streams whose chunks rode in it; all
  other streams stay clean — the per-stream analog of the fabric's
  per-message flags;
* **termination** — the explicit EOS chunk closes the stream; readers know
  a stream is complete without any out-of-band length.

``feed`` returns the tick's fresh :class:`StreamEvent`s so a serve loop can
hand tokens to callers the moment they reach the ingress (time-to-first-
token = one decode tick + one fabric tick, not the whole generation).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from ..core.stream_plans import (
    Fragment,
    StreamPlan,
    decode_fragments,
    encode_fragment_burst,
)
from ..obs.metrics import window_stats
from .chunks import TokenChunk, decode_token_chunks, encode_chunk_burst

#: the ONE shared arrive-window implementation (``obs.metrics``): kept
#: under its historical name here for the benchmarks and tests that import
#: ``repro.stream.arrive_stats``.  ``Fabric.class_arrive_stats`` and
#: :meth:`StreamReader.class_arrive_stats` both resolve to this same
#: function, so the two ends of the backpressure feedback loop can never
#: disagree on what "p95" means (see obs.metrics.window_stats for the
#: ceil-rank percentile definition).
arrive_stats = window_stats


@dataclass
class StreamEvent:
    """Tokens from one chunk the moment it reached the reader."""

    src: int
    stream_id: int
    step: int
    tokens: Tuple[int, ...]
    eos: bool
    ok: bool
    #: router scan step of the carrying message; None when the delivery
    #: carried no latency observation (never fabricated as 0 — a fake
    #: zero-latency sample would deflate the mean/p95 the backpressure
    #: scheduler feeds on and inflate jitter)
    arrive_step: Optional[int] = None


class StreamWriter:
    """Write side of one token stream (one generating sequence)."""

    def __init__(self, lane: "ChunkLane", stream_id: int):
        self.lane = lane
        self.stream_id = stream_id
        self.step = 0
        self.closed = False

    def write(self, tokens: Sequence, eos: bool = False) -> None:
        """Queue one decode step's elements; sent at the lane's next flush.

        Elements follow the lane's generated plan: plain ints for the
        default token lane (and any single-leaf plan), tuples of ints in
        leaf order for multi-leaf element types.
        """
        if self.closed:
            raise RuntimeError(f"stream {self.stream_id} already closed")
        plan = self.lane.plan
        if plan is None or plan.n_leaves == 1:
            elems = tuple(int(t) for t in tokens)
        else:
            elems = tuple(tuple(int(v) for v in e) for e in tokens)
        cls = TokenChunk if plan is None else Fragment
        self.lane._pending.append(cls(self.stream_id, self.step, elems, eos))
        self.step += 1
        self.closed = eos

    def close(self) -> None:
        """Emit the explicit end-of-stream terminator chunk (idempotent)."""
        if not self.closed:
            self.write((), eos=True)


class ChunkLane:
    """Batches one tick's chunks from one rank to one destination (one QoS
    class) into a single fabric message.

    **Backpressure-fed flush clamping** (``p95_threshold``): the reader
    side surfaces per-QoS-class arrive-step percentiles
    (:meth:`StreamReader.class_arrive_stats` /
    ``Fabric.class_arrive_stats``); feeding them back via :meth:`feedback`
    clamps the lane's flush rate while its class's p95 in-fabric latency
    sits above the threshold.  A clamped lane *trickles*: each flush mails
    only its oldest ``clamp_chunks`` chunks (default 1) and holds the rest
    for later bursts, so its QoS class presents almost no frames at the
    router's inject step and its WRR credit quota spills to the other
    classes — a stalled tenant stops inflating everyone else's queues,
    while its own stream keeps trickling forward (never a stop-then-dump
    that would slam a multi-tick mega-burst into the link).  With
    ``clamp_chunks=0`` the lane holds entirely, bounded by ``max_hold``
    consecutive held flushes.  Held chunks ride later bursts in write
    order, so the reader sees the same step sequence and reassembled
    tokens whether or not the clamp ever engaged.
    """

    def __init__(self, mailbox, dst: int, list_level: int = 1,
                 p95_threshold: Optional[float] = None,
                 clamp_chunks: int = 1, max_hold: int = 3,
                 metrics=None, plan: Optional[StreamPlan] = None):
        self.mailbox = mailbox
        self.dst = dst
        self.list_level = list_level
        #: generated ``core.stream_plans.StreamPlan`` this lane serializes
        #: with; None = the shipped token plan (``chunks.py`` codec).  Any
        #: ``Stream<T>`` declared in schema JSON rides the lane unchanged.
        self.plan = plan
        self.p95_threshold = p95_threshold
        self.clamp_chunks = clamp_chunks
        self.max_hold = max_hold
        self._pending: List[TokenChunk] = []
        self._clamped = False
        self._held = 0  # consecutive fully-held flushes
        self.holds = 0  # flushes that held chunks back (observability)
        self.flushes = 0  # bursts actually mailed
        #: optional obs.metrics.MetricsRegistry; None = no-op telemetry
        #: (the no-telemetry path must exist so serve output can be
        #: asserted byte-identical with and without a registry attached)
        self.metrics = metrics
        #: optional obs.spans.SpanTracker + stream_id -> request id map;
        #: a stream's step-0 chunk riding a burst marks the span's
        #: "stream.first_flush" tick (held chunks mark when they SHIP,
        #: not when they queue — the clamp delay is part of the latency)
        self.spans = None
        self.span_ids: Dict[int, int] = {}

    def _counter(self, name: str):
        if self.metrics is None:
            return None
        return self.metrics.counter(name, dst=self.dst,
                                    level=self.list_level)

    @property
    def clamped(self) -> bool:
        """True while the reader-fed latency signal clamps this lane."""
        return self._clamped

    def feedback(self, p95: Optional[float]) -> None:
        """Feed the reader's p95 arrive latency for this lane's QoS class;
        clamps the flush rate while it exceeds ``p95_threshold``.  ``None``
        (no observation yet) never clamps."""
        was = self._clamped
        self._clamped = (
            self.p95_threshold is not None
            and p95 is not None
            and p95 > self.p95_threshold
        )
        if self.metrics is not None:
            if p95 is not None:
                self.metrics.series("stream.lane.feedback_p95",
                                    dst=self.dst,
                                    level=self.list_level).append(p95)
            if self._clamped and not was:
                self._counter("stream.lane.clamp_engaged").add(1)

    def writer(self, stream_id: int) -> StreamWriter:
        return StreamWriter(self, stream_id)

    def flush(self, force: bool = False) -> int:
        """Serialize pending chunks (ONE batched Pallas SER pass) and mail
        the burst.  A clamped lane trickles its oldest ``clamp_chunks``
        and holds the rest (or holds everything when ``clamp_chunks=0``,
        up to ``max_hold`` consecutive flushes).  Returns the number of
        chunks sent; ``force=True`` bypasses the clamp (the end-of-serve
        drain)."""
        if not self._pending:
            return 0
        held_before = self.holds
        if self._clamped and not force:
            if self.clamp_chunks <= 0:  # full hold, bounded by max_hold
                if self._held < self.max_hold:
                    self._held += 1
                    self.holds += 1
                    self._note_flush(0, held_before)
                    return 0
                chunks, self._pending = self._pending, []
            else:  # trickle: oldest chunks ride, the rest wait
                chunks = self._pending[: self.clamp_chunks]
                self._pending = self._pending[self.clamp_chunks:]
                if self._pending:
                    self.holds += 1
        else:
            chunks, self._pending = self._pending, []
        self._held = 0
        if self.plan is None:
            wire = encode_chunk_burst(chunks)
        else:
            wire = encode_fragment_burst(self.plan, chunks)
        self.mailbox.send(self.dst, wire, list_level=self.list_level)
        self.flushes += 1
        if self.spans is not None:
            for c in chunks:
                if c.step == 0 and c.stream_id in self.span_ids:
                    self.spans.event(self.span_ids[c.stream_id],
                                     "stream.first_flush", dst=self.dst,
                                     level=self.list_level)
        self._note_flush(len(chunks), held_before)
        return len(chunks)

    def _note_flush(self, sent: int, held_before: int) -> None:
        if self.metrics is None:
            return
        if sent:
            self._counter("stream.lane.flushes").add(1)
            self._counter("stream.lane.chunks_sent").add(sent)
        if self.holds > held_before:
            self._counter("stream.lane.holds").add(1)
            self.metrics.gauge("stream.lane.chunks_held", dst=self.dst,
                               level=self.list_level).set(len(self._pending))


@dataclass
class StreamState:
    """Reader-side reassembly state of one (src, stream_id) stream."""

    tokens: List[int] = field(default_factory=list)
    eos: bool = False
    ok: bool = True
    next_step: int = 0
    level: int = 1
    #: router scan step each of this stream's chunks arrived at (one entry
    #: per OBSERVED chunk, in step order) — the per-tick fabric latency
    #: trace that makes time-to-token *jitter* measurable, not just the
    #: mean.  Deliveries that carry no ``arrive_step`` are skipped, never
    #: recorded as 0 (a fake zero-latency sample deflates mean/p95 and
    #: inflates jitter — the signal the backpressure scheduler feeds on).
    arrive_steps: List[int] = field(default_factory=list)


class StreamReader:
    """Demultiplexes chunk bursts into per-stream token sequences.

    ``on_corrupt`` picks the posture toward corrupt DELIVERIES (failed
    CRC32 or unparseable burst):

    * ``"flag"`` (default) — poison exactly the streams whose chunks rode
      in the delivery (``StreamState.ok=False``), the PR-8 behavior;
    * ``"raise"`` — raise ``RuntimeError`` the moment a corrupt delivery
      is fed (stream state untouched by it);
    * ``"retry"`` — skip the corrupt delivery WITHOUT touching stream
      state, so a clean re-delivery (the fabric's ARQ replay, or the
      serve plane's request retry) can land in its place; the skipped
      chunks surface as a step gap only if no replacement ever arrives.

    Stream-level damage the reader itself detects (a step gap or a chunk
    after EOS) always flags the stream — those are reassembly facts, not
    recoverable wire damage.
    """

    def __init__(self, metrics=None, spans=None,
                 on_corrupt: str = "flag",
                 plan: Optional[StreamPlan] = None) -> None:
        if on_corrupt not in ("flag", "raise", "retry"):
            raise ValueError(
                f"on_corrupt must be 'flag', 'raise' or 'retry', got "
                f"{on_corrupt!r}"
            )
        self.on_corrupt = on_corrupt
        #: generated plan bursts are parsed with; None = the token plan
        self.plan = plan
        self.streams: Dict[Tuple[int, int], StreamState] = {}
        #: deliveries whose bursts yielded no parseable chunk at all —
        #: corruption that cannot be attributed to a stream
        self.unattributed: List = []
        #: optional obs.metrics.MetricsRegistry; None = no-op telemetry
        self.metrics = metrics
        #: optional obs.spans.SpanTracker + (src, stream_id) -> request id
        #: map; a stream turning corrupt degrades its request's span with
        #: the reason, an unattributable burst records a tracker anomaly
        self.spans = spans
        self.span_ids: Dict[Tuple[int, int], int] = {}

    def feed(self, deliveries: Iterable) -> List[StreamEvent]:
        """Consume fabric deliveries; returns the fresh stream events."""
        events: List[StreamEvent] = []
        m = self.metrics
        for d in deliveries:
            if self.plan is None:
                chunks, parsed = decode_token_chunks(d.wire)
            else:
                chunks, parsed = decode_fragments(self.plan, d.wire)
            clean = bool(d.ok) and parsed
            if not clean and self.on_corrupt == "raise":
                raise RuntimeError(
                    f"corrupt stream delivery from src {d.src} (level "
                    f"{d.list_level}): CRC failure or unparseable burst — "
                    f"feed with on_corrupt='flag' to inspect"
                )
            if not clean and self.on_corrupt == "retry":
                # drop it whole: a replayed/retried delivery carries the
                # same chunks clean, and folding the damaged copy in now
                # would poison the stream the replacement repairs
                if m is not None:
                    m.counter("stream.reader.skipped_corrupt").add(1)
                continue
            if not chunks:
                if not clean:
                    self.unattributed.append(d)
                    if m is not None:
                        m.counter("stream.reader.unattributed").add(1)
                    if self.spans is not None:
                        self.spans.anomaly(
                            "stream.reader.unattributed", src=d.src,
                            level=d.list_level,
                            request_id=getattr(d, "request_id", None))
                continue
            arrive = getattr(d, "arrive_step", None)
            for c in chunks:
                key = (d.src, c.stream_id)
                st = self.streams.setdefault(key, StreamState())
                st.level = d.list_level
                was_ok = st.ok
                reasons = []
                if not clean:
                    st.ok = False  # CRC/parse failure poisons this stream
                    reasons.append("crc")
                if c.corrupt:
                    # fragment meta violated the plan's declared budgets
                    # (out-of-budget id/step, unknown flags): flag the
                    # stream instead of trusting garbage metadata
                    st.ok = False
                    reasons.append("meta-budget")
                if c.step != st.next_step or st.eos:
                    st.ok = False  # lost, duplicated, or post-EOS chunk
                    reasons.append("chunk-gap")
                if (reasons and self.spans is not None
                        and key in self.span_ids):
                    self.spans.degrade(self.span_ids[key],
                                       ",".join(reasons), src=d.src,
                                       stream_id=c.stream_id, step=c.step)
                st.next_step = c.step + 1
                st.tokens.extend(c.tokens)
                st.eos = st.eos or c.eos
                if m is not None:
                    m.counter("stream.reader.chunks",
                              level=d.list_level).add(1)
                    m.counter("stream.reader.tokens",
                              level=d.list_level).add(len(c.tokens))
                    if was_ok and not st.ok:
                        m.counter("stream.reader.corrupt_streams").add(1)
                    if arrive is not None:
                        m.histogram("stream.reader.arrive_step",
                                    level=d.list_level).observe(arrive)
                if arrive is not None:
                    # a delivery without the field contributes NO latency
                    # sample (recording 0 would claim an impossible
                    # zero-step arrival and drag mean/p95 down)
                    st.arrive_steps.append(arrive)
                events.append(
                    StreamEvent(
                        d.src, c.stream_id, c.step, c.tokens, c.eos, st.ok,
                        arrive,
                    )
                )
        return events

    def arrive_stats(self) -> Dict[str, float]:
        """Aggregate in-fabric latency of every chunk seen so far: the
        router scan step each chunk's carrying message arrived at (see the
        module-level :func:`arrive_stats` for the fields)."""
        return arrive_stats(
            s for st in self.streams.values() for s in st.arrive_steps
        )

    def class_arrive_stats(
        self, window: Optional[int] = None
    ) -> Dict[int, Dict[str, float]]:
        """In-fabric latency per ListLevel (QoS tenant tag): ``{level:
        {n, mean, p95, max, jitter}}``.  This is the reader-side signal the
        backpressure loop feeds into each :class:`ChunkLane` — a lane whose
        level's p95 sits above its threshold clamps its flush rate and
        yields its WRR credits to the other classes.  ``window`` restricts
        each stream to its most recent samples so a clamped tenant can
        *recover* once its tail drains instead of being haunted by old
        congestion forever."""
        per: Dict[int, List[int]] = {}
        for st in self.streams.values():
            tr = st.arrive_steps[-window:] if window else st.arrive_steps
            per.setdefault(st.level, []).extend(tr)
        return {lvl: arrive_stats(tr) for lvl, tr in sorted(per.items())}

    def all_eos(self, expected: Optional[Iterable[Tuple[int, int]]] = None) -> bool:
        """True when every stream (or every ``expected`` key) saw its EOS."""
        if expected is not None:
            return all(
                k in self.streams and self.streams[k].eos for k in expected
            )
        return all(st.eos for st in self.streams.values())
