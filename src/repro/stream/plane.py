"""StreamWriter / StreamReader: per-request token streams over the fabric.

The writer side lives on a serving shard.  A :class:`ChunkLane` owns the
(shard -> ingress, tenant) direction: every live sequence holds a
:class:`StreamWriter` on the lane, ``write`` queues that decode step's
tokens as a :class:`~repro.stream.chunks.TokenChunk`, and one ``flush`` per
tick serializes ALL of the lane's chunks in a single batched Pallas pass
(``encode_chunk_burst``) and mails the burst as ONE fabric message tagged
with the lane's ``list_level`` — the QoS class the router's weighted
round-robin credit scheduler keys on.

The reader side lives at the ingress.  :meth:`StreamReader.feed` consumes
fabric :class:`~repro.fabric.mailbox.Delivery` records, parses each burst
back-to-front, and demultiplexes chunks into per-``(src, stream_id)``
:class:`StreamState`s:

* **ordering** — bursts arrive per (src, dst) in fabric-seq order and each
  chunk carries its stream-local ``step``; a step gap or a chunk after EOS
  marks the stream corrupt (lost/duplicated burst), mirroring the frame-seq
  gap rule one layer down;
* **corruption** — a delivery whose frames failed CRC32 (or whose burst
  does not parse) poisons exactly the streams whose chunks rode in it; all
  other streams stay clean — the per-stream analog of the fabric's
  per-message flags;
* **termination** — the explicit EOS chunk closes the stream; readers know
  a stream is complete without any out-of-band length.

``feed`` returns the tick's fresh :class:`StreamEvent`s so a serve loop can
hand tokens to callers the moment they reach the ingress (time-to-first-
token = one decode tick + one fabric tick, not the whole generation).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .chunks import TokenChunk, decode_token_chunks, encode_chunk_burst


def arrive_stats(steps: Iterable[int]) -> Dict[str, float]:
    """Latency statistics over a trace of router arrive steps: ``mean``
    tracks hop count + queueing, ``p95``/``max`` expose the tail a
    far-shard or starved tenant produces, and ``jitter`` is the stddev —
    the time-to-token wobble the shortest-path router shrinks.  Shared by
    :meth:`StreamReader.arrive_stats` and the benchmarks so the two can
    never diverge."""
    arr = sorted(steps)
    if not arr:
        return {"n": 0, "mean": 0.0, "p95": 0.0, "max": 0.0, "jitter": 0.0}
    n = len(arr)
    mean = sum(arr) / n
    var = sum((s - mean) ** 2 for s in arr) / n
    return {
        "n": n,
        "mean": mean,
        "p95": float(arr[min(n - 1, int(0.95 * n))]),
        "max": float(arr[-1]),
        "jitter": var ** 0.5,
    }


@dataclass
class StreamEvent:
    """Tokens from one chunk the moment it reached the reader."""

    src: int
    stream_id: int
    step: int
    tokens: Tuple[int, ...]
    eos: bool
    ok: bool
    arrive_step: int = 0  # router scan step of the carrying message


class StreamWriter:
    """Write side of one token stream (one generating sequence)."""

    def __init__(self, lane: "ChunkLane", stream_id: int):
        self.lane = lane
        self.stream_id = stream_id
        self.step = 0
        self.closed = False

    def write(self, tokens: Sequence[int], eos: bool = False) -> None:
        """Queue one decode step's tokens; sent at the lane's next flush."""
        if self.closed:
            raise RuntimeError(f"stream {self.stream_id} already closed")
        self.lane._pending.append(
            TokenChunk(self.stream_id, self.step, tuple(int(t) for t in tokens), eos)
        )
        self.step += 1
        self.closed = eos

    def close(self) -> None:
        """Emit the explicit end-of-stream terminator chunk (idempotent)."""
        if not self.closed:
            self.write((), eos=True)


class ChunkLane:
    """Batches one tick's chunks from one rank to one destination (one QoS
    class) into a single fabric message."""

    def __init__(self, mailbox, dst: int, list_level: int = 1):
        self.mailbox = mailbox
        self.dst = dst
        self.list_level = list_level
        self._pending: List[TokenChunk] = []

    def writer(self, stream_id: int) -> StreamWriter:
        return StreamWriter(self, stream_id)

    def flush(self) -> int:
        """Serialize every pending chunk (ONE batched Pallas SER pass) and
        mail the burst.  Returns the number of chunks sent."""
        if not self._pending:
            return 0
        chunks, self._pending = self._pending, []
        self.mailbox.send(
            self.dst, encode_chunk_burst(chunks), list_level=self.list_level
        )
        return len(chunks)


@dataclass
class StreamState:
    """Reader-side reassembly state of one (src, stream_id) stream."""

    tokens: List[int] = field(default_factory=list)
    eos: bool = False
    ok: bool = True
    next_step: int = 0
    level: int = 1
    #: router scan step each of this stream's chunks arrived at (one entry
    #: per chunk, in step order) — the per-tick fabric latency trace that
    #: makes time-to-token *jitter* measurable, not just the mean
    arrive_steps: List[int] = field(default_factory=list)


class StreamReader:
    """Demultiplexes chunk bursts into per-stream token sequences."""

    def __init__(self) -> None:
        self.streams: Dict[Tuple[int, int], StreamState] = {}
        #: deliveries whose bursts yielded no parseable chunk at all —
        #: corruption that cannot be attributed to a stream
        self.unattributed: List = []

    def feed(self, deliveries: Iterable) -> List[StreamEvent]:
        """Consume fabric deliveries; returns the fresh stream events."""
        events: List[StreamEvent] = []
        for d in deliveries:
            chunks, parsed = decode_token_chunks(d.wire)
            clean = bool(d.ok) and parsed
            if not chunks:
                if not clean:
                    self.unattributed.append(d)
                continue
            for c in chunks:
                key = (d.src, c.stream_id)
                st = self.streams.setdefault(key, StreamState())
                st.level = d.list_level
                if not clean:
                    st.ok = False  # CRC/parse failure poisons this stream
                if c.step != st.next_step or st.eos:
                    st.ok = False  # lost, duplicated, or post-EOS chunk
                st.next_step = c.step + 1
                st.tokens.extend(c.tokens)
                st.eos = st.eos or c.eos
                st.arrive_steps.append(getattr(d, "arrive_step", 0))
                events.append(
                    StreamEvent(
                        d.src, c.stream_id, c.step, c.tokens, c.eos, st.ok,
                        getattr(d, "arrive_step", 0),
                    )
                )
        return events

    def arrive_stats(self) -> Dict[str, float]:
        """Aggregate in-fabric latency of every chunk seen so far: the
        router scan step each chunk's carrying message arrived at (see the
        module-level :func:`arrive_stats` for the fields)."""
        return arrive_stats(
            s for st in self.streams.values() for s in st.arrive_steps
        )

    def all_eos(self, expected: Optional[Iterable[Tuple[int, int]]] = None) -> bool:
        """True when every stream (or every ``expected`` key) saw its EOS."""
        if expected is not None:
            return all(
                k in self.streams and self.streams[k].eos for k in expected
            )
        return all(st.eos for st in self.streams.values())
