"""Streaming message plane: token-level streamed responses over the fabric.

HGum serializes a List incrementally — neither side buffers the whole
message (§IV).  This package applies that rule to the serving response
path: instead of waiting for a shard's whole ``response_schema`` wire, each
decode step's tokens leave the shard the tick they are produced, as framed
chunk bursts (``chunks.py``) demultiplexed back into per-request streams at
the ingress (``plane.py``).

Layers:

* ``chunks`` — the token-chunk codec, *generated* from its ``Stream<T>``
  schema declaration (``core.stream_plans``): count-after-elements List
  fragments with stream ids, step numbers, and explicit end-of-stream
  terminators; bursts serialize through the batched Pallas small-chunk
  kernel.  New streamed payloads (e.g. the shipped logprob stream) are
  declared purely in schema JSON — no hand-written codec.
* ``plane``  — ``StreamWriter``/``ChunkLane`` on the shard side (one fabric
  message per tenant per tick), ``StreamReader`` at the ingress (ordering,
  per-stream corruption flags, EOS tracking).  Both take a generated
  ``plan=`` to carry any typed stream; the default is the token plan.

The serve driver that ties this to compute — overlapped
``Fabric.exchange_async`` ticks against ``ContinuousBatcher`` steps, QoS
credit classes per tenant — is ``launch.serve.serve_requests_streaming``.
"""
from .chunks import (
    CHUNK_META_WORDS,
    FLAG_EOS,
    LOGPROB_STREAM_SCHEMA_JSON,
    MAX_CHUNK_TOKENS,
    STREAM_ID_BITS,
    TOKEN_STREAM_SCHEMA_JSON,
    TokenChunk,
    decode_token_chunks,
    encode_chunk_burst,
    encode_token_chunk,
    logprob_stream_plan,
    token_stream_plan,
)
from .plane import (
    ChunkLane,
    StreamEvent,
    StreamReader,
    StreamState,
    StreamWriter,
    arrive_stats,
)

__all__ = [
    "CHUNK_META_WORDS", "FLAG_EOS", "MAX_CHUNK_TOKENS", "STREAM_ID_BITS",
    "TOKEN_STREAM_SCHEMA_JSON", "LOGPROB_STREAM_SCHEMA_JSON", "TokenChunk",
    "decode_token_chunks", "encode_chunk_burst", "encode_token_chunk",
    "logprob_stream_plan", "token_stream_plan",
    "ChunkLane", "StreamEvent", "StreamReader", "StreamState", "StreamWriter",
    "arrive_stats",
]
