"""Token-chunk wire format for the streaming message plane.

HGum's claim is that a large List streams through the SER/DES incrementally
— nobody buffers the whole message.  Applied to serving, a response is a
List of generated tokens whose length is unknown until decode finishes, so
the shard should emit each decode step's tokens the tick they are produced
instead of buffering the whole ``response_schema`` wire.  The unit of that
stream is a *token chunk*: one decode step's tokens for one sequence,
serialized as an incremental HGum List fragment.

Chunk layout (u32 words, HW->SW List convention — the count comes AFTER
the elements, paper §IV-B, so the host parses from the end)::

    [ stream_id | step | flags ] [ tok0 .. tok_{n-1} ] [ n ]

* ``stream_id`` — writer-scoped stream identifier (the serve plane packs
  ``(local_request << 16) | prompt_index``);
* ``step``      — chunk sequence number within the stream, starting at 0;
  the reader flags gaps exactly like the fabric flags frame-seq gaps;
* ``flags``     — bit 0 = end-of-stream terminator (the explicit EOS the
  paper's size-0 frame plays at the framing layer);
* ``n``         — token count, written last.

Because the count trails the elements, chunk wires concatenate into a
*burst* that parses back-to-front with no delimiters: the last word of the
burst is the last chunk's count, which locates that chunk's start, and so
on.  One fabric message per (shard, tenant) per tick therefore carries every
live sequence's chunk — ``encode_chunk_burst`` assembles them all in ONE
batched Pallas pass (``kernels.ops.encode_chunks_batch``).

Ordering and integrity ride the layers below: the fabric's route-word seq
numbers order the bursts per (src, dst) stream, the per-frame CRC32 flags
corruption per message, and ``stream.plane.StreamReader`` turns both into
per-stream corruption flags.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

#: words before the token run: stream_id, step, flags
CHUNK_META_WORDS = 3
#: smallest legal chunk: meta words + the trailing count
CHUNK_MIN_WORDS = CHUNK_META_WORDS + 1
#: flags bit 0 — end-of-stream terminator
FLAG_EOS = 1
#: sanity bound used by the back-to-front parser (a corrupt count word must
#: not send the cursor to a plausible-looking but wrong chunk boundary)
MAX_CHUNK_TOKENS = 1 << 16
#: stream ids pack (local_request:u16 | prompt_index:u16) — the analyzer's
#: stream-id-width rule checks serve calls against this budget
STREAM_ID_BITS = 16


def check_chunk_tokens(n: int) -> None:
    """Single source of the chunk token-count bound (analyzer rule
    stream-chunk-tokens), shared by both encode paths."""
    if n >= MAX_CHUNK_TOKENS:
        raise ValueError(f"chunk of {n} tokens exceeds {MAX_CHUNK_TOKENS}")


@dataclass(frozen=True)
class TokenChunk:
    """One decode step's tokens for one stream."""

    stream_id: int
    step: int
    tokens: Tuple[int, ...]
    eos: bool = False


def encode_token_chunk(
    stream_id: int, step: int, tokens: Sequence[int], eos: bool = False
) -> bytes:
    """Serialize ONE chunk (reference path; bursts use the Pallas kernel)."""
    n = len(tokens)
    check_chunk_tokens(n)
    words = np.empty(CHUNK_META_WORDS + n + 1, np.uint32)
    words[0] = stream_id
    words[1] = step
    words[2] = FLAG_EOS if eos else 0
    words[CHUNK_META_WORDS : CHUNK_META_WORDS + n] = np.asarray(
        tokens, np.uint32
    ) if n else 0
    words[-1] = n
    return words.tobytes()


def encode_chunk_burst(chunks: Sequence[TokenChunk]) -> bytes:
    """Serialize a tick's chunks into one burst wire via the batched Pallas
    small-chunk kernel (one SER pass for every live sequence).

    Bit-identical to concatenating ``encode_token_chunk`` outputs; the
    token capacity and batch axes are pow2-bucketed so the jitted kernel is
    reused across ticks with varying live-sequence counts.
    """
    from ..kernels.ops import encode_chunks_batch

    if not chunks:
        return b""
    B = len(chunks)
    cap = max(max(len(c.tokens) for c in chunks), 1)
    cap = 1 << (cap - 1).bit_length()
    Bp = 1 << max(B - 1, 0).bit_length()
    meta = np.zeros((Bp, CHUNK_META_WORDS), np.uint32)
    toks = np.zeros((Bp, cap), np.uint32)
    counts = np.zeros((Bp,), np.int32)
    for i, c in enumerate(chunks):
        check_chunk_tokens(len(c.tokens))
        meta[i] = (c.stream_id, c.step, FLAG_EOS if c.eos else 0)
        toks[i, : len(c.tokens)] = c.tokens
        counts[i] = len(c.tokens)
    rows = np.asarray(encode_chunks_batch(meta, toks, counts))[:B]
    # trim each row to its live tokens: [meta | tok0..tok_{n-1} | count]
    parts = []
    for i in range(B):
        n = int(counts[i])
        parts.append(rows[i, : CHUNK_META_WORDS + n].tobytes())
        parts.append(rows[i, -1:].tobytes())
    return b"".join(parts)


def decode_token_chunks(wire: bytes) -> Tuple[List[TokenChunk], bool]:
    """Parse a burst wire back into chunks, BACK TO FRONT (§IV-B: the host
    reads trailing counts to locate element runs).

    Returns ``(chunks, ok)`` with chunks in emission order.  ``ok`` is
    False when the structure does not parse cleanly (truncated wire,
    impossible count) — the parser salvages every chunk it can walk from
    the end so a flagged delivery still attributes corruption to streams.
    """
    ok = True
    nbytes = len(wire)
    if nbytes % 4:
        ok = False
        nbytes -= nbytes % 4
    words = np.frombuffer(wire[:nbytes], np.uint32)
    out: List[TokenChunk] = []
    end = len(words)
    while end > 0:
        if end < CHUNK_MIN_WORDS:
            ok = False
            break
        n = int(words[end - 1])
        lo = end - 1 - n - CHUNK_META_WORDS
        if n >= MAX_CHUNK_TOKENS or lo < 0:
            ok = False
            break
        out.append(
            TokenChunk(
                stream_id=int(words[lo]),
                step=int(words[lo + 1]),
                tokens=tuple(int(t) for t in words[lo + CHUNK_META_WORDS : end - 1]),
                eos=bool(int(words[lo + 2]) & FLAG_EOS),
            )
        )
        end = lo
    out.reverse()
    return out, ok
