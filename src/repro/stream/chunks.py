"""Token-chunk wire format — the first *generated* ``Stream<T>`` codec.

HGum's claim is that a large List streams through the SER/DES incrementally
— nobody buffers the whole message.  Applied to serving, a response is a
List of generated tokens whose length is unknown until decode finishes, so
the shard should emit each decode step's tokens the tick they are produced
instead of buffering the whole ``response_schema`` wire.  The unit of that
stream is a *token chunk*: one decode step's tokens for one sequence,
serialized as an incremental HGum List fragment.

This module used to be a hand-rolled one-off wire format riding beside the
schema-driven core.  It is now the first generated instance of the
``["Stream", t]`` IDL node: the token stream is *declared* as schema JSON
(:data:`TOKEN_STREAM_SCHEMA_JSON`, a ``Stream<Bytes 4>``), compiled through
the schema ROM into a ``core.stream_plans.StreamPlan``, and every public
function below delegates to the generated codec.  The wire format is
byte-for-byte identical to the pre-refactor hand-rolled one (regression:
``tests/golden/token_chunks.bin``).

Chunk layout (u32 words, HW->SW List convention — the count comes AFTER
the elements, paper §IV-B, so the host parses from the end)::

    [ stream_id | step | flags ] [ tok0 .. tok_{n-1} ] [ n ]

* ``stream_id`` — writer-scoped stream identifier (the serve plane packs
  ``(local_request << 16) | prompt_index``);
* ``step``      — chunk sequence number within the stream, starting at 0;
  the reader flags gaps exactly like the fabric flags frame-seq gaps;
* ``flags``     — bit 0 = end-of-stream terminator (the explicit EOS the
  paper's size-0 frame plays at the framing layer);
* ``n``         — token count, written last.

Because the count trails the elements, chunk wires concatenate into a
*burst* that parses back-to-front with no delimiters: the last word of the
burst is the last chunk's count, which locates that chunk's start, and so
on.  One fabric message per (shard, tenant) per tick therefore carries every
live sequence's chunk — ``encode_chunk_burst`` assembles them all in ONE
batched Pallas pass (``kernels.ops.encode_chunks_batch``).

Ordering and integrity ride the layers below: the fabric's route-word seq
numbers order the bursts per (src, dst) stream, the per-frame CRC32 flags
corruption per message, and ``stream.plane.StreamReader`` turns both into
per-stream corruption flags.  Fragment metadata that violates the plan's
declared bit budgets (e.g. a step past the u16 step budget, or unknown
flag bits) additionally sets the per-chunk :attr:`TokenChunk.corrupt`
flag rather than silently attributing tokens to a garbage stream.

Declaring a *new* streamed payload needs no codec code at all — see
:data:`LOGPROB_STREAM_SCHEMA_JSON` (per-token logprobs as
``Stream<Struct{tok, logprob}>``) and ``examples/typed_streams.py``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Sequence, Tuple

from ..core.idl import Schema
from ..core.stream_plans import (
    CHUNK_META_WORDS,
    CHUNK_MIN_WORDS,
    FLAG_EOS,
    MAX_CHUNK_TOKENS,
    STREAM_ID_BITS,
    StreamPlan,
    check_chunk_tokens,
    decode_fragments,
    encode_fragment,
    encode_fragment_burst,
    stream_plans,
)

__all__ = [
    "CHUNK_META_WORDS",
    "CHUNK_MIN_WORDS",
    "FLAG_EOS",
    "MAX_CHUNK_TOKENS",
    "STREAM_ID_BITS",
    "TOKEN_STREAM_SCHEMA_JSON",
    "LOGPROB_STREAM_SCHEMA_JSON",
    "TokenChunk",
    "check_chunk_tokens",
    "decode_token_chunks",
    "encode_chunk_burst",
    "encode_token_chunk",
    "logprob_stream_plan",
    "token_stream_plan",
]

#: the shipped token stream, declared in schema JSON: one decode step's
#: tokens as a ``Stream<Bytes 4>`` (a u32 token id per element)
TOKEN_STREAM_SCHEMA_JSON = {
    "TokenStream": [["tokens", ["Stream", ["Bytes", 4]]]],
}

#: per-token logprobs — the second shipped typed stream, proving the
#: generated codec path: each element is ``Struct{tok, logprob}`` (the
#: chosen token id + its float32 logprob bit pattern), two u32 words on
#: the wire, and NO hand-written codec exists for it anywhere.
LOGPROB_STREAM_SCHEMA_JSON = {
    "LogprobStream": [["entries", ["Stream", ["Struct", "LogprobEntry"]]]],
    "LogprobEntry": [["tok", ["Bytes", 4]], ["logprob", ["Bytes", 4]]],
}


@functools.lru_cache(maxsize=None)
def token_stream_plan() -> StreamPlan:
    """The generated plan behind this module's public codec functions.

    ``id_bits`` is the full u32 word (serve packs ``(request:u16 |
    prompt:u16)``, using both :data:`STREAM_ID_BITS` halves);
    ``step_bits`` is the u16 step budget the serve plane guarantees.
    """
    schema = Schema.from_json(TOKEN_STREAM_SCHEMA_JSON)
    return stream_plans(
        schema, id_bits=2 * STREAM_ID_BITS, step_bits=STREAM_ID_BITS
    )["tokens"]


@functools.lru_cache(maxsize=None)
def logprob_stream_plan() -> StreamPlan:
    """Generated plan for the shipped logprob stream (same meta budgets)."""
    schema = Schema.from_json(LOGPROB_STREAM_SCHEMA_JSON)
    return stream_plans(
        schema, id_bits=2 * STREAM_ID_BITS, step_bits=STREAM_ID_BITS
    )["entries"]


@dataclass(frozen=True)
class TokenChunk:
    """One decode step's tokens for one stream.

    ``corrupt`` is set by the decoder when the fragment's metadata
    violated the token plan's declared budgets (out-of-budget step,
    unknown flag bits) — the tokens are kept for diagnostics but the
    stream must be treated as corrupt.
    """

    stream_id: int
    step: int
    tokens: Tuple[int, ...]
    eos: bool = False
    corrupt: bool = False


def encode_token_chunk(
    stream_id: int, step: int, tokens: Sequence[int], eos: bool = False
) -> bytes:
    """Serialize ONE chunk (reference path; bursts use the Pallas kernel)."""
    return encode_fragment(token_stream_plan(), stream_id, step, tokens, eos)


def encode_chunk_burst(chunks: Sequence[TokenChunk]) -> bytes:
    """Serialize a tick's chunks into one burst wire via the batched Pallas
    small-chunk kernel (one SER pass for every live sequence).

    Bit-identical to concatenating ``encode_token_chunk`` outputs; the
    token capacity and batch axes are pow2-bucketed so the jitted kernel is
    reused across ticks with varying live-sequence counts.
    """
    return encode_fragment_burst(token_stream_plan(), chunks)


def decode_token_chunks(wire: bytes) -> Tuple[List[TokenChunk], bool]:
    """Parse a burst wire back into chunks, BACK TO FRONT (§IV-B: the host
    reads trailing counts to locate element runs).

    Returns ``(chunks, ok)`` with chunks in emission order.  ``ok`` is
    False when the structure does not parse cleanly (truncated wire,
    impossible count) — the parser salvages every chunk it can walk from
    the end so a flagged delivery still attributes corruption to streams.
    Chunks whose metadata is structurally fine but out of the plan's
    budgets come back with ``corrupt=True`` instead of poisoning ``ok``.
    """
    frags, ok = decode_fragments(token_stream_plan(), wire)
    return [
        TokenChunk(f.stream_id, f.step, f.tokens, f.eos, f.corrupt)
        for f in frags
    ], ok
