"""Host->device input pipeline over the HGum wire (SW->HW direction).

Host side (software, store-and-forward, paper §IV-A1):
  documents -> packed rows -> Batch message -> ``ser_sw_to_hw`` wire bytes.
Device side (streaming DES, §IV-A2, TPU-adapted):
  wire -> structure pass (``plan_from_wire``) -> Pallas ``unpack_run`` per
  leaf -> (tokens, segment_ids, positions, labels, loss_mask).

The bulk serialize of fixed-width rows is vectorized with numpy (the
software SER is byte-for-byte identical to ``ser_sw_to_hw``; asserted in
tests on small batches).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Tuple

import numpy as np

import jax.numpy as jnp

from ..core.schema_tree import COUNT_BYTES
from ..core.vectorized import DecodePlan
from ..kernels.ops import decode_message_kernel, wire_to_u32
from .schemas import TOKEN_BYTES


# ---------------------------------------------------------------------------
# Synthetic corpus (documents with power-law lengths)
# ---------------------------------------------------------------------------


class SyntheticCorpus:
    """Reproducible stream of documents; stands in for a tokenized dataset."""

    def __init__(self, vocab: int, seed: int = 0, mean_len: int = 512):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        self.mean_len = mean_len

    def next_doc(self) -> np.ndarray:
        n = int(np.clip(self.rng.pareto(2.0) * self.mean_len / 2 + 8, 8, 8 * self.mean_len))
        # markov-ish tokens so loss can actually fall
        base = self.rng.integers(2, self.vocab, 4)
        toks = base[self.rng.integers(0, 4, n)]
        noise = self.rng.integers(2, self.vocab, n)
        keep = self.rng.random(n) < 0.8
        return np.where(keep, toks, noise).astype(np.uint32)

    def docs(self) -> "Iterator[np.ndarray]":
        while True:
            yield self.next_doc()


def pack_documents(
    docs: Iterator[np.ndarray], batch: int, seq: int, eod: int = 1
) -> Tuple[np.ndarray, np.ndarray]:
    """Greedy sequence packing: (tokens, segids) both (batch, seq) uint32."""
    tokens = np.zeros((batch, seq), np.uint32)
    segids = np.zeros((batch, seq), np.uint32)
    for b in range(batch):
        pos, seg = 0, 1
        while pos < seq:
            d = next(docs)
            take = min(len(d), seq - pos)
            tokens[b, pos : pos + take] = d[:take]
            segids[b, pos : pos + take] = seg
            pos += take
            seg += 1
            if pos < seq:
                tokens[b, pos] = eod
                segids[b, pos] = 0
                pos += 1
    return tokens, segids


# ---------------------------------------------------------------------------
# Bulk software SER of a Batch message (vectorized; byte-identical to
# ser_sw_to_hw on the Batch schema)
# ---------------------------------------------------------------------------


def serialize_batch(tokens: np.ndarray, segids: np.ndarray) -> bytes:
    B, S = tokens.shape
    row_bytes = 2 * (COUNT_BYTES + S * TOKEN_BYTES)
    out = np.zeros(COUNT_BYTES + B * row_bytes, np.uint8)
    out[:COUNT_BYTES] = np.frombuffer(np.uint32(B).tobytes(), np.uint8)
    rows = out[COUNT_BYTES:].reshape(B, row_bytes)
    cnt = np.frombuffer(np.uint32(S).tobytes(), np.uint8)
    tok_end = COUNT_BYTES + S * TOKEN_BYTES
    rows[:, :COUNT_BYTES] = cnt
    rows[:, COUNT_BYTES:tok_end] = (
        tokens.astype("<u4").view(np.uint8).reshape(B, S * TOKEN_BYTES)
    )
    rows[:, tok_end : tok_end + COUNT_BYTES] = cnt
    rows[:, tok_end + COUNT_BYTES :] = (
        segids.astype("<u4").view(np.uint8).reshape(B, S * TOKEN_BYTES)
    )
    return out.tobytes()


def batch_plan(batch: int, seq: int) -> DecodePlan:
    """Static DecodePlan for a (batch, seq) Batch wire (offsets are affine)."""
    row_bytes = 2 * (COUNT_BYTES + seq * TOKEN_BYTES)
    base = COUNT_BYTES
    rows = np.arange(batch, dtype=np.int64) * row_bytes
    tok0 = base + COUNT_BYTES
    seg0 = tok0 + seq * TOKEN_BYTES + COUNT_BYTES
    elem = np.arange(seq, dtype=np.int64) * TOKEN_BYTES
    offs = {
        "rows": np.zeros(1, np.int32),
        "rows.elem.tokens": (base + rows).astype(np.int32),
        "rows.elem.tokens.elem": (tok0 + rows[:, None] + elem[None, :]).reshape(-1).astype(np.int32),
        "rows.elem.segids": (seg0 - COUNT_BYTES + rows).astype(np.int32),
        "rows.elem.segids.elem": (seg0 + rows[:, None] + elem[None, :]).reshape(-1).astype(np.int32),
    }
    counts = {p: len(v) for p, v in offs.items()}
    nbytes = {p: (COUNT_BYTES if "elem" != p.split(".")[-1] else TOKEN_BYTES) for p in offs}
    nbytes["rows"] = COUNT_BYTES
    is_cont = {p: not p.endswith(".elem") or p in ("rows",) for p in offs}
    wire_len = COUNT_BYTES + batch * row_bytes
    return DecodePlan(offs, counts, nbytes, is_cont, wire_len)


# ---------------------------------------------------------------------------
# Device-side decode -> training batch dict
# ---------------------------------------------------------------------------


def decode_batch(
    wire: bytes, batch: int, seq: int, interpret: bool = True
) -> Dict[str, jnp.ndarray]:
    plan = batch_plan(batch, seq)
    w32 = wire_to_u32(wire)
    dec = decode_message_kernel(
        w32, plan, paths=["rows.elem.tokens.elem", "rows.elem.segids.elem"],
        interpret=interpret,
    )
    tokens = dec["rows.elem.tokens.elem"][:, 0].reshape(batch, seq).astype(jnp.int32)
    segids = dec["rows.elem.segids.elem"][:, 0].reshape(batch, seq).astype(jnp.int32)
    return finalize_batch(tokens, segids)


def finalize_batch(tokens: jnp.ndarray, segids: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Shift labels within segments; positions restart per segment."""
    B, S = tokens.shape
    labels = jnp.concatenate([tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], 1)
    next_seg = jnp.concatenate([segids[:, 1:], jnp.zeros((B, 1), segids.dtype)], 1)
    loss_mask = ((segids == next_seg) & (segids > 0)).astype(jnp.float32)
    idx = jnp.arange(S, dtype=jnp.int32)[None, :]
    is_start = jnp.concatenate(
        [jnp.ones((B, 1), bool), segids[:, 1:] != segids[:, :-1]], axis=1
    )
    seg_start = jnp.where(is_start, idx, 0)
    seg_start = jax_lax_cummax(seg_start, axis=1)
    positions = idx - seg_start
    return {
        "tokens": tokens,
        "labels": labels,
        "loss_mask": loss_mask,
        "segment_ids": segids,
        "positions": positions,
    }


def jax_lax_cummax(x, axis):
    import jax

    return jax.lax.cummax(x, axis=axis)


# ---------------------------------------------------------------------------
# Pipeline object
# ---------------------------------------------------------------------------


@dataclass
class HGumBatchPipeline:
    """End-to-end: corpus -> pack -> HGum wire -> device decode -> batch."""

    vocab: int
    batch: int
    seq: int
    seed: int = 0
    interpret: bool = True
    use_kernel: bool = True

    def __post_init__(self):
        self.corpus = SyntheticCorpus(self.vocab, self.seed)
        self._docs = self.corpus.docs()

    def host_make_wire(self) -> bytes:
        tokens, segids = pack_documents(self._docs, self.batch, self.seq)
        return serialize_batch(tokens, segids)

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, jnp.ndarray]:
        wire = self.host_make_wire()
        return decode_batch(wire, self.batch, self.seq, interpret=self.interpret)
