"""Background prefetcher with a bounded queue + straggler watchdog.

The producer thread runs the host-side work (pack + HGum SER (+ decode when
the device step consumes ready batches)); the consumer (training loop) pops
ready batches.  ``StragglerWatchdog`` tracks per-step wall time and flags
steps slower than ``threshold x`` the trailing median — the launcher reacts
by forcing an early checkpoint (see ``launch/train.py``).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Optional


class Prefetcher:
    def __init__(self, make_item: Callable[[], object], depth: int = 2):
        self.make_item = make_item
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._exc: Optional[BaseException] = None
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        try:
            while not self._stop.is_set():
                item = self.make_item()
                while not self._stop.is_set():
                    try:
                        self.q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # surface in consumer
            self._exc = e

    def get(self, timeout: float = 60.0):
        if self._exc is not None:
            raise self._exc
        return self.q.get(timeout=timeout)

    def close(self):
        self._stop.set()
        # drain so the producer unblocks
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=5.0)


class StragglerWatchdog:
    """Flags steps slower than `threshold` x trailing-median step time."""

    def __init__(self, threshold: float = 3.0, window: int = 32):
        self.threshold = threshold
        self.window = window
        self.times = []
        self.flagged = 0
        self._t0 = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> bool:
        """Record one step; True when the step was a straggler."""
        dt = time.monotonic() - self._t0
        slow = False
        if len(self.times) >= 8:
            med = sorted(self.times[-self.window :])[len(self.times[-self.window :]) // 2]
            slow = dt > self.threshold * med
        self.times.append(dt)
        if slow:
            self.flagged += 1
        return slow
