"""HGum-schema'd data plane: host SER -> phit wire -> device DES -> batches."""
from .schemas import batch_schema, request_schema, response_schema
from .pipeline import HGumBatchPipeline, SyntheticCorpus, pack_documents
from .prefetch import Prefetcher

__all__ = [
    "batch_schema", "request_schema", "response_schema",
    "HGumBatchPipeline", "SyntheticCorpus", "pack_documents", "Prefetcher",
]
