"""Central schemas (HGum IDL) for the framework's own messages.

These are the *messages* of the training/serving system — the paper's
technique applied to ourselves:

* ``batch_schema``    — SW->HW training batch: an Array of fixed-length rows
  (tokens + segment ids).  Fixed-size rows make every leaf a uniform run,
  so the device DES hits the ``unpack_run`` Pallas fast path.
* ``request_schema``  — serving request: a List of prompts, each a List of
  token ids (lengths unknown up front — the paper's List case).
* ``response_schema`` — HW->SW response: List of generated ids per prompt
  (hardware SER writes counts after elements, host parses from the end).
"""
from __future__ import annotations

from ..core.idl import ClientSchema, Schema

TOKEN_BYTES = 4


def batch_schema(seq_len: int) -> Schema:
    # Fixed-length rows: Array of Row structs; row fields are Arrays whose
    # runtime length equals seq_len (validated by the pipeline).
    return Schema.from_json({
        "Batch": [
            ["rows", ["Array", ["Struct", "Row"]]],
        ],
        "Row": [
            ["tokens", ["Array", ["Bytes", TOKEN_BYTES]]],
            ["segids", ["Array", ["Bytes", TOKEN_BYTES]]],
        ],
    })


def batch_client_schema() -> ClientSchema:
    return ClientSchema.from_json({
        "rows.start": 1,
        "rows.elem.tokens.start": 2,
        "rows.elem.tokens.elem": 3,
        "rows.elem.segids.start": 4,
        "rows.elem.segids.elem": 5,
    })


def request_schema() -> Schema:
    return Schema.from_json({
        "Request": [
            ["req_id", ["Bytes", 8]],
            ["prompts", ["List", ["Struct", "Prompt"]]],
        ],
        "Prompt": [
            ["tokens", ["List", ["Bytes", TOKEN_BYTES]]],
        ],
    })


def response_schema() -> Schema:
    return Schema.from_json({
        "Response": [
            ["req_id", ["Bytes", 8]],
            ["outputs", ["List", ["Struct", "Output"]]],
        ],
        "Output": [
            ["tokens", ["List", ["Bytes", TOKEN_BYTES]]],
        ],
    })
