"""phi3.5-moe-42b-a6.6b [moe]: 32L d4096 32H (GQA kv=8) d_ff 6400
vocab 32064, MoE 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="lm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=6400,
    vocab=32064,
    moe_experts=16,
    moe_topk=2,
    act="swiglu",
    microbatch=16,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    verified="hf",
))
