"""stablelm-3b [dense]: 32L d2560 32H (MHA kv=32) d_ff 6912 vocab 50304.

[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-3b",
    family="lm",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv=32,
    d_ff=6912,
    vocab=50304,
    act="swiglu",
    microbatch=8,
    source="hf:stabilityai/stablelm-2-1_6b",
    verified="unverified",
))
