"""Architecture registry: one module per assigned architecture (+ shapes)."""
from .base import (
    ModelConfig,
    ShapeConfig,
    SHAPES,
    all_archs,
    get_config,
    register,
    smoke_config,
    supports_shape,
)

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "all_archs", "get_config",
    "register", "smoke_config", "supports_shape",
]
