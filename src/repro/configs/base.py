"""Model / run configuration system.

One frozen dataclass describes an architecture; ``src/repro/configs/<id>.py``
instantiates it with the exact published numbers.  ``registry`` maps
``--arch`` ids to configs; ``smoke_config`` shrinks any config to a
CPU-runnable variant of the same family for tests.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # "lm" | "encdec" | "vlm"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # --- attention flavour ---
    window: Optional[int] = None  # sliding-window size for local layers
    local_global_alternate: bool = False  # gemma2: alternate local/global
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    use_rope: bool = True

    # --- ffn flavour ---
    act: str = "swiglu"  # swiglu | geglu | gelu

    # --- MoE ---
    moe_experts: int = 0
    moe_topk: int = 2
    moe_every: int = 1  # MoE FFN on layers where (layer % moe_every == moe_offset)
    moe_offset: int = 0
    moe_dff: Optional[int] = None  # expert hidden dim (default d_ff)
    capacity_factor: float = 1.25

    # --- layer pattern (hybrid models) ---
    layer_pattern: str = "attn"  # "attn" | "jamba" (attn every 8th) | "xlstm"

    # --- SSM (mamba / xlstm) dims ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head: int = 64  # SSD head dim

    # --- encoder-decoder ---
    enc_layers: int = 0
    enc_seq: int = 1500  # whisper frame count after conv stub
    enc_dim: Optional[int] = None

    # --- VLM ---
    vision_tokens: int = 0
    vision_dim: int = 0

    # --- misc ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-6
    embed_scale: bool = False  # multiply embeddings by sqrt(d) (gemma)
    sandwich_norm: bool = False  # post-sublayer norms (gemma2)
    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    # --- runtime knobs (perf-iteration surface) ---
    scan_layers: bool = False  # scan over layers (smaller HLO, fuzzier costs)
    remat: bool = True
    microbatch: int = 1  # gradient-accumulation steps per train_step
    opt_moments: str = "fp32"  # "q8": int8/bf16 Adam moments (398B-class)
    remat_policy: str = "nothing"  # "nothing" | "dots" (save dot outputs)
    attn_p_bf16: bool = False  # cast softmax weights to bf16 for the PV dot

    # --- provenance ---
    source: str = ""
    verified: str = "unverified"

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows: vocab rounded up to 128 so the vocab dim
        shards over any mesh axis (whisper's 51865 is otherwise prime-ish
        and forces replicated fp32 logits).  Pad logits are masked to -inf
        at the unembed."""
        return -(-self.vocab // 128) * 128

    # ---- layer plans ------------------------------------------------------

    def layer_kinds(self) -> Tuple[str, ...]:
        """Sequence kind per layer: attn | mamba | mlstm | slstm."""
        if self.layer_pattern == "attn":
            return ("attn",) * self.n_layers
        if self.layer_pattern == "jamba":
            # paper: Jamba block = 8 layers, 1 attention : 7 mamba
            return tuple(
                "attn" if (i % 8) == 4 else "mamba" for i in range(self.n_layers)
            )
        if self.layer_pattern == "xlstm":
            # alternate mLSTM / sLSTM blocks
            return tuple(
                "mlstm" if (i % 2) == 0 else "slstm" for i in range(self.n_layers)
            )
        raise ValueError(f"unknown layer_pattern {self.layer_pattern!r}")

    def ffn_kinds(self) -> Tuple[str, ...]:
        """FFN kind per layer: dense | moe | none."""
        if self.d_ff == 0:
            return ("none",) * self.n_layers
        if self.moe_experts > 0:
            return tuple(
                "moe" if (i % self.moe_every) == self.moe_offset else "dense"
                for i in range(self.n_layers)
            )
        return ("dense",) * self.n_layers

    def attn_is_local(self, layer: int) -> bool:
        if self.window is None:
            return False
        if self.local_global_alternate:
            return layer % 2 == 0  # gemma2: even layers local
        return True  # uniform sliding window (mistral/mixtral style)

    # ---- parameter count (for 6ND model-flops accounting) -----------------

    def param_counts(self) -> Dict[str, float]:
        d, hd = self.d_model, self.hd
        nq, nkv = self.n_heads, self.n_kv
        counts = {"embed": self.vocab * d}
        attn = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
        glu = 3 if self.act in ("swiglu", "geglu") else 2
        dense_ffn = glu * d * self.d_ff
        moe_dff = self.moe_dff or self.d_ff
        moe_ffn = self.moe_experts * glu * d * moe_dff + d * self.moe_experts
        d_in = self.ssm_expand * d
        mamba = (
            2 * d * d_in  # in/out proj (x and gate)
            + d_in * self.ssm_conv
            + d_in * (2 * self.ssm_state + d_in // self.ssm_head)  # B,C,dt heads
            + d_in
        )
        # q,k,v + output gate (d->d_in each) + out_proj + i/f gate heads
        mlstm = 5 * d * d_in + 2 * d * self.n_heads + 3 * d_in
        slstm = 4 * d * d + 4 * d  # i,f,z,o projections
        total = counts["embed"] * (1 if self.tie_embeddings else 2)
        active = total
        for kind, fk in zip(self.layer_kinds(), self.ffn_kinds()):
            seq_p = {"attn": attn, "mamba": mamba, "mlstm": mlstm, "slstm": slstm}[kind]
            total += seq_p
            active += seq_p
            if fk == "dense":
                total += dense_ffn
                active += dense_ffn
            elif fk == "moe":
                total += moe_ffn
                active += d * self.moe_experts + self.moe_topk * glu * d * moe_dff
        if self.family == "encdec":
            enc = self.enc_layers * (attn + dense_ffn)
            cross = self.n_layers * attn
            total += enc + cross
            active += enc + cross
        if self.family == "vlm":
            total += self.vision_dim * d
            active += self.vision_dim * d
        counts["total"] = float(total)
        counts["active"] = float(active)
        return counts


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set) and registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_archs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


def _ensure_loaded() -> None:
    # import the per-arch modules exactly once (they call register()).
    from . import (  # noqa: F401
        gemma2_27b,
        granite_34b,
        yi_6b,
        stablelm_3b,
        whisper_tiny,
        jamba_1_5_large,
        mixtral_8x22b,
        phi35_moe,
        phi3_vision,
        xlstm_125m,
    )


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs — long_500k needs sub-quadratic
    attention (see DESIGN.md §5)."""
    if shape.name == "long_500k":
        subq = cfg.layer_pattern in ("jamba", "xlstm") or (
            cfg.window is not None and not cfg.local_global_alternate
        )
        if not subq:
            return False, "full attention is not sub-quadratic at 500k (DESIGN.md §5)"
    if cfg.family == "encdec" and shape.name == "long_500k":
        return False, "enc-dec: 500k decoder context out of scope (DESIGN.md §5)"
    return True, ""


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return replace(
        cfg,
        n_layers=min(cfg.n_layers, 4 if cfg.layer_pattern == "attn" else 8),
        d_model=128,
        n_heads=4,
        n_kv=min(cfg.n_kv, 4) if cfg.n_kv > 1 else 1,
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        moe_dff=None,
        vocab=512,
        moe_experts=min(cfg.moe_experts, 4),
        window=min(cfg.window, 64) if cfg.window else None,
        enc_layers=min(cfg.enc_layers, 2),
        enc_seq=min(cfg.enc_seq, 32),
        vision_tokens=min(cfg.vision_tokens, 16),
        vision_dim=min(cfg.vision_dim, 64) if cfg.vision_dim else 0,
        ssm_state=min(cfg.ssm_state, 16),
        ssm_head=32,
        dtype="float32",
        microbatch=1,
    )
