"""jamba-1.5-large-398b [hybrid]: 72L d8192 64H (GQA kv=8) d_ff 24576
vocab 65536, MoE 16e top-2.  Mamba+attention 1:7 interleave, MoE every
other layer.  [arXiv:2403.19887; hf]
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="lm",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_ff=24576,
    vocab=65536,
    layer_pattern="jamba",
    moe_experts=16,
    moe_topk=2,
    moe_every=2,
    moe_offset=1,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head=64,
    act="swiglu",
    use_rope=False,  # jamba uses no positional encoding (mamba carries order)
    microbatch=64,
    opt_moments="q8",  # 398B: fp32 moments alone exceed 16 GiB/chip at 512 chips
    source="arXiv:2403.19887",
    verified="hf",
))
