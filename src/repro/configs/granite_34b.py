"""granite-34b [dense]: 88L d6144 48H (MQA kv=1) d_ff 24576 vocab 49152.

Llama-architecture code model with multi-query attention.
[arXiv:2405.04324; hf]
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-34b",
    family="lm",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv=1,
    d_ff=24576,
    vocab=49152,
    act="gelu",  # gpt-bigcode 2-matrix MLP (GLU would be ~46B, not 34B)
    microbatch=32,
    source="arXiv:2405.04324",
    verified="hf",
))
