"""gemma2-27b [dense]: 46L d4608 32H (GQA kv=16) d_ff 36864 vocab 256000.

Local+global alternating attention (window 4096 on local layers), logit
softcapping (attn 50.0, final 30.0), GeGLU.  [arXiv:2408.00118; hf]
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-27b",
    family="lm",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    window=4096,
    local_global_alternate=True,
    attn_softcap=50.0,
    final_softcap=30.0,
    embed_scale=True,
    sandwich_norm=True,
    act="geglu",
    microbatch=16,
    source="arXiv:2408.00118",
    verified="hf",
))
