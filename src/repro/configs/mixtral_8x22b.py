"""mixtral-8x22b [moe]: 56L d6144 48H (GQA kv=8) d_ff 16384 vocab 32768,
MoE 8 experts top-2, sliding-window attention.  [arXiv:2401.04088; hf]
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    family="lm",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_ff=16384,
    vocab=32768,
    moe_experts=8,
    moe_topk=2,
    window=4096,  # SWA per assignment spec
    act="swiglu",
    microbatch=16,
    source="arXiv:2401.04088",
    verified="hf",
))
