"""yi-6b [dense]: 32L d4096 32H (GQA kv=4) d_ff 11008 vocab 64000.

Llama-architecture GQA.  [arXiv:2403.04652; hf]
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="yi-6b",
    family="lm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=4,
    d_ff=11008,
    vocab=64000,
    act="swiglu",
    microbatch=4,
    source="arXiv:2403.04652",
    verified="hf",
))
