"""xlstm-125m [ssm]: 12L d768 4H d_ff=0 vocab 50304.

Alternating mLSTM (matrix memory) and sLSTM (scalar memory, exponential
gating) blocks; no FFN (d_ff=0).  [arXiv:2405.04517; unverified]
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-125m",
    family="lm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv=4,
    d_ff=0,
    vocab=50304,
    layer_pattern="xlstm",
    ssm_expand=2,
    ssm_head=192,  # d_inner(1536) / 8 heads -> use 4 heads of 384? keep 192x8
    act="gelu",
    use_rope=False,
    microbatch=1,
    source="arXiv:2405.04517",
    verified="unverified",
))
