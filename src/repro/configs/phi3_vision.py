"""phi-3-vision-4.2b [vlm]: 32L d3072 32H (MHA kv=32) d_ff 8192 vocab 32064.

phi3-mini backbone + CLIP frontend STUB: ``input_specs()`` provides
precomputed patch embeddings (B, 576, 1024), projected into d_model and
prepended to the token embeddings.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=32064,
    vision_tokens=576,
    vision_dim=1024,
    act="swiglu",
    microbatch=4,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
    verified="hf",
))
