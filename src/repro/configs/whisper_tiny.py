"""whisper-tiny [audio]: 4L d384 6H (kv=6) d_ff 1536 vocab 51865.

Encoder-decoder; conv frontend is a STUB — ``input_specs()`` provides
precomputed frame embeddings (B, enc_seq, d).  [arXiv:2212.04356; unverified]
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    enc_layers=4,
    enc_seq=1500,
    d_model=384,
    n_heads=6,
    n_kv=6,
    d_ff=1536,
    vocab=51865,
    act="gelu",
    norm="layernorm",
    use_rope=False,  # whisper uses learned/sinusoidal positions
    tie_embeddings=True,
    microbatch=8,
    source="arXiv:2212.04356",
    verified="unverified",
))
