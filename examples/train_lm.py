"""End-to-end training driver example (~100M-param model, a few hundred steps).

Everything is the production path at reduced scale: HGum-wire input
pipeline (host SER -> device DES), AdamW with fp32 master, HGum-framed
checkpoints with keep-K + auto-resume, straggler watchdog.

Run (fast demo, ~2 min on CPU):
  PYTHONPATH=src python examples/train_lm.py --steps 120

Full ~100M config (slower):
  PYTHONPATH=src python examples/train_lm.py --steps 300 --full
"""
import argparse

from repro.configs.base import ModelConfig
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--full", action="store_true",
                    help="~100M params (12L x 768d) instead of the tiny demo")
    ap.add_argument("--ckpt-dir", default="/tmp/hgum_train_lm")
    args = ap.parse_args()

    if args.full:
        # register a ~100M-param decoder (gpt2-small-like) on the fly
        from repro.configs.base import register
        cfg = ModelConfig(
            name="demo-100m", family="lm", n_layers=12, d_model=768,
            n_heads=12, n_kv=12, d_ff=3072, vocab=50304, act="gelu",
            dtype="float32", microbatch=1,
        )
        try:
            register(cfg)
        except ValueError:
            pass
        out = train_loop("demo-100m", steps=args.steps, batch=8, seq=256,
                         smoke=False, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                         resume="auto", lr=6e-4)
    else:
        out = train_loop("yi-6b", steps=args.steps, batch=8, seq=128,
                         smoke=True, ckpt_dir=args.ckpt_dir, ckpt_every=40,
                         resume="auto", lr=1e-3)
    print(f"\nfirst loss {out['first_loss']:.3f} -> final {out['final_loss']:.3f} "
          f"({out['steps']} steps, {out['stragglers']} straggler steps)")
    assert out["final_loss"] < out["first_loss"], "loss must fall"


if __name__ == "__main__":
    main()
