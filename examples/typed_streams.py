"""Typed streams end to end: a second ``Stream<T>`` payload over the fabric.

The token chunks of the streaming serve plane are generated from a
``Stream<Bytes 4>`` schema declaration (``repro.stream.chunks``).  This
example proves the generality claim of ``core.stream_plans`` with the
shipped SECOND typed stream — per-token log-probabilities, declared
purely in schema JSON as ``Stream<Struct{tok, logprob}>`` — and the PR's
two regression gates:

1. **golden byte-compat** — the generated token codec emits byte-for-byte
   the frozen hand-rolled wire format (``tests/golden/token_chunks.bin``);
2. **token identity** — attaching the logprob stream changes NOTHING
   about the token plane: the streamed final wires stay byte-identical to
   the batched plane and to the logprob-free streamed run, while every
   ``on_logprob`` event's token cross-validates against ``on_token``.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/typed_streams.py
"""
import dataclasses
import pathlib
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.launch.serve import (
    default_serve_fabric, encode_request, serve_requests,
    serve_requests_streaming,
)
from repro.models import init_params
from repro.stream import (
    LOGPROB_STREAM_SCHEMA_JSON, TokenChunk, encode_chunk_burst,
    logprob_stream_plan,
)

MAX_NEW = 6
PAD_TO = 16
GOLDEN = pathlib.Path(__file__).parent.parent / "tests" / "golden" \
    / "token_chunks.bin"


def check_golden_fixture():
    """The generated ``Stream<Bytes 4>`` codec vs the frozen wire bytes."""
    rng = np.random.default_rng(1801)
    specs = [
        (0x0001_0000, 1, False), (0xFFFF_FFFF, 0, False), (7, 0, True),
        (0x0002_0003, 13, False), (42, 16, True), (0x1234_5678, 250, False),
    ]
    chunks, step_per_sid = [], {}
    for sid, n, eos in specs:
        step = step_per_sid.get(sid, 0)
        toks = tuple(
            int(t) for t in rng.integers(0, 1 << 32, n, dtype=np.uint64)
        )
        chunks.append(TokenChunk(sid, step, toks, eos))
        step_per_sid[sid] = step + 1
    golden = GOLDEN.read_bytes()
    assert encode_chunk_burst(chunks) == golden, \
        "generated token codec diverged from the frozen golden fixture"
    print(f"[golden]     generated codec byte-identical to "
          f"{GOLDEN.name} ({len(golden)} B, {len(chunks)} chunks)")


def main():
    check_golden_fixture()

    plan = logprob_stream_plan()
    print(f"[plan]       logprob stream from schema JSON alone: "
          f"{list(LOGPROB_STREAM_SCHEMA_JSON)} -> "
          f"{plan.n_leaves} leaves x {plan.elem_words} word(s)/element")

    cfg = dataclasses.replace(smoke_config(get_config("yi-6b")), n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    wires = [
        encode_request(r, [
            list(map(int, rng.integers(2, cfg.vocab, PAD_TO)))
            for _ in range(int(rng.integers(1, 3)))
        ])
        for r in range(4)
    ]

    if default_serve_fabric(None) is None:
        print("[skip]       needs >= 2 devices (set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return

    kw = dict(max_new=MAX_NEW, pad_to=PAD_TO, slots=8)
    batched = serve_requests(params, cfg, wires, **kw)
    plain = serve_requests_streaming(params, cfg, wires, **kw)
    assert plain == batched, "streaming diverged from the batched plane"

    toks, lps = {}, {}
    t0 = time.time()
    with_lp = serve_requests_streaming(
        params, cfg, wires, logprobs=True,
        on_token=lambda m, j, s, t: toks.setdefault((m, j), []).append(t),
        on_logprob=lambda m, j, s, t, lp:
            lps.setdefault((m, j), []).append((t, lp)),
        **kw)
    dt = time.time() - t0

    assert with_lp == plain == batched, \
        "attaching the logprob stream changed the token plane"
    assert set(lps) == set(toks), "logprob/token stream key mismatch"
    n_events = 0
    for key, pairs in lps.items():
        assert [t for t, _ in pairs] == toks[key], \
            f"logprob stream tokens diverged for {key}"
        assert all(np.isfinite(lp) and lp <= 0.0 for _, lp in pairs)
        n_events += len(pairs)
    sample = lps[min(lps)][0]
    print(f"[logprobs]   {n_events} logprob events over "
          f"{len(lps)} streams in {dt:.2f}s; tokens byte-identical with "
          f"and without the extra stream; sample (tok={sample[0]}, "
          f"lp={sample[1]:.4f})")


if __name__ == "__main__":
    main()
