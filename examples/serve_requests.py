"""Batched serving over HGum wires (the paper's three directions, live).

A burst of requests arrives as SW->HW HGum wires (List of prompts, unknown
lengths).  The batched message plane deserializes ALL of them with one
schema walk + one gather per leaf path (``core.vectorized.batch_plans`` /
``decode_batch``), feeds the prompts through the continuous-batching
scheduler (fixed KV slots, admit/evict per step, cached jitted steps), and
answers with HW->SW wires serialized in bulk (counts after elements; the
host parses from the end).

The seed's one-wire-at-a-time path is run on the same burst for
comparison — it re-walks the ROM and re-jits prefill for every request.
Prompt lengths are kept >= PAD_TO so both paths pad to the same length and
must produce token-identical responses (asserted below); with shorter
prompts the seed path picks a per-request pad length while the fixed-slot
scheduler always pads to PAD_TO, so outputs may legitimately differ.

With ``--sharded`` the same burst additionally goes through the routed
message fabric (``repro.fabric``): rank 0 (ingress) routes each request
wire to a serving shard, every shard answers through its own
continuous-batching plane, and the response wires ride the multi-hop
return path back — asserted token-identical to the local batched plane.

With ``--streaming`` the shards stream instead of buffering: every decode
tick each shard mails the step's tokens back as framed chunk bursts
(``repro.stream``), the fabric tick overlaps the next decode step
(``Fabric.exchange_async``), and the ingress surfaces tokens the tick
they arrive — the example prints the time-to-first-token against the
whole-burst wall clock, and the final wires are asserted byte-identical
to the batched plane.

Run:  PYTHONPATH=src python examples/serve_requests.py [--sharded|--streaming]
      (use XLA_FLAGS=--xla_force_host_platform_device_count=8 to get
      a multi-rank fabric on CPU)
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.launch.serve import (
    decode_response, encode_request, serve_request, serve_requests,
    serve_requests_sharded,
)
from repro.models import init_params

MAX_NEW = 8
PAD_TO = 16


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sharded", action="store_true",
                    help="also route the burst through the message fabric "
                         "to per-shard batchers and assert token-identity")
    ap.add_argument("--streaming", action="store_true",
                    help="also stream token chunks back from the shards "
                         "every decode tick and report time-to-first-token")
    ap.add_argument("--n-shards", type=int, default=None)
    ap.add_argument("--metrics-json", metavar="PATH",
                    help="with --sharded/--streaming: write the serve "
                         "metrics snapshot (TTFT, tokens/s, fabric "
                         "counters) as JSON; inspect with "
                         "`python -m repro.obs PATH`")
    ap.add_argument("--trace-out", metavar="PATH",
                    help="with --sharded/--streaming: write a Chrome-trace "
                         "JSON of the fabric/serve timeline (ticks, chunk "
                         "arrivals, request flow arcs) for "
                         "chrome://tracing / Perfetto")
    args = ap.parse_args()
    metrics = trace = None
    if args.metrics_json or args.trace_out:
        from repro.obs import MetricsRegistry, TraceRecorder

        if args.metrics_json:
            metrics = MetricsRegistry()
        if args.trace_out:
            trace = TraceRecorder()
    cfg = dataclasses.replace(smoke_config(get_config("yi-6b")), n_layers=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    wires = []
    for req_id in range(4):
        n_prompts = int(rng.integers(1, 5))
        prompts = [
            list(map(int, rng.integers(2, cfg.vocab, rng.integers(PAD_TO, PAD_TO + 8))))
            for _ in range(n_prompts)
        ]
        wires.append(encode_request(req_id, prompts))
    total_b = sum(len(w) for w in wires)

    # --- batched message plane ---------------------------------------
    t0 = time.time()
    resp_wires = serve_requests(
        params, cfg, wires, max_new=MAX_NEW, pad_to=PAD_TO, slots=8
    )
    dt_batched = time.time() - t0
    n_tok = 0
    for w, rw in zip(wires, resp_wires):
        rid, outs = decode_response(rw)
        n_tok += sum(len(o) for o in outs)
        print(f"req {rid}: {len(outs)} prompts ({len(w)} B) -> "
              f"{sum(len(o) for o in outs)} tokens ({len(rw)} B)")
        for i, o in enumerate(outs[:2]):
            print(f"   out[{i}] = {o}")
    print(f"[batched]    {len(wires)} requests ({total_b} B) -> {n_tok} tokens "
          f"in {dt_batched:.2f}s ({n_tok / dt_batched:.1f} tok/s)")

    # --- sharded plane over the message fabric -----------------------
    if args.sharded:
        from repro.launch.serve import default_serve_fabric

        fabric = default_serve_fabric(args.n_shards)
        if fabric is None:
            print("[sharded]    skipped: needs >= 2 devices (set "
                  "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        else:
            t0 = time.time()
            shard_wires = serve_requests_sharded(
                params, cfg, wires, max_new=MAX_NEW, pad_to=PAD_TO, slots=8,
                fabric=fabric, metrics=metrics, trace=trace,
            )
            dt_shard = time.time() - t0
            assert shard_wires == resp_wires, \
                "sharded plane diverged from the batched plane"
            print(f"[sharded]    same burst over the fabric "
                  f"({fabric.n_ranks - 1} shards, "
                  f"{fabric.frames_routed} frames), token-identical, "
                  f"in {dt_shard:.2f}s ({n_tok / dt_shard:.1f} tok/s)")

    # --- streaming plane: tokens surface per decode tick --------------
    if args.streaming:
        from repro.launch.serve import default_serve_fabric, serve_requests_streaming

        fabric = default_serve_fabric(args.n_shards)
        if fabric is None:
            print("[streaming]  skipped: needs >= 2 devices (set "
                  "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        else:
            arrivals = []
            t0 = time.time()
            stream_wires = serve_requests_streaming(
                params, cfg, wires, max_new=MAX_NEW, pad_to=PAD_TO, slots=8,
                fabric=fabric, overlap=True,
                metrics=metrics, trace=trace,
                on_token=lambda m, j, step, tok:
                    arrivals.append(time.time() - t0),
            )
            dt_stream = time.time() - t0
            assert stream_wires == resp_wires, \
                "streaming plane diverged from the batched plane"
            print(f"[streaming]  same burst streamed per decode tick "
                  f"({fabric.n_ranks - 1} shards, {len(arrivals)} token "
                  f"events), byte-identical wires, "
                  f"time-to-first-token {arrivals[0]:.3f}s vs "
                  f"{dt_stream:.2f}s total "
                  f"({n_tok / dt_stream:.1f} tok/s)")
            # congestion-control knobs: defection + the backpressure-fed
            # lane clamp delay bursts but can never change tokens
            cc_wires = serve_requests_streaming(
                params, cfg, wires, max_new=MAX_NEW, pad_to=PAD_TO, slots=8,
                n_shards=args.n_shards, defect_after=2,
                backpressure_p95=4.0,
            )
            assert cc_wires == resp_wires, \
                "congestion-controlled streaming diverged"
            print("[streaming]  defect_after=2 + backpressure_p95=4.0: "
                  "still byte-identical")

    # --- seed sequential path, same burst ----------------------------
    t0 = time.time()
    seq_wires = [
        serve_request(params, cfg, w, max_new=MAX_NEW, pad_to=PAD_TO)
        for w in wires
    ]
    dt_seq = time.time() - t0
    assert [decode_response(w) for w in seq_wires] == [
        decode_response(w) for w in resp_wires
    ], "sequential and batched paths disagree"
    print(f"[sequential] same burst, same tokens, in {dt_seq:.2f}s "
          f"({n_tok / dt_seq:.1f} tok/s) -> batched is {dt_seq / dt_batched:.1f}x")

    # --- telemetry artifacts (whichever fabric modes ran) --------------
    if metrics is not None:
        import json

        from repro.obs import environment_meta

        snap = metrics.snapshot()
        snap["meta"] = environment_meta()
        with open(args.metrics_json, "w") as f:
            json.dump(snap, f, indent=1)
        print(f"[obs]        wrote {args.metrics_json} "
              f"({len(snap['metrics'])} metrics)")
    if trace is not None:
        trace.save(args.trace_out)
        print(f"[obs]        wrote {args.trace_out} "
              f"({len(trace.events)} events)")


if __name__ == "__main__":
    main()
