"""Batched serving over HGum wires (the paper's three directions, live).

Requests arrive as SW->HW HGum wires (List of prompts, unknown lengths);
the serving host deserializes with the streaming FSM, batches prompts,
runs prefill + greedy decode, and answers with an HW->SW wire (counts after
elements; host parses from the end).

Run:  PYTHONPATH=src python examples/serve_requests.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.launch.serve import (
    decode_response, encode_request, serve_request,
)
from repro.models import init_params


def main():
    cfg = dataclasses.replace(smoke_config(get_config("yi-6b")), n_layers=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    for req_id in range(3):
        n_prompts = int(rng.integers(2, 6))
        prompts = [
            list(map(int, rng.integers(2, cfg.vocab, rng.integers(3, 20))))
            for _ in range(n_prompts)
        ]
        wire = encode_request(req_id, prompts)
        t0 = time.time()
        resp = serve_request(params, cfg, wire, max_new=8, pad_to=32)
        dt = time.time() - t0
        rid, outs = decode_response(resp)
        print(f"req {rid}: {n_prompts} prompts ({len(wire)} B) -> "
              f"{sum(len(o) for o in outs)} tokens ({len(resp)} B) in {dt:.2f}s")
        for i, o in enumerate(outs):
            print(f"   prompt[{i}] len={len(prompts[i]):2d} -> {o}")


if __name__ == "__main__":
    main()
