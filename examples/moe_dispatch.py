"""Framed MoE dispatch demo: expert token groups as routed HGum Lists.

MoE dispatch is HGum's List-framing in disguise (DESIGN.md §5): each expert
receives a variable-length list of tokens, packed into fixed-capacity
frames (the (E, C, d) buffer = one frame per expert with a count header).
This demo runs the sort-based dispatch, prints per-expert frame fill, then
performs the expert **all-to-all over the routed message fabric**
(``repro.fabric``): every rank sends each expert's token list to the rank
that owns that expert as a routed framed List — CRC32 per frame, multi-hop
delivery, credit flow control — replacing the seed's hand-rolled
single-hop neighbour exchange.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/moe_dispatch.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.fabric import Fabric, FabricConfig
from repro.models.ffn import init_moe_ffn, moe_capacity, moe_ffn


def main():
    cfg = smoke_config(get_config("mixtral-8x22b"))
    key = jax.random.PRNGKey(0)
    p = init_moe_ffn(key, cfg, jnp.float32)
    B, S = 4, 32
    x = jax.random.normal(key, (B, S, cfg.d_model))

    y, aux = moe_ffn(p, x, cfg)
    C = moe_capacity(cfg, B * S)
    print(f"experts={cfg.moe_experts} top-{cfg.moe_topk} capacity={C}")
    print(f"balance_loss={float(aux['moe_balance_loss']):.4f} "
          f"dropped={float(aux['moe_dropped']):.3f}")

    # expert load = list length per expert (the HGum frame count header)
    logits = x.reshape(-1, cfg.d_model) @ p["router"]
    top = np.asarray(
        jax.lax.top_k(jax.nn.softmax(logits), cfg.moe_topk)[1].reshape(-1)
    )
    counts = np.bincount(top, minlength=cfg.moe_experts)
    for e, c in enumerate(counts):
        bar = "#" * int(30 * c / counts.max())
        print(f"  expert {e}: {c:4d} tokens (fill {c/C:5.1%}) {bar}")

    # ------------------------------------------------------------------
    # expert all-to-all over the routed fabric: rank r holds 1/R of the
    # token stream; expert e lives on rank e % R; every (rank, expert)
    # token-id list crosses the fabric as one routed framed List.
    # ------------------------------------------------------------------
    R = min(len(jax.devices()), cfg.moe_experts)
    if R < 2:
        print("(single device: skip the fabric all-to-all half)")
        return
    fabric = Fabric(n_ranks=R, config=FabricConfig(frame_phits=8))
    boxes = [fabric.mailbox(r) for r in range(R)]
    owner = lambda e: e % R
    token_ids = np.arange(top.shape[0], dtype=np.uint32)
    my_slice = np.array_split(np.arange(top.shape[0]), R)

    sent = {}
    for r in range(R):
        for e in range(cfg.moe_experts):
            ids = token_ids[my_slice[r]][top[my_slice[r]] == e]
            if ids.size == 0:
                continue  # unused expert: nothing to route (empty wires
                # are rejected at send — absence IS the empty list)
            sent[(r, e)] = ids
            # routed framed List: the expert id rides as the ListLevel
            boxes[r].send(owner(e), ids.tobytes(), list_level=e + 1)
    fabric.exchange()

    print(f"\nexpert all-to-all over the fabric: {fabric.n_ranks} ranks, "
          f"{fabric.frames_routed} frames routed, "
          f"crc_ok={fabric.last_crc_ok}")
    ok = True
    for d in range(R):
        got = boxes[d].recv()
        per_expert = {}
        for dl in got:
            assert dl.ok, f"corrupt frames from rank {dl.src}"
            e = dl.list_level - 1  # the expert id rode as the ListLevel
            ids = np.frombuffer(dl.wire, np.uint32)
            ok &= owner(e) == d and np.array_equal(sent[(dl.src, e)], ids)
            per_expert[e] = per_expert.get(e, 0) + len(ids)
        loads = {e: n for e, n in sorted(per_expert.items()) if n}
        print(f"  rank {d}: {len(got)} routed lists, expert loads {loads}")
    print(f"fabric all-to-all bit-exact: {ok}")


if __name__ == "__main__":
    main()
