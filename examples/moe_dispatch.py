"""Framed MoE dispatch demo: expert token groups as HGum Lists.

MoE dispatch is HGum's List-framing in disguise (DESIGN.md §5): each expert
receives a variable-length list of tokens, packed into fixed-capacity
frames (the (E, C, d) buffer = one frame per expert with a count header).
This demo runs the sort-based dispatch, prints per-expert frame fill, and
moves the framed buffers across a 2-member mesh axis with the HGum framed
channel (headers + checksums + empty-frame terminators).

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=2 \
      PYTHONPATH=src python examples/moe_dispatch.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models.ffn import init_moe_ffn, moe_capacity, moe_ffn
from repro.runtime import frame_stream, make_framed_sender, unframe_stream


def main():
    cfg = smoke_config(get_config("mixtral-8x22b"))
    key = jax.random.PRNGKey(0)
    p = init_moe_ffn(key, cfg, jnp.float32)
    B, S = 4, 32
    x = jax.random.normal(key, (B, S, cfg.d_model))

    y, aux = moe_ffn(p, x, cfg)
    C = moe_capacity(cfg, B * S)
    print(f"experts={cfg.moe_experts} top-{cfg.moe_topk} capacity={C}")
    print(f"balance_loss={float(aux['moe_balance_loss']):.4f} "
          f"dropped={float(aux['moe_dropped']):.3f}")

    # expert load = list length per expert (the HGum frame count header)
    logits = x.reshape(-1, cfg.d_model) @ p["router"]
    top = jax.lax.top_k(jax.nn.softmax(logits), cfg.moe_topk)[1].reshape(-1)
    counts = np.bincount(np.asarray(top), minlength=cfg.moe_experts)
    for e, c in enumerate(counts):
        bar = "#" * int(30 * c / counts.max())
        print(f"  expert {e}: {c:4d} tokens (fill {c/C:5.1%}) {bar}")

    # ship one expert buffer across a 2-member axis as HGum frames
    if len(jax.devices()) >= 2:
        mesh = jax.make_mesh((2,), ("ep",), devices=jax.devices()[:2])
        buf = jnp.arange(2 * 4096, dtype=jnp.uint32).reshape(2, 4096)
        nbytes = jnp.asarray([counts[0] * cfg.d_model * 4,
                              counts[1] * cfg.d_model * 4], jnp.int32)
        nbytes = jnp.minimum(nbytes, 4096 * 4)
        sender = make_framed_sender(mesh, "ep", frame_phits=64)
        out, nb, ok = jax.jit(sender)(buf, nbytes)
        print(f"\nframed exchange over 'ep' axis: ok={bool(ok.all())}, "
              f"lengths {list(np.asarray(nbytes))} -> {list(np.asarray(nb))}")
    else:
        print("(single device: skip the framed exchange half)")


if __name__ == "__main__":
    main()
