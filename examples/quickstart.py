"""Quickstart: HGum end to end in two minutes.

1. Define a message schema in the HGum IDL (paper Fig. 6).
2. Software-serialize a message (SW->HW).
3. Deserialize it with the cycle-accurate hardware DES FSM -> tagged tokens.
4. Deserialize the bulk payload with the TPU-native Pallas kernel path.
5. Loop a message through the HW->HW framed link.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    ClientSchema, DesFSM, Schema, SerFSM, build_plan, build_rom,
    lanes_to_int, ser_sw_to_hw, strip_for_ser,
    tokens_to_msg,
)
from repro.kernels import decode_message_kernel, wire_to_u32

# -- 1. the paper's Fig. 6 schema -------------------------------------------
schema = Schema.from_json({
    "Msg": [
        ["a", ["List", ["Array", ["Struct", "Tuple"]]]],
        ["b", ["Bytes", 1]],
    ],
    "Tuple": [["x", ["Bytes", 4]], ["y", ["Bytes", 8]]],
})
client = ClientSchema.from_json({  # paper Fig. 7
    "a.start": 1, "a.elem.start": 2, "a.elem.elem.x": 3,
    "a.elem.elem.y": 4, "a.elem.end": 5,
})
rom = build_rom(schema, client)
print("schema ROM:")
print(rom.describe())

# -- 2. software SER ---------------------------------------------------------
msg = {"a": [[{"x": 17, "y": 34}, {"x": 51, "y": 68}]], "b": 9}
wire = ser_sw_to_hw(schema, msg)
print(f"\nwire = {len(wire)} bytes: {wire.hex()}")

# -- 3. streaming hardware DES (cycle-accurate FSM) --------------------------
res = DesFSM(rom, "sw2hw").run(wire)
print(f"\nDES: {res.cycles} cycles -> {len(res.tokens)} tokens")
for t in res.tokens:
    print("  ", t)
assert tokens_to_msg(schema, res.tokens, client) == msg

# -- 4. TPU-native decode (structure pass + Pallas payload pass) --------------
plan = build_plan(schema, msg)
dec = decode_message_kernel(wire_to_u32(wire), plan)
xs = lanes_to_int(np.asarray(dec["a.elem.elem.x"]), 4)
print(f"\nPallas decode of a[.][.].x -> {list(xs)}")
assert list(xs) == [17, 51]

# -- 5. HW->HW framed loopback ------------------------------------------------
ser = SerFSM(rom, "hw2hw", frame_phits=4).run(strip_for_ser(res.tokens))
back = DesFSM(rom, "hw2hw").run(ser.wire)
assert [(t.kind, t.value) for t in back.tokens] == [
    (t.kind, t.value) for t in res.tokens
]
print(f"\nHW->HW: {ser.frames} frames, {len(ser.wire)} wire bytes, "
      f"SER {ser.cycles} cycles, DES {back.cycles} cycles — loopback OK")
