"""Paper Fig. 14a/14b: relative throughput vs optimum for arrays and lists.

Reproduces the paper's §V-A loopback (Fig. 13): SW SER -> HW DES (sw2hw) ->
HW SER (hw2hw) -> HW DES (hw2hw) -> HW SER (hw2sw) -> SW DES, with 128-bit
phits and 500-phit frames.  The cycle-accurate FSM engines report per-module
cycles; steady-state pipeline throughput is 1 / max(stage cycles).

Optimal throughput (paper): array of n elements = 1/(n+1) msg/cycle
(n data tokens + 1 array-length); list = 1/(n+2) (+ list-begin/end).
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import (
    ClientSchema, DesFSM, Schema, SerFSM, build_rom, des_hw_to_sw,
    ser_sw_to_hw, strip_for_ser,
)
from .common import Table

PHIT = 16  # 128-bit
FRAME_PHITS = 500
LENGTHS = [1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192]


def _loopback_cycles(schema: Schema, client: ClientSchema, msg: dict) -> Dict[str, int]:
    rom = build_rom(schema, client)  # DES modules use the client schema
    rom_plain = build_rom(schema)
    wire = ser_sw_to_hw(schema, msg)
    # stage 1: SW->HW DES
    des1 = DesFSM(rom, "sw2hw", phit_bytes=PHIT).run(wire)
    # stage 2: HW->HW SER
    ser2 = SerFSM(rom_plain, "hw2hw", phit_bytes=PHIT, frame_phits=FRAME_PHITS).run(
        strip_for_ser(des1.tokens)
    )
    # stage 3: HW->HW DES
    des3 = DesFSM(rom, "hw2hw", phit_bytes=PHIT).run(ser2.wire)
    # stage 4: HW->SW SER
    ser4 = SerFSM(rom_plain, "hw2sw", phit_bytes=PHIT).run(strip_for_ser(des3.tokens))
    assert des_hw_to_sw(schema, ser4.wire) == msg  # correctness of the loop
    return {
        "sw2hw_des": des1.cycles,
        "hw2hw_ser": ser2.cycles,
        "hw2hw_des": des3.cycles,
        "hw2sw_ser": ser4.cycles,
    }


def bench_array() -> Table:
    schema = Schema.from_json({"Msg": [["a", ["Array", ["Bytes", 16]]]]})
    client = ClientSchema.from_json({"a.elem": 1})  # no array-end tag -> not emitted
    t = Table("fig14a_array_128bit", [
        "n", "optimal_msgs_per_cycle", "measured", "ratio",
        "des_cycles", "ser_hh", "des_hh", "ser_hs",
    ])
    for n in LENGTHS:
        msg = {"a": list(range(n))}
        cyc = _loopback_cycles(schema, client, msg)
        bottleneck = max(cyc.values())
        optimal = 1.0 / (n + 1)
        measured = 1.0 / bottleneck
        t.add(n, optimal, measured, measured / optimal,
              cyc["sw2hw_des"], cyc["hw2hw_ser"], cyc["hw2hw_des"], cyc["hw2sw_ser"])
    return t


def bench_list() -> Table:
    schema = Schema.from_json({"Msg": [["a", ["List", ["Bytes", 16]]]]})
    client = ClientSchema()
    t = Table("fig14b_list_128bit", [
        "n", "optimal_msgs_per_cycle", "measured", "ratio",
        "des_cycles", "ser_hh", "des_hh", "ser_hs", "frames",
    ])
    for n in LENGTHS:
        msg = {"a": list(range(n))}
        rom = build_rom(schema, client)
        wire = ser_sw_to_hw(schema, msg)
        des1 = DesFSM(rom, "sw2hw", phit_bytes=PHIT).run(wire)
        ser2 = SerFSM(rom, "hw2hw", phit_bytes=PHIT, frame_phits=FRAME_PHITS).run(
            strip_for_ser(des1.tokens))
        des3 = DesFSM(rom, "hw2hw", phit_bytes=PHIT).run(ser2.wire)
        ser4 = SerFSM(rom, "hw2sw", phit_bytes=PHIT).run(strip_for_ser(des3.tokens))
        assert des_hw_to_sw(schema, ser4.wire) == msg
        cyc = [des1.cycles, ser2.cycles, des3.cycles, ser4.cycles]
        optimal = 1.0 / (n + 2)
        measured = 1.0 / max(cyc)
        t.add(n, optimal, measured, measured / optimal, *cyc, ser2.frames)
    return t


def run() -> List[Table]:
    return [bench_array(), bench_list()]


if __name__ == "__main__":
    for tb in run():
        print(tb.show())
