"""Paper §V area/frequency analog: cost vs schema complexity.

On an FPGA the claim is "critical path delay and area are almost insensitive
to the message schema" because the traversal FSM is schema-independent and
only the ROM grows.  The TPU analogues measured here, as schema complexity
grows (fields x nesting depth):

  * schema-ROM entries        (the only thing that *should* grow, linearly),
  * context-stack depth       (grows with nesting only),
  * decode jaxpr op count     (generated decoder: should stay ~constant per
                               leaf — the "FSM area" analog),
  * decode wall time per byte (the "frequency" analog, CPU interpret mode),

versus the *naive* per-field unrolled decoder (the paper's FSM-per-field
anti-pattern), whose op count grows with total field count.
"""
from __future__ import annotations

from typing import List

import numpy as np

import jax

from repro.core import Schema, build_rom, build_plan, random_message, ser_sw_to_hw
from repro.core.vectorized import decode_message, wire_to_u8
from .common import Table, time_call


def make_schema(n_fields: int, depth: int) -> Schema:
    """n_fields scalar fields wrapped in `depth` levels of Array/List."""
    inner = [[f"f{i}", ["Bytes", 4]] for i in range(n_fields)]
    obj = {"Inner": inner}
    t = ["Struct", "Inner"]
    for d in range(depth):
        t = ["Array", t] if d % 2 == 0 else ["List", t]
    obj = {"Msg": [["a", t], ["tail", ["Bytes", 4]]], "Inner": inner}
    return Schema.from_json(obj)


def naive_unrolled_decoder(schema: Schema, msg: dict):
    """The anti-pattern: one python-generated op per field instance."""
    plan = build_plan(schema, msg)

    def decode(wire_u8):
        out = []
        for p, offs in plan.offsets.items():
            nb = plan.nbytes[p]
            for i in range(plan.counts[p]):  # unrolled per INSTANCE
                o = int(offs[i])
                b = wire_u8[o : o + nb].astype(np.uint32)
                shifts = np.asarray([1, 256, 65536, 16777216][: nb], np.uint32)
                out.append((b * shifts).sum())
        return out

    return decode, plan


def run() -> List[Table]:
    t = Table("schema_complexity_area_freq_analog", [
        "fields", "depth", "rom_entries", "stack_depth",
        "hgum_jaxpr_ops", "naive_jaxpr_ops",
        "hgum_ns_per_byte", "wire_bytes",
    ])
    rng = np.random.default_rng(0)
    for n_fields, depth in [(2, 1), (4, 1), (8, 1), (16, 1),
                            (4, 2), (4, 3), (8, 3), (16, 3), (16, 4)]:
        schema = make_schema(n_fields, depth)
        rom = build_rom(schema)
        # representative message: containers get 3 elements each
        def gen(max_elems=6):
            # threshold must be reachable: the smallest config (2 fields,
            # depth 1) tops out at 4 + 6*8 + 4 = 56 bytes
            for _ in range(10_000):
                m = random_message(schema, rng, max_elems=max_elems, depth_decay=1.0)
                if len(ser_sw_to_hw(schema, m)) > 40:
                    return m
            return m
        msg = gen()
        wire = ser_sw_to_hw(schema, msg)
        plan = build_plan(schema, msg)
        w8 = wire_to_u8(wire)

        # generated decoder op count (jaxpr size — the "area" analog)
        jaxpr = jax.make_jaxpr(lambda w: decode_message(w, plan))(w8)
        hgum_ops = sum(1 for _ in jaxpr.jaxpr.eqns)
        # naive unrolled decoder op count (python-op proxy: field instances)
        naive_ops = sum(plan.counts.values())

        dt = time_call(
            lambda: jax.block_until_ready(decode_message(w8, plan)), repeats=3
        )
        t.add(n_fields, depth, rom.n_nodes, rom.stack_depth,
              hgum_ops, naive_ops, 1e9 * dt / len(wire), len(wire))
    return [t]


if __name__ == "__main__":
    for tb in run():
        print(tb.show())
