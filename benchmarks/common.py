"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import csv
import io
import time
from typing import Callable, List


def time_call(fn: Callable, *args, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


class Table:
    def __init__(self, name: str, columns: List[str]):
        self.name = name
        self.columns = columns
        self.rows: List[List] = []

    def add(self, *row):
        assert len(row) == len(self.columns)
        self.rows.append(list(row))

    def csv(self) -> str:
        buf = io.StringIO()
        w = csv.writer(buf)
        w.writerow([f"# {self.name}"])
        w.writerow(self.columns)
        w.writerows(self.rows)
        return buf.getvalue()

    def show(self) -> str:
        widths = [
            max(len(str(c)), *(len(_fmt(r[i])) for r in self.rows)) if self.rows else len(str(c))
            for i, c in enumerate(self.columns)
        ]
        out = [f"== {self.name} =="]
        out.append("  ".join(str(c).ljust(w) for c, w in zip(self.columns, widths)))
        for r in self.rows:
            out.append("  ".join(_fmt(v).ljust(w) for v, w in zip(r, widths)))
        return "\n".join(out)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)
