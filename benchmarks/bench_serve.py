"""Serving throughput: batched message plane vs the seed sequential loop.

Measures, at request-batch sizes {1, 8, 32}:

* **sequential** — the seed's ``serve_request`` loop: per-wire streaming-FSM
  DES, fresh ROM walk, per-request ``jax.jit`` of prefill/decode;
* **batched**    — ``serve_requests``: one batched structure pass + one
  gather per leaf for ALL wires, continuous-batching scheduler with cached
  jitted steps, bulk SER of the responses.

Also times the wire plane alone (batched DES vs per-message DES) and
asserts the batched decode is bit-exact against the per-message jnp oracle
before timing anything.

Run:  PYTHONPATH=src python benchmarks/bench_serve.py
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from pathlib import Path
from typing import List

sys.path.insert(0, str(Path(__file__).parent))

import jax
import jax.numpy as jnp
import numpy as np

from common import Table
from repro.configs import get_config, smoke_config
from repro.core import (
    batch_plans, decode_batch, decode_message, plan_from_wire, stack_wires,
    wire_to_u8,
)
from repro.data.schemas import request_schema
from repro.launch.serve import (
    decode_request, decode_request_batch, decode_response, encode_request,
    serve_request, serve_requests,
)
from repro.models import init_params

MAX_NEW = 8
PAD_TO = 16
BATCHES = (1, 8, 32)


def make_wires(cfg, n, rng):
    """n single-prompt request wires.  Prompt lengths are 16..23 >= PAD_TO,
    so both paths truncate/pad to exactly PAD_TO tokens and must produce
    identical responses (asserted in bench_serving)."""
    return [
        encode_request(r, [
            list(map(int, rng.integers(2, cfg.vocab, 16 + int(rng.integers(0, 8)))))
        ])
        for r in range(n)
    ]


def check_decode_bit_exact(wires) -> None:
    """Batched decode == per-message jnp oracle, bitwise."""
    schema = request_schema()
    bp = batch_plans(schema, wires)
    caps = {p: bp.cap(p) for p in bp.offsets}
    vals = decode_batch(jnp.asarray(stack_wires(wires)), bp)
    for i, w in enumerate(wires):
        ref = decode_message(wire_to_u8(w), plan_from_wire(schema, w, caps=caps))
        for p, v in vals.items():
            n = int(bp.counts[p][i])
            np.testing.assert_array_equal(np.asarray(v[i, :n]), np.asarray(ref[p][:n]))


def bench_wire_plane(cfg, rng, n=64) -> Table:
    t = Table("wire plane (request DES only)", ["path", "wires", "s", "wires/s"])
    wires = make_wires(cfg, n, rng)
    check_decode_bit_exact(wires)
    for name, fn in [
        ("per-message FSM", lambda: [decode_request(w) for w in wires]),
        ("batched plan+gather", lambda: decode_request_batch(wires)),
    ]:
        fn()  # warmup
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        t.add(name, n, round(dt, 4), round(n / dt, 1))
    return t


def bench_serving(params, cfg, rng, slots=8, batches=BATCHES) -> Table:
    t = Table(
        "serving throughput",
        ["batch", "path", "s", "req/s", "tok/s", "speedup"],
    )
    for B in batches:
        wires = make_wires(cfg, B, rng)
        t0 = time.perf_counter()
        seq_resp = [
            serve_request(params, cfg, w, max_new=MAX_NEW, pad_to=PAD_TO)
            for w in wires
        ]
        dt_seq = time.perf_counter() - t0
        t0 = time.perf_counter()
        bat_resp = serve_requests(
            params, cfg, wires, max_new=MAX_NEW, pad_to=PAD_TO,
            slots=min(slots, max(B, 1)),
        )
        dt_bat = time.perf_counter() - t0
        n_tok = sum(
            sum(len(o) for o in decode_response(w)[1]) for w in bat_resp
        )
        assert [decode_response(w) for w in bat_resp] == [
            decode_response(w) for w in seq_resp
        ], "batched plane diverged from the sequential path"
        t.add(B, "sequential", round(dt_seq, 2), round(B / dt_seq, 2),
              round(n_tok / dt_seq, 1), 1.0)
        t.add(B, "batched", round(dt_bat, 2), round(B / dt_bat, 2),
              round(n_tok / dt_bat, 1), round(dt_seq / dt_bat, 2))
    return t


def run() -> List[Table]:
    """Aggregator entry (``python -m benchmarks.run``): the wire plane plus
    a trimmed serving sweep (batch 32 is left to the standalone CLI)."""
    cfg = dataclasses.replace(smoke_config(get_config("yi-6b")), n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    return [
        bench_wire_plane(cfg, rng),
        bench_serving(params, cfg, rng, batches=(1, 8)),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        smoke_config(get_config(args.arch)), n_layers=args.layers
    )
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    print(bench_wire_plane(cfg, rng).show())
    print()
    tbl = bench_serving(params, cfg, rng, slots=args.slots)
    print(tbl.show())
    by_batch = {r[0]: r for r in tbl.rows if r[1] == "batched"}
    speedup32 = by_batch[32][5]
    print(f"\nbatched vs sequential at batch 32: {speedup32}x "
          f"({'PASS' if speedup32 >= 3.0 else 'FAIL'} >= 3x)")


if __name__ == "__main__":
    main()
