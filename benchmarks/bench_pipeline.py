"""Framework benches: input-pipeline throughput + framed-channel overhead.

* pipeline: host pack+SER -> device DES (Pallas kernel vs jnp oracle) in
  tokens/sec — the SW->HW direction at bulk rate.
* channel: HW->HW framing overhead vs frame size (paper: negligible once
  frames are large; an empty frame per list is the floor).
"""
from __future__ import annotations

from typing import List


import jax
import jax.numpy as jnp

from repro.core.vectorized import decode_message, wire_to_u8
from repro.data.pipeline import batch_plan, pack_documents, serialize_batch
from repro.data import SyntheticCorpus
from repro.kernels.ops import decode_message_kernel, wire_to_u32
from repro.runtime import frame_stream, unframe_stream
from .common import Table, time_call


def bench_pipeline() -> Table:
    t = Table("input_pipeline_throughput", [
        "batch", "seq", "stage", "ms", "mtok_per_s",
    ])
    for B, S in [(8, 512), (16, 1024)]:
        corpus = SyntheticCorpus(50_000, seed=0)
        docs = corpus.docs()
        ntok = B * S

        dt = time_call(lambda: serialize_batch(*pack_documents(docs, B, S)), repeats=3)
        t.add(B, S, "host_pack_ser", 1e3 * dt, ntok / dt / 1e6)

        tokens, segids = pack_documents(docs, B, S)
        wire = serialize_batch(tokens, segids)
        plan = batch_plan(B, S)
        w32 = wire_to_u32(wire)
        w8 = wire_to_u8(wire)
        paths = ["rows.elem.tokens.elem", "rows.elem.segids.elem"]

        k = jax.jit(lambda w: decode_message_kernel(w, plan, paths=paths))
        dt = time_call(lambda: jax.block_until_ready(k(w32)), repeats=3)
        t.add(B, S, "device_des_pallas", 1e3 * dt, ntok / dt / 1e6)

        o = jax.jit(lambda w: decode_message(w, plan, paths=paths))
        dt = time_call(lambda: jax.block_until_ready(o(w8)), repeats=3)
        t.add(B, S, "device_des_jnp_oracle", 1e3 * dt, ntok / dt / 1e6)
    return t


def bench_channel() -> Table:
    t = Table("framed_channel_overhead", [
        "payload_bytes", "frame_phits", "frames", "wire_bytes", "overhead_frac",
    ])
    for payload_bytes in (1 << 12, 1 << 16, 1 << 20):
        words = payload_bytes // 4
        payload = jnp.arange(words, dtype=jnp.uint32)
        for frame_phits in (4, 64, 500):
            frames, nf = frame_stream(payload, jnp.asarray(payload_bytes),
                                      frame_phits=frame_phits)
            nf = int(nf)
            hdr_bytes = nf * 16
            wire = payload_bytes + hdr_bytes
            out, nb, ok = unframe_stream(frames)
            assert bool(ok) and int(nb) == payload_bytes
            t.add(payload_bytes, frame_phits, nf, wire,
                  hdr_bytes / payload_bytes)
    return t


def run() -> List[Table]:
    return [bench_pipeline(), bench_channel()]


if __name__ == "__main__":
    for tb in run():
        print(tb.show())
