"""Benchmark aggregator: one table per paper figure + framework benches.

``python -m benchmarks.run`` prints every table and writes
``experiments/benchmarks.csv``.

``python -m benchmarks.run --smoke`` runs the fabric + stream benches only
and ALSO writes ``BENCH_fabric.json`` / ``BENCH_stream.json`` at the repo
root — headline metrics (frames/s, far-destination speedup, TTFT, hop
counts, arrive-step jitter, starved-link defection, backpressure clamp)
plus the full tables — so CI can upload them and the perf trajectory is
tracked across PRs instead of being a fresh anecdote every time.

The smoke run additionally gates on the COMMITTED ``BENCH_fabric.json``:
if the fabric smoke frames/s (``smoke_frames_per_s``, the fused-tick
throughput) regressed more than the threshold (default 20%) vs the number
checked in, the run exits non-zero so CI fails loudly instead of letting
a slow fabric ship silently.  Override with ``BENCH_GATE_MIN_RATIO``
(e.g. ``0.5`` on noisy shared runners) or disable with ``BENCH_GATE=0``.

Every BENCH_*.json carries a ``meta`` block (schema version, jax/device
platform, git sha, timestamp — ``repro.obs.report.environment_meta``) so a
committed baseline is attributable to the hardware that produced it.  The
gate reads metrics strictly by name and ignores keys it does not know, so
old gates keep working against newer artifacts and vice versa.

``--metrics-json``/``--trace-out`` export the observability artifacts:
a metrics snapshot of the bench run and a Chrome-trace JSON with one span
per bench module (load either into ``python -m repro.obs`` or
chrome://tracing).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT / "src") not in sys.path:  # `python -m benchmarks.run`
    sys.path.insert(0, str(REPO_ROOT / "src"))  # without PYTHONPATH=src


def _tables_json(tables) -> list:
    return [
        {"name": t.name, "columns": t.columns, "rows": t.rows}
        for t in tables
    ]


def _run_mod(name: str, mod, metrics=None, trace=None) -> list:
    t0 = time.time()
    span_t0 = trace.now_us() if trace is not None else 0.0
    tables = mod.run()
    dt = time.time() - t0
    print(f"[{name}] {dt:.1f}s", file=sys.stderr)
    if trace is not None:
        trace.complete(name, span_t0, dt * 1e6, cat="bench",
                       args={"tables": len(tables)})
    if metrics is not None:
        metrics.series("bench.module.seconds", module=name).append(dt)
        for k, v in getattr(mod, "LAST_METRICS", {}).items():
            if isinstance(v, (int, float)):
                metrics.gauge("bench.metric", module=name, metric=k).set(v)
    for tb in tables:
        print(tb.show())
        print()
    return tables


def _perf_gate(baseline, metrics) -> None:
    """Fail the smoke run when the fabric regressed vs the committed
    BENCH_fabric.json (artifacts are already written, so CI still uploads
    the evidence).  Two checks:

    * **router steps** (machine-independent, strict 20% floor): the
      starved-link tick's drain steps under defection are a deterministic
      simulation observable — the same code produces the same number on
      any host, so growth here is a real routing regression, never noise.
    * **wall-clock frames/s** (hardware-dependent): compared at the
      ``BENCH_GATE_MIN_RATIO`` floor, which CI sets generously (0.5)
      because the committed baseline may come from different hardware and
      shared runners are noisy.  ``BENCH_GATE=0`` disables both.
    """
    if os.environ.get("BENCH_GATE", "1") in ("0", "false", "no"):
        print("[perf-gate] disabled via BENCH_GATE=0", file=sys.stderr)
        return
    baseline, metrics = baseline or {}, metrics or {}
    failed = False
    old_steps = baseline.get("starved_steps_on")
    new_steps = metrics.get("starved_steps_on")
    if old_steps and new_steps:
        if new_steps > old_steps * 1.2:
            print(f"[perf-gate] FAIL: starved-link drain steps (defection "
                  f"on, deterministic) regressed {old_steps} -> "
                  f"{new_steps} (> 1.20x floor)", file=sys.stderr)
            failed = True
        else:
            print(f"[perf-gate] ok: starved-link drain steps {old_steps} "
                  f"-> {new_steps} (deterministic, <= 1.20x)",
                  file=sys.stderr)
    min_ratio = float(os.environ.get("BENCH_GATE_MIN_RATIO", "0.8"))
    old = baseline.get("smoke_frames_per_s")
    new = metrics.get("smoke_frames_per_s")
    if not old or not new:
        print(f"[perf-gate] no frames/s baseline (old={old}, new={new}) "
              f"— skipping the wall-clock check", file=sys.stderr)
    elif new / old < min_ratio:
        print(f"[perf-gate] FAIL: fabric smoke frames/s regressed "
              f"{old} -> {new} ({new / old:.2f}x < {min_ratio:.2f}x "
              f"floor); set BENCH_GATE_MIN_RATIO or BENCH_GATE=0 to "
              f"override", file=sys.stderr)
        failed = True
    else:
        print(f"[perf-gate] ok: fabric smoke frames/s {old} -> {new} "
              f"({new / old:.2f}x >= {min_ratio:.2f}x floor)",
              file=sys.stderr)
    if failed:
        sys.exit(1)


def _maybe_slo(spec, metrics, values) -> None:
    """Evaluate ``--slo`` targets against the bench run (the metrics
    snapshot when one was collected, plus the modules' flat LAST_METRICS
    under ``fabric.*`` / ``stream.*`` keys); exits 1 on any violation."""
    if not spec:
        return
    from repro.obs import evaluate_slo

    rep = evaluate_slo(
        spec,
        snapshot=metrics.snapshot() if metrics is not None else None,
        values=values,
    )
    print(rep.render_text())
    if not rep.ok:
        sys.exit(1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fabric + stream benches only; write "
                         "BENCH_fabric.json / BENCH_stream.json at the "
                         "repo root (CI perf tracking)")
    ap.add_argument("--metrics-json", metavar="PATH",
                    help="write a repro.obs metrics snapshot of the bench "
                         "run (module wall-times + LAST_METRICS gauges)")
    ap.add_argument("--trace-out", metavar="PATH",
                    help="write a Chrome-trace JSON with one span per "
                         "bench module (chrome://tracing / Perfetto)")
    ap.add_argument("--slo", metavar="SPEC",
                    help="evaluate SLO targets against the bench metrics "
                         "('k=v,k=v' inline or JSON file; flat keys like "
                         "max:fabric.smoke_frames_per_s address each "
                         "module's LAST_METRICS) and exit 1 on violation")
    args = ap.parse_args()

    from repro.obs import MetricsRegistry, TraceRecorder, environment_meta

    metrics = MetricsRegistry() if args.metrics_json else None
    trace = TraceRecorder() if args.trace_out else None

    def _export() -> None:
        if metrics is not None:
            snap = metrics.snapshot()
            snap["meta"] = environment_meta()
            with open(args.metrics_json, "w") as f:
                json.dump(snap, f, indent=1)
            print(f"wrote {args.metrics_json}", file=sys.stderr)
        if trace is not None:
            trace.save(args.trace_out)
            print(f"wrote {args.trace_out}", file=sys.stderr)

    from . import bench_fabric, bench_stream

    if args.smoke:
        # read the COMMITTED fabric baseline before this run overwrites it.
        # Strictly by-name with unknown keys ignored: a baseline written by
        # a newer (or older) schema still gates on the metrics both know.
        baseline = None
        fabric_json = REPO_ROOT / "BENCH_fabric.json"
        if fabric_json.exists():
            try:
                loaded = json.loads(fabric_json.read_text())
                baseline = loaded.get("metrics") if isinstance(loaded, dict) \
                    else None
            except ValueError:
                baseline = None
        all_tables = []
        for name, mod in (("fabric", bench_fabric), ("stream", bench_stream)):
            tables = _run_mod(f"bench_{name}", mod, metrics, trace)
            all_tables.extend(tables)
            out = REPO_ROOT / f"BENCH_{name}.json"
            out.write_text(json.dumps({
                "bench": name,
                "meta": environment_meta(),
                "metrics": getattr(mod, "LAST_METRICS", {}),
                "tables": _tables_json(tables),
            }, indent=2) + "\n")
            print(f"wrote {out}", file=sys.stderr)
        csv_path = REPO_ROOT / "experiments" / "benchmarks.csv"
        os.makedirs(csv_path.parent, exist_ok=True)
        with open(csv_path, "w") as f:
            for tb in all_tables:
                f.write(tb.csv())
                f.write("\n")
        print(f"wrote {csv_path} ({len(all_tables)} tables)")
        _export()
        # append this run to the perf trajectory: one JSONL row per smoke
        # run, summarized by `python -m repro.obs history`
        meta = environment_meta()
        hist_path = REPO_ROOT / "experiments" / "bench_history.jsonl"
        with open(hist_path, "a") as f:
            f.write(json.dumps({
                "git_sha": meta.get("git_sha"),
                "timestamp": meta.get("timestamp"),
                "metrics": {
                    "fabric": getattr(bench_fabric, "LAST_METRICS", {}),
                    "stream": getattr(bench_stream, "LAST_METRICS", {}),
                },
            }) + "\n")
        print(f"appended {hist_path}", file=sys.stderr)
        _maybe_slo(args.slo, metrics, {
            **{f"fabric.{k}": v
               for k, v in getattr(bench_fabric, "LAST_METRICS", {}).items()},
            **{f"stream.{k}": v
               for k, v in getattr(bench_stream, "LAST_METRICS", {}).items()},
        })
        _perf_gate(baseline, bench_fabric.LAST_METRICS)
        return

    from . import bench_fig14, bench_fe_case_study, bench_schema_complexity
    from . import bench_pipeline, bench_serve

    mods = [
        ("fig14 (throughput vs optimum)", bench_fig14),
        ("schema complexity (area/freq analog)", bench_schema_complexity),
        ("FE case study", bench_fe_case_study),
        ("framework pipeline + channel", bench_pipeline),
        ("serving plane (batched vs sequential)", bench_serve),
        ("routed fabric (hops + flow control)", bench_fabric),
        ("streaming plane (TTFT + overlap + QoS)", bench_stream),
    ]
    tables = []
    for name, mod in mods:
        tables.extend(_run_mod(name, mod, metrics, trace))
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/benchmarks.csv", "w") as f:
        for tb in tables:
            f.write(tb.csv())
            f.write("\n")
    print(f"wrote experiments/benchmarks.csv ({len(tables)} tables)")
    _export()
    _maybe_slo(args.slo, metrics, {
        f"{mod.__name__.rsplit('.', 1)[-1].replace('bench_', '')}.{k}": v
        for _, mod in mods
        for k, v in getattr(mod, "LAST_METRICS", {}).items()
    })


if __name__ == "__main__":
    main()
