"""Benchmark aggregator: one table per paper figure + framework benches.

``python -m benchmarks.run`` prints every table and writes
``experiments/benchmarks.csv``.

``python -m benchmarks.run --smoke`` runs the fabric + stream benches only
and ALSO writes ``BENCH_fabric.json`` / ``BENCH_stream.json`` at the repo
root — headline metrics (frames/s, far-destination speedup, TTFT, hop
counts, arrive-step jitter) plus the full tables — so CI can upload them
and the perf trajectory is tracked across PRs instead of being a fresh
anecdote every time.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def _tables_json(tables) -> list:
    return [
        {"name": t.name, "columns": t.columns, "rows": t.rows}
        for t in tables
    ]


def _run_mod(name: str, mod) -> list:
    t0 = time.time()
    tables = mod.run()
    print(f"[{name}] {time.time()-t0:.1f}s", file=sys.stderr)
    for tb in tables:
        print(tb.show())
        print()
    return tables


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fabric + stream benches only; write "
                         "BENCH_fabric.json / BENCH_stream.json at the "
                         "repo root (CI perf tracking)")
    args = ap.parse_args()

    from . import bench_fabric, bench_stream

    if args.smoke:
        all_tables = []
        for name, mod in (("fabric", bench_fabric), ("stream", bench_stream)):
            tables = _run_mod(f"bench_{name}", mod)
            all_tables.extend(tables)
            out = REPO_ROOT / f"BENCH_{name}.json"
            out.write_text(json.dumps({
                "bench": name,
                "metrics": getattr(mod, "LAST_METRICS", {}),
                "tables": _tables_json(tables),
            }, indent=2) + "\n")
            print(f"wrote {out}", file=sys.stderr)
        csv_path = REPO_ROOT / "experiments" / "benchmarks.csv"
        os.makedirs(csv_path.parent, exist_ok=True)
        with open(csv_path, "w") as f:
            for tb in all_tables:
                f.write(tb.csv())
                f.write("\n")
        print(f"wrote {csv_path} ({len(all_tables)} tables)")
        return

    from . import bench_fig14, bench_fe_case_study, bench_schema_complexity
    from . import bench_pipeline, bench_serve

    mods = [
        ("fig14 (throughput vs optimum)", bench_fig14),
        ("schema complexity (area/freq analog)", bench_schema_complexity),
        ("FE case study", bench_fe_case_study),
        ("framework pipeline + channel", bench_pipeline),
        ("serving plane (batched vs sequential)", bench_serve),
        ("routed fabric (hops + flow control)", bench_fabric),
        ("streaming plane (TTFT + overlap + QoS)", bench_stream),
    ]
    tables = []
    for name, mod in mods:
        tables.extend(_run_mod(name, mod))
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/benchmarks.csv", "w") as f:
        for tb in tables:
            f.write(tb.csv())
            f.write("\n")
    print(f"wrote experiments/benchmarks.csv ({len(tables)} tables)")


if __name__ == "__main__":
    main()
