"""Benchmark aggregator: one table per paper figure + framework benches.

``python -m benchmarks.run`` prints every table and writes
``experiments/benchmarks.csv``.
"""
from __future__ import annotations

import os
import sys
import time


def main() -> None:
    from . import bench_fig14, bench_fe_case_study, bench_schema_complexity
    from . import bench_fabric, bench_pipeline, bench_serve, bench_stream

    mods = [
        ("fig14 (throughput vs optimum)", bench_fig14),
        ("schema complexity (area/freq analog)", bench_schema_complexity),
        ("FE case study", bench_fe_case_study),
        ("framework pipeline + channel", bench_pipeline),
        ("serving plane (batched vs sequential)", bench_serve),
        ("routed fabric (hops + flow control)", bench_fabric),
        ("streaming plane (TTFT + overlap + QoS)", bench_stream),
    ]
    tables = []
    for name, mod in mods:
        t0 = time.time()
        got = mod.run()
        tables.extend(got)
        print(f"[{name}] {time.time()-t0:.1f}s", file=sys.stderr)
        for tb in got:
            print(tb.show())
            print()
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/benchmarks.csv", "w") as f:
        for tb in tables:
            f.write(tb.csv())
            f.write("\n")
    print(f"wrote experiments/benchmarks.csv ({len(tables)} tables)")


if __name__ == "__main__":
    main()
