"""Routed message fabric: bit-exactness, shortest-path + fused-tick wins,
frames/sec vs hop count, and credit flow control.

Measurements on an 8-rank host mesh (``XLA_FLAGS`` device count 8):

* **bit-exact vs direct single-hop** — every rank fabric-sends a payload to
  its +1 neighbour; the delivered bytes must equal what the seed's
  single-hop framed channel (``runtime.channels.make_framed_sender``)
  moves for the same payloads.  The routed path adds route words, CRC32,
  and the router's queue/credit machinery — none of it may change a byte.
* **shortest-path + fused tick vs the PR-3 baseline** — K messages from
  rank 0 to far destinations, timed end to end under (a) dimension-order
  routing with the three-program tick (the PR-3 configuration) and (b)
  per-frame shortest-path routing with the fused single-jit tick.  The
  table shows the hop counts each mode pays and the frames/s speedup; the
  delivered bytes are asserted identical in every row.
* **fused tick vs three programs** — the same transfer with routing held
  fixed, isolating what fusing pack -> route -> RX split into one jit (no
  host round-trips between the stages) buys on its own.
* **frames/sec vs hop count** — how throughput decays with distance under
  the default (shortest-path, fused) fabric.
* **credit sweep** — same transfer at different per-link credit budgets:
  fewer credits = more steps (flow control back-pressure made visible).
* **starved-link defection sweep** — one saturated +1 link (a heavy tenant
  bursts 0 -> 1 while a light tenant streams 0 -> 4 across the same
  outgoing link), with congestion-aware direction defection off vs on
  (``FabricConfig.defect_after``).  With defection, starved frames escape
  to the idle opposite ring direction, so the tick drains both directions
  in parallel: higher frames/s AND a lower light-tenant p95 arrive step.
  Delivered bytes are asserted identical in every row.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python benchmarks/bench_fabric.py
"""
from __future__ import annotations

import sys
from pathlib import Path
from typing import List

sys.path.insert(0, str(Path(__file__).parent))

import jax
import jax.numpy as jnp
import numpy as np

from common import Table, time_call
from repro.fabric import Fabric, FabricConfig
from repro.runtime import make_framed_sender

PAYLOAD_BYTES = 4096
N_MSGS = 8
FRAME_PHITS = 16

#: headline numbers for BENCH_fabric.json (filled by run())
LAST_METRICS: dict = {}


def _fabric(credits: int = 8, routing: str = "shortest",
            fused: bool = True, defect_after: int = 0,
            arq: bool = False) -> Fabric:
    n = min(len(jax.devices()), 8)
    return Fabric(n_ranks=n, config=FabricConfig(
        frame_phits=FRAME_PHITS, credits=credits, routing=routing,
        fused=fused, defect_after=defect_after, arq=arq,
    ))


def _payload(rng, nbytes: int) -> bytes:
    return rng.integers(0, 256, nbytes, dtype=np.uint8).tobytes()


def _make_tick(fab: Fabric, dst: int, wires: List[bytes]):
    """One full tick of len(wires) messages 0 -> dst, delivery asserted
    bit-exact."""
    src, box = fab.mailbox(0), fab.mailbox(dst)

    def tick():
        for w in wires:
            src.send(dst, w)
        fab.exchange()
        got = box.recv()
        assert len(got) == len(wires) and all(d.ok for d in got)
        assert [d.wire for d in got] == wires

    return tick


def _tick_time(fab: Fabric, dst: int, wires: List[bytes],
               repeats: int = 5) -> float:
    """Median seconds per tick."""
    tick = _make_tick(fab, dst, wires)
    tick()  # warm the jit caches
    return time_call(tick, repeats=repeats, warmup=0)


def _interleaved_times(ticks, repeats: int = 7) -> List[float]:
    """Median seconds per tick for several tick fns, measured INTERLEAVED
    (a-b-a-b…) so background machine load biases every contestant equally
    instead of whichever ran during a quiet moment."""
    import time as _time

    for t in ticks:
        t()  # warm every jit cache before any measurement
    samples = [[] for _ in ticks]
    for _ in range(repeats):
        for i, t in enumerate(ticks):
            t0 = _time.perf_counter()
            t()
            samples[i].append(_time.perf_counter() - t0)
    return [sorted(s)[len(s) // 2] for s in samples]


def check_bit_exact_vs_single_hop() -> int:
    """Fabric one-hop delivery == the seed's direct framed channel."""
    fab = _fabric()
    n = fab.n_ranks
    rng = np.random.default_rng(0)
    wires = [_payload(rng, PAYLOAD_BYTES) for _ in range(n)]

    # direct single-hop: the seed channel rotates payloads by one rank
    mesh = fab.router.mesh
    words = PAYLOAD_BYTES // 4
    payload = jnp.asarray(
        np.stack([np.frombuffer(w, np.uint8).view(np.uint32) for w in wires])
    )
    nbytes = jnp.full((n,), PAYLOAD_BYTES, jnp.int32)
    sender = make_framed_sender(mesh, fab.router.axis_names[0],
                                frame_phits=FRAME_PHITS)
    p_out, nb_out, ok = jax.jit(sender)(payload, nbytes)
    assert bool(np.asarray(ok).all())
    direct = {
        r: np.asarray(p_out[r][:words]).tobytes() for r in range(n)
    }  # rank r received from r-1

    # routed: same transfer as fabric sends (dst = src + 1)
    boxes = [fab.mailbox(r) for r in range(n)]
    for r in range(n):
        boxes[r].send((r + 1) % n, wires[r])
    fab.exchange()
    for r in range(n):
        got = boxes[r].recv()
        assert len(got) == 1 and got[0].ok
        assert got[0].src == (r - 1) % n
        assert got[0].wire == direct[r] == wires[(r - 1) % n], r
    return n


def bench_routing() -> Table:
    """The headline table: shortest-path + fused tick vs the PR-3 baseline
    (dimension-order + three-program tick) for far-destination traffic."""
    t = Table("fabric: shortest-path + fused tick vs PR-3 baseline", [
        "dst", "hops_dim", "hops_sp", "base_s", "new_s",
        "base_frames/s", "new_frames/s", "speedup",
    ])
    base = _fabric(routing="dimension", fused=False)
    new = _fabric(routing="shortest", fused=True)
    n = base.n_ranks
    if n < 2:  # single device: no links to route over — degrade gracefully
        return t
    rng = np.random.default_rng(1)
    wires = [_payload(rng, PAYLOAD_BYTES) for _ in range(N_MSGS)]
    n_frames = None
    speedups = {}
    for dst in range(max(1, n // 2), n):  # the far half of the ring
        before = new.frames_routed
        tb, tn = _interleaved_times([
            _make_tick(base, dst, wires), _make_tick(new, dst, wires),
        ])
        if n_frames is None:
            n_frames = (new.frames_routed - before) // 8  # warm + 7 reps
        hops_dim = base.router.hops(0, dst)
        hops_sp = new.router.min_hops(0, dst)
        speedups[dst] = tb / tn
        t.add(dst, hops_dim, hops_sp, round(tb, 4), round(tn, 4),
              round(n_frames / tb, 1), round(n_frames / tn, 1),
              round(tb / tn, 2))
    # on tiny rings the "far half" may be a single destination — fall back
    # to every measured row rather than reporting a silent 0.0
    far = [s for d, s in speedups.items() if d > n // 2] or \
        list(speedups.values())
    LAST_METRICS["far_speedup_max"] = round(max(speedups.values()), 2)
    LAST_METRICS["far_speedup_mean"] = round(sum(far) / len(far), 2)
    LAST_METRICS["speedup_at_worst_dst"] = round(speedups[n - 1], 2)
    LAST_METRICS["hops_dim_worst"] = base.router.hops(0, n - 1)
    LAST_METRICS["hops_sp_worst"] = new.router.min_hops(0, n - 1)
    return t


def bench_fused() -> Table:
    """Fusion in isolation: same routing, tick as one jit vs three programs
    with host syncs between them.  Both fabrics run with ``arq=True`` (the
    serving default) so the gated ``smoke_frames_per_s`` number includes —
    and the committed-baseline perf gate therefore bounds — the ARQ
    bookkeeping cost on a clean link."""
    t = Table("fabric: fused single-jit tick vs three-program tick (ARQ on)", [
        "tick", "msgs", "s/tick", "frames/s",
    ])
    rng = np.random.default_rng(3)
    wires = [_payload(rng, PAYLOAD_BYTES) for _ in range(N_MSGS)]
    fabs = {
        name: _fabric(routing="shortest", fused=fused, arq=True)
        for name, fused in (("three-program", False), ("fused", True))
    }
    dst = next(iter(fabs.values())).n_ranks - 1
    before = {n: f.frames_routed for n, f in fabs.items()}
    dts = _interleaved_times([
        _make_tick(f, dst, wires) for f in fabs.values()
    ])
    times = {}
    for (name, fab), dt in zip(fabs.items(), dts):
        n_frames = (fab.frames_routed - before[name]) // 8  # warm + 7 reps
        times[name] = dt
        t.add(name, N_MSGS, round(dt, 4), round(n_frames / dt, 1))
        if name == "fused":
            # the CI perf gate compares this across PRs (run.py --smoke)
            LAST_METRICS["smoke_frames_per_s"] = round(n_frames / dt, 1)
    LAST_METRICS["fused_speedup"] = round(
        times["three-program"] / times["fused"], 2
    )
    return t


def bench_hops() -> Table:
    t = Table("fabric: routed delivery vs hop count (shortest-path, fused)", [
        "dst", "hops", "msgs", "frames", "payload_B", "s/tick", "frames/s",
        "MB/s",
    ])
    fab = _fabric()
    n = fab.n_ranks
    rng = np.random.default_rng(1)
    wires = [_payload(rng, PAYLOAD_BYTES) for _ in range(N_MSGS)]
    for dst in range(1, n):
        before = fab.frames_routed
        dt = _tick_time(fab, dst, wires, repeats=3)
        n_frames = (fab.frames_routed - before) // 4
        t.add(dst, fab.router.route_hops(0, dst), N_MSGS, n_frames,
              PAYLOAD_BYTES, round(dt, 4), round(n_frames / dt, 1),
              round(N_MSGS * PAYLOAD_BYTES / dt / 1e6, 2))
    return t


def bench_credits() -> Table:
    t = Table("fabric: credit-based flow control (4 hops)", [
        "credits", "msgs", "frames", "s/tick", "frames/s",
    ])
    rng = np.random.default_rng(2)
    wires = [_payload(rng, PAYLOAD_BYTES) for _ in range(N_MSGS)]
    for credits in (1, 2, 4, 8, 16):
        fab = _fabric(credits=credits)
        h = min(4, fab.n_ranks - 1)
        before = fab.frames_routed
        dt = _tick_time(fab, h, wires, repeats=3)
        n_frames = (fab.frames_routed - before) // 4
        t.add(credits, N_MSGS, n_frames, round(dt, 4),
              round(n_frames / dt, 1))
    return t


def bench_starved_link() -> Table:
    """Congestion-aware defection under one saturated +1 link: a heavy
    tenant bursts 0 -> 1 while a light tenant streams 0 -> 4 through the
    same outgoing link.  With ``defect_after`` set, starved frames escape
    to the idle -1 ring, so the tick drains both directions in parallel —
    more frames/s AND a lower light-tenant tail latency."""
    t = Table("fabric: starved +1 link — defection off vs on", [
        "defect_after", "frames", "light_p95", "light_max", "steps",
        "s/tick", "frames/s", "speedup",
    ])
    from repro.stream import arrive_stats

    rng = np.random.default_rng(5)
    heavy = [_payload(rng, 1536) for _ in range(6)]  # saturates 0 -> 1
    light = [_payload(rng, 1536) for _ in range(6)]  # 0 -> 4, same out-link
    stats = {}

    def make_tick(fab):
        a, hv, lt = fab.mailbox(0), fab.mailbox(1), fab.mailbox(4)

        def tick():
            for w in heavy:
                a.send(1, w, list_level=2)
            for w in light:
                a.send(4, w, list_level=1)
            fab.exchange()
            got_h, got_l = hv.recv(), lt.recv()
            assert [d.wire for d in got_h] == heavy
            assert [d.wire for d in got_l] == light
            return got_h, got_l

        return tick

    fabs = {k: _fabric(credits=2, defect_after=k) for k in (0, 2)}
    if next(iter(fabs.values())).n_ranks < 8:
        return t  # the scenario needs the full 8-ring
    ticks = {k: make_tick(f) for k, f in fabs.items()}
    dts = dict(zip(fabs, _interleaved_times(list(ticks.values()))))
    n_frames = None
    for k, fab in fabs.items():
        got_h, got_l = ticks[k]()  # one extra tick for the latency trace
        if n_frames is None:
            n_frames = fab.frames_routed // (8 + 1)  # warm + 7 reps + trace
        st = arrive_stats([d.arrive_step for d in got_l])
        steps = max(d.arrive_step for d in got_h + got_l)
        stats[k] = (st, steps, dts[k])
        t.add(k, n_frames, st["p95"], st["max"], steps, round(dts[k], 4),
              round(n_frames / dts[k], 1),
              round(dts[0] / dts[k], 2) if 0 in stats else 1.0)
    LAST_METRICS["starved_fps_defect_off"] = round(n_frames / dts[0], 1)
    LAST_METRICS["starved_fps_defect_on"] = round(n_frames / dts[2], 1)
    LAST_METRICS["starved_fps_speedup"] = round(dts[0] / dts[2], 2)
    LAST_METRICS["starved_light_p95_off"] = stats[0][0]["p95"]
    LAST_METRICS["starved_light_p95_on"] = stats[2][0]["p95"]
    LAST_METRICS["starved_steps_off"] = stats[0][1]
    LAST_METRICS["starved_steps_on"] = stats[2][1]
    return t


def bench_faulty_link() -> Table:
    """Reliable delivery economics on a seeded lossy link: N_MSGS payloads
    0 -> 4 at 0% / 1% / 5% frame drop, ARQ off vs on.  Without ARQ a
    dropped frame is a lost (or poisoned) message — the ``delivered``
    column shows what actually survived; with ARQ every message arrives
    byte-identical and in order, and the extra ticks plus retransmitted
    frames ARE the recovery cost, measured rather than asserted away.
    The two zero-drop rows isolate pure ARQ bookkeeping overhead
    (``arq_overhead_pct`` in BENCH_fabric.json)."""
    t = Table("fabric: seeded lossy link 0 -> 4 — ARQ off vs on", [
        "drop%", "arq", "delivered", "ticks", "retx", "p95_arrive",
        "s/xfer", "frames/s",
    ])
    import time as _time

    from repro.fabric import FaultPlan
    from repro.stream import arrive_stats

    if _fabric().n_ranks < 5:
        return t  # needs the multi-hop 0 -> 4 path
    dst = 4
    rng = np.random.default_rng(9)
    wires = [_payload(rng, PAYLOAD_BYTES) for _ in range(N_MSGS)]
    fps = {}
    for drop in (0.0, 0.01, 0.05):
        for arq_on in (False, True):
            fab = _fabric(credits=8, arq=arq_on)
            fab.faults = FaultPlan(seed=9, drop=drop) if drop else None
            src, box = fab.mailbox(0), fab.mailbox(dst)

            def xfer():
                got, steps, quiet = [], [], 0
                for w in wires:
                    src.send(dst, w)
                ticks = 0
                # ARQ gets room to recover; without it nothing new comes
                # once the in-flight frames have drained
                while ticks < (400 if arq_on else 12):
                    fab.exchange()
                    ticks += 1
                    new = box.recv()
                    quiet = 0 if new else quiet + 1
                    for d in new:
                        if d.ok:
                            got.append(d.wire)
                            if d.arrive_step is not None:
                                steps.append(d.arrive_step)
                    if len(got) >= len(wires) or (not arq_on and quiet >= 3):
                        break
                return got, steps, ticks

            # warm the jit caches TWICE: the second transfer's first tick
            # also carries the previous transfer's owed ACK frame, which
            # is its own transmit shape (and its own compile)
            xfer()
            xfer()
            before = fab.frames_routed
            t0 = _time.perf_counter()
            got, steps, ticks = xfer()
            dt = _time.perf_counter() - t0
            if arq_on:
                # the whole point: byte-identical, in-order, every time
                assert got == wires, (drop, len(got))
            retx = sum(
                m["value"] for m in fab.metrics.snapshot()["metrics"]
                if m["name"] == "fabric.arq.retransmits"
            ) if arq_on else 0
            st = arrive_stats(steps) if steps else {"p95": float("nan")}
            n_frames = fab.frames_routed - before
            fps[(drop, arq_on)] = n_frames / dt
            t.add(round(drop * 100, 1), "on" if arq_on else "off", len(got),
                  ticks, retx, st["p95"], round(dt, 4),
                  round(n_frames / dt, 1))
    LAST_METRICS["faulty_fps_clean_noarq"] = round(fps[(0.0, False)], 1)
    LAST_METRICS["faulty_fps_clean_arq"] = round(fps[(0.0, True)], 1)
    LAST_METRICS["arq_overhead_pct"] = round(
        (1.0 - fps[(0.0, True)] / fps[(0.0, False)]) * 100, 1)
    return t


def run() -> List[Table]:
    LAST_METRICS.clear()
    n = check_bit_exact_vs_single_hop()
    print(f"[bench_fabric] routed one-hop bit-exact vs direct channel "
          f"on {n} ranks", file=sys.stderr)
    tables = [bench_routing(), bench_fused(), bench_hops(), bench_credits(),
              bench_starved_link(), bench_faulty_link()]
    if "far_speedup_mean" in LAST_METRICS:  # absent on a 1-device run
        print(f"[bench_fabric] far-destination speedup (shortest+fused vs "
              f"dimension+unfused): mean "
              f"{LAST_METRICS['far_speedup_mean']}x, "
              f"{LAST_METRICS['speedup_at_worst_dst']}x at the far corner "
              f"(hops {LAST_METRICS['hops_dim_worst']} -> "
              f"{LAST_METRICS['hops_sp_worst']}); fused tick alone "
              f"{LAST_METRICS['fused_speedup']}x", file=sys.stderr)
    if "arq_overhead_pct" in LAST_METRICS:
        print(f"[bench_fabric] lossy link: ARQ bookkeeping costs "
              f"{LAST_METRICS['arq_overhead_pct']}% frames/s on a clean "
              f"link ({LAST_METRICS['faulty_fps_clean_noarq']} -> "
              f"{LAST_METRICS['faulty_fps_clean_arq']}) and recovers "
              f"byte-identical delivery at 1% and 5% drop",
              file=sys.stderr)
    if "starved_fps_speedup" in LAST_METRICS:
        print(f"[bench_fabric] starved +1 link: defection "
              f"{LAST_METRICS['starved_fps_speedup']}x frames/s, light "
              f"tenant p95 arrive {LAST_METRICS['starved_light_p95_off']} "
              f"-> {LAST_METRICS['starved_light_p95_on']} router steps "
              f"(tick drains in {LAST_METRICS['starved_steps_off']} -> "
              f"{LAST_METRICS['starved_steps_on']} steps)", file=sys.stderr)
    return tables


def main() -> None:
    for tb in run():
        print(tb.show())
        print()


if __name__ == "__main__":
    main()
