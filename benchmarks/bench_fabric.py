"""Routed message fabric: bit-exactness + frames/sec vs hop count.

Three measurements on an 8-rank host mesh (``XLA_FLAGS`` device count 8):

* **bit-exact vs direct single-hop** — every rank fabric-sends a payload to
  its +1 neighbour; the delivered bytes must equal what the seed's
  single-hop framed channel (``runtime.channels.make_framed_sender``)
  moves for the same payloads.  The routed path adds route words, CRC32,
  and the router's queue/credit machinery — none of it may change a byte.
* **frames/sec vs hop count** — K messages from rank 0 to a destination
  ``h`` hops away, full fabric tick (frame + route + reassemble) timed;
  the table shows how throughput decays as frames pipeline through more
  ppermute steps.
* **credit sweep** — same transfer at different per-link credit budgets:
  fewer credits = more steps (flow control back-pressure made visible).

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python benchmarks/bench_fabric.py
"""
from __future__ import annotations

import sys
from pathlib import Path
from typing import List

sys.path.insert(0, str(Path(__file__).parent))

import jax
import jax.numpy as jnp
import numpy as np

from common import Table, time_call
from repro.fabric import Fabric, FabricConfig
from repro.runtime import make_framed_sender

PAYLOAD_BYTES = 4096
N_MSGS = 8
FRAME_PHITS = 16


def _ring_fabric(credits: int = 8) -> Fabric:
    n = min(len(jax.devices()), 8)
    return Fabric(
        n_ranks=n, config=FabricConfig(frame_phits=FRAME_PHITS, credits=credits)
    )


def _payload(rng, nbytes: int) -> bytes:
    return rng.integers(0, 256, nbytes, dtype=np.uint8).tobytes()


def check_bit_exact_vs_single_hop() -> int:
    """Fabric one-hop delivery == the seed's direct framed channel."""
    fab = _ring_fabric()
    n = fab.n_ranks
    rng = np.random.default_rng(0)
    wires = [_payload(rng, PAYLOAD_BYTES) for _ in range(n)]

    # direct single-hop: the seed channel rotates payloads by one rank
    mesh = fab.router.mesh
    words = PAYLOAD_BYTES // 4
    payload = jnp.asarray(
        np.stack([np.frombuffer(w, np.uint8).view(np.uint32) for w in wires])
    )
    nbytes = jnp.full((n,), PAYLOAD_BYTES, jnp.int32)
    sender = make_framed_sender(mesh, fab.router.axis_names[0],
                                frame_phits=FRAME_PHITS)
    p_out, nb_out, ok = jax.jit(sender)(payload, nbytes)
    assert bool(np.asarray(ok).all())
    direct = {
        r: np.asarray(p_out[r][:words]).tobytes() for r in range(n)
    }  # rank r received from r-1

    # routed: same transfer as fabric sends (dst = src + 1)
    boxes = [fab.mailbox(r) for r in range(n)]
    for r in range(n):
        boxes[r].send((r + 1) % n, wires[r])
    fab.exchange()
    for r in range(n):
        got = boxes[r].recv()
        assert len(got) == 1 and got[0].ok
        assert got[0].src == (r - 1) % n
        assert got[0].wire == direct[r] == wires[(r - 1) % n], r
    return n


def bench_hops() -> Table:
    t = Table("fabric: routed delivery vs hop count", [
        "hops", "msgs", "frames", "payload_B", "s/tick", "frames/s", "MB/s",
    ])
    fab = _ring_fabric()
    n = fab.n_ranks
    rng = np.random.default_rng(1)
    wires = [_payload(rng, PAYLOAD_BYTES) for _ in range(N_MSGS)]
    src = fab.mailbox(0)
    for h in range(1, n):
        dst = fab.mailbox(h)

        def tick():
            for w in wires:
                src.send(h, w)
            fab.exchange()
            got = dst.recv()
            assert len(got) == N_MSGS and all(d.ok for d in got)
            assert [d.wire for d in got] == wires  # bit-exact at every hop
            return got

        before = fab.frames_routed
        tick()
        n_frames = fab.frames_routed - before
        dt = time_call(tick, repeats=3, warmup=0)
        t.add(h, N_MSGS, n_frames, PAYLOAD_BYTES, round(dt, 4),
              round(n_frames / dt, 1),
              round(N_MSGS * PAYLOAD_BYTES / dt / 1e6, 2))
    return t


def bench_credits() -> Table:
    t = Table("fabric: credit-based flow control (4 hops)", [
        "credits", "msgs", "frames", "s/tick", "frames/s",
    ])
    rng = np.random.default_rng(2)
    wires = [_payload(rng, PAYLOAD_BYTES) for _ in range(N_MSGS)]
    for credits in (1, 2, 4, 8, 16):
        fab = _ring_fabric(credits=credits)
        h = min(4, fab.n_ranks - 1)
        src, dst = fab.mailbox(0), fab.mailbox(h)

        def tick():
            for w in wires:
                src.send(h, w)
            fab.exchange()
            got = dst.recv()
            assert len(got) == N_MSGS and all(d.ok for d in got)
            assert [d.wire for d in got] == wires

        before = fab.frames_routed
        tick()
        n_frames = fab.frames_routed - before
        dt = time_call(tick, repeats=3, warmup=0)
        t.add(credits, N_MSGS, n_frames, round(dt, 4), round(n_frames / dt, 1))
    return t


def run() -> List[Table]:
    n = check_bit_exact_vs_single_hop()
    print(f"[bench_fabric] routed one-hop bit-exact vs direct channel "
          f"on {n} ranks", file=sys.stderr)
    return [bench_hops(), bench_credits()]


def main() -> None:
    for tb in run():
        print(tb.show())
        print()


if __name__ == "__main__":
    main()
