"""Paper §V-B: the Feature Extraction (FE) case study.

FE-orig  = hand-written schema-specific decoder (stand-in for the paper's
           hand-written FSM; it may exploit schema knowledge arbitrarily).
FE-HGum  = the generated engines (schema ROM + traversal FSM).

The request schema follows the paper's description: "multiple levels of
nested arrays and structures ... the element type of an array in the schema
is a structure that contains other arrays as structure fields."  The metric
is the paper's: per-request latency (here: cycle counts of the cycle-accurate
engines, DES-start to SER-end) ratio FE-HGum / FE-orig over a request
population, reported as a distribution + geometric mean (paper: 1.05).

We also report the LOC analog: hand-written lines for the adapter shim vs
the hand-written decoder (paper: 27%).
"""
from __future__ import annotations

import inspect
from typing import Dict, List

import numpy as np

from repro.core import (
    ClientSchema, DesFSM, Schema, SerFSM, build_rom, msg_to_des_tokens,
    ser_sw_to_hw, strip_for_ser, )
from .common import Table

PHIT = 16

# FE request: query with nested term structures (3 levels of nesting)
FE_REQUEST = {
    "Request": [
        ["query_id", ["Bytes", 8]],
        ["terms", ["Array", ["Struct", "Term"]]],
        ["metadata", ["Array", ["Bytes", 4]]],
    ],
    "Term": [
        ["term_id", ["Bytes", 4]],
        ["weight", ["Bytes", 2]],
        ["positions", ["Array", ["Bytes", 4]]],
        ["subterms", ["Array", ["Struct", "SubTerm"]]],
    ],
    "SubTerm": [
        ["sub_id", ["Bytes", 4]],
        ["hits", ["Array", ["Bytes", 2]]],
    ],
}

FE_RESPONSE = {
    "Response": [
        ["features", ["List", ["Bytes", 4]]],
        ["meta", ["List", ["Bytes", 4]]],
    ],
}


# ---------------------------------------------------------------------------
# FE-orig: hand-written schema-specific streaming decoder (cycle model:
# 1 field-read per cycle, containers cost 1 cycle for the count read, no
# structural tokens are emitted at all — the hand-written FSM feeds the
# kernels directly, which is why it is the lower bound).
# ---------------------------------------------------------------------------


def fe_orig_decode_cycles(wire: bytes) -> int:
    pos = 0
    cycles = 0

    def rd(n):
        nonlocal pos, cycles
        v = int.from_bytes(wire[pos : pos + n], "little")
        pos += n
        cycles += 1
        return v

    rd(8)  # query_id
    n_terms = rd(4)
    for _ in range(n_terms):
        rd(4); rd(2)  # term_id, weight
        n_pos = rd(4)
        for _ in range(n_pos):
            rd(4)
        n_sub = rd(4)
        for _ in range(n_sub):
            rd(4)  # sub_id
            n_hits = rd(4)
            for _ in range(n_hits):
                rd(2)
    n_meta = rd(4)
    for _ in range(n_meta):
        rd(4)
    assert pos == len(wire)
    return cycles


def fe_orig_encode_cycles(features: List[int], meta: List[int]) -> int:
    # one write per element + one per trailing count (paper §IV-B layout)
    return len(features) + len(meta) + 2


# ---------------------------------------------------------------------------
# FE-HGum: generated engines + the adapter shim
# ---------------------------------------------------------------------------

# client schema = "how to convert each token into an FE-kernel input"
FE_CLIENT = {
    "query_id": 1,
    "terms.start": 2, "terms.elem.term_id": 3, "terms.elem.weight": 4,
    "terms.elem.positions.start": 5, "terms.elem.positions.elem": 6,
    "terms.elem.subterms.start": 7, "terms.elem.subterms.elem.sub_id": 8,
    "terms.elem.subterms.elem.hits.start": 9,
    "terms.elem.subterms.elem.hits.elem": 10,
    "metadata.start": 11, "metadata.elem": 12,
}


def adapter_shim(tokens) -> Dict[str, list]:
    """The ONLY hand-written DES logic in FE-HGum (paper: 27% of the LOC)."""
    feat_in: Dict[str, list] = {k: [] for k in ("ids", "weights", "positions", "hits")}
    for t in tokens:
        if t.tag == 3:
            feat_in["ids"].append(t.value)
        elif t.tag == 4:
            feat_in["weights"].append(t.value)
        elif t.tag == 6:
            feat_in["positions"].append(t.value)
        elif t.tag == 10:
            feat_in["hits"].append(t.value)
    return feat_in


def run() -> List[Table]:
    req_schema = Schema.from_json(FE_REQUEST)
    resp_schema = Schema.from_json(FE_RESPONSE)
    client = ClientSchema.from_json(FE_CLIENT)
    rom_req = build_rom(req_schema, client)
    rom_resp = build_rom(resp_schema)

    rng = np.random.default_rng(42)
    ratios = []
    t = Table("fe_case_study", [
        "request", "wire_bytes", "orig_cycles", "hgum_cycles", "ratio",
    ])

    def make_request():
        """Ranking-request population: few terms, longer feature arrays
        (the paper's requests are real Bing traffic, up to 64 KB)."""
        r = lambda a, b: int(rng.integers(a, b + 1))
        return {
            "query_id": int(rng.integers(0, 2**63)),
            "terms": [
                {
                    "term_id": r(0, 2**31), "weight": r(0, 2**15),
                    "positions": [r(0, 2**31) for _ in range(r(8, 64))],
                    "subterms": [
                        {"sub_id": r(0, 2**31),
                         "hits": [r(0, 2**15) for _ in range(r(4, 32))]}
                        for _ in range(r(0, 4))
                    ],
                }
                for _ in range(r(2, 16))
            ],
            "metadata": [r(0, 2**31) for _ in range(r(4, 32))],
        }

    n_requests = 200
    for i in range(n_requests):
        msg = make_request()
        wire = ser_sw_to_hw(req_schema, msg)
        # ---- FE-orig
        c_orig_des = fe_orig_decode_cycles(wire)
        feats = [int(x) for x in rng.integers(0, 2**32, rng.integers(1, 64))]
        meta = [int(x) for x in rng.integers(0, 2**32, rng.integers(1, 8))]
        c_orig = c_orig_des + fe_orig_encode_cycles(feats, meta)
        # ---- FE-HGum
        des = DesFSM(rom_req, "sw2hw", phit_bytes=PHIT).run(wire)
        shim_out = adapter_shim(des.tokens)  # would feed the FE kernels
        resp_msg = {"features": feats, "meta": meta}
        resp_toks = strip_for_ser(msg_to_des_tokens(resp_schema, resp_msg))
        ser = SerFSM(rom_resp, "hw2sw", phit_bytes=PHIT).run(resp_toks)
        c_hgum = des.cycles + ser.cycles
        ratio = c_hgum / c_orig
        ratios.append(ratio)
        if i < 12:
            t.add(i, len(wire), c_orig, c_hgum, ratio)

    g = float(np.exp(np.mean(np.log(ratios))))
    s = Table("fe_case_study_summary", ["metric", "value", "paper"])
    s.add("n_requests", n_requests, 3468)
    s.add("geomean_latency_ratio", g, 1.05)
    s.add("p50_ratio", float(np.median(ratios)), "-")
    s.add("p95_ratio", float(np.percentile(ratios, 95)), "-")
    s.add("max_ratio", float(np.max(ratios)), "-")
    # LOC analog: shim vs hand-written decoder
    shim_loc = len(inspect.getsource(adapter_shim).splitlines())
    orig_loc = len(inspect.getsource(fe_orig_decode_cycles).splitlines()) + \
        len(inspect.getsource(fe_orig_encode_cycles).splitlines())
    s.add("handwritten_loc_ratio", round(shim_loc / orig_loc, 3), 0.27)
    return [t, s]


if __name__ == "__main__":
    for tb in run():
        print(tb.show())
