"""Streaming message plane: time-to-first-token, overlap, and QoS fairness.

Three measurements on the 8 simulated host devices:

* **TTFT vs whole-response** — the same request burst served three ways on
  a fabric where every request is pinned >= 2 hops from the ingress:
  whole-response ``serve_requests_sharded`` (ingress sees nothing until the
  full response wires ride back) vs ``serve_requests_streaming`` with the
  async overlap pipeline off and on.  Time-to-first-token is the wall
  clock until the first ``on_token`` callback; the streamed paths must
  also be byte-identical to the local batched plane.
* **overlap on/off** — tokens/s of the streamed path with the synchronous
  tick vs the double-buffered ``exchange_async`` pipeline (fabric hops
  hiding behind decode steps).
* **QoS fairness sweep** — a saturating tenant and a light tenant share
  the 1 -> 0 multi-hop path; the table reports the router scan step at
  which the light tenant's stream completes under FIFO credits and under
  weighted round-robin credit classes of increasing light-tenant weight.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python benchmarks/bench_stream.py [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time
from pathlib import Path
from typing import List

sys.path.insert(0, str(Path(__file__).parent))

import jax
import numpy as np

from common import Table, time_call
from repro.fabric import Fabric, FabricConfig

MAX_NEW = 8
PAD_TO = 8
N_REQUESTS = 4


def _setup(n_layers: int = 2):
    from repro.configs import get_config, smoke_config
    from repro.launch.serve import encode_request
    from repro.models import init_params

    cfg = dataclasses.replace(
        smoke_config(get_config("yi-6b")), n_layers=n_layers
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    wires = [
        encode_request(r, [
            list(map(int, rng.integers(2, cfg.vocab, 8)))
            for _ in range(2)
        ])
        for r in range(N_REQUESTS)
    ]
    return params, cfg, wires


def bench_ttft(max_new: int = 48) -> Table:
    from repro.launch.serve import (
        serve_requests, serve_requests_sharded, serve_requests_streaming,
    )

    t = Table("stream: time-to-first-token vs whole-response (>= 2 hops)", [
        "mode", "hops_out", "hops_back", "ttft_s", "total_s", "tok/s",
        "ttft_speedup",
    ])
    # generation long enough that the whole-response wait (ticks x decode)
    # dwarfs the streamed plane's constant first-tick latency — the regime
    # streaming exists for
    params, cfg, wires = _setup()
    fabric = Fabric(n_ranks=4, config=FabricConfig(frame_phits=16, credits=4))
    shard = 2  # 2 hops out, 2 hops back on the 4-ring: >= 2 each way
    placement = [shard] * len(wires)
    hops_out = fabric.router.hops(0, shard)
    hops_back = fabric.router.hops(shard, 0)
    kw = dict(max_new=max_new, pad_to=PAD_TO, slots=8, fabric=fabric,
              placement=placement)
    baseline = serve_requests(
        params, cfg, wires, max_new=max_new, pad_to=PAD_TO, slots=8
    )
    n_tok = N_REQUESTS * 2 * max_new

    def run_whole():
        t0 = time.perf_counter()
        out = serve_requests_sharded(params, cfg, wires, **kw)
        dt = time.perf_counter() - t0
        assert out == baseline
        return dt, dt  # first token is only visible with the full response

    def run_streamed(overlap):
        first = []
        t0 = time.perf_counter()
        out = serve_requests_streaming(
            params, cfg, wires, overlap=overlap,
            on_token=lambda m, j, s, tok:
                first.append(time.perf_counter() - t0) if not first else None,
            **kw,
        )
        dt = time.perf_counter() - t0
        assert out == baseline  # bit-identical to the local batched plane
        return first[0], dt

    rows = [
        ("whole-response", run_whole),
        ("streamed", lambda: run_streamed(False)),
        ("streamed+overlap", lambda: run_streamed(True)),
    ]
    base_ttft = None
    for name, fn in rows:
        fn()  # warm the jit caches so TTFT measures the plane, not tracing
        ttft, total = fn()
        if base_ttft is None:
            base_ttft = ttft
        t.add(name, hops_out, hops_back, round(ttft, 4), round(total, 4),
              round(n_tok / total, 1), round(base_ttft / ttft, 2))
    return t


def bench_overlap() -> Table:
    from repro.launch.serve import serve_requests_streaming

    t = Table("stream: async fabric/compute overlap", [
        "overlap", "ticks", "s/serve", "tok/s",
    ])
    params, cfg, wires = _setup()
    fabric = Fabric(n_ranks=8, config=FabricConfig(frame_phits=16, credits=4))
    n_tok = N_REQUESTS * 2 * MAX_NEW
    for overlap in (False, True):
        kw = dict(max_new=MAX_NEW, pad_to=PAD_TO, slots=4, fabric=fabric,
                  overlap=overlap)
        serve_requests_streaming(params, cfg, wires, **kw)  # warmup
        before = fabric.exchanges
        dt = time_call(
            lambda: serve_requests_streaming(params, cfg, wires, **kw),
            repeats=3, warmup=0,
        )
        ticks = (fabric.exchanges - before) // 3
        t.add(str(overlap), ticks, round(dt, 4), round(n_tok / dt, 1))
    return t


def bench_qos() -> Table:
    from repro.stream import ChunkLane, StreamReader

    t = Table("stream: QoS credit classes under a saturating tenant", [
        "sched", "light_done_step", "heavy_done_step", "light_stalled",
    ])
    for name, weights in (
        ("fifo", None), ("wrr 1:1", (1, 1)), ("wrr 3:1", (3, 1)),
        ("wrr 1:3", (1, 3)),
    ):
        fab = Fabric(
            n_ranks=4,
            config=FabricConfig(frame_phits=2, credits=4, qos_weights=weights),
        )
        # tenant A saturates the 1 -> 0 path with bulk messages (level 2 ->
        # class 0); tenant B streams one chunk burst behind them (level 1)
        for i in range(8):
            fab.mailbox(1).send(0, bytes([i]) * 96, list_level=2)
        lane = ChunkLane(fab.mailbox(1), 0, list_level=1)
        w = lane.writer(7)
        w.write((1, 2, 3), eos=True)
        lane.flush()
        fab.exchange()
        got = fab.mailbox(0).recv()
        reader = StreamReader()
        evs = reader.feed([d for d in got if d.list_level == 1])
        assert evs and evs[0].ok and reader.streams[(1, 7)].tokens == [1, 2, 3]
        light = next(d for d in got if d.list_level == 1).arrive_step
        heavy = max(d.arrive_step for d in got if d.list_level == 2)
        t.add(name, light, heavy, "yes" if light >= heavy else "no")
    return t


def run() -> List[Table]:
    print("[bench_stream] streamed wires asserted bit-identical to the "
          "batched plane in every row", file=sys.stderr)
    return [bench_ttft(), bench_overlap(), bench_qos()]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="also write experiments/benchmarks.csv (CI smoke)")
    args = ap.parse_args()
    tables = run()
    for tb in tables:
        print(tb.show())
        print()
    if args.smoke:
        os.makedirs("experiments", exist_ok=True)
        with open("experiments/benchmarks.csv", "w") as f:
            for tb in tables:
                f.write(tb.csv())
                f.write("\n")
        print(f"wrote experiments/benchmarks.csv ({len(tables)} tables)")


if __name__ == "__main__":
    main()
