"""Streaming message plane: time-to-first-token, routing mode, overlap,
and QoS fairness.

Measurements on the 8 simulated host devices:

* **TTFT vs whole-response** — the same request burst served three ways on
  a fabric where every request is pinned >= 2 hops from the ingress:
  whole-response ``serve_requests_sharded`` (ingress sees nothing until the
  full response wires ride back) vs ``serve_requests_streaming`` with the
  async overlap pipeline off and on.  Time-to-first-token is the wall
  clock until the first ``on_token`` callback; the streamed paths must
  also be byte-identical to the local batched plane.
* **routing mode at >= 2 hops** — the same streamed serve with the shard
  pinned deep in the ring, under dimension-order (+1 only) vs
  shortest-path routing: TTFT, total time, and the arrive-step latency
  trace of every chunk (collected via ``on_event`` and reduced with
  ``repro.stream.arrive_stats`` — the same statistics
  ``StreamReader.arrive_stats`` reports) — the request path shrinks from
  6 hops to 2, and every per-tick chunk burst rides the short way back,
  so both the first token and the per-token wobble drop.
* **overlap on/off** — tokens/s of the streamed path with the synchronous
  tick vs the double-buffered ``exchange_async`` pipeline (fabric hops
  hiding behind decode steps).
* **generated codec vs hand-rolled baseline** — the ``Stream<Bytes 4>``
  chunk codec *generated* from the token-stream schema
  (``core.stream_plans`` driving ``kernels.ops.encode_chunks_batch``)
  against a frozen replica of the pre-refactor hand-rolled host assembly
  riding the SAME Pallas pack kernel and pow2 bucketing.  Every shape is
  asserted byte-identical between the two paths before timing; the
  throughput ratio is the no-regression gate for moving the serve plane
  onto the generated codec.
* **QoS fairness sweep** — a saturating tenant and a light tenant share
  the 1 -> 0 multi-hop path; the table reports the router scan step at
  which the light tenant's stream completes under FIFO credits and under
  weighted round-robin credit classes of increasing light-tenant weight.
* **backpressure-fed lane clamping** — a saturating tenant and a light
  tenant stream from a 4-hop shard; the reader's per-class p95 arrive
  latency feeds back into the heavy tenant's ``ChunkLane``
  (``p95_threshold``), which then *holds* its bursts and yields its
  credits: the light tenant's p95/max arrive steps drop while the heavy
  stream still completes (held chunks ride the next burst, tokens
  identical).  Reported per scheduler (FIFO and WRR) with the clamp off
  vs on; all four runs are asserted token-identical.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python benchmarks/bench_stream.py [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time
from pathlib import Path
from typing import List

sys.path.insert(0, str(Path(__file__).parent))

import jax
import numpy as np

from common import Table, time_call
from repro.fabric import Fabric, FabricConfig
from repro.stream import arrive_stats

MAX_NEW = 8
PAD_TO = 8
N_REQUESTS = 4

#: headline numbers for BENCH_stream.json (filled by run())
LAST_METRICS: dict = {}


def _setup(n_layers: int = 2):
    from repro.configs import get_config, smoke_config
    from repro.launch.serve import encode_request
    from repro.models import init_params

    cfg = dataclasses.replace(
        smoke_config(get_config("yi-6b")), n_layers=n_layers
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    wires = [
        encode_request(r, [
            list(map(int, rng.integers(2, cfg.vocab, 8)))
            for _ in range(2)
        ])
        for r in range(N_REQUESTS)
    ]
    return params, cfg, wires


def bench_ttft(max_new: int = 48) -> Table:
    from repro.launch.serve import (
        serve_requests, serve_requests_sharded, serve_requests_streaming,
    )

    t = Table("stream: time-to-first-token vs whole-response (>= 2 hops)", [
        "mode", "hops_out", "hops_back", "ttft_s", "total_s", "tok/s",
        "ttft_speedup",
    ])
    # generation long enough that the whole-response wait (ticks x decode)
    # dwarfs the streamed plane's constant first-tick latency — the regime
    # streaming exists for
    params, cfg, wires = _setup()
    fabric = Fabric(n_ranks=4, config=FabricConfig(frame_phits=16, credits=4))
    shard = 2  # 2 hops out, 2 hops back on the 4-ring: >= 2 each way
    placement = [shard] * len(wires)
    hops_out = fabric.router.hops(0, shard)
    hops_back = fabric.router.hops(shard, 0)
    kw = dict(max_new=max_new, pad_to=PAD_TO, slots=8, fabric=fabric,
              placement=placement)
    baseline = serve_requests(
        params, cfg, wires, max_new=max_new, pad_to=PAD_TO, slots=8
    )
    n_tok = N_REQUESTS * 2 * max_new

    def run_whole():
        t0 = time.perf_counter()
        out = serve_requests_sharded(params, cfg, wires, **kw)
        dt = time.perf_counter() - t0
        assert out == baseline
        return dt, dt  # first token is only visible with the full response

    def run_streamed(overlap):
        first = []
        t0 = time.perf_counter()
        out = serve_requests_streaming(
            params, cfg, wires, overlap=overlap,
            on_token=lambda m, j, s, tok:
                first.append(time.perf_counter() - t0) if not first else None,
            **kw,
        )
        dt = time.perf_counter() - t0
        assert out == baseline  # bit-identical to the local batched plane
        return first[0], dt

    rows = [
        ("whole-response", run_whole),
        ("streamed", lambda: run_streamed(False)),
        ("streamed+overlap", lambda: run_streamed(True)),
    ]
    base_ttft = None
    for name, fn in rows:
        fn()  # warm the jit caches so TTFT measures the plane, not tracing
        ttft, total = fn()
        if base_ttft is None:
            base_ttft = ttft
        t.add(name, hops_out, hops_back, round(ttft, 4), round(total, 4),
              round(n_tok / total, 1), round(base_ttft / ttft, 2))
    return t


def bench_routing(max_new: int = 24) -> Table:
    from repro.launch.serve import (
        encode_request, serve_requests, serve_requests_streaming,
    )

    t = Table("stream: routing mode (streamed serve, >= 2 hops)", [
        "scenario", "routing", "max_hops_back", "ttft_steps", "ttft_s",
        "total_s", "arrive_mean", "arrive_p95", "jitter",
    ])
    # ``ttft_steps`` is the deterministic time-to-first-token observable:
    # the router scan steps the FIRST chunk spends in the fabric (hops +
    # credit stalls).  Wall-clock ``ttft_s``/``total_s`` ride on top of the
    # CPU simulation's per-dispatch floor (~tens of ms per tick regardless
    # of scan length), so on this host they understate what the hop
    # reduction buys on real links.
    params, cfg, setup_wires = _setup()
    rng = np.random.default_rng(7)
    # two traffic shapes: "far-shard" pins every request 2 hops out with a
    # 6-hop +1-ring return path (the TTFT story — the first token and every
    # chunk after it ride the short way back under shortest-path routing);
    # "spread" places one request per shard, so dimension-order return
    # paths span 1..7 hops while shortest-path caps them at 4 (the
    # cross-shard time-to-token JITTER story a multi-tenant ingress sees).
    wires8 = [
        encode_request(r, [list(map(int, rng.integers(2, cfg.vocab, 8)))])
        for r in range(8)
    ]
    # far-shard runs at credits=1 with two-prompt requests — the
    # flow-control-constrained regime where the scan length (and
    # therefore the tick wall time) tracks hop count, so the 6 -> 2
    # return-path win is visible as wall-clock TTFT
    scenarios = [
        ("far-shard", setup_wires, [2] * len(setup_wires), 1),
        ("spread", wires8, [(r % 7) + 1 for r in range(8)], 4),
    ]
    for scen, wires, placement, credits in scenarios:
        baseline = serve_requests(
            params, cfg, wires, max_new=max_new, pad_to=PAD_TO, slots=8
        )
        fabrics, runners = {}, {}
        for routing in ("dimension", "shortest"):
            fabric = Fabric(n_ranks=8, config=FabricConfig(
                frame_phits=16, credits=credits, routing=routing))
            fabrics[routing] = fabric
            kw = dict(max_new=max_new, pad_to=PAD_TO, slots=8,
                      fabric=fabric, placement=placement)

            def run_once(kw=kw):
                first, steps = [], []
                t0 = time.perf_counter()
                out = serve_requests_streaming(
                    params, cfg, wires,
                    on_token=lambda m, j, s, tok:
                        first.append(time.perf_counter() - t0)
                        if not first else None,
                    on_event=lambda ev: steps.append(ev.arrive_step),
                    **kw,
                )
                dt = time.perf_counter() - t0
                assert out == baseline  # bit-identical under both modes
                return first[0], dt, steps

            runners[routing] = run_once
            run_once()  # warm the jit caches
        # interleave the modes so machine load biases both equally
        samples = {r: [] for r in runners}
        for _ in range(5):
            for r, fn in runners.items():
                samples[r].append(fn())
        for routing, runs in samples.items():
            ttft, total, steps = sorted(runs)[2]  # median by TTFT
            st = arrive_stats(steps)  # same math as StreamReader's
            mean, p95, jitter = st["mean"], st["p95"], st["jitter"]
            ttft_steps = steps[0]  # first chunk's in-fabric latency
            max_back = max(
                fabrics[routing].router.route_hops(s, 0)
                for s in set(placement)
            )
            t.add(scen, routing, max_back, ttft_steps, round(ttft, 4),
                  round(total, 4), round(mean, 2), p95, round(jitter, 2))
            tag = f"{scen}_{routing}"
            LAST_METRICS[f"ttft_steps_{tag}"] = ttft_steps
            LAST_METRICS[f"ttft_{tag}"] = round(ttft, 4)
            LAST_METRICS[f"total_{tag}"] = round(total, 4)
            LAST_METRICS[f"arrive_mean_{tag}"] = round(mean, 2)
            LAST_METRICS[f"arrive_p95_{tag}"] = p95
            LAST_METRICS[f"jitter_{tag}"] = round(jitter, 2)
    LAST_METRICS["ttft_routing_speedup"] = round(
        LAST_METRICS["ttft_far-shard_dimension"]
        / LAST_METRICS["ttft_far-shard_shortest"], 2
    )
    LAST_METRICS["total_routing_speedup"] = round(
        LAST_METRICS["total_far-shard_dimension"]
        / LAST_METRICS["total_far-shard_shortest"], 2
    )
    LAST_METRICS["jitter_routing_ratio"] = round(
        LAST_METRICS["jitter_spread_dimension"]
        / max(LAST_METRICS["jitter_spread_shortest"], 1e-9), 2
    )
    return t


def bench_overlap() -> Table:
    from repro.launch.serve import serve_requests_streaming

    t = Table("stream: async fabric/compute overlap", [
        "overlap", "ticks", "s/serve", "tok/s",
    ])
    params, cfg, wires = _setup()
    fabric = Fabric(n_ranks=8, config=FabricConfig(frame_phits=16, credits=4))
    n_tok = N_REQUESTS * 2 * MAX_NEW
    for overlap in (False, True):
        kw = dict(max_new=MAX_NEW, pad_to=PAD_TO, slots=4, fabric=fabric,
                  overlap=overlap)
        serve_requests_streaming(params, cfg, wires, **kw)  # warmup
        before = fabric.exchanges
        dt = time_call(
            lambda: serve_requests_streaming(params, cfg, wires, **kw),
            repeats=3, warmup=0,
        )
        ticks = (fabric.exchanges - before) // 3
        t.add(str(overlap), ticks, round(dt, 4), round(n_tok / dt, 1))
    return t


def bench_codec(repeats: int = 15) -> Table:
    """Generated ``Stream<Bytes 4>`` SER pass vs the frozen hand-rolled
    baseline it replaced — same Pallas ``encode_chunks_batch`` kernel,
    same pow2 bucketing, byte-identical wires (asserted per shape before
    timing).  The ratio row is the chunk-encode-throughput regression
    gate for the schema-generated codec path."""
    from repro.kernels.ops import encode_chunks_batch
    from repro.stream import (
        CHUNK_META_WORDS, FLAG_EOS, TokenChunk, decode_token_chunks,
        encode_chunk_burst,
    )
    from repro.stream.chunks import check_chunk_tokens

    def handrolled_burst(chunks):
        # frozen replica of the pre-``Stream<T>`` hand-rolled host
        # assembly (see git history of stream/chunks.py): identical pow2
        # bucketing and Pallas pack call at elem_words=1
        if not chunks:
            return b""
        B = len(chunks)
        cap = max(max(len(c.tokens) for c in chunks), 1)
        cap = 1 << (cap - 1).bit_length()
        Bp = 1 << max(B - 1, 0).bit_length()
        meta = np.zeros((Bp, CHUNK_META_WORDS), np.uint32)
        toks = np.zeros((Bp, cap), np.uint32)
        counts = np.zeros((Bp,), np.int32)
        for i, c in enumerate(chunks):
            check_chunk_tokens(len(c.tokens))
            meta[i] = (c.stream_id, c.step, FLAG_EOS if c.eos else 0)
            toks[i, : len(c.tokens)] = c.tokens
            counts[i] = len(c.tokens)
        rows = np.asarray(encode_chunks_batch(meta, toks, counts))[:B]
        parts = []
        for i in range(B):
            n = int(counts[i])
            parts.append(rows[i, : CHUNK_META_WORDS + n].tobytes())
            parts.append(rows[i, -1:].tobytes())
        return b"".join(parts)

    t = Table("stream: generated codec vs hand-rolled baseline "
              "(same Pallas pass)", [
        "chunks x toks", "codec", "wire_KB", "s/pass", "chunks/s", "ratio",
    ])
    rng = np.random.default_rng(1801)
    # serve-tick shapes: a smoke tick (8 live sequences x 4 tokens), a
    # loaded tick, and a speculative/bulk tick
    for B, n in ((8, 4), (32, 16), (64, 64)):
        chunks = [
            TokenChunk(
                (i << 16) | (i % 3), i % 11,
                tuple(int(x) for x in
                      rng.integers(0, 1 << 32, n, dtype=np.uint64)),
                eos=i % 5 == 0,
            )
            for i in range(B)
        ]
        wire = encode_chunk_burst(chunks)
        assert wire == handrolled_burst(chunks), \
            "generated codec diverged from the hand-rolled baseline"
        back, ok = decode_token_chunks(wire)
        assert ok and [
            (c.stream_id, c.step, c.tokens, c.eos) for c in back
        ] == [(c.stream_id, c.step, c.tokens, c.eos) for c in chunks]
        # interleave the two codecs so CPU-frequency drift biases neither
        pairs = (("hand-rolled", handrolled_burst),
                 ("generated", encode_chunk_burst))
        samples = {name: [] for name, _ in pairs}
        for name, fn in pairs:
            fn(chunks)  # warm the jit cache
        for _ in range(repeats):
            for name, fn in pairs:
                t0 = time.perf_counter()
                fn(chunks)
                samples[name].append(time.perf_counter() - t0)
        per_s = {
            name: B / sorted(ts)[len(ts) // 2]
            for name, ts in samples.items()
        }
        for name in ("hand-rolled", "generated"):
            t.add(f"{B} x {n}", name, round(len(wire) / 1024, 2),
                  round(B / per_s[name], 6), round(per_s[name], 1),
                  round(per_s[name] / per_s["hand-rolled"], 3))
        if (B, n) == (32, 16):  # the loaded-tick shape is the headline
            LAST_METRICS["codec_generated_chunks_per_s"] = round(
                per_s["generated"], 1)
            LAST_METRICS["codec_handrolled_chunks_per_s"] = round(
                per_s["hand-rolled"], 1)
            LAST_METRICS["codec_throughput_ratio"] = round(
                per_s["generated"] / per_s["hand-rolled"], 3)
    return t


def bench_qos() -> Table:
    from repro.stream import ChunkLane, StreamReader

    t = Table("stream: QoS credit classes under a saturating tenant", [
        "sched", "light_done_step", "heavy_done_step", "light_stalled",
    ])
    for name, weights in (
        ("fifo", None), ("wrr 1:1", (1, 1)), ("wrr 3:1", (3, 1)),
        ("wrr 1:3", (1, 3)),
    ):
        fab = Fabric(
            n_ranks=4,
            config=FabricConfig(frame_phits=2, credits=4, qos_weights=weights),
        )
        # tenant A saturates the 1 -> 0 path with bulk messages (level 2 ->
        # class 0); tenant B streams one chunk burst behind them (level 1)
        for i in range(8):
            fab.mailbox(1).send(0, bytes([i]) * 96, list_level=2)
        lane = ChunkLane(fab.mailbox(1), 0, list_level=1)
        w = lane.writer(7)
        w.write((1, 2, 3), eos=True)
        lane.flush()
        fab.exchange()
        got = fab.mailbox(0).recv()
        reader = StreamReader()
        evs = reader.feed([d for d in got if d.list_level == 1])
        assert evs and evs[0].ok and reader.streams[(1, 7)].tokens == [1, 2, 3]
        light = next(d for d in got if d.list_level == 1).arrive_step
        heavy = max(d.arrive_step for d in got if d.list_level == 2)
        t.add(name, light, heavy, "yes" if light >= heavy else "no")
    return t


def bench_backpressure() -> Table:
    """Backpressure-fed lane scheduling: the reader's per-class p95 arrive
    latency clamps a saturating tenant's ``ChunkLane`` flush rate, so its
    credits spill to the light tenant instead of inflating the queues.
    Deterministic router-step metrics (no wall clock): the win is where the
    light tenant's tail latency lands, not how fast this host dispatches."""
    from repro.fabric import Fabric, FabricConfig
    from repro.stream import ChunkLane, StreamReader

    t = Table("stream: backpressure-fed lane clamping (4-hop shard)", [
        "sched", "bp_p95", "light_mean", "light_p95", "tick_steps_mean",
        "heavy_p95", "heavy_holds",
    ])
    N_TICKS, N_HEAVY = 24, 6
    rng = np.random.default_rng(9)
    heavy_toks = rng.integers(0, 1 << 31, (N_TICKS, N_HEAVY, 16))
    light_toks = rng.integers(0, 1 << 31, (N_TICKS, 2))
    tokens = {}
    for sched, weights in (("fifo", None), ("wrr 3:1", (3, 1))):
        for bp in (None, 6.0):
            fab = Fabric(n_ranks=8, config=FabricConfig(
                frame_phits=2, credits=4, qos_weights=weights))
            box = fab.mailbox(4)  # 4 hops back to the ingress either way
            heavy_lane = ChunkLane(box, 0, list_level=2, p95_threshold=bp)
            light_lane = ChunkLane(box, 0, list_level=1)
            hw = [heavy_lane.writer(100 + i) for i in range(N_HEAVY)]
            lw = light_lane.writer(7)
            reader = StreamReader()
            tick_steps = []  # per-tick fabric drain (max arrive step)
            for tick in range(N_TICKS):
                eos = tick == N_TICKS - 1
                for i, w in enumerate(hw):
                    w.write([int(x) for x in heavy_toks[tick, i]], eos=eos)
                lw.write([int(x) for x in light_toks[tick]], eos=eos)
                heavy_lane.flush()  # heavy queues first: worst case FIFO
                light_lane.flush()
                fab.exchange()
                got = fab.mailbox(0).recv()
                tick_steps.append(max(d.arrive_step for d in got))
                reader.feed(got)
                per = reader.class_arrive_stats(window=64)
                heavy_lane.feedback((per.get(2) or {}).get("p95"))
            while heavy_lane.flush(force=True):  # drain the held backlog
                fab.exchange()
                reader.feed(fab.mailbox(0).recv())
            # token identity: clamping delays bursts, never changes them
            assert reader.all_eos()
            toks = {k: tuple(st.tokens) for k, st in reader.streams.items()}
            assert all(st.ok for st in reader.streams.values())
            tokens.setdefault("ref", toks)
            assert toks == tokens["ref"], (sched, bp)
            per = reader.class_arrive_stats()
            steps_mean = sum(tick_steps) / len(tick_steps)
            tag = f"{'wrr' if weights else 'fifo'}_{'on' if bp else 'off'}"
            LAST_METRICS[f"bp_light_mean_{tag}"] = round(per[1]["mean"], 2)
            LAST_METRICS[f"bp_light_p95_{tag}"] = per[1]["p95"]
            LAST_METRICS[f"bp_tick_steps_mean_{tag}"] = round(steps_mean, 2)
            LAST_METRICS[f"bp_heavy_holds_{tag}"] = heavy_lane.holds
            t.add(sched, bp or "off", round(per[1]["mean"], 2),
                  per[1]["p95"], round(steps_mean, 2), per[2]["p95"],
                  heavy_lane.holds)
    LAST_METRICS["bp_light_p95_ratio_fifo"] = round(
        LAST_METRICS["bp_light_p95_fifo_off"]
        / max(LAST_METRICS["bp_light_p95_fifo_on"], 1e-9), 2
    )
    return t


def run() -> List[Table]:
    LAST_METRICS.clear()
    print("[bench_stream] streamed wires asserted bit-identical to the "
          "batched plane in every row", file=sys.stderr)
    tables = [bench_ttft(), bench_routing(), bench_overlap(), bench_codec(),
              bench_qos(), bench_backpressure()]
    ttfts = {r[0]: r[3] for r in tables[0].rows}
    LAST_METRICS["ttft_whole_response"] = ttfts.get("whole-response")
    LAST_METRICS["ttft_streamed_overlap"] = ttfts.get("streamed+overlap")
    print(f"[bench_stream] routing-mode wins at >= 2 hops: first-token "
          f"fabric latency {LAST_METRICS['ttft_steps_far-shard_dimension']}"
          f" -> {LAST_METRICS['ttft_steps_far-shard_shortest']} router "
          f"steps (whole serve {LAST_METRICS['total_routing_speedup']}x "
          f"lower wall clock at the far shard); cross-shard arrive jitter "
          f"{LAST_METRICS['jitter_spread_dimension']} -> "
          f"{LAST_METRICS['jitter_spread_shortest']} router steps "
          f"(p95 {LAST_METRICS['arrive_p95_spread_dimension']} -> "
          f"{LAST_METRICS['arrive_p95_spread_shortest']})",
          file=sys.stderr)
    print(f"[bench_stream] schema-generated chunk codec: "
          f"{LAST_METRICS['codec_generated_chunks_per_s']} chunks/s vs "
          f"{LAST_METRICS['codec_handrolled_chunks_per_s']} hand-rolled "
          f"({LAST_METRICS['codec_throughput_ratio']}x, byte-identical "
          f"wires)", file=sys.stderr)
    print(f"[bench_stream] backpressure clamp (FIFO): light-tenant p95 "
          f"{LAST_METRICS['bp_light_p95_fifo_off']} -> "
          f"{LAST_METRICS['bp_light_p95_fifo_on']} router steps "
          f"({LAST_METRICS['bp_light_p95_ratio_fifo']}x) with the heavy "
          f"lane held {LAST_METRICS['bp_heavy_holds_fifo_on']} ticks",
          file=sys.stderr)
    return tables


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="also write experiments/benchmarks.csv (CI smoke)")
    args = ap.parse_args()
    tables = run()
    for tb in tables:
        print(tb.show())
        print()
    if args.smoke:
        os.makedirs("experiments", exist_ok=True)
        with open("experiments/benchmarks.csv", "w") as f:
            for tb in tables:
                f.write(tb.csv())
                f.write("\n")
        print(f"wrote experiments/benchmarks.csv ({len(tables)} tables)")


if __name__ == "__main__":
    main()
