"""Distribution runtime: shardings resolve, framed channels, compression,
pipeline, end-to-end sharded train step on a small mesh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs import all_archs, get_config, smoke_config
from repro.models import init_cache, init_params
from repro.runtime import (
    ShardRules, batch_pspec, cache_shardings,
    cross_pod_mean_int8, frame_stream, make_framed_sender, param_shardings,
    unframe_stream,
)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))


@pytest.mark.parametrize("arch", all_archs())
def test_param_shardings_resolve_and_place(arch, mesh):
    cfg = smoke_config(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    sh = param_shardings(params, cfg, mesh)
    placed = jax.device_put(params, sh)  # divisibility errors would raise
    n_sharded = sum(1 for s in jax.tree.leaves(sh) if s.spec != P())
    assert n_sharded > 0
    del placed


@pytest.mark.parametrize("arch", ["yi-6b", "jamba-1.5-large-398b", "whisper-tiny"])
def test_cache_shardings_resolve(arch, mesh):
    cfg = smoke_config(get_config(arch))
    cache = init_cache(cfg, 4, 32)
    sh = cache_shardings(cache, cfg, mesh)
    jax.device_put(cache, sh)


def test_batch_pspec_divisibility(mesh):
    rules = ShardRules()
    assert batch_pspec(mesh, rules, 8) == P(("pod", "data"))
    assert batch_pspec(mesh, rules, 2) == P(("pod",))  # 2 % 4 != 0 -> drop data
    assert batch_pspec(mesh, rules, 3) == P(None)  # prime -> replicate


def test_frame_stream_roundtrip():
    payload = jnp.arange(4096, dtype=jnp.uint32)
    for nbytes in (0, 10, 100, 4096 * 4):
        frames, nf = frame_stream(payload, jnp.asarray(nbytes), frame_phits=16)
        out, nb, ok = unframe_stream(frames)
        assert bool(ok)
        assert int(nb) == nbytes
        words = (nbytes + 3) // 4
        np.testing.assert_array_equal(np.asarray(out[:words]), np.asarray(payload[:words]))
        assert np.all(np.asarray(out[words:]) == 0)


def test_frame_checksum_detects_corruption():
    payload = jnp.arange(256, dtype=jnp.uint32)
    frames, _ = frame_stream(payload, jnp.asarray(1024), frame_phits=16)
    bad = frames.at[0, 8].add(1)
    _, _, ok = unframe_stream(bad)
    assert not bool(ok)


def test_framed_channel_ring_exchange(mesh):
    payload = jnp.arange(2 * 2048, dtype=jnp.uint32).reshape(2, 2048)
    nbytes = jnp.array([100, 8192], jnp.int32)
    sender = make_framed_sender(mesh, "pod", frame_phits=32)
    p_out, nb_out, ok = jax.jit(sender)(payload, nbytes)
    assert bool(ok.all())
    assert list(np.asarray(nb_out)) == [8192, 100]
    np.testing.assert_array_equal(np.asarray(p_out)[0, :2048], np.asarray(payload[1]))
    np.testing.assert_array_equal(np.asarray(p_out)[1, :25], np.asarray(payload[0, :25]))


def test_int8_cross_pod_mean(mesh):
    g = {"w": jnp.stack([jnp.full((4, 4), 1.0), jnp.full((4, 4), 3.0)])}
    e = {"w": jnp.zeros((2, 4, 4))}

    def red(g, e):
        gl = jax.tree.map(lambda x: x[0], g)
        el = jax.tree.map(lambda x: x[0], e)
        m, en = cross_pod_mean_int8(gl, el, "pod")
        return (jax.tree.map(lambda x: x[None], m),
                jax.tree.map(lambda x: x[None], en))

    f = shard_map(red, mesh=mesh, in_specs=(P("pod"), P("pod")),
                  out_specs=(P("pod"), P("pod")), check_rep=False)
    m, en = jax.jit(f)(g, e)
    np.testing.assert_allclose(np.asarray(m["w"])[0], 2.0, atol=0.05)
    # error feedback: residual bounded by one quantization step
    assert np.abs(np.asarray(en["w"])).max() <= 3.0 / 127 + 1e-6


def test_error_feedback_converges():
    """Repeated compression of a constant gradient: mean of dequantized
    values (with error feedback) converges to the true value."""
    from repro.runtime.compress import quantize_leaf, dequantize_leaf
    g = jnp.asarray([[0.3141, -0.0017], [0.9, 2e-4]])
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for i in range(64):
        q, s = quantize_leaf(g, err)
        dq = dequantize_leaf(q, s)
        err = g + err - dq
        acc = acc + dq
    np.testing.assert_allclose(np.asarray(acc / 64), np.asarray(g), rtol=2e-2, atol=2e-5)


def test_gpipe_matches_reference(mesh):
    from repro.runtime.pipeline import gpipe_forward
    k = jax.random.PRNGKey(0)
    W = jax.random.normal(k, (2, 1, 8, 8)) * 0.5
    sp = {"w": W}

    def stage_fn(p, x):
        for i in range(p["w"].shape[0]):
            x = jnp.tanh(x @ p["w"][i])
        return x

    x = jax.random.normal(k, (4, 2, 6, 8))
    pm = jax.make_mesh((2,), ("pod",), devices=jax.devices()[:2])
    y = gpipe_forward(pm, "pod", stage_fn, sp, x)
    ref = x
    for s in range(2):
        ref = jnp.tanh(ref @ W[s, 0])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)


def test_sharded_train_step_runs(mesh):
    """End-to-end pjit train step on the 8-device debug mesh."""
    from repro.launch.dryrun import lower_cell
    from repro.configs.base import ShapeConfig
    cfg = dataclasses.replace(
        smoke_config(get_config("yi-6b")), n_layers=2, microbatch=2,
        scan_layers=True,
    )
    shape = ShapeConfig("t", 32, 8, "train")
    lowered, jitted, specs = lower_cell(cfg, shape, mesh)
    compiled = lowered.compile()
    assert compiled.memory_analysis().temp_size_in_bytes >= 0
    # actually execute with real arrays
    params = init_params(cfg, jax.random.PRNGKey(0))
    from repro.optim import adamw_init
    opt = adamw_init(params)
    batch = {
        "tokens": jnp.ones((8, 32), jnp.int32),
        "labels": jnp.ones((8, 32), jnp.int32),
        "loss_mask": jnp.ones((8, 32), jnp.float32),
        "segment_ids": jnp.ones((8, 32), jnp.int32),
        "positions": jnp.tile(jnp.arange(32, dtype=jnp.int32)[None], (8, 1)),
    }
    p2, o2, metrics = jitted(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_serve_step_sharded(mesh):
    from repro.launch.dryrun import lower_cell
    from repro.configs.base import ShapeConfig
    cfg = dataclasses.replace(smoke_config(get_config("yi-6b")), n_layers=2)
    shape = ShapeConfig("d", 64, 8, "decode")
    lowered, jitted, specs = lower_cell(cfg, shape, mesh)
    compiled = lowered.compile()
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, 8, 64)
    toks = jnp.ones((8, 1), jnp.int32)
    nt, c2 = jitted(params, cache, toks)
    assert nt.shape == (8, 1)
