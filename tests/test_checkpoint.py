"""HGum-framed checkpoints: atomicity, CRC, keep-K, resume, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager, load_checkpoint, restore_into, save_checkpoint,
)
from repro.checkpoint.store import CorruptCheckpoint, FRAME_PAYLOAD
from repro.optim import adamw_init


def tree():
    return {
        "w": jnp.arange(24, dtype=jnp.bfloat16).reshape(4, 6) / 3,
        "layers": [{"a": jnp.ones((3,), jnp.float32) * i} for i in range(3)],
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    p = str(tmp_path / "c.hgck")
    t = tree()
    save_checkpoint(p, t, meta={"note": "x"})
    meta, tensors = load_checkpoint(p)
    assert meta["user"]["note"] == "x"
    got = restore_into(t, tensors)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_multi_frame_tensor(tmp_path):
    """Tensors larger than one frame span multiple frames + terminator."""
    p = str(tmp_path / "big.hgck")
    big = {"x": jnp.arange(FRAME_PAYLOAD // 4 * 3 + 17, dtype=jnp.int32)}
    save_checkpoint(p, big)
    _, tensors = load_checkpoint(p)
    got = restore_into(big, tensors)
    np.testing.assert_array_equal(np.asarray(got["x"]), np.asarray(big["x"]))


@pytest.mark.parametrize("corrupt_at", [30, 200, -30])
def test_crc_detects_corruption(tmp_path, corrupt_at):
    p = str(tmp_path / "c.hgck")
    save_checkpoint(p, tree())
    raw = bytearray(open(p, "rb").read())
    raw[corrupt_at] ^= 0xFF
    open(p, "wb").write(bytes(raw))
    with pytest.raises((CorruptCheckpoint, Exception)):
        load_checkpoint(p)


def test_truncation_detected(tmp_path):
    p = str(tmp_path / "c.hgck")
    save_checkpoint(p, tree())
    raw = open(p, "rb").read()
    open(p, "wb").write(raw[: len(raw) - 20])
    with pytest.raises(CorruptCheckpoint):
        load_checkpoint(p)


def test_manager_keep_k_and_fallback(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=2)
    t = {"params": tree(), "opt": adamw_init(tree())}
    for s in (10, 20, 30):
        mgr.save(s, t)
    assert mgr.all_steps() == [20, 30]
    # corrupt newest -> restore falls back
    raw = bytearray(open(mgr.path(30), "rb").read())
    raw[60] ^= 1
    open(mgr.path(30), "wb").write(bytes(raw))
    step, restored = mgr.restore_latest(t)
    assert step == 20


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Save sharded on a 4-device mesh, restore onto a 2-device mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    devs = jax.devices()
    assert len(devs) >= 8
    mesh4 = jax.make_mesh((4,), ("data",), devices=devs[:4])
    mesh2 = jax.make_mesh((2,), ("data",), devices=devs[4:6])
    x = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)
    xs = jax.device_put(x, NamedSharding(mesh4, P("data")))
    p = str(tmp_path / "e.hgck")
    save_checkpoint(p, {"x": xs})
    _, tensors = load_checkpoint(p)
    out = restore_into(
        {"x": x},
        tensors,
        place=lambda path, arr: jax.device_put(
            jnp.asarray(arr), NamedSharding(mesh2, P("data"))
        ),
    )
    np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(x))
    assert len(out["x"].sharding.device_set) == 2


def test_atomic_no_partial_file(tmp_path):
    """A .tmp file from a crashed save is invisible to the manager."""
    d = str(tmp_path)
    mgr = CheckpointManager(d)
    mgr.save(1, tree())
    open(os.path.join(d, "ckpt_00000002.hgck.tmp"), "wb").write(b"garbage")
    assert mgr.all_steps() == [1]
