"""Launchers: train restart-after-kill, serve wire roundtrip, HLO analyzer."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_train(args, check=True):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = ""  # single device
    cmd = [sys.executable, "-m", "repro.launch.train"] + args
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=os.path.dirname(SRC), check=check)


@pytest.mark.slow
def test_train_checkpoint_restart_bitwise(tmp_path):
    """Kill at step 12, resume, final state must equal the uninterrupted run."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    common = ["--arch", "xlstm-125m", "--smoke", "--steps", "16",
              "--batch", "2", "--seq", "32", "--ckpt-every", "4"]
    # uninterrupted
    r = _run_train(common + ["--ckpt-dir", d1])
    assert "done" in r.stdout
    # interrupted at 12 then resumed
    r = _run_train(common + ["--ckpt-dir", d2, "--die-at", "12"], check=False)
    assert r.returncode == 17
    r = _run_train(common + ["--ckpt-dir", d2, "--resume", "auto"])
    assert "resumed from step 12" in r.stdout
    # compare final checkpoints bitwise
    from repro.checkpoint import load_checkpoint
    from repro.checkpoint.store import CheckpointManager
    m1, m2 = CheckpointManager(d1), CheckpointManager(d2)
    assert m1.latest() == m2.latest() == 16
    _, t1 = load_checkpoint(m1.path(16))
    _, t2 = load_checkpoint(m2.path(16))
    assert set(t1) == set(t2)
    for k in t1:
        np.testing.assert_array_equal(t1[k], t2[k], err_msg=k)


def test_serve_wire_roundtrip():
    from repro.launch.serve import (
        decode_request, decode_response, encode_request, encode_response,
    )
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9, 10]]
    wire = encode_request(99, prompts)
    rid, got = decode_request(wire)
    assert rid == 99 and got == prompts
    outs = [[11, 12], [13], []]
    rwire = encode_response(7, outs)
    rid, got = decode_response(rwire)
    assert rid == 7 and got == outs


@pytest.mark.slow
def test_serve_end_to_end_smoke():
    import dataclasses
    from repro.configs import get_config, smoke_config
    from repro.launch.serve import decode_response, encode_request, serve_request
    from repro.models import init_params
    cfg = dataclasses.replace(smoke_config(get_config("yi-6b")), n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    wire = encode_request(1, [[5, 6, 7], [9, 10]])
    resp = serve_request(params, cfg, wire, max_new=4, pad_to=16)
    rid, outs = decode_response(resp)
    assert rid == 1
    assert len(outs) == 2 and all(len(o) == 4 for o in outs)
    assert all(0 <= t < cfg.vocab for o in outs for t in o)


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------


def test_analyzer_multiplies_while_trip_counts():
    from repro.launch.hloanalysis import analyze

    def f_scan(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    def f_unroll(x, w):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    want = 2 * 64 * 128 * 128 * 10
    for f in (f_scan, f_unroll):
        rep = analyze(jax.jit(f).lower(x, w).compile().as_text())
        assert abs(rep.dot_flops - want) / want < 1e-6


def test_analyzer_nested_scans():
    from repro.launch.hloanalysis import analyze

    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    rep = analyze(jax.jit(f).lower(x, w).compile().as_text())
    want = 2 * 32 * 64 * 64 * 15
    assert abs(rep.dot_flops - want) / want < 1e-6


def test_analyzer_collectives_counted():
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.hloanalysis import analyze
    mesh = jax.make_mesh((4,), ("d",), devices=jax.devices()[:4])

    def f(x):
        return jax.lax.with_sharding_constraint(
            x.sum(0, keepdims=True), NamedSharding(mesh, P())
        )

    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    jitted = jax.jit(f, in_shardings=NamedSharding(mesh, P("d")))
    rep = analyze(jitted.lower(x).compile().as_text())
    assert rep.collective_bytes > 0


def test_input_specs_all_cells():
    """input_specs builds for every (arch x supported shape) without alloc."""
    from repro.configs import SHAPES, all_archs, get_config, supports_shape
    from repro.launch.steps import input_specs
    for arch in all_archs():
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = supports_shape(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, (jax.ShapeDtypeStruct,))
