"""End-to-end request tracing: flight recorder + causal spans + SLO gates.

The acceptance criteria of the tracing PR:

* **attribution exactness** — the per-frame flight-recorder columns the
  router scan carries (queue wait / per-axis transit / starvation stall /
  defections) reconstruct ``Delivery.arrive_step`` EXACTLY:
  ``queue_wait + stall + total_transit == arrive_step`` for every
  delivered message, under dimension-order, shortest-path and
  congestion-defection routing alike;
* **engine bit-identity** — the fused single-jit tick and the
  three-program path produce identical attribution vectors (the columns
  are step-indexed event counts carried with the frames, so engine choice
  cannot skew them);
* **tick telescoping** — the span tick marks (ingress / admit / first
  flush / first token) break TTFT into components whose sum equals the
  end-to-end tick count exactly, by construction;
* **byte invisibility** — attaching a SpanTracker (and the trace flow
  events it emits) to the streaming serve loop changes ZERO response
  bytes;
* **degrade, never vanish** — a seeded ``tx_hook`` corruption yields a
  span marked degraded with the reason (``crc`` / ``seq-gap``), not a
  silently missing or miswired request.

Runs on the 8 simulated host devices from ``conftest.py``.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.fabric import Fabric, FabricConfig
from repro.obs import (
    FrameAttribution,
    SpanTracker,
    TraceRecorder,
    tick_breakdown,
    validate_trace,
)

# ---------------------------------------------------------------------------
# flight recorder: exact arrive-step reconstruction + engine bit-identity
# ---------------------------------------------------------------------------


def _run_fabric(fused, routing, defect_after, n=8):
    fab = Fabric(n_ranks=n, config=FabricConfig(
        frame_phits=2, credits=2, routing=routing, qos_weights=(2, 1),
        fused=fused, defect_after=defect_after))
    boxes = [fab.mailbox(r) for r in range(n)]
    for s in range(n):
        for d in range(n):
            if s != d:
                boxes[s].send(d, bytes([s, d]) * 17, list_level=1 + (s % 2),
                              request_id=s * n + d)
    fab.exchange()
    rows = []
    for r in range(n):
        for dv in boxes[r].recv():
            assert dv.ok
            att = dv.attribution
            assert isinstance(att, FrameAttribution)
            # the telescoping identity: at every executed step the critical
            # frame was queued-waiting, stalled-eligible, or hopping
            assert att.wait + att.stall + att.total_transit \
                == att.arrive_step == dv.arrive_step, (r, dv.src)
            # rid correlation through the (src, dst, seq) route-word range
            assert dv.request_id == dv.src * n + r
            rows.append((r, dv.src, dv.request_id, att))
    return sorted(rows, key=lambda t: t[:3])


@pytest.mark.parametrize("routing,defect_after", [
    ("dimension", 0), ("shortest", 0), ("shortest", 2),
])
def test_attribution_reconstructs_arrive_step_exactly(routing, defect_after):
    """Every delivery's flight-recorder vector sums to its arrive step
    exactly, and the fused and three-program engines agree bit-for-bit."""
    fused_rows = _run_fabric(True, routing, defect_after)
    three_rows = _run_fabric(False, routing, defect_after)
    assert len(fused_rows) == 8 * 7
    assert fused_rows == three_rows  # attribution is engine-invariant


def test_attribution_components_and_histograms():
    """The component dict drives the per-class ``fabric.attr.*``
    histograms, and a congested workload actually records nonzero wait."""
    fab = Fabric(n_ranks=8, config=FabricConfig(
        frame_phits=2, credits=2, qos_weights=(2, 1)))
    boxes = [fab.mailbox(r) for r in range(8)]
    for s in range(1, 8):
        boxes[s].send(0, bytes([s]) * 40, list_level=1 + (s % 2),
                      request_id=100 + s)  # hotspot: everyone dogpiles rank 0
    fab.exchange()
    got = boxes[0].recv()
    assert len(got) == 7
    comp_sum = {}
    for dv in got:
        comps = dv.attribution.components()
        assert set(comps) == {"queue_wait", "stall", "transit", "defections"}
        assert comps["queue_wait"] + comps["stall"] + comps["transit"] \
            == dv.arrive_step
        for k, v in comps.items():
            comp_sum[k] = comp_sum.get(k, 0) + v
    assert comp_sum["transit"] > 0
    # under a 7-to-1 hotspot with 2 credits the later frames MUST have
    # waited or stalled somewhere
    assert comp_sum["queue_wait"] + comp_sum["stall"] > 0
    names = {k for k in fab.metrics.flat() if k.startswith("fabric.attr.")}
    for leg in ("queue_wait", "stall", "transit", "defections"):
        assert any(k.startswith(f"fabric.attr.{leg}") for k in names), leg


# ---------------------------------------------------------------------------
# spans: telescoping breakdown + degradation under seeded faults
# ---------------------------------------------------------------------------


def test_tick_breakdown_telescopes_exactly():
    sp = SpanTracker()
    sp.set_tick(0)
    rid = sp.start("request", cls=1)
    sp.event(rid, "serve.ingress")
    sp.set_tick(2)
    sp.event(rid, "batcher.admit")
    sp.set_tick(5)
    sp.event(rid, "stream.first_flush")
    sp.set_tick(6)
    sp.event(rid, "serve.first_token")
    sp.finish(rid)
    bd = tick_breakdown(sp.get(rid))
    assert bd == {"admit_wait": 2, "decode": 3, "return": 1, "ttft_ticks": 6}
    assert sum(v for k, v in bd.items() if k != "ttft_ticks") \
        == bd["ttft_ticks"]
    # a skipped mark merges its delta into the next, still telescoping
    rid2 = sp.start("request")
    sp.set_tick(6)
    sp.event(rid2, "serve.ingress")
    sp.set_tick(9)
    sp.event(rid2, "serve.first_token")
    bd2 = tick_breakdown(sp.get(rid2))
    assert sum(v for k, v in bd2.items() if k != "ttft_ticks") \
        == bd2["ttft_ticks"] == 3


def test_unknown_rid_surfaces_as_anomaly_never_raises():
    sp = SpanTracker()
    sp.event(999, "batcher.admit")
    sp.degrade(999, "crc")
    sp.add_component(999, "fabric.transit", 1)
    assert len(sp.anomalies) == 3
    assert all(a["name"] == "span.unknown_rid" for a in sp.anomalies)


def _send_multiframe(tx_hook):
    """One multi-frame message rank 1 -> rank 0 through a seeded fault."""
    fab = Fabric(n_ranks=4, config=FabricConfig(frame_phits=2, credits=4))
    spans = SpanTracker()
    fab.spans = spans
    rid = spans.start("request", req=0)
    fab.tx_hook = tx_hook
    fab.mailbox(1).send(0, bytes(range(64)), request_id=rid)
    fab.exchange()
    return spans, rid, fab.mailbox(0).recv()


def test_seeded_payload_corruption_degrades_span_with_crc():
    """Satellite: a tx_hook flipping payload bits of a NON-FIRST frame
    must yield a delivery still correlated to its request id, with the
    span degraded ``crc`` — never silently missing or miswired."""
    def corrupt(tx, tx_valid):
        tx = np.array(tx)
        assert int(np.asarray(tx_valid)[1].sum()) >= 2, \
            "need a multi-frame send"
        tx[1, 1, 5] ^= 0xFF  # payload phit of the second frame
        return tx

    spans, rid, got = _send_multiframe(corrupt)
    assert len(got) == 1 and not got[0].ok
    assert got[0].request_id == rid  # first frame intact -> still matched
    span = spans.get(rid)
    assert span.degraded and "crc" in span.reasons
    assert not spans.anomalies


def test_seeded_seq_rewrite_degrades_span_with_seq_gap():
    """Satellite: rewriting a non-first frame's seq field creates a frame
    sequence gap; the span is degraded ``seq-gap``, still correlated."""
    from repro.fabric.frames import HDR_ROUTE

    def skip_seq(tx, tx_valid):
        tx = np.array(tx)
        w = int(tx[1, 1, HDR_ROUTE])
        tx[1, 1, HDR_ROUTE] = (w & ~0xFFFF) | ((w + 5) & 0xFFFF)
        return tx

    spans, rid, got = _send_multiframe(skip_seq)
    assert len(got) == 1 and not got[0].ok
    assert got[0].request_id == rid
    span = spans.get(rid)
    assert span.degraded and "seq-gap" in span.reasons


# ---------------------------------------------------------------------------
# streaming serve: end-to-end arcs, TTFT identity, byte invisibility
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serve_setup():
    from repro.configs import get_config, smoke_config
    from repro.launch.serve import encode_request
    from repro.models import init_params

    cfg = dataclasses.replace(smoke_config(get_config("yi-6b")), n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    wires = []
    for r in range(3):
        prompts = [
            list(map(int, rng.integers(2, cfg.vocab, int(rng.integers(8, 16)))))
            for _ in range(int(rng.integers(1, 3)))
        ]
        wires.append(encode_request(r, prompts))
    return params, cfg, wires


def test_streaming_serve_spans_end_to_end(serve_setup):
    """One span per request wire, closed, undegraded, with the tick
    breakdown telescoping to TTFT exactly and the on-device fabric
    components attached — and attaching the tracker + trace changes zero
    response bytes."""
    from repro.launch.serve import serve_requests_streaming

    params, cfg, wires = serve_setup
    kw = dict(max_new=4, pad_to=8, slots=4, n_shards=2)
    plain = serve_requests_streaming(params, cfg, wires, **kw)
    trace = TraceRecorder()
    spans = SpanTracker(trace)
    observed = serve_requests_streaming(
        params, cfg, wires, trace=trace, spans=spans, **kw)
    assert observed == plain  # tracing must never touch tokens

    reqs = spans.requests()
    assert len(reqs) == len(wires)
    for span in reqs:
        assert span.done and not span.degraded, span.rid
        bd = tick_breakdown(span)
        # every serve tick mark was hit, and the components telescope
        assert {"admit_wait", "ttft_ticks"} <= set(bd)
        assert sum(v for k, v in bd.items() if k != "ttft_ticks") \
            == bd["ttft_ticks"]
        assert span.first_tick("serve.ingress") == 0
        assert span.first_tick("serve.first_token") == bd["ttft_ticks"]
        # the request wire's fabric legs rode along (flight recorder)
        assert "fabric.transit" in span.components
        assert span.components["fabric.queue_wait"] \
            + span.components["fabric.stall"] >= 0
        names = [e.name for e in span.events]
        for must in ("serve.ingress", "fabric.deliver", "batcher.admit",
                     "stream.first_flush", "serve.first_token",
                     "batcher.evict", "request.done"):
            assert must in names, (span.rid, must)
    assert not spans.anomalies

    # the trace renders each request as one connected flow arc: an origin
    # ("s"), steps ("t") and a terminus ("f") all sharing the span id
    obj = trace.to_json()
    assert validate_trace(obj) == []
    flows = [e for e in obj["traceEvents"]
             if e.get("cat") == "span" and e.get("ph") in "stf"]
    by_rid = {}
    for e in flows:
        by_rid.setdefault(e["id"], set()).add(e["ph"])
    assert set(by_rid) == {s.rid for s in reqs}
    assert all(phs == {"s", "t", "f"} for phs in by_rid.values())

    # the export round-trips through JSON and carries the breakdowns
    export = json.loads(json.dumps(spans.export()))
    assert len(export["requests"]) == len(wires)
    assert all(r["breakdown"]["ttft_ticks"] >= 1 for r in export["requests"])


def test_streaming_serve_trace_auto_creates_spans(serve_setup):
    """Passing only a trace still traces requests (SpanTracker is
    auto-created) — the CLI's --trace-out gets flow arcs for free."""
    from repro.launch.serve import serve_requests_streaming

    params, cfg, wires = serve_setup
    trace = TraceRecorder()
    serve_requests_streaming(params, cfg, wires, max_new=4, pad_to=8,
                             slots=4, n_shards=2, trace=trace)
    assert any(e.get("ph") == "s" and e.get("cat") == "span"
               for e in trace.events)
