"""Property tests: the FSM engines against the software oracles.

Invariants (paper §III, §IV):
 * sw2hw: DesFSM(ser_sw_to_hw(msg)) emits exactly msg_to_des_tokens(msg).
 * hw2sw: SerFSM emits the trailing-count wire; des_hw_to_sw parses it back.
 * hw2hw: SerFSM -> frames -> DesFSM is identity on token streams for any
   frame size >= 1 phit.
 * tokens_to_msg inverts msg_to_des_tokens.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    ClientSchema, DesFSM, Schema, SerFSM, build_rom,
    des_hw_to_sw, des_sw_oracle, msg_to_des_tokens, random_message,
    ser_hw_to_sw_reference, ser_sw_to_hw, strip_for_ser, tokens_to_msg,
)

# ---------------------------------------------------------------------------
# hypothesis strategies: random schemas + conforming messages
# ---------------------------------------------------------------------------

_FIELD_NAMES = [f"f{i}" for i in range(8)]


@st.composite
def schema_type(draw, depth):
    kinds = ["bytes"] * 3 + (["array", "list", "struct"] if depth < 3 else [])
    k = draw(st.sampled_from(kinds))
    if k == "bytes":
        return ["Bytes", draw(st.sampled_from([1, 2, 4, 8, 16]))]
    if k == "array":
        return ["Array", draw(schema_type(depth + 1))]
    if k == "list":
        return ["List", draw(schema_type(depth + 1))]
    return ["Struct", "S%d" % (depth + 1)]  # S1..S3 are defined below


@st.composite
def schemas(draw):
    # build referenced structs S1..S3 bottom-up so references resolve
    obj = {}
    for d in (3, 2, 1):
        nf = draw(st.integers(1, 3))
        obj[f"S{d}"] = [
            [f"g{d}_{i}",
             ["Bytes", draw(st.sampled_from([1, 2, 4]))] if d == 3
             else draw(schema_type(d))]
            for i in range(nf)
        ]
    nf = draw(st.integers(1, 4))
    fields = [[_FIELD_NAMES[i], draw(schema_type(0))] for i in range(nf)]
    obj = {"Msg": fields, **obj}
    return Schema.from_json(obj)


def tok_tuple(ts):
    return [(t.kind, t.value, t.tag) for t in ts]


@settings(max_examples=60, deadline=None)
@given(schemas(), st.integers(0, 2**32 - 1))
def test_sw2hw_des_matches_oracle(schema, seed):
    rng = np.random.default_rng(seed)
    msg = random_message(schema, rng, max_elems=4)
    wire = ser_sw_to_hw(schema, msg)
    assert des_sw_oracle(schema, wire) == msg
    rom = build_rom(schema)
    res = DesFSM(rom, "sw2hw").run(wire)
    assert tok_tuple(res.tokens) == tok_tuple(msg_to_des_tokens(schema, msg))
    assert tokens_to_msg(schema, res.tokens) == msg


@settings(max_examples=60, deadline=None)
@given(schemas(), st.integers(0, 2**32 - 1))
def test_hw2sw_ser_and_reverse_parse(schema, seed):
    rng = np.random.default_rng(seed)
    msg = random_message(schema, rng, max_elems=4)
    rom = build_rom(schema)
    toks = strip_for_ser(msg_to_des_tokens(schema, msg))
    res = SerFSM(rom, "hw2sw").run(toks)
    assert res.wire == ser_hw_to_sw_reference(schema, msg)
    assert des_hw_to_sw(schema, res.wire) == msg


@settings(max_examples=60, deadline=None)
@given(schemas(), st.integers(0, 2**32 - 1), st.sampled_from([1, 2, 5, 500]))
def test_hw2hw_loopback(schema, seed, frame_phits):
    rng = np.random.default_rng(seed)
    msg = random_message(schema, rng, max_elems=4)
    rom = build_rom(schema)
    oracle = msg_to_des_tokens(schema, msg)
    ser = SerFSM(rom, "hw2hw", frame_phits=frame_phits).run(strip_for_ser(oracle))
    des = DesFSM(rom, "hw2hw").run(ser.wire)
    assert tok_tuple(des.tokens) == tok_tuple(oracle)


@settings(max_examples=40, deadline=None)
@given(schemas(), st.integers(0, 2**32 - 1))
def test_client_schema_tags_propagate(schema, seed):
    from repro.core import all_token_paths
    rng = np.random.default_rng(seed)
    msg = random_message(schema, rng, max_elems=3)
    paths = all_token_paths(schema)
    client = ClientSchema({p: i for i, p in enumerate(paths)})
    rom = build_rom(schema, client)
    wire = ser_sw_to_hw(schema, msg)
    res = DesFSM(rom, "sw2hw").run(wire)
    oracle = msg_to_des_tokens(schema, msg, client)
    assert tok_tuple(res.tokens) == tok_tuple(oracle)
    # every token now carries a real tag
    assert all(t.tag >= 0 for t in res.tokens)


# ---------------------------------------------------------------------------
# paper fig. 3/4 worked examples
# ---------------------------------------------------------------------------


def test_paper_fig3_des_example():
    schema = Schema.from_json({
        "Msg": [["a", ["Bytes", 2]], ["b", ["Bytes", 2]], ["c", ["Bytes", 4]]],
    })
    client = ClientSchema.from_json({"a": 0, "b": 1, "c": 2})
    rom = build_rom(schema, client)
    wire = (0x1234).to_bytes(2, "little") + (0x5678).to_bytes(2, "little") + \
           (0xDEADBEEF).to_bytes(4, "little")
    res = DesFSM(rom, "sw2hw", phit_bytes=4).run(wire)
    assert [(t.value, t.tag) for t in res.tokens] == [
        (0x1234, 0), (0x5678, 1), (0xDEADBEEF, 2)]


def test_paper_token_stream_example():
    """§III-C1: list a with one element, inner array with two elements."""
    schema = Schema.from_json({
        "Msg": [["a", ["List", ["Array", ["Struct", "Tuple"]]]],
                 ["b", ["Bytes", 1]]],
        "Tuple": [["x", ["Bytes", 4]], ["y", ["Bytes", 8]]],
    })
    client = ClientSchema.from_json({"a.elem.end": 5})  # array-end emitted
    msg = {"a": [[{"x": 1, "y": 2}, {"x": 3, "y": 4}]], "b": 9}
    toks = msg_to_des_tokens(schema, msg, client)
    from repro.core import (TOK_ARRAY_END, TOK_ARRAY_LENGTH, TOK_DATA,
                            TOK_LIST_BEGIN, TOK_LIST_END)
    kinds = [t.kind for t in toks]
    assert kinds == [
        TOK_LIST_BEGIN,      # a.list-begin
        TOK_ARRAY_LENGTH,    # a[0].array-length
        TOK_DATA, TOK_DATA,  # a[0][0].x .y
        TOK_DATA, TOK_DATA,  # a[0][1].x .y
        TOK_ARRAY_END,       # a[0].array-end
        TOK_LIST_END,        # a.list-end
        TOK_DATA,            # b
    ]
    rom = build_rom(schema, client)
    res = DesFSM(rom, "sw2hw").run(ser_sw_to_hw(schema, msg))
    assert tok_tuple(res.tokens) == tok_tuple(toks)


def test_framing_ambiguity_schema_fig12():
    """The paper's Fig. 12 nested-list disambiguation cases (§IV-C)."""
    schema = Schema.from_json({
        "Msg": [["a", ["Bytes", 4]],
                 ["b", ["List", ["Struct", "Foo"]]],
                 ["d", ["Bytes", 4]]],
        "Foo": [["c", ["List", ["Bytes", 4]]]],
    })
    rom = build_rom(schema)
    for msg in (
        {"a": 1, "b": [], "d": 2},                                  # case 1
        {"a": 1, "b": [{"c": []}], "d": 2},                         # case 2
        {"a": 1, "b": [{"c": [7, 8]}], "d": 2},                     # case 3
        {"a": 1, "b": [{"c": [7]}, {"c": []}, {"c": [1, 2, 3]}], "d": 2},
    ):
        oracle = msg_to_des_tokens(schema, msg)
        ser = SerFSM(rom, "hw2hw", frame_phits=1).run(strip_for_ser(oracle))
        des = DesFSM(rom, "hw2hw").run(ser.wire)
        assert tok_tuple(des.tokens) == tok_tuple(oracle), msg
