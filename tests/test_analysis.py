"""repro.analysis: the static schema + fabric analyzer.

Three obligations (ISSUE 6 acceptance criteria):

* **shipped targets are clean** — every schema, fabric config, bench
  demand, and model config the repo ships analyzes with zero findings;
* **seeded-bad corpus** — each known-bad fixture triggers exactly its
  expected rule id (no false positives, no misses);
* **oracle agreement** — the static load matrix and bounds the analyzer
  computes match what ``Router.plan_steps`` derives (by construction) AND
  what an independent per-frame path walk counts (non-tautological), and
  any demand the analyzer passes delivers cleanly on a real fabric.
"""
from __future__ import annotations

import json
from types import SimpleNamespace

import numpy as np
import pytest

from repro.analysis import (
    RULES,
    Severity,
    analyze_plan_caps,
    analyze_schema,
    assert_clean,
    list_level_error,
    max_ranks_error,
    message_wire_len,
    wire_bounds,
)
from repro.analysis.comm import (
    DIR_BWD,
    DIR_FWD,
    LinkLoad,
    bounds_from_loads,
    demand_link_loads,
)
from repro.analysis.config_passes import analyze_model_config
from repro.analysis.fabric_passes import (
    analyze_demand,
    analyze_fabric_values,
)
from repro.analysis.targets import (
    demand_targets,
    fabric_targets,
    model_config_targets,
    schema_targets,
)
from repro.core import DesFSM, Schema, build_rom, ser_sw_to_hw, tokens_to_msg
from repro.core.idl import ClientSchema, SchemaError
from repro.core.schema_tree import ROM_CAPACITY, STACK_CAPACITY
from repro.fabric import FabricConfig
from repro.fabric.router import Router


def _rules(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# shipped targets: zero findings
# ---------------------------------------------------------------------------


def test_shipped_schemas_clean():
    for loc, schema, client, caps in schema_targets():
        fs = analyze_schema(schema, client=client, caps=caps, location=loc)
        assert fs == [], f"{loc}: {[f.render() for f in fs]}"


def test_shipped_fabric_configs_clean():
    for loc, kw in fabric_targets():
        fs = analyze_fabric_values(location=loc, **kw)
        assert fs == [], f"{loc}: {[f.render() for f in fs]}"


def test_shipped_demands_clean():
    for loc, sizes, cfg_kw, srcs, dsts, counts, levels in demand_targets():
        cfg = FabricConfig(**cfg_kw)
        _, fs = analyze_demand(sizes, cfg, srcs, dsts, counts,
                               levels=levels, location=loc)
        assert fs == [], f"{loc}: {[f.render() for f in fs]}"


def test_shipped_model_configs_clean():
    for loc, cfg in model_config_targets():
        fs = analyze_model_config(cfg, location=loc)
        assert fs == [], f"{loc}: {[f.render() for f in fs]}"


# ---------------------------------------------------------------------------
# seeded-bad corpus: each fixture -> exactly its expected rule id
# ---------------------------------------------------------------------------

GOOD = {"M": [["x", ["Bytes", 4]]]}


def _deep_schema(depth, inner=("Bytes", 4), kind="List"):
    t = list(inner)
    for _ in range(depth):
        t = [kind, t]
    return Schema.from_json({"M": [["x", t]]})


def test_bad_undefined_struct():
    # raw construction: from_json would refuse this at the door
    from repro.core.idl import StructRef
    s = Schema({"M": [("x", StructRef("Ghost"))]}, top="M")
    assert _rules(analyze_schema(s)) == ["schema-undefined-struct"]


def test_bad_recursive_struct():
    from repro.core.idl import StructRef
    s = Schema({"M": [("x", StructRef("M"))]}, top="M")
    assert _rules(analyze_schema(s)) == ["schema-recursive"]


def test_bad_unreachable_struct_warns():
    s = Schema.from_json({
        "M": [["x", ["Bytes", 4]]],
        "Dead": [["y", ["Bytes", 1]]],
    })
    fs = analyze_schema(s)
    assert _rules(fs) == ["schema-unreachable-struct"]
    assert all(f.severity is Severity.WARN for f in fs)


def test_bad_empty_struct():
    from repro.core.idl import StructRef
    s = Schema({"M": [("x", StructRef("E"))], "E": []}, top="M")
    assert _rules(analyze_schema(s)) == ["schema-empty-struct"]


def test_bad_stack_depth():
    s = _deep_schema(STACK_CAPACITY + 1)
    assert "schema-stack-depth" in _rules(analyze_schema(s))
    assert analyze_schema(_deep_schema(STACK_CAPACITY - 1)) == []


def test_bad_rom_capacity():
    s = Schema.from_json({
        "M": [[f"f{i}", ["Bytes", 1]] for i in range(ROM_CAPACITY + 1)],
    })
    assert _rules(analyze_schema(s)) == ["schema-rom-capacity"]


def test_bad_client_tag_collision_and_unknown_path():
    s = Schema.from_json(GOOD)
    c = ClientSchema({"x": 1, "ghost": 1})
    rules = _rules(analyze_schema(s, client=c))
    assert rules == ["client-tag-collision", "client-unknown-path"]


def test_bad_plan_caps():
    s = Schema.from_json({
        "M": [["lst", ["List", ["List", ["Bytes", 4]]]]],
    })
    fs = analyze_plan_caps(s, {"lst": 8, "lst.elem": 4})
    assert _rules(fs) == ["plan-cap-overflow"]
    fs = analyze_plan_caps(s, {"lst": 2 ** 32})
    assert _rules(fs) == ["plan-cap-count-width"]
    assert analyze_plan_caps(s, {"lst": 8, "lst.elem": 64}) == []


def test_bad_credit_deadlock():
    fs = analyze_fabric_values(credits=2, qos_weights=(1, 1, 1))
    assert _rules(fs) == ["fabric-credit-deadlock"]
    # runtime construction raises the same message
    with pytest.raises(ValueError, match=fs[0].message[:40]):
        FabricConfig(credits=2, qos_weights=(1, 1, 1))


def test_bad_qos_quota_floor_warns():
    fs = analyze_fabric_values(credits=4, qos_weights=(8, 1, 1))
    assert _rules(fs) == ["fabric-qos-quota-floor"]
    assert all(f.severity is Severity.WARN for f in fs)
    # WARN only: the config still constructs
    FabricConfig(credits=4, qos_weights=(8, 1, 1))


def test_bad_defect_bound_warns():
    fs = analyze_fabric_values(credits=2, defect_after=8, sizes=(8,))
    assert _rules(fs) == ["fabric-defect-bound"]
    assert all(f.severity is Severity.WARN for f in fs)


def test_bad_max_ranks():
    fs = analyze_fabric_values(n_ranks=129)
    assert _rules(fs) == ["fabric-max-ranks"]
    assert analyze_fabric_values(n_ranks=128) == []
    # sizes multiply into the rank count
    assert _rules(analyze_fabric_values(sizes=(16, 16))) == [
        "fabric-max-ranks"
    ]


def test_bad_demand_rules():
    cfg = FabricConfig(frame_phits=16, credits=4)
    _, fs = analyze_demand((8,), cfg, [0], [9], [1])
    assert _rules(fs) == ["fabric-rank-range"]
    _, fs = analyze_demand((8,), cfg, [0], [1], [1], levels=[300])
    assert _rules(fs) == ["fabric-list-level"]
    _, fs = analyze_demand((8,), cfg, [0], [1], [1 << 16])
    assert _rules(fs) == ["fabric-seq-window"]
    cfg_rx = FabricConfig(frame_phits=16, credits=4, rx_frames=2)
    _, fs = analyze_demand((8,), cfg_rx, [0, 2], [1, 1], [2, 2])
    assert _rules(fs) == ["fabric-rx-overflow"]
    _, fs = analyze_demand((8,), cfg_rx, [0], [1], [2])
    assert fs == []


# ---------------------------------------------------------------------------
# satellites: deduplicated validation, from_json validating
# ---------------------------------------------------------------------------


def test_max_ranks_messages_identical():
    """Fabric and Router raise the SAME shared-rule message."""
    from repro.fabric import Fabric

    def stub(n):
        return SimpleNamespace(axis_names=("fx",), shape={"fx": n})

    with pytest.raises(ValueError) as e_fab:
        Fabric(n_ranks=129)
    with pytest.raises(ValueError) as e_router:
        Router(stub(129))
    assert str(e_fab.value) == str(e_router.value) == max_ranks_error(129)
    assert max_ranks_error(128) is None


def test_list_level_send_uses_shared_rule(fabric8):
    box = fabric8.mailbox(0)
    with pytest.raises(ValueError) as e:
        box.send(1, b"payload", list_level=256)
    assert str(e.value) == list_level_error(256)
    assert list_level_error(0) is None and list_level_error(255) is None
    assert list_level_error(True) is not None  # bools are not levels


def test_fabric_config_messages_identical():
    """Every ERROR FabricConfig refuses carries the analyzer's words."""
    bad = [
        dict(frame_phits=0),
        dict(credits=0),
        dict(routing="fastest"),
        dict(defect_after=-1),
        dict(routing="dimension", defect_after=2),
        dict(qos_weights=(0, 1)),
        dict(credits=1, qos_weights=(1, 1)),
    ]
    for kw in bad:
        fs = [f for f in analyze_fabric_values(**kw)
              if f.severity is Severity.ERROR]
        assert fs, kw
        with pytest.raises(ValueError) as e:
            FabricConfig(**kw)
        assert str(e.value) == fs[0].message, kw


def test_client_schema_from_json_validates_tags():
    with pytest.raises(SchemaError, match="shared by paths"):
        ClientSchema.from_json({"a": 1, "b": 1})
    ClientSchema.from_json({"a": 1, "b": 2})  # unique tags pass


def test_schema_from_json_validates():
    with pytest.raises(SchemaError):
        Schema.from_json({"M": [["x", ["Struct", "Ghost"]]]})


def test_fsm_step_bound_shared():
    from repro.core.fsm import fsm_step_bound

    rom = build_rom(Schema.from_json(GOOD))
    assert fsm_step_bound(rom, 10) == 8 * 10 + 64 * rom.n_nodes + 64


def test_chunk_token_check_shared():
    from repro.stream.chunks import (
        MAX_CHUNK_TOKENS,
        check_chunk_tokens,
        encode_token_chunk,
    )

    check_chunk_tokens(MAX_CHUNK_TOKENS - 1)
    with pytest.raises(ValueError, match="exceeds"):
        check_chunk_tokens(MAX_CHUNK_TOKENS)
    with pytest.raises(ValueError, match="exceeds"):
        encode_token_chunk(0, 0, list(range(MAX_CHUNK_TOKENS)))


# ---------------------------------------------------------------------------
# oracle agreement: analyzer loads == plan_steps == brute-force path walk
# ---------------------------------------------------------------------------


def _stub_mesh(sizes, names=None):
    names = names or tuple(f"ax{i}" for i in range(len(sizes)))
    return SimpleNamespace(axis_names=names, shape=dict(zip(names, sizes)))


def _walk_loads(sizes, srcs, dsts, counts, adaptive):
    """Independent ground truth: walk every frame's dimension-ordered
    path, counting frames and max hops per (axis, ring, direction) from
    the coordinates alone — no shared code with comm.demand_link_loads."""
    loads = [dict() for _ in sizes]
    strides = [int(np.prod(sizes[i + 1:], dtype=int))
               for i in range(len(sizes))]

    def coords(r):
        return [(r // strides[i]) % sizes[i] for i in range(len(sizes))]

    for s, d, cnt in zip(srcs, dsts, counts):
        if cnt == 0:
            continue
        cur = coords(s)
        dst_c = coords(d)
        for ai, n in enumerate(sizes):
            fwd = (dst_c[ai] - cur[ai]) % n
            if fwd == 0:
                continue
            if adaptive and fwd > n // 2:
                direction, hops = DIR_BWD, n - fwd
            else:
                direction, hops = DIR_FWD, fwd
            # ring = the rank's other coordinates while crossing axis ai
            fixed = list(cur)
            fixed[ai] = 0
            done = sum(c * st for c, st in zip(fixed, strides))
            ring = (done // (strides[ai] * n), done % strides[ai])
            key = (ring, direction)
            prev = loads[ai].get(key, LinkLoad(0, 0))
            loads[ai][key] = LinkLoad(prev.frames + cnt,
                                      max(prev.max_hops, hops))
            cur[ai] = dst_c[ai]  # axis done; move on dimension-ordered
    return tuple(loads)


@pytest.mark.parametrize("sizes", [(8,), (4, 2)])
@pytest.mark.parametrize("adaptive", [True, False])
def test_load_matrix_matches_brute_force(sizes, adaptive):
    rng = np.random.default_rng(7)
    n = int(np.prod(sizes))
    srcs = rng.integers(0, n, 64).tolist()
    dsts = rng.integers(0, n, 64).tolist()
    counts = rng.integers(0, 5, 64).tolist()
    got = demand_link_loads(sizes, srcs, dsts, counts, adaptive)
    want = _walk_loads(sizes, srcs, dsts, counts, adaptive)
    assert got == want


@pytest.mark.parametrize("sizes", [(8,), (4, 2)])
def test_plan_steps_composes_analyzer(sizes):
    """plan_steps == bounds_from_loads(demand_link_loads(...)) for every
    config mode — the by-construction half of the oracle."""
    rng = np.random.default_rng(11)
    n = int(np.prod(sizes))
    srcs = rng.integers(0, n, 32).tolist()
    dsts = rng.integers(0, n, 32).tolist()
    counts = rng.integers(0, 4, 32).tolist()
    for kw in (dict(), dict(routing="dimension"), dict(defect_after=2),
               dict(credits=1)):
        cfg = FabricConfig(frame_phits=16, **kw)
        r = Router(_stub_mesh(sizes), config=cfg)
        defect = cfg.defect_after if cfg.defection else 0
        loads = demand_link_loads(sizes, srcs, dsts, counts, cfg.adaptive)
        want = bounds_from_loads(loads, sizes, cfg.credits, defect,
                                 r.default_steps(sum(counts)))
        assert r.plan_steps(srcs, dsts, counts) == want


def test_bench_demand_loads_match_plan_steps():
    """Acceptance criterion: on the deterministic bench_fabric workloads,
    the communication pass's load matrix IS what plan_steps derives its
    bounds from (checked via the brute-force walker too)."""
    for loc, sizes, cfg_kw, srcs, dsts, counts, levels in demand_targets():
        cfg = FabricConfig(**cfg_kw)
        loads, fs = analyze_demand(sizes, cfg, srcs, dsts, counts,
                                   levels=levels, location=loc)
        assert fs == []
        assert loads == _walk_loads(sizes, srcs, dsts, counts,
                                    cfg.adaptive), loc
        r = Router(_stub_mesh(sizes), config=cfg)
        defect = cfg.defect_after if cfg.defection else 0
        assert r.plan_steps(srcs, dsts, counts) == bounds_from_loads(
            loads, sizes, cfg.credits, defect,
            r.default_steps(sum(counts)),
        ), loc


# ---------------------------------------------------------------------------
# analyzer-pass => runtime-clean (property test, seeded; hypothesis when
# available)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fabric8():
    from repro.fabric import Fabric

    return Fabric(config=FabricConfig(frame_phits=2, credits=2))


def test_analyzer_pass_implies_delivery(fabric8):
    """Any random demand the analyzer passes delivers cleanly (ok=True,
    right bytes) through a real 8-rank fabric."""
    n = fabric8.n_ranks
    rng = np.random.default_rng(3)
    for trial in range(4):
        sends = []
        for _ in range(int(rng.integers(1, 9))):
            src, dst = int(rng.integers(0, n)), int(rng.integers(0, n))
            wire = rng.integers(0, 256, int(rng.integers(1, 65)),
                                dtype=np.uint8).tobytes()
            sends.append((src, dst, wire, int(rng.integers(0, 4))))
        from repro.analysis.fabric_passes import analyze_sends

        _, fs = analyze_sends((n,), fabric8.config, sends)
        assert_clean(fs, f"trial {trial}")  # analyzer passes it...
        for s, d, w, lvl in sends:
            fabric8.send(s, d, w, list_level=lvl)
        fabric8.exchange()  # ...so the runtime must deliver it
        got = {}
        for r in range(n):
            for dv in fabric8.drain(r):
                assert dv.ok
                got.setdefault((dv.src, r), []).append(dv.wire)
        want = {}
        for s, d, w, _ in sends:
            want.setdefault((s, d), []).append(w)
        assert got == want


def test_analyzer_pass_implies_encode_roundtrip():
    """Any random schema+message the analyzer passes encodes and decodes
    cleanly through the SW SER -> HW DES -> client path."""
    rng = np.random.default_rng(5)

    def rand_type(depth):
        r = int(rng.integers(0, 3 if depth < 3 else 1))
        if r == 0:
            return ["Bytes", int(rng.integers(1, 9))]
        return [["List", "Array"][int(rng.integers(0, 2))],
                rand_type(depth + 1)]

    def rand_msg(t):
        if t[0] == "Bytes":  # leaf values are ints of the field's width
            raw = bytes(rng.integers(0, 256, t[1], dtype=np.uint8))
            return int.from_bytes(raw, "little")
        return [rand_msg(t[1]) for _ in range(int(rng.integers(0, 3)))]

    for _ in range(8):
        fields = [[f"f{i}", rand_type(0)]
                  for i in range(int(rng.integers(1, 4)))]
        schema = Schema.from_json({"M": fields})
        assert analyze_schema(schema) == []  # analyzer passes it...
        msg = {f: rand_msg(t) for f, t in fields}
        wire = ser_sw_to_hw(schema, msg)
        wb = wire_bounds(schema)
        assert wb.min_bytes <= len(wire)
        assert wb.max_bytes is None or len(wire) <= wb.max_bytes
        assert message_wire_len(schema, msg) == len(wire)
        res = DesFSM(build_rom(schema), "sw2hw").run(wire)
        out = tokens_to_msg(schema, res.tokens)
        assert out == msg  # ...so encode/deliver is clean


def test_analyzer_property_hypothesis():
    """The same property under hypothesis when the container has it
    (skipped otherwise — the seeded variants above always run)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.integers(1, 8), st.integers(1, 64))
    @hyp.settings(max_examples=20, deadline=None)
    def prop(nfields, width):
        schema = Schema.from_json(
            {"M": [[f"f{i}", ["Bytes", width]] for i in range(nfields)]}
        )
        assert analyze_schema(schema) == []
        assert wire_bounds(schema).min_bytes == nfields * width

    prop()


# ---------------------------------------------------------------------------
# runtime hooks + CLI
# ---------------------------------------------------------------------------


def test_fabric_analyze_hook_pre_dispatch(fabric8):
    """analyze=True fails a doomed tick BEFORE dispatch with the rule's
    fix hint (vs. the RuntimeError mid-flight without it)."""
    from repro.fabric import Fabric

    fab = Fabric(config=FabricConfig(frame_phits=2, credits=2, rx_frames=1),
                 analyze=True)
    box = fab.mailbox(0)
    box.send(1, b"x" * 64)
    box.send(1, b"y" * 64)  # > rx_frames=1 at rank 1: static overflow
    with pytest.raises(ValueError, match="fabric-rx-overflow"):
        fab.exchange()
    fab._pending = []  # drop the doomed sends


def test_fabric_analyze_warn_configs_still_construct():
    # quota-floor is WARN-severity: analyze=True re-checks the config at
    # construction but only ERRORs raise, so the fabric still builds
    from repro.fabric import Fabric

    fab = Fabric(config=FabricConfig(frame_phits=2, credits=4,
                                     qos_weights=(8, 1, 1)), analyze=True)
    assert fab.analyze


def test_cli_runs_clean(tmp_path):
    from repro.analysis.__main__ import main, run_all

    report = run_all()
    assert report.targets >= 40
    assert report.findings == []  # shipped targets: zero findings
    out = tmp_path / "f.json"
    assert main(["--strict", "--quiet", "--json", str(out)]) == 0
    data = json.loads(out.read_text())
    assert data["errors"] == 0 and data["warnings"] == 0
    assert set(data["rules"]) == set(RULES)


def test_rule_catalog_consistency():
    for rid, rule in RULES.items():
        assert rule.id == rid
        assert rule.proves and rule.hint
        assert rule.severity in (Severity.INFO, Severity.WARN,
                                 Severity.ERROR)


def test_serve_analyze_hook():
    """serve_requests_sharded(analyze=True) proves the serving schemas +
    fabric clean and arms the per-tick checks (smoke via _analyze_serve:
    a clean fabric passes, an armed fabric gets analyze=True)."""
    from repro.fabric import Fabric
    from repro.launch.serve import _analyze_serve

    fab = Fabric(config=FabricConfig(frame_phits=16, credits=4))
    _analyze_serve(fab, 4, "test")
    assert fab.analyze  # armed for per-tick demand analysis
    with pytest.raises(ValueError, match="stream-id-width"):
        _analyze_serve(fab, 1 << 16, "test")
