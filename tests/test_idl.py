"""IDL parsing / validation (paper §III-B)."""
import pytest

from repro.core import Schema, SchemaError, ClientSchema, all_token_paths
from repro.core.idl import Array, ListT, StructRef, parse_type


PAPER_SCHEMA = {
    "Msg": [["a", ["List", ["Array", ["Struct", "Tuple"]]]], ["b", ["Bytes", 1]]],
    "Tuple": [["x", ["Bytes", 4]], ["y", ["Bytes", 8]]],
}


def test_parse_paper_example():
    s = Schema.from_json(PAPER_SCHEMA)
    assert s.top == "Msg"
    a_type = dict(s.structs["Msg"])["a"]
    assert isinstance(a_type, ListT)
    assert isinstance(a_type.elem, Array)
    assert isinstance(a_type.elem.elem, StructRef)
    assert s.max_depth() == 2


def test_roundtrip_json():
    s = Schema.from_json(PAPER_SCHEMA)
    assert Schema.from_json(s.to_json()).to_json() == s.to_json()


@pytest.mark.parametrize("bad", [
    {},  # empty
    {"M": [["a", ["Bytes", 0]]]},  # zero width
    {"M": [["a", ["Bytes", -3]]]},
    {"M": [["a", ["Struct", "Nope"]]]},  # undefined struct
    {"M": [["a", ["Bytes", 4]], ["a", ["Bytes", 4]]]},  # dup field
    {"M": [["a", ["Weird", 4]]]},  # unknown constructor
])
def test_rejects_malformed(bad):
    with pytest.raises(SchemaError):
        Schema.from_json(bad)


def test_rejects_recursive():
    with pytest.raises(SchemaError):
        Schema.from_json({"M": [["a", ["Struct", "M"]]]})
    with pytest.raises(SchemaError):
        Schema.from_json({
            "M": [["a", ["Struct", "N"]]],
            "N": [["b", ["List", ["Struct", "M"]]]],
        })


def test_token_paths_and_client_schema():
    s = Schema.from_json(PAPER_SCHEMA)
    paths = set(all_token_paths(s))
    # the paper's Fig. 7 paths
    for p in ("a.start", "a.elem.start", "a.elem.elem.x", "a.elem.elem.y",
              "a.elem.end", "a.end", "b"):
        assert p in paths, p
    cs = ClientSchema.from_json({"a.start": 1, "a.elem.elem.x": 3})
    cs.validate_against(s)
    assert cs.tag_for("a.start") == 1
    assert cs.tag_for("b") == -1
    with pytest.raises(SchemaError):
        ClientSchema.from_json({"zzz.bogus": 1}).validate_against(s)


def test_parse_type_errors():
    with pytest.raises(SchemaError):
        parse_type(["Bytes"])
    with pytest.raises(SchemaError):
        parse_type(["Struct", 7])
