"""Optimizer: AdamW convergence, clipping, schedules, microbatch equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    AdamWConfig, adamw_init, adamw_update, clip_by_global_norm,
    cosine_schedule, global_norm, linear_warmup_cosine, microbatched_grads,
)


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    st = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=None)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, st, _ = adamw_update(g, st, params, cfg, cfg.lr)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_bf16_params_fp32_master():
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    st = adamw_init(params)
    assert st.master["w"].dtype == jnp.float32
    cfg = AdamWConfig(lr=1e-4, clip_norm=None, weight_decay=0.0)
    g = {"w": jnp.full(4, 1e-3, jnp.float32)}
    p1, st1, _ = adamw_update(g, st, params, cfg, cfg.lr)
    assert p1["w"].dtype == jnp.bfloat16
    # master moved even though bf16 param may round
    assert float(jnp.abs(st1.master["w"] - 1.0).max()) > 0


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    g2, n2 = clip_by_global_norm({"a": jnp.ones(2) * 0.1}, 1.0)
    np.testing.assert_allclose(np.asarray(g2["a"]), 0.1)


def test_schedules():
    lr = linear_warmup_cosine(1e-3, warmup=10, total_steps=110, min_frac=0.1)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.asarray(110))) >= 1e-4 - 1e-9
    cs = cosine_schedule(1.0, 100)
    assert float(cs(jnp.asarray(0))) == 1.0


def test_microbatched_grads_match_full_batch():
    k = jax.random.PRNGKey(0)
    W = jax.random.normal(k, (8, 4))
    batch = {"x": jax.random.normal(k, (6, 8)), "y": jax.random.normal(k, (6, 4))}

    def loss_fn(p, b):
        pred = b["x"] @ p["W"]
        l = jnp.mean((pred - b["y"]) ** 2)
        return l, {"loss": l}

    params = {"W": W}
    l1, g1, m1 = microbatched_grads(loss_fn, params, batch, 1)
    l3, g3, m3 = microbatched_grads(loss_fn, params, batch, 3)
    np.testing.assert_allclose(float(l1), float(l3), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g1["W"]), np.asarray(g3["W"]), rtol=1e-5)


def test_optstate_is_pytree():
    params = {"w": jnp.ones(3)}
    st = adamw_init(params)
    leaves = jax.tree.leaves(st)
    assert len(leaves) == 1 + 3  # step + mu/nu/master


def test_q8_moments_converge_like_fp32():
    """int8/bf16 moments (the 398B memory knob) track fp32 AdamW."""
    target = jnp.asarray(np.random.default_rng(0).normal(size=300).astype(np.float32))
    final = {}
    for moments in ("fp32", "q8"):
        params = {"w": jnp.zeros(300)}
        st = adamw_init(params, moments)
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0, clip_norm=None,
                          moments=moments)
        loss = lambda p: jnp.sum((p["w"] - target) ** 2)
        step = jax.jit(lambda p, s: adamw_update(jax.grad(loss)(p), s, p, cfg, cfg.lr)[:2])
        for _ in range(400):
            params, st = step(params, st)
        final[moments] = float(loss(params))
    assert final["q8"] < 1e-2, final
    # q8 memory: int8 blocks + bf16 nu
    st = adamw_init({"w": jnp.zeros(1000)}, "q8")
    assert st.mu["w"]["q"].dtype == jnp.int8
    assert st.nu["w"].dtype == jnp.bfloat16


def test_q8_train_quickstart_model():
    """q8 moments on a real (tiny) LM: loss falls over a few steps."""
    import dataclasses
    from repro.configs import get_config, smoke_config
    from repro.models import init_params, loss_fn
    cfg = dataclasses.replace(smoke_config(get_config("yi-6b")), n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab),
    }
    batch["labels"] = batch["tokens"]
    batch["loss_mask"] = jnp.ones((2, 32))
    ocfg = AdamWConfig(lr=3e-3, weight_decay=0.0, moments="q8")
    st = adamw_init(params, "q8")
    g_fn = jax.jit(jax.value_and_grad(lambda p: loss_fn(p, cfg, batch)[0]))
    l0, _ = g_fn(params)
    for _ in range(8):
        l, g = g_fn(params)
        params, st, _ = adamw_update(g, st, params, ocfg, ocfg.lr)
    l1, _ = g_fn(params)
    assert float(l1) < float(l0)
