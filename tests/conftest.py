import os

# Tests run on small fake-device counts (NOT 512 — that is dryrun-only).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
